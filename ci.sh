#!/usr/bin/env bash
# Executable verify recipe (ROADMAP "Tier-1 verify" + benchmark smoke).
#
#   ./ci.sh                 static analyzer, tier-1 test suite, then the
#                           benchmark smoke subset
#   ./ci.sh --fast          static analyzer + tier-1 test suite only
#   ./ci.sh --conformance   dispatch conformance matrix only: every
#                           dispatch_backend x ragged_a2a x sort_impl cell
#                           vs the dense oracle + the group-sort property
#                           suite + the hop-pipeline golden-equivalence
#                           matrix (bit-identical to the pre-refactor
#                           layers) and the options-registry / deprecation-
#                           shim checks (the targeted gate for dispatch
#                           and pipeline changes)
#   ./ci.sh --static        static analyzer only: trace-time SPMD/collective
#                           invariants over the entrypoint grid (cond branch
#                           congruence, axis names, int32 count boundaries,
#                           comm.py provenance), the Pallas kernel lint
#                           (VMEM budget, tile alignment, index-map bounds,
#                           dimension_semantics grid races) and the AST repo
#                           lint (options registry, kernel ops/ref twins,
#                           rogue lax collectives) — exits nonzero on any
#                           finding (the targeted gate for kernel, comm,
#                           and config-surface changes)
#   ./ci.sh --serve         serving gate only: paged-KV-cache + continuous-
#                           batching engine tests (allocator invariants,
#                           paged-vs-ring equivalence across page
#                           boundaries, dirty-page reuse, recompile
#                           determinism, scheduler starvation/determinism)
#                           plus one tiny Poisson trace through
#                           bench_serving --smoke — the targeted gate for
#                           serve/, paged-attention, and decode-path changes
#   ./ci.sh --faults        fault-contained-runtime gate only: the step
#                           sentinel (skip semantics, spike/non-finite
#                           verdicts, the gated ZeRO-1 apply), the hardened
#                           checkpoint rotation + resume bit-determinism,
#                           and the 8-device fault containment matrix
#                           (every faultinject kind x {switch, smile} x
#                           wire_integrity policy with exact event/drop/
#                           per-rank accounting) — the targeted gate for
#                           sentinel, checkpoint, and hop-hardening changes
#
# The tier-1 suite is the driver-enforced gate; the smoke step additionally
# compiles and runs one jitted round trip of every dispatch backend
# (dense / sort / dropless) and both group-sort impls so a backend that
# only breaks under jit is caught here rather than in a 20-minute bench run.
set -euo pipefail
cd "$(dirname "$0")"

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "--conformance" ]]; then
    echo "== dispatch conformance + pipeline golden-equivalence matrix =="
    python -m pytest -q tests/test_dispatch_conformance.py \
        tests/test_group_sort.py tests/test_pipeline_golden.py
    echo "CI OK (conformance)"
    exit 0
fi

if [[ "${1:-}" == "--static" ]]; then
    echo "== static analyzer =="
    python -m repro.launch.analyze
    echo "CI OK (static)"
    exit 0
fi

if [[ "${1:-}" == "--serve" ]]; then
    echo "== serving gate: paged KV cache + continuous batching =="
    python -m pytest -q tests/test_kvcache.py tests/test_serving.py \
        "tests/test_distributed.py::test_decode_equivalence"
    python -m benchmarks.bench_serving --smoke
    echo "CI OK (serve)"
    exit 0
fi

if [[ "${1:-}" == "--faults" ]]; then
    echo "== fault-contained runtime gate =="
    python -m pytest -q tests/test_sentinel.py tests/test_checkpoint.py \
        tests/test_distributed.py::test_fault_containment \
        tests/test_distributed.py::test_zero1_equivalence
    echo "CI OK (faults)"
    exit 0
fi

echo "== repo hygiene =="
if git ls-files '*.pyc' | grep -q .; then
    echo "ERROR: compiled bytecode is tracked (git ls-files '*.pyc'):" >&2
    git ls-files '*.pyc' >&2
    exit 1
fi

echo "== static analyzer =="
python -m repro.launch.analyze -q

echo "== tier-1 test suite =="
python -m pytest -x -q

if [[ "${1:-}" != "--fast" ]]; then
    echo "== benchmark smoke =="
    python -m benchmarks.run --smoke
fi

echo "CI OK"

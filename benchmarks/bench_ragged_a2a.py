"""Ragged vs capacity-padded All2All dispatch hops (EXPERIMENTS.md §Perf-4).

Times the full meshed switch MoE layer forward — router, dispatch, BOTH
All2All hops, expert FFN, combine — on an 8-fake-device mesh for three wire
strategies:

* ``sort@cf``          — capacity buffer on the wire AND into the FFN;
* ``dropless_pad@cf``  — capacity buffer on the wire, ragged re-compaction
  before the FFN (the pre-ragged dropless path, ``ragged_a2a=False``);
* ``ragged``           — exact tile-aligned segments on the wire via
  ``comm.ragged_all_to_all`` (no capacity factor: there is no capacity);
* ``ragged_rb@f``      — ragged wire PLUS the receive-bound factor
  (``MoEConfig.recv_bound_factor``, the hop-pipeline knob): the receive
  slab — and with it the post-hop re-compaction sort and recompacted FFN
  bound — shrinks from the worst-case ``P x R`` rows to
  ``~f x expected``, trading bounded clamp drops under extreme skew
  (``drop_frac`` is measured and reported; 0.0 at this benchmark's
  near-uniform routing) for the P-fold smaller compute bound.

Alongside wall time it reports per-hop WIRE BYTES two ways: *measured* from
the live routing (the actual per-destination segment counts the exchange
ships, aggregated over ranks, headers included) and *modeled* from
``benchmarks.cost_model.hop_wire_report`` — the measured-vs-modeled check
that keeps the cost model honest.

Honest caveat, recorded in the JSON: on this CPU container the ragged
exchange runs through the fused-slab emulation (jax < 0.4.38 has no
``lax.ragged_all_to_all``), whose equal-split collective ships the full
``P x R`` statically-bounded staging slab where real fabric moves only the
valid segments, and the worst-case receive bound inflates the recompacted
FFN the same way.  Wall-clock here therefore UNDERSTATES the ragged path;
wire bytes are the portable number (exact, from live counts), and
``modeled_step_ratio_*`` applies them to the Table-3-calibrated cost model.

Multi-device emulation needs its own XLA_FLAGS before jax initializes, so
``main()``/``run_smoke()`` re-exec this module as a ``--child`` subprocess.

Writes ``BENCH_ragged_a2a.json`` (skipped in ``--smoke``).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

D_MODEL = 128
D_FF = 256
ITERS = 10
WARMUP = 2
CFS = (1.25, 1.5, 2.0)
RB_FACTORS = (1.5, 2.0)       # recv_bound_factor cells (ragged wire)
# (local tokens per device, experts, k) on the 8-rank mesh — production-ish
# local shapes (high tokens-per-expert, the regime the dropless sweep
# documents as the win case)
SWEEP = [(2048, 8, 2), (4096, 8, 1)]
SMOKE_SWEEP = [(128, 8, 1)]


# =============================================================================
# child: runs under 8 fake devices
# =============================================================================

def _child(smoke: bool) -> None:
    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from benchmarks import cost_model
    from benchmarks.bench_dispatch import _time_interleaved
    from repro.common.config import MoEConfig
    from repro.core import dispatch as D
    from repro.core.moe import capacity, init_moe_params, moe_layer, \
        router_probs, topk_gates
    from repro.sharding.compat import make_mesh, shard_map
    from repro.sharding.plan import plan_from_mesh

    P_ = 8
    mesh = make_mesh((P_,), ("data",))
    plan = plan_from_mesh(mesh)
    assert plan.ep == P_
    bpe = 4                                    # fp32 on the CPU emulation
    sweep = SMOKE_SWEEP if smoke else SWEEP
    cfs = (1.25,) if smoke else CFS            # smoke: one cf, one compile each
    iters, warmup = (2, 1) if smoke else (ITERS, WARMUP)
    results = []

    for T_local, E, k in sweep:
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(jax.random.PRNGKey(1), (P_ * T_local, D_MODEL))

        def layer_fn(cfg):
            """One compiled (y, drop_frac) layer; the timing wrapper takes
            y, ``drop`` reads the stat off the SAME compiled function."""
            params = init_moe_params(key, cfg, D_MODEL, plan, glu=False)
            pspecs = {"experts": {"w1": P("data", None, None, None),
                                  "w2": P("data", None, None, None)},
                      "router": {"w": P(None, None)}}

            def f(p, xx):
                y, st = moe_layer(p, xx, cfg, plan, act="gelu")
                return y, st.drop_frac

            fsm = jax.jit(shard_map(f, mesh=mesh,
                                    in_specs=(pspecs, P("data", None)),
                                    out_specs=(P("data", None), P())))
            timed_fn = lambda xx: fsm(params, xx)[0]
            drop = lambda xx: float(fsm(params, xx)[1])
            return timed_fn, params, drop

        fns = {}
        cfg_r = MoEConfig(num_experts=E, top_k=k, d_ff_expert=D_FF,
                          router="switch", grid=(P_, 1),
                          renorm_gates=(k > 1), dispatch_backend="dropless")
        fns["ragged"], params_r, _ = layer_fn(cfg_r)
        rbs = RB_FACTORS[:1] if smoke else RB_FACTORS
        rb_drops = {}
        for rb in rbs:
            fns[f"ragged_rb{rb}"], _, rb_drops[rb] = layer_fn(
                dataclasses.replace(cfg_r, recv_bound_factor=rb))
        for cf in cfs:
            fns[f"sort@cf{cf}"], _, _ = layer_fn(dataclasses.replace(
                cfg_r, dispatch_backend="sort", capacity_factor=cf))
            fns[f"dropless_pad@cf{cf}"], _, _ = layer_fn(dataclasses.replace(
                cfg_r, ragged_a2a=False, capacity_factor=cf))
        timed = _time_interleaved(fns, (x,), iters=iters, warmup=warmup)

        # ---- measured wire bytes of the forward hop ------------------------
        # ragged: the actual per-destination aligned segment counts each rank
        # ships (grid (8,1): groups are already rank-major, one per rank)
        V = E  # h = E // P_ ... V = virtual_total = P_ * (E // P_)
        rw = params_r["router"]["w"]

        def counts_fn(xx):
            probs, _ = router_probs(xx, rw)
            _, eidx = topk_gates(probs, k, k > 1)
            n_local_g = V // P_
            _, starts, st = D.dispatch_ragged(xx, eidx.reshape(-1),
                                              jnp.ones((xx.shape[0] * k,),
                                                       jnp.float32),
                                              V, k=k)
            return D.ragged_send_counts(starts, n_local_g)[None], \
                jnp.int32(st.cap)[None]

        cm = jax.jit(shard_map(counts_fn, mesh=mesh,
                               in_specs=P("data", None),
                               out_specs=(P("data"), P("data"))))
        counts, blks = cm(x)
        counts = np.asarray(counts)                     # (P, P) [src, dst]
        block = int(np.asarray(blks)[0])
        off_diag_rows = int(counts.sum() - np.trace(counts))
        header = P_ * (P_ + V) * cost_model.BYTES_INT32
        ragged_measured = off_diag_rows * D_MODEL * bpe + header

        cap_rows = {cf: V * capacity(T_local, k, cf, V) for cf in cfs}
        padded_measured = {
            cf: int(P_ * cap_rows[cf] * (P_ - 1) / P_) * D_MODEL * bpe
            for cf in cfs}

        row = {"T_local": T_local, "E": E, "k": k, "block": block,
               "ragged_ms": timed["ragged"],
               "ragged_wire_bytes_measured": ragged_measured}

        # ---- wire-integrity parity overhead (EXPERIMENTS.md §Robust-2) -----
        # wire_integrity != off appends nl parity rows per (src, dst)
        # segment on the forward hop and 1 per peer on the reverse hop —
        # counted off-diagonal like the measured data bytes above.  The
        # overhead is routing-independent (a constant per-peer tax), so it
        # shrinks as tokens/device grow; acceptance bound is <= 5% here.
        nl_parity = V // P_
        parity_rows = P_ * (P_ - 1) * (nl_parity + 1)   # fwd + reverse
        data_rows_2hop = 2 * off_diag_rows              # fwd + reverse (echo)
        row["wire_parity_rows"] = parity_rows
        row["wire_parity_bytes"] = parity_rows * D_MODEL * bpe
        row["wire_integrity_overhead_frac"] = (
            parity_rows * D_MODEL * bpe
            / (data_rows_2hop * D_MODEL * bpe + 2 * header))

        # ---- bounded receive slab (recv_bound_factor) ----------------------
        # the payoff is a STATIC bound: every post-hop stage (re-compaction
        # sort, recompacted FFN) scans `slab_rows` instead of P x R
        from repro.core.dispatch import ragged_rows
        from repro.core.pipeline import recv_bound_rows
        R_layout = ragged_rows(T_local * k, V, block)
        nl_g = V // P_
        row["ffn_bound_rows_unbounded"] = P_ * R_layout
        for rb in rbs:
            bnd = recv_bound_rows(rb, R_layout, P_, nl_g, block)
            row[f"ragged_rb{rb}_ms"] = timed[f"ragged_rb{rb}"]
            row[f"ffn_bound_rows_rb{rb}"] = bnd
            row[f"ffn_bound_shrink_rb{rb}"] = P_ * R_layout / bnd
            # measured drop_frac of the bounded-slab cell (honesty check:
            # the clamp must not bite at this near-uniform routing) — read
            # off the already-compiled timing function, zero extra compiles
            row[f"drop_frac_rb{rb}"] = rb_drops[rb](x)
            row[f"cpu_emulated_rb{rb}_speedup"] = (timed["ragged"]
                                                   / timed[f"ragged_rb{rb}"])
        for cf in cfs:
            model = cost_model.hop_wire_report(
                T_local, k, cf, V, block, D_MODEL, P_, bytes_per_elem=bpe)
            row[f"sort_cf{cf}_ms"] = timed[f"sort@cf{cf}"]
            row[f"dropless_pad_cf{cf}_ms"] = timed[f"dropless_pad@cf{cf}"]
            row[f"padded_wire_bytes_measured_cf{cf}"] = padded_measured[cf]
            # modeled numbers are per-device; measured aggregate over ranks
            row[f"padded_wire_bytes_modeled_cf{cf}"] = int(
                model["padded_bytes"] * P_)
            row[f"ragged_wire_bytes_modeled_cf{cf}"] = int(
                model["ragged_bytes"] * P_)
            row[f"wire_reduction_cf{cf}"] = (padded_measured[cf]
                                             / ragged_measured)
            row[f"cpu_emulated_step_ratio_cf{cf}"] = (
                timed[f"dropless_pad@cf{cf}"] / timed["ragged"])
            # modeled hop round trip on real fabric (exact segments on the
            # wire — what lax.ragged_all_to_all / a remote-DMA kernel ships),
            # on both hardware profiles of the calibrated cost model
            for hw in (cost_model.V5E, cost_model.P4D):
                t = cost_model.hop_time_report(
                    T_local, k, cf, V, block, D_MODEL, D_FF, P_, hw,
                    bytes_per_elem=2)
                row[f"modeled_step_ratio_cf{cf}_{hw.name}"] = t["ratio"]
        results.append(row)

    rb_cols = RB_FACTORS[:1] if smoke else RB_FACTORS
    hdr = ("T_local,E,k,block,ragged_ms,"
           + ",".join(f"rb{rb}_ms,rb{rb}_ffn_shrink,rb{rb}_drop"
                      for rb in rb_cols) + ","
           + ",".join(f"sort_cf{cf}_ms,dropless_pad_cf{cf}_ms,"
                      f"wire_red_cf{cf},cpu_emu_ratio_cf{cf},"
                      f"v5e_model_ratio_cf{cf}" for cf in cfs))
    print(hdr)
    for r in results:
        print(f"{r['T_local']},{r['E']},{r['k']},{r['block']},"
              f"{r['ragged_ms']:.2f}," +
              ",".join(f"{r[f'ragged_rb{rb}_ms']:.2f},"
                       f"{r[f'ffn_bound_shrink_rb{rb}']:.2f}x,"
                       f"{r[f'drop_frac_rb{rb}']:.4f}"
                       for rb in rb_cols) + "," +
              ",".join(f"{r[f'sort_cf{cf}_ms']:.2f},"
                       f"{r[f'dropless_pad_cf{cf}_ms']:.2f},"
                       f"{r[f'wire_reduction_cf{cf}']:.2f}x,"
                       f"{r[f'cpu_emulated_step_ratio_cf{cf}']:.2f}x,"
                       f"{r[f'modeled_step_ratio_cf{cf}_tpu-v5e']:.2f}x"
                       for cf in cfs))
    if smoke:
        print("SMOKE OK")
        return
    payload = {
        "bench": "ragged_vs_padded_a2a",
        "d_model": D_MODEL, "d_ff": D_FF, "iters": ITERS, "ranks": P_,
        "capacity_factors": list(CFS),
        "recv_bound_factors": list(RB_FACTORS),
        "jax_backend": jax.default_backend(),
        "native_ragged_all_to_all": hasattr(jax.lax, "ragged_all_to_all"),
        "wire_integrity_note": (
            "wire_parity_rows / wire_integrity_overhead_frac quantify the "
            "wire_integrity=detect|quarantine parity-row tax (one extra "
            "row per (rank, group) segment each direction, no extra "
            "collective) against the measured two-hop ragged wire bytes; "
            "see repro.sharding.comm.checksummed_ragged_all_to_all."),
        "caveat": ("CPU container, jax without lax.ragged_all_to_all: the "
                   "ragged exchange runs the fused-slab emulation, whose "
                   "equal-split collective ships the full P x R staging "
                   "bound instead of exact segments (a P-fold byte blowup "
                   "the native op does not have), and the worst-case "
                   "receive bound inflates the recompacted FFN the same "
                   "way.  cpu_emulated_step_ratio therefore UNDERSTATES "
                   "the ragged path; wire bytes (measured from live "
                   "segment counts) are the portable number, and "
                   "modeled_step_ratio_* applies them to the Table-3-"
                   "calibrated congestion model, where the ragged hop is "
                   "parity-or-better at every cf >= 1.25."),
        "results": results,
    }
    out_path = os.path.join(ROOT, "BENCH_ragged_a2a.json")
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {out_path}")


# =============================================================================
# parent: re-exec with multi-device XLA_FLAGS
# =============================================================================

def _spawn(extra) -> None:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.pathsep.join(
        [ROOT, os.path.join(ROOT, "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    p = subprocess.run([sys.executable, os.path.abspath(__file__),
                        "--child"] + extra, cwd=ROOT, env=env,
                       capture_output=True, text=True, timeout=1800)
    sys.stdout.write(p.stdout)
    if p.returncode != 0:
        sys.stderr.write(p.stderr[-3000:])
        raise RuntimeError(f"bench_ragged_a2a child failed ({p.returncode})")


def run_smoke() -> None:
    """One jitted ragged-exchange round trip (both wire formats) on the fake
    multi-device mesh — the CI smoke half; writes no artifacts."""
    _spawn(["--smoke"])


def main() -> None:
    _spawn([])


if __name__ == "__main__":
    if "--child" in sys.argv:
        _child("--smoke" in sys.argv)
    else:
        if "--smoke" in sys.argv:
            run_smoke()
        else:
            main()

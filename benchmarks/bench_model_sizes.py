"""Paper Table 2: throughput vs model size (3.7B / 13B / 48B, 128 experts,
16 nodes), Switch vs SMILE, from the calibrated cost model."""
from __future__ import annotations

from benchmarks.cost_model import (P4D, MoELayerShape, allreduce_time,
                                   calibrate_alpha, calibrate_tau,
                                   moe_layer_time)

SEQ, M, N_NODES = 128, 8, 16
GLOBAL = 16384

SIZES = {
    # name: (micro, layers, d_model, d_ff, dense-equivalent active params)
    "3.7B": (128, 12, 768, 3072, 110e6),
    "13B": (64, 24, 1024, 4096, 340e6),
    "48B": (64, 36, 1600, 6400, 1.2e9),
}
PAPER = {"3.7B": (8112, 20011), "13B": (4001, 6829), "48B": (889, 2223)}


def table2():
    alpha, tau = calibrate_alpha(), calibrate_tau()
    rows = []
    for name, (micro, L, d, ff, active) in SIZES.items():
        s = MoELayerShape(tokens_per_device=micro * SEQ, d_model=d, d_ff=ff)
        n_micro = max(1, GLOBAL // (micro * N_NODES * M))
        out = {}
        for router in ("switch", "smile"):
            layer = moe_layer_time(s, P4D, N_NODES, router,
                                   alpha=alpha, tau=tau)
            t_c = 6 * active * micro * SEQ / (P4D.flops * 0.45)
            t_micro = t_c + (L // 2) * (layer["a2a_s"] + layer["other_s"]) * 2
            t = n_micro * t_micro + allreduce_time(active * 2, N_NODES,
                                                   P4D.inter_bw)
            out[router] = GLOBAL / t
        rows.append((name, out["switch"], out["smile"]))
    return rows


def main():
    print("# Table 2 reproduction (cost model; samples/second, 16 nodes)")
    print("size,switch_ours,smile_ours,speedup_ours,switch_paper,"
          "smile_paper,speedup_paper")
    for name, sw, sm in table2():
        psw, psm = PAPER[name]
        print(f"{name},{sw:,.0f},{sm:,.0f},{sm/sw:.2f},{psw},{psm},"
              f"{psm/psw:.2f}")


if __name__ == "__main__":
    main()

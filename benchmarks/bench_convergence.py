"""Paper Fig. 6/7: iteration-to-loss parity and the LB-loss factor-2.

MEASURED (not modeled): trains reduced SMILE / Switch / BERT variants on the
same synthetic MLM stream and reports:
  * CE per step (Fig. 6: SMILE's convergence matches Switch; both beat the
    flop-matched dense baseline per-step... at toy scale we check parity);
  * unscaled LB loss (Fig. 7: SMILE's unscaled LB ~= 2x Switch's because it
    is the SUM of two additive terms, each with minimum 1 when unscaled —
    here we report the scaled value whose floors are alpha+beta vs alpha).
"""
from __future__ import annotations

import json

from repro.launch.train import train

STEPS = 40


def convergence(steps: int = STEPS):
    rows = {}
    for arch in ("smile-3.7b", "switch-3.7b", "bert-110m"):
        _, hist = train(arch, reduced=True, steps=steps, batch=16, seq=128,
                        lr=1e-3, optimizer="lamb", seed=0, log_every=5)
        rows[arch] = hist
    return rows


def main():
    rows = convergence()
    print("# Fig. 6/7 reproduction (measured, reduced models, synthetic MLM)")
    print("arch,step,ce,lb_scaled")
    for arch, hist in rows.items():
        for h in hist:
            print(f"{arch},{h['step']},{h['ce']:.4f},{h['lb']:.5f}")
    s = rows["smile-3.7b"][-1]
    o = rows["switch-3.7b"][-1]
    print(f"# final CE smile {s['ce']:.3f} vs switch {o['ce']:.3f} "
          f"(paper: curves overlap)")
    if o["lb"] > 0:
        print(f"# scaled LB smile/switch = {s['lb']/o['lb']:.2f} "
            f"(floors: (a+b)/a = (0.005+0.005)/0.01 = 1.0 when scaled; "
            f"paper Fig.7 reports ~2x when UNscaled)")


if __name__ == "__main__":
    main()

"""One-pass Pallas radix (counting) sort vs XLA argsort for dispatch
(EXPERIMENTS.md §Perf-5).

Times the jitted group-sort primitive under every dispatch hop —
``repro.kernels.ops.group_sort``: stable sort of A int32 group ids with
domain E, returning each assignment's sorted rank plus the per-group
exclusive prefix counts — for ``impl="argsort"`` (packed single-operand
``lax.sort``, XLA's generic O(A log A) comparison sort) against
``impl="radix"`` (the O(A + E) Pallas counting sort of
:mod:`repro.kernels.radix_sort`), sweeping A x E across the dispatch-sized
regime (A = tokens * k per hop, E = experts or ranks * groups_per_rank).

HONEST CPU CAVEAT (same as §Perf-4): on this container the Pallas kernel
runs in interpret mode — a per-grid-step emulation that measures
correctness, not speed — so the measured "radix" numbers are emulation
overhead, not kernel time.  The structural claim is carried by the modeled
projection from :func:`benchmarks.cost_model.sort_time_report` (log2(A)
HBM passes for the comparison sort vs 3 streaming passes + a VPU compare
term for the counting sort), reported per cell alongside the measurement.
The bit-identicality of the two impls IS measured here (asserted on every
cell) and in tests/test_dispatch_conformance.py.

Prints a CSV block and writes machine-readable ``BENCH_radix_sort.json``.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import cost_model
from benchmarks.bench_dispatch import _time_interleaved
from repro.kernels import ops as kops

ITERS = 8
WARMUP = 2
SWEEP_A = (4096, 16384, 65536, 262144)
SWEEP_E = (8, 64, 256)


def _sort_fn(impl: str, num_keys: int):
    @jax.jit
    def fn(keys):
        ranks, starts = kops.group_sort(keys, num_keys, impl=impl)
        # the timed fn consumes both outputs in one array so neither is
        # dead-code-eliminated (bit-identicality is asserted separately on
        # the full (ranks, starts) pair, see _assert_bit_identical)
        return ranks + jnp.take(starts, keys)
    return fn


def _assert_bit_identical(keys, num_keys: int) -> None:
    """Full (ranks, starts) equality between the two impls — array by
    array, not a derived reduction."""
    outs = {impl: kops.group_sort(keys, num_keys, impl=impl)
            for impl in kops.SORT_IMPLS}
    np.testing.assert_array_equal(np.asarray(outs["radix"][0]),
                                  np.asarray(outs["argsort"][0]))
    np.testing.assert_array_equal(np.asarray(outs["radix"][1]),
                                  np.asarray(outs["argsort"][1]))


def run_sweep(sweep_a=SWEEP_A, sweep_e=SWEEP_E, iters=ITERS):
    rng = np.random.default_rng(0)
    results = []
    for A in sweep_a:
        for E in sweep_e:
            # domain mirrors dispatch: E groups + the invalid sentinel
            D = E + 1
            keys = jnp.asarray(rng.integers(0, E, A), jnp.int32)
            fns = {impl: _sort_fn(impl, D) for impl in kops.SORT_IMPLS}
            # bit-identicality of the two impls, asserted on every cell
            _assert_bit_identical(keys, D)
            timed = _time_interleaved(fns, (keys,), iters=iters,
                                      warmup=WARMUP)
            model = cost_model.sort_time_report(A, D, cost_model.V5E)
            results.append({
                "A": A, "E": E,
                "radix_ms": timed["radix"],
                "argsort_ms": timed["argsort"],
                "measured_ratio": timed["argsort"] / timed["radix"],
                "modeled_v5e_argsort_us": model["argsort_s"] * 1e6,
                "modeled_v5e_radix_us": model["radix_s"] * 1e6,
                "modeled_v5e_speedup": model["speedup"],
            })
    return results


def run_smoke():
    """CI smoke: one tiny cell, both impls through their jitted round trip
    (radix through the real interpret-mode Pallas kernel), bit-identical
    outputs asserted, no numbers recorded."""
    rng = np.random.default_rng(0)
    A, E = 4096, 8
    keys = jnp.asarray(rng.integers(0, E, A), jnp.int32)
    for impl in kops.SORT_IMPLS:
        _sort_fn(impl, E + 1)(keys).block_until_ready()
        print(f"smoke group_sort[{impl}]: ok")
    _assert_bit_identical(keys, E + 1)


def main() -> None:
    results = run_sweep()
    print(f"# stable group sort (ranks + prefix counts), jitted, best of "
          f"{ITERS} interleaved (backend={jax.default_backend()}; radix "
          f"runs in Pallas interpret mode off-TPU — measured radix ms is "
          f"emulation overhead, see modeled columns)")
    print("A,E,argsort_ms,radix_ms,modeled_v5e_argsort_us,"
          "modeled_v5e_radix_us,modeled_v5e_speedup")
    for r in results:
        print(f"{r['A']},{r['E']},{r['argsort_ms']:.3f},{r['radix_ms']:.3f},"
              f"{r['modeled_v5e_argsort_us']:.1f},"
              f"{r['modeled_v5e_radix_us']:.1f},"
              f"{r['modeled_v5e_speedup']:.1f}x")
    worst = min(r["modeled_v5e_speedup"] for r in results)
    print(f"# outputs bit-identical on every cell; worst modeled v5e "
          f"radix-vs-argsort speedup across the sweep: {worst:.1f}x")
    payload = {
        "bench": "radix_sort_vs_argsort",
        "iters": ITERS,
        "jax_backend": jax.default_backend(),
        "pallas_interpret_mode": jax.default_backend() != "tpu",
        "note": "off-TPU the radix measurement is interpret-mode emulation "
                "overhead; the structural comparison is the modeled v5e "
                "projection (cost_model.sort_time_report)",
        "results": results,
    }
    out_path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_radix_sort.json")
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {out_path}")


if __name__ == "__main__":
    main()

"""Paper Fig. 8: weak & strong scaling, 1 -> 16 nodes, Switch vs SMILE.

Weak scaling: global batch grows with GPUs (micro batch fixed at 128/GPU,
one micro-step). Strong scaling: global batch fixed at 16384 (gradient
accumulation shrinks as nodes grow). Reported as samples/second from the
calibrated cost model; reproduces the paper's qualitative claims:

  * Switch throughput is nearly flat (even non-monotonic) beyond 4 nodes;
  * SMILE keeps scaling to 16 nodes (paper: 7.7x weak / 4x strong vs 1 node);
  * on a single node bi-level routing only adds overhead (paper §4.3.1 obs 2).
"""
from __future__ import annotations

from benchmarks.cost_model import (P4D, MoELayerShape, allreduce_time,
                                   calibrate_alpha, calibrate_tau,
                                   moe_layer_time)

SEQ, MICRO, M = 128, 128, 8
GLOBAL = 16384


def step_time(router: str, n_nodes: int, n_micro: int, alpha, tau) -> float:
    s = MoELayerShape(tokens_per_device=MICRO * SEQ, d_model=768, d_ff=3072)
    layer = moe_layer_time(s, P4D, n_nodes, router, alpha=alpha, tau=tau)
    t_compute = 6 * 110e6 * MICRO * SEQ / (P4D.flops * 0.45)
    t_micro = t_compute + 6 * (layer["a2a_s"] + layer["other_s"]) * 2.0
    t_dp = allreduce_time(110e6 * 2, n_nodes, P4D.inter_bw)
    return n_micro * t_micro + t_dp


def scaling():
    alpha, tau = calibrate_alpha(), calibrate_tau()
    rows = []
    for n in (1, 2, 4, 8, 16):
        gpus = n * M
        # weak: batch = 128 * gpus, one micro-step
        for router in ("switch", "smile"):
            t = step_time(router, n, 1, alpha, tau)
            rows.append(("weak", router, n, (MICRO * gpus) / t))
        # strong: fixed global batch; accumulation steps shrink
        n_micro = max(1, GLOBAL // (MICRO * gpus))
        for router in ("switch", "smile"):
            t = step_time(router, n, n_micro, alpha, tau)
            rows.append(("strong", router, n, GLOBAL / t))
    return rows


def main():
    rows = scaling()
    print("# Fig. 8 reproduction (cost model; samples/second)")
    print("mode,router,nodes,samples_per_s")
    for mode, router, n, thr in rows:
        print(f"{mode},{router},{n},{thr:,.0f}")
    d = {(m, r, n): t for m, r, n, t in rows}
    print(f"# weak scaling 16/1 nodes: smile "
          f"{d[('weak','smile',16)]/d[('weak','smile',1)]:.1f}x "
          f"(paper 7.7x), switch "
          f"{d[('weak','switch',16)]/d[('weak','switch',1)]:.1f}x")
    print(f"# strong scaling 16/1 nodes: smile "
          f"{d[('strong','smile',16)]/d[('strong','smile',1)]:.1f}x "
          f"(paper 4x)")


if __name__ == "__main__":
    main()

"""Closed-loop serving benchmark: Poisson traffic through the paged engine.

A seeded, replayable request trace (Poisson arrivals, mixed short/long
prompt and output length distributions, persisted as a ``.memmap`` +
``.meta`` shard so a run can be replayed bit-for-bit) is played against
:class:`repro.serve.engine.Engine` in a closed loop: requests are submitted
when the wall clock passes their arrival offset, the engine ticks until the
trace drains, and per-request timestamps give TTFT and per-token latency.

Reported per arch: p50/p99 inter-token latency, p50/p99 TTFT, tokens/s,
page-pool occupancy, MoE decode-hop telemetry (drop fraction, per-hop max
load / load entropy), and the engine's compile counts (the recompile-
determinism headline: ONE fused decode compile + one per prefill bucket).

**Honest caveat** (same spirit as EXPERIMENTS.md §Perf-4): the measured
numbers come from interpret-mode CPU emulation of REDUCED configs — they
validate scheduling behaviour (no starvation, page reuse, compile counts),
not accelerator performance. The ``modeled_v5e`` section therefore projects
the FULL config's decode tick on TPU v5e via ``benchmarks.cost_model``
(weight-streaming HBM bound + bi-level expert-hop A2A), which is where the
throughput claims live.

Writes ``BENCH_serving.json`` (skipped in ``--smoke``).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
TRACE_DIR = os.path.join(ROOT, "experiments", "serve_traces")

# trace columns (one float32 row per request)
COL_ARRIVAL_S, COL_PROMPT_LEN, COL_NEW_TOKENS, COL_SEED = range(4)


# =============================================================================
# Replayable trace (memmap shard + sidecar meta, SNIPPETS-style)
# =============================================================================

def make_trace(n_requests: int, seed: int, *, rate_rps: float = 8.0,
               short_frac: float = 0.7, cache_len: int = 64,
               trace_dir: str = TRACE_DIR) -> np.ndarray:
    """Generate + persist a seeded Poisson trace; returns the (N, 4) rows.

    Arrival offsets are cumulative Exp(rate) gaps; prompt lengths mix a
    short mode (chat turns) and a long mode (context dumps); output lengths
    are uniform. Every row carries its own token seed so prompt CONTENT is
    replayable from the trace file alone.
    """
    rng = np.random.default_rng(seed)
    rows = np.zeros((n_requests, 4), np.float32)
    rows[:, COL_ARRIVAL_S] = np.cumsum(rng.exponential(1.0 / rate_rps,
                                                       n_requests))
    is_short = rng.random(n_requests) < short_frac
    plen = np.where(is_short,
                    rng.integers(2, 12, n_requests),
                    rng.integers(cache_len // 3, cache_len // 2 + 1,
                                 n_requests))
    new = rng.integers(2, 12, n_requests)
    new = np.minimum(new, cache_len - plen)
    rows[:, COL_PROMPT_LEN] = plen
    rows[:, COL_NEW_TOKENS] = np.maximum(new, 1)
    rows[:, COL_SEED] = rng.integers(0, 2**31 - 1, n_requests)

    os.makedirs(trace_dir, exist_ok=True)
    shard = os.path.join(trace_dir, f"trace_{seed}.memmap")
    mm = np.memmap(shard, dtype=np.float32, mode="w+", shape=rows.shape)
    mm[:] = rows
    mm.flush()
    with open(shard.replace(".memmap", ".meta"), "w") as f:
        json.dump({"shape": list(rows.shape), "dtype": "float32",
                   "seed": seed, "rate_rps": rate_rps,
                   "short_frac": short_frac, "cache_len": cache_len}, f)
    del mm
    return rows


def load_trace(seed: int, trace_dir: str = TRACE_DIR) -> np.ndarray:
    shard = os.path.join(trace_dir, f"trace_{seed}.memmap")
    with open(shard.replace(".memmap", ".meta")) as f:
        meta = json.load(f)
    mm = np.memmap(shard, dtype=np.float32, mode="r",
                   shape=tuple(meta["shape"]))
    return np.array(mm)


def _prompt_tokens(row, vocab: int) -> np.ndarray:
    rng = np.random.default_rng(int(row[COL_SEED]))
    return rng.integers(8, vocab, int(row[COL_PROMPT_LEN])).astype(np.int32)


# =============================================================================
# Closed-loop run
# =============================================================================

def run_trace(arch: str, trace: np.ndarray, *, cache_len: int = 64,
              n_slots: int = 4, page_size: int = 8,
              time_scale: float = 1.0) -> dict:
    """Play the trace against the engine; submit when the (scaled) wall
    clock passes each arrival offset, tick until drained."""
    import jax
    from repro.configs import get_reduced
    from repro.models.transformer import init_model
    from repro.serve.engine import Engine
    from repro.sharding.plan import single_device_plan

    plan = single_device_plan()
    cfg = get_reduced(arch)
    params = init_model(jax.random.PRNGKey(0), cfg, plan)
    eng = Engine(params, cfg, plan, cache_len=cache_len, n_slots=n_slots,
                 page_size=page_size)

    reqs = {}                               # uid -> trace row
    t0 = time.monotonic()
    nxt = 0
    while nxt < len(trace) or eng.busy:
        now = (time.monotonic() - t0) * time_scale
        while nxt < len(trace) and trace[nxt, COL_ARRIVAL_S] <= now:
            row = trace[nxt]
            uid = eng.submit(_prompt_tokens(row, cfg.vocab_size),
                             int(row[COL_NEW_TOKENS]))
            reqs[uid] = row
            nxt += 1
        if not eng.busy:                    # drained early: wait for traffic
            gap = float(trace[nxt, COL_ARRIVAL_S]) / time_scale \
                - (time.monotonic() - t0)
            if gap > 0:
                time.sleep(min(gap, 0.05))
            continue
        eng.step()
    wall_s = time.monotonic() - t0

    # latency aggregation off the engine's per-token wall timestamps
    assert all(r is None for r in eng.slot_req), "undrained slot"
    ttft, itl = [], []
    n_tokens = 0
    for uid in reqs:
        req = eng.requests[uid]
        n_tokens += len(req.generated)
        ttft.append(req.t_first - req.t_submit)
        itl.extend(np.diff(req.t_tokens))
    ttft, itl = np.asarray(ttft), np.asarray(itl if itl else [0.0])
    m = eng.metrics()
    return {
        "arch": arch, "requests": len(trace), "tokens": n_tokens,
        "ticks": m["ticks"], "wall_s": wall_s,
        "tokens_per_s": n_tokens / max(wall_s, 1e-9),
        "ttft_p50_ms": float(np.percentile(ttft, 50) * 1e3),
        "ttft_p99_ms": float(np.percentile(ttft, 99) * 1e3),
        "itl_p50_ms": float(np.percentile(itl, 50) * 1e3),
        "itl_p99_ms": float(np.percentile(itl, 99) * 1e3),
        "page_occupancy_mean": m["page_occupancy_mean"],
        "page_occupancy_max": m["page_occupancy_max"],
        "moe_drop_frac_mean": m["moe_drop_frac_mean"],
        "moe_hop_max_load_max": m["moe_hop_max_load_max"],
        "moe_hop_load_entropy_min": m["moe_hop_load_entropy_min"],
        "compiles": m["compiles"],
    }


# =============================================================================
# Modeled v5e decode tick (full config — where the perf claims live)
# =============================================================================

def modeled_v5e(arch: str, n_slots: int) -> dict:
    """Project one fused decode tick of the FULL config on a v5e pod slice:
    weight-streaming HBM bound for the dense trunk + bi-level expert-hop
    A2A for the MoE FFN (cost_model's calibrated congestion/launch terms)."""
    from benchmarks.cost_model import (V5E, a2a_time, hop_time_report,
                                       ragged_hop_payload)
    from repro.configs import get_config

    cfg = get_config(arch)
    mo = cfg.moe
    d, L, V = cfg.d_model, cfg.num_layers, cfg.vocab_size
    hd, H, KV = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads
    # active params per token: attention + router + top_k expert FFNs (GLU)
    attn_p = L * (d * hd * (H + 2 * KV) + H * hd * d)
    expert_p = L * mo.top_k * 3 * d * mo.d_ff_expert
    embed_p = 2 * d * V
    active = attn_p + expert_p + embed_p
    bytes_active = active * 2               # bf16 weight streaming
    t_hbm = bytes_active / V5E.hbm_bw
    t_flops = 2 * active * n_slots / V5E.flops

    # bi-level expert hop for ONE decode tick of n_slots live tokens:
    # inter hop across nodes, intra hop across the 16-worker slice
    n_nodes = max(1, mo.num_experts // V5E.workers_per_node)
    hop = hop_time_report(
        tokens=n_slots, k=mo.top_k, capacity_factor=mo.capacity_factor,
        groups=mo.num_experts, block=8, d_model=d, d_ff=mo.d_ff_expert,
        ranks=n_nodes, hw=V5E, inter=True)
    intra_payload = ragged_hop_payload(n_slots * mo.top_k,
                                       mo.num_experts, 8, d, 2,
                                       V5E.workers_per_node)
    t_intra = 2 * a2a_time(intra_payload, V5E.workers_per_node,
                           V5E.intra_bw, alpha=0.0)
    t_a2a = hop["a2a_ragged_s"] + t_intra
    t_step = max(t_hbm, t_flops) + L * t_a2a
    return {
        "hw": "tpu-v5e", "arch": arch, "n_slots": n_slots,
        "active_params": active,
        "t_hbm_ms": t_hbm * 1e3, "t_flops_ms": t_flops * 1e3,
        "t_a2a_per_layer_us": t_a2a * 1e6,
        "decode_step_ms": t_step * 1e3,
        "tokens_per_s": n_slots / t_step,
    }


# =============================================================================
# Entry points
# =============================================================================

def run_smoke() -> None:
    """CI gate: tiny trace end to end, no artifacts, invariants asserted."""
    trace = make_trace(4, seed=0, rate_rps=50.0, cache_len=32)
    replay = load_trace(0)
    assert np.array_equal(trace, replay), "trace must replay bit-for-bit"
    r = run_trace("qwen1.5-0.5b", replay, cache_len=32, n_slots=2,
                  page_size=4)
    assert r["tokens"] == int(replay[:, COL_NEW_TOKENS].sum())
    assert r["compiles"]["decode"] == 1, r["compiles"]
    print(f"smoke serving: {r['requests']} reqs, {r['tokens']} toks, "
          f"{r['ticks']} ticks, itl_p50={r['itl_p50_ms']:.1f}ms")


def main() -> None:
    results, seed = [], 11
    trace = make_trace(24, seed=seed, rate_rps=4.0, cache_len=64)
    for arch in ["qwen1.5-0.5b", "qwen3-moe-30b-a3b"]:
        r = run_trace(arch, trace, cache_len=64, n_slots=4, page_size=8)
        results.append(r)
        print(f"# {arch}: {r['tokens']} toks in {r['wall_s']:.1f}s "
              f"({r['tokens_per_s']:.1f} tok/s CPU-emulated)")
        print(f"  ttft p50/p99 {r['ttft_p50_ms']:.0f}/{r['ttft_p99_ms']:.0f}"
              f" ms, itl p50/p99 {r['itl_p50_ms']:.0f}/{r['itl_p99_ms']:.0f}"
              f" ms, occupancy {r['page_occupancy_mean']:.2f}"
              f"/{r['page_occupancy_max']:.2f}, compiles {r['compiles']}")
    modeled = [modeled_v5e("qwen3-moe-30b-a3b", n) for n in (8, 32, 128)]
    print("# modeled v5e decode tick (FULL qwen3-moe-30b-a3b)")
    print("n_slots,decode_step_ms,tokens_per_s")
    for mrow in modeled:
        print(f"{mrow['n_slots']},{mrow['decode_step_ms']:.2f},"
              f"{mrow['tokens_per_s']:,.0f}")
    payload = {
        "bench": "serving",
        "trace": {"seed": seed, "requests": len(trace),
                  "path": os.path.join(TRACE_DIR, f"trace_{seed}.memmap")},
        "caveat": "measured rows are CPU-emulated REDUCED configs "
                  "(scheduling fidelity, not accelerator perf); "
                  "modeled_v5e carries the throughput claims",
        "results": results,
        "modeled_v5e": modeled,
    }
    out_path = os.path.join(ROOT, "BENCH_serving.json")
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {out_path}")


if __name__ == "__main__":
    import sys
    if "--smoke" in sys.argv:
        run_smoke()
    else:
        main()

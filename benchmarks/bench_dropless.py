"""Dropless (capacity-free) expert compute vs the sort capacity path
(EXPERIMENTS.md §Perf-3).

Times the full local expert-compute round trip — dispatch, grouped expert
FFN, gate-weighted combine; jitted, no collectives — for the ``"sort"``
capacity-buffer path at several capacity factors against the ``"dropless"``
tile-aligned ragged path (one number per shape: dropless has no capacity
factor; nothing is ever padded past tile alignment and nothing drops).

The structural story: the capacity path gathers, FFNs, and combines
``cf * A`` buffer rows regardless of need; the dropless path touches
``A + pad`` rows where ``pad <= E * (block - 1)`` from tile alignment.
Dropless wins when tokens-per-expert is large relative to the row tile
(the production regime — A/E >= ~8 tiles); for small A/E the alignment
padding eats the margin and the capacity buffer's uniform batched matmul
is the better CPU schedule, so the sweep includes both regimes rather than
only the flattering one.  On TPU the ragged Pallas kernel removes the
per-tile weight copy the CPU path pays (the indirection moves into the DMA
descriptor via scalar prefetch), so the crossover shifts further in
dropless's favor.

Prints a CSV block and writes machine-readable ``BENCH_dropless.json``.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_dispatch import _time_interleaved
from repro.core import dispatch as D
from repro.core.moe import capacity, experts_ffn, experts_ffn_ragged

D_MODEL = 128
D_FF = 256
ACT = "gelu"
ITERS = 15
WARMUP = 3
CFS = (1.25, 1.5, 2.0)
# (tokens, groups, k) — production LOCAL shapes: on a big expert-sharded
# mesh each device owns few groups and a large local token batch, so
# tokens-per-group is high and the adaptive row tile is large enough
# (2-4k rows) for XLA's batched matmul to reach the dense grouped einsum's
# per-row throughput.  Every sweep point at cf >= 1.5 is a wall-clock win;
# cf = 1.25 measures parity within noise on this CPU container (see the
# §Perf-3 write-up — the TPU kernel path removes the per-tile weight copy
# that CPU pays, shifting the crossover further down).
SWEEP = [
    (65536, 8, 2),       # A/E = 16384
    (65536, 4, 2),       # A/E = 32768
    (131072, 4, 1),      # A/E = 32768, k = 1
]
# smaller tokens-per-expert shapes, reported alongside (NOT headline): here
# the row tiles shrink, XLA's small-batch matmul penalty and alignment
# slack eat the margin, and the capacity buffer's uniform matmul wins on
# CPU below cf ~2 — the crossover the §Perf-3 write-up documents.
CROSSOVER_SWEEP = [
    (4096, 64, 2),       # A/E = 128
    (16384, 16, 2),      # A/E = 2048
]


def _weights(rng, E):
    w = {
        "w1": jnp.asarray(rng.standard_normal((E, D_MODEL, D_FF)),
                          jnp.float32) * 0.05,
        "w2": jnp.asarray(rng.standard_normal((E, D_FF, D_MODEL)),
                          jnp.float32) * 0.05,
    }
    return w


def _sort_roundtrip(E, cap, k, w):
    @jax.jit
    def fn(x, gids, gates):
        buf, state = D.dispatch(x, gids, gates, E, cap, k=k, backend="sort")
        out = experts_ffn(w, buf, ACT)
        return D.combine(out, state)
    return fn


def _dropless_roundtrip(E, k, w):
    @jax.jit
    def fn(x, gids, gates):
        rows, starts, state = D.dispatch_ragged(x, gids, gates, E, k=k)
        out = experts_ffn_ragged(w, rows, starts, ACT, block=state.cap)
        return D.combine(out, state)
    return fn


def run_sweep(sweep=SWEEP, cfs=CFS, iters=ITERS):
    rng = np.random.default_rng(0)
    results = []
    for T, E, k in sweep:
        A = T * k
        x = jnp.asarray(rng.standard_normal((T, D_MODEL)), jnp.float32)
        gids = jnp.asarray(rng.integers(0, E, A), jnp.int32)
        gates = jnp.asarray(rng.uniform(0, 1, A), jnp.float32)
        w = _weights(rng, E)
        fns = {"dropless": _dropless_roundtrip(E, k, w)}
        caps = {}
        for cf in cfs:
            caps[cf] = capacity(T, k, cf, E)
            fns[f"sort@cf{cf}"] = _sort_roundtrip(E, caps[cf], k, w)
        timed = _time_interleaved(fns, (x, gids, gates), iters=iters,
                                  warmup=WARMUP)
        blk = D._ragged_block(A, E, None)
        row = {"T": T, "E": E, "k": k, "A": A, "block": blk,
               "ragged_rows": D.ragged_rows(A, E, blk),
               "dropless_ms": timed["dropless"]}
        for cf in cfs:
            row[f"sort_cf{cf}_ms"] = timed[f"sort@cf{cf}"]
            row[f"speedup_cf{cf}"] = timed[f"sort@cf{cf}"] / timed["dropless"]
        results.append(row)
    return results


def _print_block(results):
    print("T,E,k,rows_ragged," +
          ",".join(f"sort_cf{cf}_ms" for cf in CFS) +
          ",dropless_ms," + ",".join(f"speedup_cf{cf}" for cf in CFS))
    for r in results:
        print(f"{r['T']},{r['E']},{r['k']},{r['ragged_rows']}," +
              ",".join(f"{r[f'sort_cf{cf}_ms']:.2f}" for cf in CFS) +
              f",{r['dropless_ms']:.2f}," +
              ",".join(f"{r[f'speedup_cf{cf}']:.2f}x" for cf in CFS))


def main() -> None:
    results = run_sweep()
    print(f"# dispatch->expert FFN->combine round trip, jitted, "
          f"d={D_MODEL} f={D_FF}, best of {ITERS} interleaved "
          f"(backend={jax.default_backend()})")
    _print_block(results)
    worst = min(r[f"speedup_cf{cf}"] for r in results
                for cf in CFS if cf >= 1.5)
    print(f"# worst dropless speedup vs sort at cf>=1.5: {worst:.2f}x "
          f"(cf=1.25 is parity within noise on CPU; zero token drops at "
          f"ANY load skew at every point)")
    print("# crossover shapes (small tokens-per-expert; capacity path's "
          "uniform matmul wins on CPU below cf~2):")
    crossover = run_sweep(sweep=CROSSOVER_SWEEP)
    _print_block(crossover)
    payload = {
        "bench": "dropless_vs_capacity",
        "d_model": D_MODEL, "d_ff": D_FF, "iters": ITERS,
        "capacity_factors": list(CFS),
        "jax_backend": jax.default_backend(),
        "results": results,
        "crossover_results": crossover,
    }
    out_path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_dropless.json")
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {out_path}")


if __name__ == "__main__":
    main()

"""Paper Table 1: pretraining throughput (samples/second) at 16 p4d nodes.

BERT(110M) / BERT(3.7B) dense baselines + Switch Transformer + SMILE, via the
calibrated cost model (alpha, tau fit on Switch Table-3 rows only). The
SMILE row — and therefore the headline 2.5x — is out-of-sample.
"""
from __future__ import annotations

from benchmarks.cost_model import (P4D, MoELayerShape, allreduce_time,
                                   calibrate_alpha, calibrate_tau,
                                   moe_layer_time)

SEQ = 128
GLOBAL_BATCH = 16384
N_NODES, M = 16, 8
N_GPUS = N_NODES * M
MICRO = 128                       # per-GPU micro batch (paper §4.1)


def _dense_step_s(params: float, d_model: int) -> float:
    """Dense BERT step: 6*N*D compute at ~45% MFU + gradient all-reduce."""
    tokens_per_gpu = MICRO * SEQ
    flops = 6 * params * tokens_per_gpu
    t_compute = flops / (P4D.flops * 0.45)
    t_dp = allreduce_time(params * 2 / 1, N_NODES, P4D.inter_bw)
    n_micro = GLOBAL_BATCH // (MICRO * N_GPUS)
    return max(n_micro, 1) * t_compute + t_dp


def _moe_step_s(router: str, alpha, tau) -> float:
    """MoE (BERT-base backbone, 128 experts, 6 MoE layers) step time."""
    s = MoELayerShape(tokens_per_device=MICRO * SEQ, d_model=768, d_ff=3072)
    layer = moe_layer_time(s, P4D, N_NODES, router, alpha=alpha, tau=tau)
    dense_active = 110e6
    tokens_per_gpu = MICRO * SEQ
    t_compute = 6 * dense_active * tokens_per_gpu / (P4D.flops * 0.45)
    n_moe_layers = 6                  # every other FFN of 12 layers
    # fwd dispatch+return counted in layer; bwd repeats the A2As + other
    t_moe = n_moe_layers * (layer["a2a_s"] + layer["other_s"]) * 2.0
    t_dp = allreduce_time(110e6 * 2, N_NODES, P4D.inter_bw)
    return t_compute + t_moe + t_dp


def table1():
    alpha, tau = calibrate_alpha(), calibrate_tau()
    rows = []
    rows.append(("bert-110m", GLOBAL_BATCH / _dense_step_s(110e6, 768)))
    rows.append(("bert-3.7b", GLOBAL_BATCH / _dense_step_s(3.7e9, 2560)))
    rows.append(("switch-3.7b", GLOBAL_BATCH / _moe_step_s("switch",
                                                           alpha, tau)))
    rows.append(("smile-3.7b", GLOBAL_BATCH / _moe_step_s("smile",
                                                          alpha, tau)))
    return rows


PAPER = {"bert-110m": 93282, "bert-3.7b": 5114,
         "switch-3.7b": 8112, "smile-3.7b": 20011}


def main():
    rows = table1()
    print("# Table 1 reproduction (cost model; samples/second)")
    print("model,ours,paper,ratio_to_paper")
    for name, thr in rows:
        print(f"{name},{thr:,.0f},{PAPER[name]},{thr/PAPER[name]:.2f}")
    d = dict(rows)
    ours = d["smile-3.7b"] / d["switch-3.7b"]
    print(f"# SMILE/Switch speedup: ours {ours:.2f}x, paper 2.47x")


if __name__ == "__main__":
    main()

"""Fused Pallas routing megakernel vs the unfused XLA routing chain
(EXPERIMENTS.md §Perf-7).

Times the jitted per-hop routing prologue — router GEMM, softmax, top-k,
histogram and dispatch positions — for ``router_impl="unfused"``
(``core.moe.router_probs`` + ``topk_gates`` + ``ops.group_sort`` as
separate XLA ops, with the (t, E) logits/probs tensors round-tripping HBM
between them) against ``router_impl="fused"``
(:func:`repro.kernels.ops.router_fused`, everything after the GEMM staying
in VMEM), sweeping t x E across the dispatch-sized regime.

HONEST CPU CAVEAT (same as §Perf-4): on this container the Pallas kernel
runs in interpret mode — a per-grid-step emulation that measures
correctness, not speed — so the measured "fused" numbers are emulation
overhead, not kernel time.  The structural claim is carried by the modeled
projection from :func:`benchmarks.cost_model.routing_time_report` (4 HBM
passes over the (t, E) tensors + a separate O(A log A) sort for the
unfused chain vs one-time writes for the fused kernel), reported per cell
alongside the measurement.  The bit-identicality of the two impls IS
measured here (asserted on every cell) and in
tests/test_router_fused.py / tests/test_dispatch_conformance.py.

Prints a CSV block and writes machine-readable ``BENCH_router_fused.json``.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import cost_model
from benchmarks.bench_dispatch import _time_interleaved
from repro.core import moe as M
from repro.kernels import ops as kops

ITERS = 8
WARMUP = 2
D_MODEL = 64
K = 2
SWEEP_T = (4096, 16384, 65536)
SWEEP_E = (16, 64, 256)


def _unfused_fn(E: int, k: int):
    @jax.jit
    def fn(x, w):
        probs, logits = M.router_probs(x, w)
        gates, idx = M.topk_gates(probs, k, True)
        ranks, starts = kops.group_sort(idx.reshape(-1), E, impl="argsort")
        # consume every output in one array so nothing is dead-code
        # eliminated (bit-identicality is asserted separately per cell)
        return (gates.sum() + probs.sum() + logits.sum()
                + (ranks + jnp.take(starts, idx.reshape(-1))).sum())
    return fn


def _fused_fn(k: int):
    @jax.jit
    def fn(x, w):
        gates, idx, probs, logits, ranks, starts = kops.router_fused(
            x, w, k, renorm=True)
        return (gates.sum() + probs.sum() + logits.sum()
                + (ranks + jnp.take(starts, idx.reshape(-1))).sum())
    return fn


def _assert_bit_identical(x, w, E: int, k: int) -> None:
    """Full fused-vs-unfused equality — every output array, bit for bit."""
    gates_f, idx_f, probs_f, logits_f, ranks_f, starts_f = kops.router_fused(
        x, w, k, renorm=True)
    probs, logits = M.router_probs(x, w)
    gates, idx = M.topk_gates(probs, k, True)
    ranks, starts = kops.group_sort(idx.reshape(-1), E, impl="argsort")
    for a, b in ((gates_f, gates), (idx_f, idx), (probs_f, probs),
                 (logits_f, logits), (ranks_f, ranks), (starts_f, starts)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def run_sweep(sweep_t=SWEEP_T, sweep_e=SWEEP_E, iters=ITERS):
    rng = np.random.default_rng(0)
    results = []
    for t in sweep_t:
        for E in sweep_e:
            x = jnp.asarray(rng.standard_normal((t, D_MODEL)), jnp.float32)
            w = jnp.asarray(rng.standard_normal((D_MODEL, E)), jnp.float32)
            _assert_bit_identical(x, w, E, K)
            fns = {"unfused": _unfused_fn(E, K), "fused": _fused_fn(K)}
            timed = _time_interleaved(fns, (x, w), iters=iters,
                                      warmup=WARMUP)
            model = cost_model.routing_time_report(t, D_MODEL, E, K,
                                                   cost_model.V5E)
            results.append({
                "t": t, "E": E, "k": K, "d": D_MODEL,
                "fused_ms": timed["fused"],
                "unfused_ms": timed["unfused"],
                "measured_ratio": timed["unfused"] / timed["fused"],
                "modeled_v5e_unfused_us": model["unfused_s"] * 1e6,
                "modeled_v5e_fused_us": model["fused_s"] * 1e6,
                "modeled_v5e_speedup": model["speedup"],
            })
    return results


def run_smoke():
    """CI smoke: one dispatch-sized cell, both impls through their jitted
    round trip (fused through the real interpret-mode Pallas kernel above
    ROUTER_FUSED_MIN_ROWS), bit-identical outputs asserted, no numbers
    recorded."""
    rng = np.random.default_rng(0)
    t, E = max(kops.ROUTER_FUSED_MIN_ROWS, 1024), 16
    x = jnp.asarray(rng.standard_normal((t, D_MODEL)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((D_MODEL, E)), jnp.float32)
    _fused_fn(K)(x, w).block_until_ready()
    print("smoke router_fused[fused]: ok")
    _unfused_fn(E, K)(x, w).block_until_ready()
    print("smoke router_fused[unfused]: ok")
    _assert_bit_identical(x, w, E, K)


def main() -> None:
    results = run_sweep()
    print(f"# fused routing megakernel vs unfused XLA chain, jitted, best "
          f"of {ITERS} interleaved (backend={jax.default_backend()}; fused "
          f"runs in Pallas interpret mode off-TPU — measured fused ms is "
          f"emulation overhead, see modeled columns)")
    print("t,E,unfused_ms,fused_ms,modeled_v5e_unfused_us,"
          "modeled_v5e_fused_us,modeled_v5e_speedup")
    for r in results:
        print(f"{r['t']},{r['E']},{r['unfused_ms']:.3f},{r['fused_ms']:.3f},"
              f"{r['modeled_v5e_unfused_us']:.1f},"
              f"{r['modeled_v5e_fused_us']:.1f},"
              f"{r['modeled_v5e_speedup']:.1f}x")
    worst = min(r["modeled_v5e_speedup"] for r in results)
    print(f"# outputs bit-identical on every cell; worst modeled v5e "
          f"fused-vs-unfused speedup across the sweep: {worst:.1f}x")
    payload = {
        "bench": "router_fused_vs_unfused",
        "iters": ITERS,
        "jax_backend": jax.default_backend(),
        "pallas_interpret_mode": jax.default_backend() != "tpu",
        "note": "off-TPU the fused measurement is interpret-mode emulation "
                "overhead; the structural comparison is the modeled v5e "
                "projection (cost_model.routing_time_report)",
        "results": results,
    }
    out_path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_router_fused.json")
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {out_path}")


if __name__ == "__main__":
    main()

"""Paper Appendix A.2 / Fig. 12: pipelined comm/compute overlap — the
paper's NEGATIVE result, reproduced with the calibrated cost model.

Splitting the MoE layer's tokens into ``c`` chunks lets chunk k's expert FFN
overlap chunk k+1's All2All, but every chunk pays the full per-peer launch
overhead (tau x peers) and the flow-contention term does not shrink with
message size — so the number of All2All operations grows linearly in ``c``
while the overlappable compute is tiny. The paper: "no matter how we
manipulate the chunk size, the performance still cannot improve."
"""
from __future__ import annotations

from benchmarks.cost_model import (P4D, MoELayerShape, calibrate_alpha,
                                   calibrate_tau, moe_layer_time)


def chunked_layer_time(router: str, chunks: int, alpha, tau) -> float:
    s = MoELayerShape(tokens_per_device=(128 * 128) // chunks,
                      d_model=768, d_ff=3072)
    per = moe_layer_time(s, P4D, 16, router, alpha=alpha, tau=tau)
    # pipeline: chunk k's FFN overlaps chunk k+1's A2A; launch cost per chunk
    a2a, ffn, launch = per["a2a_s"], per["ffn_s"], per["launch_s"]
    serial = chunks * (a2a + launch) + ffn          # a2a chain + last ffn
    return serial


def fig12():
    alpha, tau = calibrate_alpha(), calibrate_tau()
    rows = []
    for c in (1, 2, 4, 8, 16):
        t = chunked_layer_time("switch", c, alpha, tau)
        rows.append((c, 16384 / t))
    return rows


def main():
    rows = fig12()
    print("# Fig. 12 reproduction: throughput vs pipeline chunks "
          "(switch, 16 nodes)")
    print("chunks,samples_per_s")
    for c, thr in rows:
        print(f"{c},{thr:,.0f}")
    base = rows[0][1]
    best = max(r[1] for r in rows)
    print(f"# paper: no chunking configuration improves throughput; "
          f"ours: best/unchunked = {best/base:.2f}x (never > 1)")


if __name__ == "__main__":
    main()

"""Dispatch-backend micro-benchmark (EXPERIMENTS.md §Perf-1).

Times the full local dispatch -> combine round trip — position assignment,
capacity-buffer build, gate-weighted combine; jitted, no collectives, no
expert FFN — for the ``dense`` one-hot/cumsum backend vs the ``sort``
backend of :mod:`repro.core.dispatch`, across (T, E, k, capacity_factor).

The dense path is O(T*k*E) in memory and work before any useful byte moves;
the sort path is O(T*k log(T*k)) + pure gathers, so the gap widens with E.
Numbers here are CPU (interpret container); the structural win carries to
TPU where the dense one-hot also stresses VMEM.

Prints a CSV block and writes machine-readable ``BENCH_dispatch.json`` so
the perf trajectory is trackable across PRs.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dispatch as D
from repro.core.moe import capacity

D_MODEL = 128
ITERS = 20
WARMUP = 3
# (tokens, groups, k, capacity_factor)
SWEEP = [
    (1024, 16, 1, 2.0),
    (1024, 64, 2, 2.0),
    (4096, 64, 1, 2.0),
    (4096, 64, 2, 1.0),
    (4096, 64, 2, 2.0),
    (4096, 256, 2, 2.0),
    (8192, 64, 2, 2.0),
    (8192, 256, 1, 2.0),
    (16384, 256, 2, 1.0),
]


def _roundtrip(backend: str, E: int, cap: int, k: int):
    @jax.jit
    def fn(x, gids, gates):
        buf, state = D.dispatch(x, gids, gates, E, cap, k=k, backend=backend)
        return D.combine(buf, state)
    return fn


def _time_interleaved(fns, args, iters: int = ITERS,
                      warmup: int = WARMUP) -> dict:
    """Best-of timing with the variants interleaved per iteration, so
    machine-load drift on a shared box hits all of them equally.  Shared by
    bench_dropless."""
    for fn in fns.values():                       # compile + cache warmup
        for _ in range(warmup):
            fn(*args).block_until_ready()
    ts = {name: [] for name in fns}
    for _ in range(iters):
        for name, fn in fns.items():
            t0 = time.perf_counter()
            fn(*args).block_until_ready()
            ts[name].append(time.perf_counter() - t0)
    return {name: float(np.min(v)) * 1e3 for name, v in ts.items()}


def run_sweep():
    rng = np.random.default_rng(0)
    results = []
    for T, E, k, cf in SWEEP:
        cap = capacity(T, k, cf, E)
        A = T * k
        x = jnp.asarray(rng.standard_normal((T, D_MODEL)), jnp.float32)
        gids = jnp.asarray(rng.integers(0, E, A), jnp.int32)
        gates = jnp.asarray(rng.uniform(0, 1, A), jnp.float32)
        row = {"T": T, "E": E, "k": k, "capacity_factor": cf, "cap": cap}
        fns = {b: _roundtrip(b, E, cap, k) for b in D.CAPACITY_BACKENDS}
        timed = _time_interleaved(fns, (x, gids, gates))
        for backend, ms in timed.items():
            row[f"{backend}_ms"] = ms
        row["speedup"] = row["dense_ms"] / row["sort_ms"]
        results.append(row)
    return results


def run_sweep_smoke():
    """CI smoke: one tiny shape, both capacity backends, two timed iters —
    exercises the jitted round trips without recording numbers."""
    rng = np.random.default_rng(0)
    T, E, k, cf = 1024, 8, 2, 2.0
    cap = capacity(T, k, cf, E)
    x = jnp.asarray(rng.standard_normal((T, D_MODEL)), jnp.float32)
    gids = jnp.asarray(rng.integers(0, E, T * k), jnp.int32)
    gates = jnp.asarray(rng.uniform(0, 1, T * k), jnp.float32)
    fns = {b: _roundtrip(b, E, cap, k) for b in D.CAPACITY_BACKENDS}
    for name, fn in fns.items():
        fn(x, gids, gates).block_until_ready()
        print(f"smoke {name}: ok")


def main() -> None:
    results = run_sweep()
    print("# dispatch->combine round trip, jitted, d_model="
          f"{D_MODEL}, best of {ITERS} interleaved "
          f"(backend={jax.default_backend()})")
    print("T,E,k,cf,cap,dense_ms,sort_ms,speedup")
    for r in results:
        print(f"{r['T']},{r['E']},{r['k']},{r['capacity_factor']},"
              f"{r['cap']},{r['dense_ms']:.3f},{r['sort_ms']:.3f},"
              f"{r['speedup']:.2f}x")
    big = [r for r in results if r["T"] >= 4096 and r["E"] >= 64]
    worst = min(r["speedup"] for r in big)
    print(f"# worst speedup at T>=4096, E>=64: {worst:.2f}x")
    payload = {
        "bench": "dispatch_backends",
        "d_model": D_MODEL,
        "iters": ITERS,
        "jax_backend": jax.default_backend(),
        "results": results,
    }
    # anchored to the repo root so the tracked artifact updates regardless
    # of the cwd the harness runs from
    out_path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_dispatch.json")
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {out_path}")


if __name__ == "__main__":
    main()

"""Analytic communication/compute cost model for the paper's cluster and ours.

Two hardware profiles:

* ``P4D`` — the paper's testbed: AWS p4d, 8xA100 per node, NVSwitch 600 GB/s
  aggregate intra-node, EFA 400 Gbit/s (= 50 GB/s) per NODE inter-node.
* ``V5E`` — our target: TPU v5e, 197 bf16 TFLOP/s, 819 GB/s HBM,
  ~50 GB/s/link ICI, ~25 GB/s DCN per chip across pods.

The congestion model captures the paper's §3.1 observation: a flat N-way
All2All issues (N-1) point-to-point flows per NIC (Fig. 2's pairwise
send/recv loop), and effective per-flow goodput collapses as flows contend
(incast + small messages). We model

    time = bytes_on_wire / bw * (1 + alpha * (flows - 1))

with ``alpha`` calibrated ONCE against the paper's Table 3 measurement
(Switch Transformer inter-node All2All: 382 ms) and then reused everywhere —
including for SMILE's predictions, which makes the 2.5x reproduction a real
out-of-sample check rather than a fit.
"""
from __future__ import annotations

import math
from dataclasses import dataclass


@dataclass(frozen=True)
class Hardware:
    name: str
    flops: float          # peak per device (bf16/fp16)
    hbm_bw: float
    intra_bw: float       # per-device fast-domain bandwidth
    inter_bw: float       # per-device slow-domain bandwidth
    workers_per_node: int


P4D = Hardware("p4d-a100", flops=312e12, hbm_bw=2.0e12,
               intra_bw=600e9 / 8, inter_bw=50e9 / 8, workers_per_node=8)
V5E = Hardware("tpu-v5e", flops=197e12, hbm_bw=819e9,
               intra_bw=50e9, inter_bw=25e9, workers_per_node=16)


# ---------------------------------------------------------------- congestion
# calibrated in calibrate_alpha(); see module docstring
DEFAULT_ALPHA = 0.35

# per-peer launch/dispatch overhead (paper §3.2.1: All2All launch cost is
# O(mn) one-hop vs O(m+n) bi-level). Calibrated ONCE on the Switch row of
# Table 3 ("FFN Expert and Others" = 153 ms at 128 peers) in calibrate_tau().
DEFAULT_TAU = 1.15e-3


def a2a_time(bytes_per_device: float, group: int, bw: float,
             alpha: float = DEFAULT_ALPHA) -> float:
    """Flat All2All across ``group`` devices."""
    if group <= 1:
        return 0.0
    wire = bytes_per_device * (group - 1) / group
    flows = group - 1
    return wire / bw * (1.0 + alpha * (flows - 1))


# ------------------------------------------------------------ hop wire bytes
# Per-hop payload accounting for the two dispatch-hop wire formats.  The
# padded hop ships the full (groups, cap, d) capacity buffer regardless of
# how many rows are real; the ragged hop ships the exact assignment rows
# plus (a) bounded tile-alignment slack and (b) the int32 count headers
# (one count per peer + one raw length per group).  bench_ragged_a2a.py
# compares these MODELED numbers against counts MEASURED from live routing.

BYTES_INT32 = 4


def a2a_wire_bytes(payload_bytes: float, group: int) -> float:
    """Bytes a flat All2All of ``payload_bytes``/device puts on the wire:
    the (group-1)/group fraction that leaves the device."""
    if group <= 1:
        return 0.0
    return payload_bytes * (group - 1) / group


def capacity_hop_payload(tokens: int, k: int, capacity_factor: float,
                         groups: int, d_model: int,
                         bytes_per_elem: int = 2) -> float:
    """Per-device payload of one capacity-padded dispatch hop: the whole
    (groups, cap, d) buffer, ``~capacity_factor x`` the real rows (more
    when routing is skewed and slots sit empty while others overflow)."""
    cap = max(1, math.ceil(tokens * k * capacity_factor / groups))
    return groups * cap * d_model * bytes_per_elem


def ragged_hop_payload(assignments: int, groups: int, block: int,
                       d_model: int, bytes_per_elem: int = 2,
                       ranks: int = 1) -> float:
    """Worst-case per-device payload of one ragged dispatch hop: every real
    assignment row exactly once, plus at most ``block - 1`` alignment rows
    per group, plus the count headers (a (ranks,) segment-count A2A and the
    (groups,) raw-length grid)."""
    rows = assignments + groups * (block - 1)
    header = (ranks + groups) * BYTES_INT32
    return rows * d_model * bytes_per_elem + header


def hop_wire_report(tokens: int, k: int, capacity_factor: float, groups: int,
                    block: int, d_model: int, ranks: int,
                    bytes_per_elem: int = 2) -> dict:
    """Modeled padded-vs-ragged wire bytes for one dispatch hop across
    ``ranks`` peers.  ``reduction`` > 1 means the ragged hop ships less."""
    padded = a2a_wire_bytes(
        capacity_hop_payload(tokens, k, capacity_factor, groups, d_model,
                             bytes_per_elem), ranks)
    ragged = a2a_wire_bytes(
        ragged_hop_payload(tokens * k, groups, block, d_model,
                           bytes_per_elem, ranks), ranks)
    return {"padded_bytes": padded, "ragged_bytes": ragged,
            "reduction": padded / ragged if ragged else float("inf")}


def hop_time_report(tokens: int, k: int, capacity_factor: float, groups: int,
                    block: int, d_model: int, d_ff: int, ranks: int,
                    hw: Hardware, *, inter: bool = True,
                    bytes_per_elem: int = 2,
                    alpha: float = DEFAULT_ALPHA) -> dict:
    """Modeled one-hop round-trip time (dispatch A2A + expert FFN + return
    A2A), padded vs ragged, on a real hardware profile.

    Both variants re-compact before the FFN (the dropless invariant), so the
    FFN term is identical; what differs is the collective payload.  The
    congestion/launch model is the one calibrated against the paper's
    Table 3 — the same ``alpha`` for both variants, so the ratio is purely
    the byte reduction.  ``ratio`` > 1 means the ragged hop's modeled step
    is faster; at ``capacity_factor >= 1 + alignment slack`` it always is,
    because the ragged payload is a strict subset of the padded one.
    """
    bw = hw.inter_bw if inter else hw.intra_bw
    a = alpha if inter else 0.0
    padded = capacity_hop_payload(tokens, k, capacity_factor, groups,
                                  d_model, bytes_per_elem)
    ragged = ragged_hop_payload(tokens * k, groups, block, d_model,
                                bytes_per_elem, ranks)
    t_ffn = 2 * 2 * tokens * k * d_model * d_ff / hw.flops
    t_pad = 2 * a2a_time(padded, ranks, bw, a) + t_ffn
    t_rag = 2 * a2a_time(ragged, ranks, bw, a) + t_ffn
    return {"hw": hw.name, "padded_s": t_pad, "ragged_s": t_rag,
            "a2a_padded_s": 2 * a2a_time(padded, ranks, bw, a),
            "a2a_ragged_s": 2 * a2a_time(ragged, ranks, bw, a),
            "ffn_s": t_ffn,
            "ratio": t_pad / t_rag if t_rag else float("inf")}


# ------------------------------------------------------------- dispatch sort
# Modeled cost of the per-hop stable group sort (benchmarks/bench_radix_sort
# compares these projections against CPU-measured numbers, which carry an
# interpret-mode caveat for the Pallas path).

# elementwise int/compare ops run on the VPU, roughly an order of magnitude
# below MXU peak (8x128 lanes x a few ops/cycle vs the systolic array); one
# shared rough ratio for BOTH sort paths, so their ratio stays structural
VPU_MXU_RATIO = 32


def sort_time_report(n: int, num_keys: int, hw: Hardware,
                     block: int = 128) -> dict:
    """Modeled on-accelerator stable sort of ``n`` small-domain keys.

    Both paths are charged their HBM passes plus their elementwise compute
    at the same VPU rate (``hw.flops / VPU_MXU_RATIO``), term for term
    against the code that actually ships:

    * ``argsort`` — the packed baseline (``ref.group_sort_ref``): key and
      arrival index packed into ONE int32, so XLA's comparison sort
      streams 4 B/element over ~``log2(n)`` sequential merge-style passes,
      each doing ~2n compare-exchanges.  (When ``num_keys * n >= 2^31``
      the real fallback widens to a variadic 8 B/element sort; every
      dispatch-sized cell fits the packed path, so the model charges the
      cheaper layout and stays conservative.)  A comparison sort cannot
      exploit the tiny key domain — and XLA's sorting networks are far
      above this floor in practice, so the modeled ratio is a lower bound.
    * ``radix`` — the one-pass counting sort of
      :mod:`repro.kernels.radix_sort`, exactly as written: 5 A-sized
      streaming int32 transfers (the kernel reads keys and writes the
      local-rank intermediate; the fused ``ranks = local + starts[keys]``
      add re-reads both and writes ranks), and per element ``block``
      pairwise within-tile compares plus two lane-padded domain sweeps
      (histogram build + rank pick):
      ``n * (block + 2 * lane_pad(num_keys + 1))`` VPU ops.  The domain
      sweeps are why the win shrinks as ``num_keys`` grows past a lane
      width — the kernel targets dispatch's small domains.

    Deliberately simple (no fusion, no cache effects) — the point is the
    structural O(A log A) vs O(A + E) comparison at dispatch-sized inputs,
    with the same hardware numbers used by every other report here.
    """
    vpu = hw.flops / VPU_MXU_RATIO
    passes = max(1.0, math.log2(max(n, 2)))
    argsort_mem_s = passes * 2 * n * 4 / hw.hbm_bw
    argsort_vpu_s = passes * 2 * n / vpu
    argsort_s = argsort_mem_s + argsort_vpu_s
    # the kernel's histogram domain includes its pad sentinel (num_keys + 1
    # values) before lane padding — charge what it actually sweeps
    lanes = ((num_keys + 1 + 127) // 128) * 128
    radix_mem_s = 5 * n * 4 / hw.hbm_bw
    radix_vpu_s = n * (block + 2 * lanes) / vpu
    radix_s = radix_mem_s + radix_vpu_s
    return {"hw": hw.name, "n": n, "num_keys": num_keys,
            "argsort_s": argsort_s, "radix_s": radix_s,
            "argsort_mem_s": argsort_mem_s, "argsort_vpu_s": argsort_vpu_s,
            "radix_mem_s": radix_mem_s, "radix_vpu_s": radix_vpu_s,
            "speedup": argsort_s / radix_s if radix_s else float("inf")}


# ------------------------------------------------------------ routing stage
def routing_time_report(t: int, d: int, E: int, k: int, hw: Hardware,
                        block: int = 128) -> dict:
    """Modeled per-hop routing-stage time: the unfused XLA op chain vs the
    fused Pallas megakernel (:mod:`repro.kernels.router_fused`).

    Both paths are charged the IDENTICAL router GEMM (``2*t*d*E`` MXU
    flops — fusion cannot remove it) plus their HBM passes and VPU
    elementwise work at the shared ``hw.flops / VPU_MXU_RATIO`` rate, term
    for term against the code that actually ships:

    * ``unfused`` — ``router_probs`` + ``topk_gates`` + ``ops.group_sort``
      as separate XLA ops: the (t, E) logits tensor is written by the GEMM,
      re-read and re-written by softmax, and the probs re-read by
      ``lax.top_k`` — 4 full (t, E) HBM passes — plus the top-k output
      write and a separate packed-argsort position pass over the A = t*k
      chosen ids (:func:`sort_time_report`'s argsort term: ~log2(A)
      streaming passes).  VPU: ~3 softmax sweeps and k max-extraction
      sweeps over E lanes per token.
    * ``fused`` — one kernel pass over the token tiles: logits and probs
      are each written exactly ONCE (the z-/LB-loss contract needs them in
      HBM) and never re-read; gates / ids / local ranks stream out once
      (t*k each); softmax, top-k, histogram and the within-tile pairwise
      count all run in VMEM — per assignment ``block`` pairwise compares
      plus two lane-padded domain sweeps (the radix-kernel accounting)
      on top of the same softmax/top-k sweeps.

    The structural win is eliminating the logits/probs HBM round trips and
    the separate O(A log A) sort pass; the GEMM and the mandatory one-time
    writes are charged identically on both sides, so the ratio isolates
    exactly what the fusion removes.  Same deliberate simplicity as
    :func:`sort_time_report` (no cache effects, no overlap) — the point is
    the structural comparison at dispatch-sized shapes, with the same
    hardware numbers as every other report here.
    """
    vpu = hw.flops / VPU_MXU_RATIO
    A = t * k
    lanes = ((E + 127) // 128) * 128
    gemm_s = 2 * t * d * E / hw.flops
    te_bytes = t * E * 4                          # one fp32 (t, E) tensor
    sort = sort_time_report(A, E + 1, hw, block)
    unf_mem_s = (4 * te_bytes + 2 * A * 4) / hw.hbm_bw
    unf_vpu_s = t * E * (3 + k) / vpu
    unfused_s = gemm_s + unf_mem_s + unf_vpu_s + sort["argsort_s"]
    fus_mem_s = (2 * te_bytes + 3 * A * 4) / hw.hbm_bw
    fus_vpu_s = (t * E * (3 + k) + A * (block + 2 * lanes)) / vpu
    fused_s = gemm_s + fus_mem_s + fus_vpu_s
    return {"hw": hw.name, "t": t, "d": d, "E": E, "k": k,
            "unfused_s": unfused_s, "fused_s": fused_s,
            "gemm_s": gemm_s,
            "unfused_mem_s": unf_mem_s, "unfused_vpu_s": unf_vpu_s,
            "unfused_sort_s": sort["argsort_s"],
            "fused_mem_s": fus_mem_s, "fused_vpu_s": fus_vpu_s,
            "speedup": unfused_s / fused_s if fused_s else float("inf")}


def allreduce_time(bytes_per_device: float, group: int, bw: float) -> float:
    if group <= 1:
        return 0.0
    return 2.0 * bytes_per_device * (group - 1) / group / bw


@dataclass
class MoELayerShape:
    """One MoE layer under the paper's microbenchmark conditions."""
    tokens_per_device: int      # micro_batch x seq
    d_model: int
    d_ff: int
    capacity_factor: float = 2.0
    bytes_per_elem: int = 2     # fp16/bf16


def moe_layer_time(s: MoELayerShape, hw: Hardware, n_nodes: int,
                   router: str, alpha: float = DEFAULT_ALPHA,
                   tau: float = DEFAULT_TAU) -> dict:
    """Per-microbatch forward time breakdown of one MoE layer (paper Table 3).

    Both routers move the same per-device payload (the dispatched capacity
    buffer, ~capacity_factor x tokens x d_model); what differs is WHICH
    network level each hop crosses and how many flows contend.
    """
    m = hw.workers_per_node
    N = n_nodes * m
    payload = (s.tokens_per_device * s.capacity_factor * s.d_model
               * s.bytes_per_elem)

    # expert FFN compute (2 matmuls fwd) on received tokens
    ffn_flops = 2 * 2 * s.tokens_per_device * s.capacity_factor * \
        s.d_model * s.d_ff
    t_ffn = ffn_flops / hw.flops
    # router compute ~ negligible but paper counts it: T*d*groups
    t_router = 0.0

    if router == "switch":
        # one flat All2All over all N workers; the inter-node fraction of the
        # payload ((N-m)/N) crosses the NIC with N-1 contending flows
        inter_frac = (N - m) / N
        intra_frac = 1.0 - inter_frac
        t_inter = a2a_time(payload * inter_frac, N, hw.inter_bw, alpha) \
            if n_nodes > 1 else 0.0
        t_intra = a2a_time(payload * intra_frac, N, hw.intra_bw, alpha=0.0)
        n_hops = 2                           # dispatch + return
        t_router = 2 * s.tokens_per_device * s.d_model * N / hw.flops
        peers = n_nodes * m                  # O(mn) launch (paper §3.2.1)
    else:  # smile bi-level
        # hop 1: All2All over n nodes (corresponding local ranks) — n-1 flows
        t_inter = a2a_time(payload, n_nodes, hw.inter_bw, alpha) \
            if n_nodes > 1 else 0.0
        # hop 2: All2All over m local workers on NVSwitch/ICI
        t_intra = a2a_time(payload, m, hw.intra_bw, alpha=0.0)
        n_hops = 2
        t_router = 2 * s.tokens_per_device * s.d_model * \
            (n_nodes + m) / hw.flops
        peers = n_nodes + m                  # O(m+n) launch

    t_a2a = n_hops * (t_inter + t_intra)
    t_other = t_ffn + t_router + tau * peers
    total = t_a2a + t_other
    return {"total_s": total, "a2a_s": t_a2a,
            "a2a_inter_s": n_hops * t_inter, "a2a_intra_s": n_hops * t_intra,
            "ffn_s": t_ffn, "router_s": t_router, "other_s": t_other,
            "launch_s": tau * peers,
            "a2a_ratio": t_a2a / total if total else 0.0}


def calibrate_alpha(target_inter_ms: float = 382.0 / 2) -> float:
    """Fit alpha so the Switch inter-node All2All matches Table 3 (382 ms
    across the 2 forward hops -> 191 ms per hop) for the paper's setup:
    16 nodes x 8 GPUs, micro_batch=128, seq=128, d=768, fp16, cap 2.0."""
    s = MoELayerShape(tokens_per_device=128 * 128, d_model=768, d_ff=3072)
    payload = (s.tokens_per_device * s.capacity_factor * s.d_model * 2)
    N, m = 128, 8
    inter_frac = (N - m) / N
    wire = payload * inter_frac * (N - 1) / N
    base = wire / P4D.inter_bw
    # target = base * (1 + alpha*(N-2))
    alpha = (target_inter_ms / 1e3 / base - 1.0) / (N - 2)
    return max(alpha, 0.0)


def calibrate_tau(target_other_ms: float = 153.0) -> float:
    """Fit tau so Switch's "FFN Expert and Others" matches Table 3 (153 ms)
    at 128 peers, after subtracting modeled FFN + router compute."""
    s = MoELayerShape(tokens_per_device=128 * 128, d_model=768, d_ff=3072)
    ffn = 2 * 2 * s.tokens_per_device * s.capacity_factor * s.d_model \
        * s.d_ff / P4D.flops
    router = 2 * s.tokens_per_device * s.d_model * 128 / P4D.flops
    return max((target_other_ms / 1e3 - ffn - router) / 128, 0.0)


def train_step_time(model_flops_per_device: float, moe: dict,
                    n_moe_layers: int, hw: Hardware,
                    dp_bytes_per_device: float, n_nodes: int) -> float:
    """Full training step: 3x forward compute (fwd+bwd) + MoE comms
    (x3 for fwd+bwd re-dispatch) + gradient all-reduce."""
    t_compute = 3.0 * model_flops_per_device / hw.flops
    t_moe = 3.0 * n_moe_layers * moe["a2a_s"]
    t_dp = allreduce_time(dp_bytes_per_device, n_nodes, hw.inter_bw)
    return t_compute + t_moe + t_dp

"""Benchmark harness entry point — one function per paper table/figure.

Prints ``name,value,derived`` CSV blocks per benchmark. The dry-run-based
roofline requires ``experiments/dryrun`` to be populated (see
``python -m repro.launch.dryrun --all``); it is skipped gracefully otherwise.

``--smoke`` runs a minutes-not-hours subset (tiny dispatch + dropless
sweeps) that exercises every dispatch backend's jitted round trip without
recording numbers — the executable half of the CI recipe (see ci.sh).
"""
from __future__ import annotations

import os
import sys
import time


def _timed(name, fn):
    t0 = time.time()
    try:
        fn()
        status = "ok"
    except Exception as e:  # pragma: no cover
        status = f"FAIL: {type(e).__name__}: {e}"
    dt = (time.time() - t0) * 1e6
    print(f"\n[bench] {name},{dt:.0f}us,{status}\n" + "=" * 70)
    return status == "ok"


def smoke() -> None:
    """Tiny sweeps of the two dispatch benches: compiles and runs every
    backend round trip, asserts nothing hangs, writes NO json artifacts."""
    from benchmarks import (bench_dispatch, bench_dropless, bench_radix_sort,
                            bench_ragged_a2a, bench_router_fused,
                            bench_serving)
    ok = True
    ok &= _timed("smoke_dispatch", lambda: bench_dispatch.run_sweep_smoke())
    ok &= _timed("smoke_dropless", lambda: bench_dropless.run_sweep(
        sweep=[(2048, 16, 2)], cfs=(1.25,), iters=2))
    # both group-sort impls through one jitted cell (radix = the real
    # interpret-mode Pallas counting sort), bit-identical outputs asserted
    ok &= _timed("smoke_radix_sort", bench_radix_sort.run_smoke)
    # fused routing megakernel vs the unfused chain through one jitted
    # dispatch-sized cell (fused = the real interpret-mode Pallas kernel),
    # all six outputs asserted bit-identical
    ok &= _timed("smoke_router_fused", bench_router_fused.run_smoke)
    # one jitted ragged-exchange round trip (ragged + padded wire formats)
    # on a fake 8-device mesh, in a subprocess with its own XLA_FLAGS
    ok &= _timed("smoke_ragged_a2a", bench_ragged_a2a.run_smoke)
    # one tiny Poisson trace end to end through the paged continuous-
    # batching engine (replayability + compile-count invariants asserted)
    ok &= _timed("smoke_serving", bench_serving.run_smoke)
    sys.exit(0 if ok else 1)


def main() -> None:
    if "--smoke" in sys.argv:
        smoke()
        return
    from benchmarks import (bench_convergence, bench_dispatch, bench_dropless,
                            bench_model_sizes, bench_moe_layer,
                            bench_pipeline_chunks, bench_radix_sort,
                            bench_ragged_a2a, bench_router_fused,
                            bench_scaling, bench_serving, bench_throughput)
    ok = True
    # emit machine-readable BENCH_*.json alongside the CSVs
    ok &= _timed("dispatch_backends", bench_dispatch.main)
    ok &= _timed("radix_sort_vs_argsort", bench_radix_sort.main)
    ok &= _timed("router_fused_vs_unfused", bench_router_fused.main)
    ok &= _timed("dropless_vs_capacity", bench_dropless.main)
    ok &= _timed("ragged_vs_padded_a2a", bench_ragged_a2a.main)
    ok &= _timed("serving_closed_loop", bench_serving.main)
    ok &= _timed("table1_throughput", bench_throughput.main)
    ok &= _timed("table2_model_sizes", bench_model_sizes.main)
    ok &= _timed("table3_moe_layer", bench_moe_layer.main)
    ok &= _timed("fig8_scaling", bench_scaling.main)
    ok &= _timed("fig12_pipeline_chunks", bench_pipeline_chunks.main)
    ok &= _timed("fig6_7_convergence", bench_convergence.main)
    if os.path.isdir("experiments/dryrun") and os.listdir("experiments/dryrun"):
        from benchmarks import roofline
        ok &= _timed("roofline", roofline.main)
    else:
        print("[bench] roofline skipped (run repro.launch.dryrun --all first)")
    sys.exit(0 if ok else 1)


if __name__ == '__main__':
    main()

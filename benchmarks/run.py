"""Benchmark harness entry point — one function per paper table/figure.

Prints ``name,value,derived`` CSV blocks per benchmark. The dry-run-based
roofline requires ``experiments/dryrun`` to be populated (see
``python -m repro.launch.dryrun --all``); it is skipped gracefully otherwise.
"""
from __future__ import annotations

import os
import sys
import time


def _timed(name, fn):
    t0 = time.time()
    try:
        fn()
        status = "ok"
    except Exception as e:  # pragma: no cover
        status = f"FAIL: {type(e).__name__}: {e}"
    dt = (time.time() - t0) * 1e6
    print(f"\n[bench] {name},{dt:.0f}us,{status}\n" + "=" * 70)
    return status == "ok"


def main() -> None:
    from benchmarks import (bench_convergence, bench_dispatch,
                            bench_model_sizes, bench_moe_layer,
                            bench_pipeline_chunks, bench_scaling,
                            bench_throughput)
    ok = True
    # emits machine-readable BENCH_dispatch.json alongside the CSV
    ok &= _timed("dispatch_backends", bench_dispatch.main)
    ok &= _timed("table1_throughput", bench_throughput.main)
    ok &= _timed("table2_model_sizes", bench_model_sizes.main)
    ok &= _timed("table3_moe_layer", bench_moe_layer.main)
    ok &= _timed("fig8_scaling", bench_scaling.main)
    ok &= _timed("fig12_pipeline_chunks", bench_pipeline_chunks.main)
    ok &= _timed("fig6_7_convergence", bench_convergence.main)
    if os.path.isdir("experiments/dryrun") and os.listdir("experiments/dryrun"):
        from benchmarks import roofline
        ok &= _timed("roofline", roofline.main)
    else:
        print("[bench] roofline skipped (run repro.launch.dryrun --all first)")
    sys.exit(0 if ok else 1)


if __name__ == '__main__':
    main()

"""Paper Table 3 / Fig. 9: single-MoE-layer time breakdown.

Two parts:
  (a) the paper's own cluster (p4d, 16 nodes) through the calibrated cost
      model — reproduces the 535 ms vs 146 ms structure;
  (b) our TPU target: lower ONE MoE layer (switch vs smile) on the
      single-pod production mesh and report measured HLO collective bytes
      per hop from the compiled module (run separately via
      ``python -m benchmarks.bench_moe_layer --lower``; needs 512 fake
      devices so it is not part of the default bench run).
"""
from __future__ import annotations

import sys

from benchmarks.cost_model import (P4D, MoELayerShape, calibrate_alpha,
                                   calibrate_tau, moe_layer_time)


def table3(alpha=None, tau=None):
    alpha = calibrate_alpha() if alpha is None else alpha
    tau = calibrate_tau() if tau is None else tau
    s = MoELayerShape(tokens_per_device=128 * 128, d_model=768, d_ff=3072)
    rows = []
    for router in ("switch", "smile"):
        r = moe_layer_time(s, P4D, n_nodes=16, router=router, alpha=alpha,
                           tau=tau)
        rows.append((router, r))
    return alpha, rows


def main():
    alpha, rows = table3()
    print(f"# Table 3 reproduction (cost model; alpha + tau calibrated on "
          f"the two Switch rows only — SMILE rows are out-of-sample)")
    print("router,total_ms,a2a_ms,a2a_inter_ms,a2a_intra_ms,other_ms,"
          "launch_ms,a2a_ratio")
    for router, r in rows:
        print(f"{router},{r['total_s']*1e3:.1f},{r['a2a_s']*1e3:.1f},"
              f"{r['a2a_inter_s']*1e3:.1f},{r['a2a_intra_s']*1e3:.1f},"
              f"{r['other_s']*1e3:.1f},{r['launch_s']*1e3:.1f},"
              f"{r['a2a_ratio']:.2f}")
    sw = dict(rows)["switch"]
    sm = dict(rows)["smile"]
    print(f"# paper: total 535 vs 146 ms (3.7x); ours: "
          f"{sw['total_s']/sm['total_s']:.2f}x")
    print(f"# paper: a2a 382 vs 86 ms (4.4x); ours: "
          f"{sw['a2a_s']/sm['a2a_s']:.2f}x")
    print(f"# paper: a2a ratio 71% -> 59%; ours: {sw['a2a_ratio']:.0%} -> "
          f"{sm['a2a_ratio']:.0%}")


if __name__ == "__main__":
    main()

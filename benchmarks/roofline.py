"""Roofline analysis (deliverable g).

Reads ``experiments/dryrun/*.json`` (written by the multi-pod dry-run) and
derives, per (arch x shape) on the single-pod 256-chip mesh:

  compute_s    = loop-corrected HLO dot FLOPs / 197e12        (per chip)
  memory_s     = loop-corrected HLO traffic bytes / 819e9     (per chip)
  collective_s = per-class wire bytes / {50e9 ICI, 25e9 DCN}  (per chip)

plus MODEL_FLOPS = 6*N_active*D and the utilization ratio
MODEL_FLOPS / HLO_dot_FLOPs. The dominant term is the hillclimb target.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

PEAK_FLOPS = 197e12
HBM_BW = 819e9
CHIPS = 256


def load(out_dir: str = "experiments/dryrun", mesh: str = "single",
         tag: str = "") -> List[Dict]:
    rows = []
    suffix = f"__{mesh}{('__' + tag) if tag else ''}.json"
    for fn in sorted(glob.glob(os.path.join(out_dir, f"*{suffix}"))):
        base = os.path.basename(fn)[: -len(suffix)]
        if not tag and "__single__" in os.path.basename(fn):
            continue
        with open(fn) as f:
            rows.append(json.load(f))
    return rows


def _tokens(shape: str) -> int:
    return {"train_4k": 256 * 4096, "prefill_32k": 32 * 32768,
            "decode_32k": 128, "long_500k": 1}[shape]


def roofline_row(r: Dict) -> Dict:
    shape = r["shape"]
    chips = 512 if r.get("mesh") == "2x16x16" else 256
    compute_s = r["dot_flops_corrected"] / PEAK_FLOPS
    # HBM proxy: matmul operand/output streams (weights + activations +
    # KV-cache reads). The all-op boundary sum is kept as an upper bound —
    # on CPU the emitter fuses nothing, so that sum counts every temp.
    memory_s = r.get("dot_bytes_corrected", 0.0) / HBM_BW
    memory_ub_s = r["traffic_bytes_corrected"] / HBM_BW
    coll_s = r["collectives"]["total_seconds"]
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dom = max(terms, key=terms.get)
    D = _tokens(shape)
    mult = 3.0 if shape == "train_4k" else 1.0        # fwd+bwd
    model_flops = 2.0 * r["active_params"] * D * mult / chips
    hlo = max(r["dot_flops_corrected"], 1.0)
    return {
        "arch": r["arch"], "shape": shape, "router": r.get("router"),
        "compute_s": compute_s, "memory_s": memory_s,
        "memory_upper_s": memory_ub_s, "collective_s": coll_s,
        "dominant": dom,
        "model_flops": model_flops,
        "useful_ratio": model_flops / hlo,
        "dcn_s": r["collectives"].get("dcn_seconds", 0.0),
        "a2a_bytes": r["collectives"]["bytes_per_op"].get("all-to-all", 0.0),
        "ar_bytes": r["collectives"]["bytes_per_op"].get("all-reduce", 0.0),
        "ag_bytes": r["collectives"]["bytes_per_op"].get("all-gather", 0.0),
        "arg_gb": r["memory"]["argument_bytes"] / 2**30,
        "temp_gb": r["memory"]["temp_bytes"] / 2**30,
    }


def table(out_dir: str = "experiments/dryrun") -> List[Dict]:
    return [roofline_row(r) for r in load(out_dir)]


def main():
    import sys
    mesh = "multi" if "--multi" in sys.argv else "single"
    rows = [roofline_row(r) for r in load(mesh=mesh)]
    print(f"# Roofline ({'multi-pod 2x16x16' if mesh == 'multi' else 'single-pod 16x16'}, per-chip seconds per step)")
    print("arch,shape,compute_s,memory_s,collective_s,dcn_s,dominant,"
          "useful_ratio,a2a_GB,arg_GB,temp_GB")
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        print(f"{r['arch']},{r['shape']},{r['compute_s']:.4f},"
              f"{r['memory_s']:.4f},{r['collective_s']:.4f},"
              f"{r['dcn_s']:.4f},{r['dominant']},"
              f"{r['useful_ratio']:.3f},{r['a2a_bytes']/2**30:.2f},"
              f"{r['arg_gb']:.1f},{r['temp_gb']:.1f}")
    doms = {}
    for r in rows:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    print(f"# dominant-term distribution: {doms}")


if __name__ == "__main__":
    main()

"""Small pytree utilities used across the framework."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_count(tree) -> int:
    """Total number of scalar parameters in a pytree."""
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree) -> int:
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(tree))


def tree_norm(tree):
    """Global L2 norm of a pytree (fp32 accumulation)."""
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def tree_cast(tree, dtype):
    return jax.tree.map(lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)


def tree_zeros_like(tree):
    return jax.tree.map(jnp.zeros_like, tree)

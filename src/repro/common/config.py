"""Configuration dataclasses for the repro framework.

Every architecture in ``repro.configs`` instantiates a :class:`ModelConfig`.
The config is a plain frozen dataclass so it is hashable (usable as a static
arg under ``jax.jit``) and trivially serializable.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Optional, Tuple


# =============================================================================
# MoE options registry — the single source of truth for every runtime-
# tunable dispatch/routing knob.  ``MoEConfig.with_options`` validates
# against it, and both launchers derive their flags from it
# (``launch/train.py`` CLI flags, ``launch/dryrun.py`` ``--opt`` tokens), so
# a new knob added here is automatically reachable from every entry point —
# it cannot silently miss a launcher.
# =============================================================================

@dataclass(frozen=True)
class MoEOption:
    """One tunable knob of :class:`MoEConfig` (also reused as the generic
    option-registry record for :data:`TRAIN_OPTIONS`).

    ``kind``: ``"choice"`` (string enum), ``"bool"``, ``"float"``
    (optional float, None = off), ``"int"`` (non-negative integer), or
    ``"str"`` (optional free-form string, None = off).  ``dryrun_opts``
    maps ``dryrun --opt`` tokens to the value they set (e.g.
    ``("padded_a2a", False)``); the CLI flag name for ``train.py`` is
    derived from ``field``.  ``requires`` lists (field, value)
    prerequisites the option is meaningless without — a dryrun token
    implies them (so ``--opt recv_bound`` alone works), and
    ``MoEConfig.with_options`` enforces them on the resulting config.
    """
    field: str
    kind: str
    choices: Tuple[str, ...] = ()
    help: str = ""
    dryrun_opts: Tuple[Tuple[str, Any], ...] = ()
    requires: Tuple[Tuple[str, Any], ...] = ()

    @property
    def flag(self) -> str:
        return "--" + self.field.replace("_", "-")


MOE_OPTIONS: Tuple[MoEOption, ...] = (
    MoEOption("dispatch_backend", "choice", ("sort", "dense", "dropless"),
              help="local dispatch/combine math: sort (argsort + fused "
                   "gathers, the fast path), dense (one-hot/cumsum oracle), "
                   "dropless (capacity-free tile-aligned ragged layout)",
              dryrun_opts=(("dropless", "dropless"),)),
    MoEOption("ragged_a2a", "bool",
              help="dropless only: exact-segment ragged All2All hops (on) "
                   "vs capacity-padded hops + on-arrival re-compaction (off)",
              dryrun_opts=(("padded_a2a", False),)),
    MoEOption("sort_impl", "choice", ("argsort", "radix"),
              help="group sort under every dispatch hop: argsort = XLA "
                   "stable sort, radix = one-pass Pallas counting sort "
                   "(TPU fast path; bit-identical)",
              dryrun_opts=(("radix_sort", "radix"),)),
    MoEOption("router_impl", "choice", ("unfused", "fused"),
              help="routing-stage implementation for every hop's router: "
                   "unfused = separate fp32 GEMM + softmax + lax.top_k XLA "
                   "ops, fused = the single-pass Pallas routing megakernel "
                   "(repro.kernels.router_fused: GEMM, softmax, top-k, "
                   "histogram and dispatch positions in one VMEM pass; "
                   "bit-compatible loss inputs, interpret-validated "
                   "off-TPU)",
              dryrun_opts=(("fused_router", "fused"),)),
    MoEOption("recv_bound_factor", "float",
              help="ragged hops only: bound each receive slab at ~factor x "
                   "expected arrivals instead of the worst-case P x R rows "
                   "(clamp-drops under extreme skew, reported in drop_frac; "
                   "None/off = unbounded, bit-identical zero-drop)",
              dryrun_opts=(("recv_bound", 2.0),),
              requires=(("dispatch_backend", "dropless"),
                        ("ragged_a2a", True))),
    MoEOption("tight_level2_capacity", "bool",
              help="SMILE: size level-2 capacity from expected valid "
                   "arrivals instead of the padded level-1 buffer",
              dryrun_opts=(("tightcap", True),)),
    MoEOption("fault_plan", "str",
              help="deterministic fault injection 'kind[@seed][:hop]' with "
                   "kind in counts|nanrows|dropseg|skew|bitflip|inflate|"
                   "dupseg (see repro.common.faultinject); count/wire "
                   "faults are inert on padded/local hops; 'off'/None = no "
                   "injection (the bit-identical production path)",
              dryrun_opts=(("fault_counts", "counts"),
                           ("fault_nanrows", "nanrows"),
                           ("fault_dropseg", "dropseg"),
                           ("fault_skew", "skew"),
                           ("fault_bitflip", "bitflip"),
                           ("fault_inflate", "inflate"),
                           ("fault_dupseg", "dupseg"))),
    MoEOption("wire_integrity", "choice", ("off", "detect", "quarantine"),
              help="per-segment payload checksums on every ragged exchange "
                   "(parity rows riding the slab, both directions): off = "
                   "production wire (bit-identical), detect = verify + "
                   "account wire_faults but pass payloads through (A/B), "
                   "quarantine = additionally zero-fill and drop flagged "
                   "segments with exact per-(hop, src rank) accounting",
              dryrun_opts=(("wire_detect", "detect"),
                           ("wire_quarantine", "quarantine")),
              requires=(("dispatch_backend", "dropless"),
                        ("ragged_a2a", True))),
)

MOE_OPTION_FIELDS = {o.field: o for o in MOE_OPTIONS}

# =============================================================================
# Train-loop options registry — same record type, same derivation contract:
# ``launch/train.py`` generates one CLI flag per entry and ``launch/dryrun``
# maps the dryrun tokens, so checkpoint/resume/sentinel knobs stay in sync
# across both launchers exactly like the MoE dispatch knobs do.  Fields that
# exist on :class:`TrainConfig` (``sentinel``, ``ckpt_every``, ``ckpt_keep``,
# ``ckpt_dir``) configure it; ``resume`` is a launcher action (auto-pickup of
# the latest valid checkpoint in ``--ckpt-dir``).
# =============================================================================

TRAIN_OPTIONS: Tuple[MoEOption, ...] = (
    MoEOption("sentinel", "bool",
              help="step sentinel: per-step non-finite / loss-spike verdict "
                   "inside jit with a lax.cond-guarded optimizer apply that "
                   "skips bad updates, plus the router-collapse watchdog "
                   "(see repro.train.sentinel)",
              dryrun_opts=(("sentinel", True),)),
    MoEOption("resume", "bool",
              help="resume from the newest valid checkpoint in --ckpt-dir "
                   "(digest-verified; falls back to older snapshots on "
                   "corruption)"),
    MoEOption("ckpt_every", "int",
              help="save a rotating checkpoint every N steps (0 = off)"),
    MoEOption("ckpt_keep", "int",
              help="checkpoints kept in the keep-last-K rotation"),
    MoEOption("ckpt_dir", "str",
              help="run directory for the rotating checkpoints + checksummed "
                   "manifest"),
)

TRAIN_OPTION_FIELDS = {o.field: o for o in TRAIN_OPTIONS}
TRAIN_DRYRUN_OPTS = {tok: {o.field: val}
                     for o in TRAIN_OPTIONS for tok, val in o.dryrun_opts}
# dryrun --opt token -> {field: value} with the option's prerequisites
# merged in (so e.g. "recv_bound" alone implies dropless + ragged hops, the
# way the old hand-written "dropless" token implied ragged_a2a); tokens not
# in this map are dryrun-local (rsc, kvseq, zero1, ...).  Callers apply
# tokens in sorted order for determinism.
MOE_DRYRUN_OPTS = {tok: {**dict(o.requires), o.field: val}
                   for o in MOE_OPTIONS for tok, val in o.dryrun_opts}

# =============================================================================
# Serving options registry — same record type and derivation contract:
# ``launch/serve.py`` generates one CLI flag per entry, and
# ``analysis/repo_lint.check_config_registry`` enforces the two-way mapping
# against :class:`ServeConfig` (every registry field exists on the config;
# every non-structural config field has a registry entry).  These are the
# continuous-batching engine knobs (``repro.serve.engine``): page-pool
# geometry, slot count, prefill bucketing, and the admission policy.
# =============================================================================

SERVE_OPTIONS: Tuple[MoEOption, ...] = (
    MoEOption("page_size", "int",
              help="paged KV cache: tokens per page (pool granularity; small "
                   "pages waste less tail space but grow the page table)"),
    MoEOption("pool_pages", "int",
              help="paged KV cache: total pages preallocated per layer "
                   "(0 = derive n_slots * ceil(cache_len / page_size), i.e. "
                   "every slot can hold a full-length sequence)"),
    MoEOption("n_slots", "int",
              help="continuous batching: sequences in flight per decode tick "
                   "(the fused batched decode step is compiled once at this "
                   "batch)"),
    MoEOption("prefill_buckets", "str",
              help="comma-separated prefill chunk lengths, each compiled "
                   "once (empty = derive doubling sizes up to cache_len); "
                   "long prompts prefill chunk-by-chunk across ticks so they "
                   "never stall the decode tick"),
    MoEOption("admit_policy", "choice", ("fcfs", "sjf"),
              help="admission order for waiting requests: fcfs = arrival "
                   "order (starvation-free), sjf = shortest prompt first "
                   "(lower mean TTFT, can starve long prompts)"),
)

SERVE_OPTION_FIELDS = {o.field: o for o in SERVE_OPTIONS}


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-Experts block configuration."""

    num_experts: int = 0                # routed experts (0 = dense layer)
    top_k: int = 1
    top_g: int = 1                      # bi-level: nodes per token (k_local = top_k/top_g)
    renorm_gates: bool = False          # renormalize selected gates to sum 1
    d_ff_expert: int = 0                # expert FFN hidden size
    num_shared_experts: int = 0         # always-on shared experts (deepseek-v3)
    capacity_factor: float = 2.0        # paper uses 2.0
    router: str = "switch"              # "switch" (one-hop) | "smile" (bi-level)
    lb_alpha: float = 0.005             # inter-node LB loss coefficient (Eq. 4)
    lb_beta: float = 0.005              # intra-node LB loss coefficient (Eq. 4)
    router_z_coef: float = 0.0          # optional z-loss on router logits
    every_n_layers: int = 1             # MoE layer every n-th layer (paper: 2)
    first_dense_layers: int = 0         # leading dense layers (deepseek-v3: 3)
    # Bi-level grid (n_inter x n_intra expert slots). 0 -> derive from mesh.
    grid: Tuple[int, int] = (0, 0)
    # beyond-paper: size level-2 capacity from EXPECTED valid arrivals rather
    # than the padded level-1 buffer (fixes capacity compounding; see
    # EXPERIMENTS.md §Perf-2). False reproduces the paper-faithful baseline.
    tight_level2_capacity: bool = False
    # local dispatch/combine math (repro.core.dispatch): "sort" (argsort +
    # fused gathers, the fast path; see EXPERIMENTS.md §Perf-1), "dense"
    # (one-hot/cumsum oracle), or "dropless" (capacity-free expert compute
    # over tile-aligned ragged segments — zero padding into the FFN and zero
    # token drops wherever the expert grid is local; capacity buffers remain
    # only on fixed-shape All2All hops.  See EXPERIMENTS.md §Perf-3).
    dispatch_backend: str = "sort"
    # "dropless" on a meshed expert grid: move exact ragged token segments
    # over every dispatch hop (repro.sharding.comm.ragged_all_to_all) instead
    # of capacity-padded All2All buffers — zero-pad AND zero-drop end-to-end.
    # False restores the fixed-shape capacity hop + on-arrival re-compaction
    # (the pre-ragged behavior, kept for A/B).  Ignored by the capacity
    # backends ("sort"/"dense"), which always ship capacity buffers.
    ragged_a2a: bool = True
    # group-sort implementation under every dispatch hop (sort backend's
    # position assignment, dropless sender layout, ragged receiver
    # re-compaction): "argsort" = XLA's generic O(A log A) sort (packed
    # single-operand lax.sort; the default — fastest on this CPU
    # container), "radix" = the one-pass O(A) Pallas counting sort over the
    # small group-id domain (repro.kernels.radix_sort — the TPU fast path;
    # interpret-validated off-TPU).  Bit-identical outputs either way; see
    # EXPERIMENTS.md §Perf-5 and tests/test_dispatch_conformance.py.
    sort_impl: str = "argsort"
    # routing-stage implementation, consumed where RouteDecision is built
    # (core/moe.py router_topk, shared by switch's flat hop and both SMILE
    # levels): "unfused" = separate fp32 GEMM + softmax + lax.top_k XLA ops
    # (the default — fastest on this CPU container), "fused" = the
    # single-pass Pallas routing megakernel (repro.kernels.router_fused —
    # GEMM, softmax, top-k, histogram and dispatch positions in one VMEM
    # pass, no logits round trip to HBM; the TPU fast path, interpret-
    # validated off-TPU).  Loss inputs (router probs/logits) stay
    # bit-compatible; see EXPERIMENTS.md §Perf-7 and
    # tests/test_dispatch_conformance.py.
    router_impl: str = "unfused"
    # ragged hops only: bound each hop's receive slab at ~factor x expected
    # arrivals (tile-aligned) instead of the zero-drop worst case of
    # n_ranks x R rows.  Arrivals beyond the bound are clamp-dropped (the
    # reverse hop echoes the clamped counts so senders know exactly which
    # rows returned) and reported in drop_frac; the post-hop FFN/router
    # bound shrinks ~n_ranks/factor-fold.  None = unbounded (bit-identical
    # zero-drop, the default).  Applies to every ragged hop — switch's flat
    # hop and both SMILE levels — through the shared HopSpec
    # (repro.core.pipeline).  Truncating hops stay on the native
    # lax.ragged_all_to_all where available: both sides pre-clamp their
    # paired sizes from the replicated count matrix
    # (comm.clamped_segment_counts), matching the emulations' prefix
    # truncation exactly.
    recv_bound_factor: Optional[float] = None
    # deterministic fault injection: "kind[@seed][:hop]" parsed by
    # repro.common.faultinject (counts | nanrows | dropseg | skew).  None =
    # no injection — the executor's fault hooks vanish and the layer is
    # bit-identical to the pre-harness pipeline (pinned by the golden
    # matrix).  Count-grid sanitization + fault_events accounting stay
    # active either way; only the *injection* is gated on this.
    fault_plan: Optional[str] = None
    # wire-integrity policy for every ragged exchange (repro.core.pipeline /
    # repro.sharding.comm checksummed_ragged_all_to_all): "off" traces the
    # exact production wire; "detect" appends per-segment parity rows,
    # verifies on arrival (both directions) and accounts
    # MoEStats.fault_events / wire_faults but passes payloads through;
    # "quarantine" additionally zero-fills flagged segments and drops their
    # assignments with exact per-(hop, src rank) accounting.  Requires the
    # dropless backend with ragged hops (nothing else puts segments on a
    # wire); single-rank hops are untouched (no wire to guard).
    wire_integrity: str = "off"

    def with_options(self, **kw) -> "MoEConfig":
        """Rebuild with runtime dispatch options swapped, validated against
        :data:`MOE_OPTIONS` — the single entry point every launcher and the
        deprecated ``configs.with_dispatch_backend`` shim route through.

        Only registered option fields are accepted; choice values are
        checked, and cross-option constraints (``recv_bound_factor``
        requires the dropless backend with ragged hops) are enforced on the
        *resulting* config so partial updates can't silently configure a
        knob onto a path that ignores it.
        """
        for key, val in kw.items():
            opt = MOE_OPTION_FIELDS.get(key)
            if opt is None:
                raise ValueError(
                    f"unknown MoE option {key!r}; registered options: "
                    f"{sorted(MOE_OPTION_FIELDS)}")
            if opt.kind == "choice" and val not in opt.choices:
                raise ValueError(f"{key}={val!r}: expected one of "
                                 f"{opt.choices}")
            if opt.kind == "bool" and not isinstance(val, bool):
                raise ValueError(f"{key}={val!r}: expected a bool")
            if opt.kind == "float" and val is not None:
                # bool is an int subclass: True would silently mean 1.0
                if (isinstance(val, bool)
                        or not isinstance(val, (int, float)) or val <= 0):
                    raise ValueError(f"{key}={val!r}: expected a positive "
                                     f"number or None")
            if opt.kind == "str" and val is not None:
                if not isinstance(val, str):
                    raise ValueError(f"{key}={val!r}: expected a string or "
                                     f"None")
                if key == "fault_plan":
                    # fail at config time, not silently mid-run (parse_
                    # fault_plan raises ValueError on malformed specs)
                    from repro.common.faultinject import parse_fault_plan
                    parse_fault_plan(val)
        cfg = dataclasses.replace(self, **kw)
        # registry-declared prerequisites, checked on the RESULT so partial
        # updates can't configure a knob onto a path that ignores it (an
        # option counts as active unless its value is the knob's inert
        # default: None, False, or the "off" choice)
        for opt in MOE_OPTIONS:
            if not opt.requires or getattr(cfg, opt.field) in (None, False,
                                                               "off"):
                continue
            for req_field, req_val in opt.requires:
                if getattr(cfg, req_field) != req_val:
                    raise ValueError(
                        f"{opt.field}={getattr(cfg, opt.field)!r} requires "
                        f"{req_field}={req_val!r}; got "
                        f"{getattr(cfg, req_field)!r}")
        return cfg


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block configuration."""

    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 128                    # SSD chunk length


@dataclass(frozen=True)
class RWKVConfig:
    """RWKV6 ("Finch") block configuration."""

    head_dim: int = 64
    decay_lora: int = 64                # rank of data-dependent decay LoRA
    mix_lora: int = 32                  # rank of token-shift mix LoRA


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    arch_type: str = "dense"            # dense|moe|hybrid|ssm|vlm|audio|mlm
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    d_ff: int = 1024
    vocab_size: int = 32000
    head_dim: int = 0                   # 0 -> d_model // num_heads
    max_seq_len: int = 131072

    # --- attention flavour -------------------------------------------------
    attention: str = "full"             # full|sliding|mla|none
    causal: bool = True                 # False -> bidirectional (BERT/MLM)
    window: int = 8192                  # sliding-window size
    rope_theta: float = 10000.0
    use_rope: bool = True
    qkv_bias: bool = False              # qwen1.5 uses QKV bias
    norm: str = "rmsnorm"               # rmsnorm|layernorm
    act: str = "silu"                   # silu|gelu
    glu: bool = True                    # gated FFN (llama-style); False -> plain MLP
    tie_embeddings: bool = False
    # MLA (deepseek-v3) dims
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    # --- block pattern ------------------------------------------------------
    # hybrid (zamba2): `ssm_layers_per_attn` mamba2 layers then 1 shared attn
    ssm_layers_per_attn: int = 6

    # --- sub-configs ---------------------------------------------------------
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None

    # --- multimodal stubs ----------------------------------------------------
    num_codebooks: int = 1              # musicgen: 4
    vision_tokens: int = 0              # phi-3-vision: image patch token budget
    vision_embed_dim: int = 0           # CLIP output dim before projector

    # --- extras ----------------------------------------------------------------
    mtp_depth: int = 0                  # deepseek-v3 multi-token prediction depth
    dtype: str = "bfloat16"             # compute dtype
    param_dtype: str = "float32"
    remat: bool = True                  # activation checkpointing over layer scan
    scan_layers: bool = True
    # beyond-paper knobs (see EXPERIMENTS.md §Perf):
    remat_save_collectives: bool = False  # don't re-psum during remat replay
    kv_seq_shard: bool = False            # decode: shard KV cache seq over tp
    # citation for the assigned config
    source: str = ""

    # ---------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.num_heads

    @property
    def is_attention_free(self) -> bool:
        return self.attention == "none"

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-FLOPs accounting)."""
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        hd = self.resolved_head_dim
        total = V * d                                     # embeddings
        if not self.tie_embeddings:
            total += V * d                                # lm head
        for i in range(L):
            total += self._layer_params(i)
        if self.mtp_depth:
            total += self.mtp_depth * (self._layer_params(L - 1) + 2 * d * d)
        return total

    def _attn_params(self) -> int:
        d, hd = self.d_model, self.resolved_head_dim
        if self.attention == "mla":
            qr, kvr = self.q_lora_rank, self.kv_lora_rank
            qk = self.qk_nope_head_dim + self.qk_rope_head_dim
            return (d * qr + qr * self.num_heads * qk
                    + d * (kvr + self.qk_rope_head_dim)
                    + kvr * self.num_heads * (self.qk_nope_head_dim + self.v_head_dim)
                    + self.num_heads * self.v_head_dim * d)
        q = d * self.num_heads * hd
        kv = 2 * d * self.num_kv_heads * hd
        o = self.num_heads * hd * d
        return q + kv + o

    def _ffn_params(self, d_ff: int) -> int:
        mult = 3 if self.glu else 2
        return mult * self.d_model * d_ff

    def _layer_params(self, i: int) -> int:
        d = self.d_model
        if self.arch_type == "ssm" and self.rwkv is not None:
            # rwkv6: time-mix ~ 4*d*d + decay/mix LoRAs, channel-mix 3*d*d
            r = self.rwkv
            tm = 4 * d * d + d * r.decay_lora * 2 + 5 * d * r.mix_lora * 2 + d * d
            cm = self.d_ff * d * 2 + d * d
            return tm + cm + 2 * d
        if self.arch_type == "hybrid" and self.ssm is not None:
            s = self.ssm
            d_in = s.expand * d
            nheads = d_in // s.head_dim
            mamba = (d * (2 * d_in + 2 * s.d_state * 0 + 0)
                     + d * (2 * d_in + 2 * s.d_state + nheads)  # in_proj (x,z,B,C,dt)
                     + d_in * d)                                 # out_proj
            per_group = self.ssm_layers_per_attn
            # shared attention amortized across groups
            shared = (self._attn_params() + self._ffn_params(self.d_ff)) / max(
                1, self.num_layers // per_group) / per_group
            return int(mamba + shared + 2 * d)
        ffn = self._ffn_params(self.d_ff)
        if self.moe is not None and self.moe.num_experts:
            is_moe = (i >= self.moe.first_dense_layers
                      and (i - self.moe.first_dense_layers) % self.moe.every_n_layers == 0)
            if is_moe:
                e_ffn = self._ffn_params(self.moe.d_ff_expert)
                ffn = (self.moe.num_experts + self.moe.num_shared_experts) * e_ffn
                ffn += self.moe.num_experts * self.d_model  # router
        return self._attn_params() + ffn + 2 * self.d_model

    def active_param_count(self) -> int:
        """Activated params per token (MoE: only top-k + shared experts)."""
        if self.moe is None or not self.moe.num_experts:
            return self.param_count()
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        total = V * d + (0 if self.tie_embeddings else V * d)
        for i in range(L):
            ffn = self._ffn_params(self.d_ff)
            is_moe = (i >= self.moe.first_dense_layers
                      and (i - self.moe.first_dense_layers) % self.moe.every_n_layers == 0)
            if is_moe:
                e_ffn = self._ffn_params(self.moe.d_ff_expert)
                ffn = (self.moe.top_k + self.moe.num_shared_experts) * e_ffn
                ffn += self.moe.num_experts * d
            total += self._attn_params() + ffn + 2 * d
        return total


@dataclass(frozen=True)
class TrainConfig:
    global_batch_size: int = 256
    micro_batch_size: int = 0           # 0 -> no gradient accumulation
    seq_len: int = 4096
    steps: int = 100
    optimizer: str = "lamb"             # lamb|adamw
    lr: float = 3e-4
    warmup_steps: int = 100
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    eps: float = 1e-6
    b1: float = 0.9
    b2: float = 0.999
    schedule: str = "cosine"            # cosine|linear|constant
    mlm_mask_prob: float = 0.15         # for MLM archs
    seed: int = 0
    log_every: int = 10
    ckpt_every: int = 0
    ckpt_keep: int = 3                  # keep-last-K checkpoint rotation
    ckpt_dir: str = ""
    # step sentinel (repro.train.sentinel): skip non-finite / loss-spike
    # optimizer updates inside jit; False keeps the pre-sentinel step path
    # verbatim (bit-identical)
    sentinel: bool = False


@dataclass(frozen=True)
class ServeConfig:
    batch_size: int = 8
    prompt_len: int = 128
    max_new_tokens: int = 32
    cache_len: int = 0                  # 0 -> prompt_len + max_new_tokens
    temperature: float = 0.0            # 0 -> greedy
    # continuous-batching engine knobs (SERVE_OPTIONS registry; see
    # repro.serve.engine and repro.serve.kvcache)
    page_size: int = 16                 # tokens per KV page
    pool_pages: int = 0                 # 0 -> n_slots * ceil(cache_len/page)
    n_slots: int = 8                    # fused decode batch (compiled once)
    prefill_buckets: str = ""           # csv chunk lens; "" -> doubling
    admit_policy: str = "fcfs"          # fcfs | sjf

    def resolved_cache_len(self) -> int:
        return self.cache_len or (self.prompt_len + self.max_new_tokens)

    def resolved_pool_pages(self) -> int:
        import math as _m
        per_seq = _m.ceil(self.resolved_cache_len() / self.page_size)
        return self.pool_pages or self.n_slots * per_seq


# The four assigned input shapes -------------------------------------------------
@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

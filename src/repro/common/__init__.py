from repro.common.config import (
    INPUT_SHAPES,
    InputShape,
    ModelConfig,
    MoEConfig,
    RWKVConfig,
    SSMConfig,
    ServeConfig,
    TrainConfig,
)
from repro.common.pytree import tree_bytes, tree_count, tree_norm

__all__ = [
    "INPUT_SHAPES",
    "InputShape",
    "ModelConfig",
    "MoEConfig",
    "RWKVConfig",
    "SSMConfig",
    "ServeConfig",
    "TrainConfig",
    "tree_bytes",
    "tree_count",
    "tree_norm",
]

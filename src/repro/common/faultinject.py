"""Deterministic fault-injection harness for the hop pipeline.

**Architecture.**  Production MoE training fails in ways a dense loop never
sees: a corrupted count grid on a dispatch hop, NaN payload rows from a bad
reduction, a peer that silently drops its segment, a routing-collapse storm
that funnels every token to one expert.  The containment machinery for each
of those lives in three layers — count-grid sanitization in
``core/pipeline`` + ``sharding/comm``, drop accounting through the echoed
reverse hop, and the step sentinel in ``train/sentinel`` — and every one of
those paths must be *exercisable*, not just argued.  This module is the
exerciser: a seeded, config-driven :class:`FaultPlan` registered in
``MOE_OPTIONS`` (``MoEConfig.fault_plan``) that the pipeline executor
consults at trace time and injects faults from deterministically, so the
fault matrix in ``tests/distributed/_faults.py`` runs the same fault on the
8-fake-device mesh and the single-device oracle and asserts *exact*
``fault_events`` / ``drop_frac`` accounting.

**Determinism.**  Every injection site is chosen at *trace* time from
``random.Random(seed, level, shape)`` — static Python ints, no jax PRNG —
so a plan is a pure function of its spec string and the (static) shapes it
meets: re-running a faulted step reproduces the identical fault, and the
tests can compute the expected event counts with the ``expected_*`` /
``*_victim`` helpers below instead of re-deriving them by hand.

**Plan spec.**  ``kind[@seed][:hop]`` where ``kind`` is one of

* ``counts``  — overwrite seeded entries of the exchanged ``(P, nl)`` count
  grid with a negative value.  Exercises the sanitizer: each violating
  entry is one ``fault_event``; the corrupted source is quarantined (its
  whole segment dropped with exact ``drop_frac`` accounting via the echoed
  reverse hop).  Inert on padded/local hops (no count grid on the wire).
* ``nanrows`` — overwrite seeded rows of the post-exchange receive slab
  (or the local/padded dispatch buffer) with NaN.  With
  ``wire_integrity="off"`` there is no hop-level detection — containment
  is the step sentinel's non-finite verdict skipping the optimizer
  update.  With the wire-integrity layer on, the injection moves onto the
  received *wire* slab (one seeded source rank's region) and the
  per-segment parity row localizes it to the exact (hop, src rank).
* ``dropseg`` — zero one seeded source rank's row of the count grid at
  every receiver: the peer "sent nothing" (silent segment loss).  A valid
  grid, so zero ``fault_events``; containment is exact drop accounting —
  every assignment from the victim rank drops, ``drop_frac == 1/P`` on an
  otherwise drop-free hop — with the victim's outputs zero-filled.
* ``skew``  — override the hop's route decision so every assignment
  targets one seeded group (router-collapse storm).  Unbounded ragged hops
  absorb it with zero drops; bounded hops clamp and account; the router
  watchdog (``hop_max_load`` / ``hop_load_entropy`` in ``MoEStats``) alarms.
* ``bitflip`` — XOR one bit per lane of one seeded source rank's region of
  the received wire slab (bit 0 on data rows, bit 8 on parity rows, so the
  two deltas can never cancel for segments shorter than 256 rows).
  Structurally invisible (a valid grid, finite floats, plausible
  magnitudes): the count-grid sanitizer *provably cannot* see it.  Only
  the checksum layer detects it; with ``wire_integrity="off"`` the flipped
  payload flows to the loss undetected.
* ``inflate`` — add 1 to one seeded in-bounds entry of the count grid
  before sanitation.  Still a valid grid (zero sanitizer events), but the
  believed segment length now disagrees with the parity word's length
  term, so checksum verification localizes the inflating source exactly.
* ``dupseg``  — replay one seeded source rank's segment as its
  neighbour's: grid row ``w`` is overwritten with row ``v=(w+1)%P`` and
  ``v``'s wire region is copied onto ``w``'s.  Data, length and fold all
  verify — only the parity word's (src, dest, group) *tag* gives the
  replay away, which is exactly what the tag term exists for.

``@seed`` defaults to 0; ``:hop`` defaults to ``-1`` (all hops).
``"none"``/``""`` parse to ``None`` (no injection — the bit-identical
production path).

This module keeps jax out of its import path (``repro.common.config``
validates plans and must stay jax-free); the injectors import ``jax.numpy``
lazily.
"""
from __future__ import annotations

import random
from dataclasses import dataclass
from typing import List, Optional, Tuple

FAULT_KINDS = ("counts", "nanrows", "dropseg", "skew", "bitflip", "inflate",
               "dupseg")

# injected magnitudes (static; chosen so tests can assert exact accounting)
COUNT_POISON = -7          # negative count written by the "counts" kind
N_COUNT_FAULTS = 2         # grid entries poisoned per (device, hop)
N_NAN_ROWS = 3             # slab rows NaN'd per (device, hop)


@dataclass(frozen=True)
class FaultPlan:
    """One parsed fault plan (hashable; lives on the frozen MoEConfig)."""
    kind: str
    seed: int = 0
    hop: int = -1            # -1 = every hop

    def targets(self, level: int) -> bool:
        return self.hop in (-1, level)

    @property
    def wants_echo(self) -> bool:
        """Count-targeting kinds need the echoed reverse hop for exact
        drop accounting (see ``pipeline._ragged_reverse``)."""
        return self.kind in ("counts", "dropseg", "inflate", "dupseg")


def parse_fault_plan(spec: Optional[str]) -> Optional[FaultPlan]:
    """Parse ``kind[@seed][:hop]`` -> :class:`FaultPlan` (or None).

    Raises ``ValueError`` on malformed specs — called by
    ``MoEConfig.with_options`` so a typo'd plan fails at config time, not
    silently mid-run.
    """
    if spec is None:
        return None
    s = spec.strip()
    if s in ("", "none", "off"):
        return None
    hop = -1
    if ":" in s:
        s, hop_s = s.rsplit(":", 1)
        try:
            hop = int(hop_s)
        except ValueError:
            raise ValueError(f"fault plan {spec!r}: hop {hop_s!r} is not an "
                             f"integer") from None
        if hop < -1:
            raise ValueError(f"fault plan {spec!r}: hop must be >= -1")
    seed = 0
    if "@" in s:
        s, seed_s = s.rsplit("@", 1)
        try:
            seed = int(seed_s)
        except ValueError:
            raise ValueError(f"fault plan {spec!r}: seed {seed_s!r} is not "
                             f"an integer") from None
    if s not in FAULT_KINDS:
        raise ValueError(f"fault plan {spec!r}: unknown kind {s!r}; expected "
                         f"one of {FAULT_KINDS}")
    return FaultPlan(s, seed, hop)


def _rng(fp: FaultPlan, level: int, *shape_tag: int) -> random.Random:
    return random.Random((fp.seed, fp.kind, level) + shape_tag)


# =============================================================================
# Trace-time site selection (static; shared with the tests' expectations)
# =============================================================================

def count_fault_sites(fp: FaultPlan, level: int, P: int, nl: int
                      ) -> List[Tuple[int, int]]:
    """The (src, group) grid entries the ``counts`` kind poisons."""
    r = _rng(fp, level, P, nl)
    n = min(N_COUNT_FAULTS, P * nl)
    flat = r.sample(range(P * nl), n)
    return [(i // nl, i % nl) for i in sorted(flat)]


def expected_count_events(fp: FaultPlan, level: int, P: int, nl: int) -> int:
    """Sanitizer events one device reports on this hop (== poisoned sites)."""
    return len(count_fault_sites(fp, level, P, nl))


def dropseg_victim(fp: FaultPlan, level: int, P: int) -> int:
    """The source rank whose segments the ``dropseg`` kind suppresses."""
    return _rng(fp, level, P).randrange(P)


def nan_row_sites(fp: FaultPlan, level: int, rows: int) -> List[int]:
    r = _rng(fp, level, rows)
    return sorted(r.sample(range(rows), min(N_NAN_ROWS, rows)))


def expected_nan_rows() -> int:
    return N_NAN_ROWS


def skew_target(fp: FaultPlan, level: int, num_groups: int) -> int:
    return _rng(fp, level, num_groups).randrange(num_groups)


def wire_victim(fp: FaultPlan, level: int, P: int) -> int:
    """The source rank whose received wire region the wire-slab kinds
    (``bitflip``, wire-mode ``nanrows``, ``dupseg``) corrupt."""
    return _rng(fp, level, P).randrange(P)


def inflate_site(fp: FaultPlan, level: int, P: int, nl: int
                 ) -> Tuple[int, int]:
    """The (src, group) count-grid entry the ``inflate`` kind bumps by 1."""
    i = _rng(fp, level, P, nl).randrange(P * nl)
    return (i // nl, i % nl)


def wire_fault_victim(fp: FaultPlan, level: int, P: int, nl: int) -> int:
    """The source rank the checksum layer must localize for ``fp.kind``
    on this hop — shared with the fault-matrix tests' expectations."""
    if fp.kind == "inflate":
        return inflate_site(fp, level, P, nl)[0]
    return wire_victim(fp, level, P)


# =============================================================================
# Injectors (called by the pipeline executor at trace time; lazy jnp)
# =============================================================================

def corrupt_len_grid(fp: FaultPlan, level: int, len_grid):
    """``counts``: poison seeded entries of the exchanged (P, nl) grid."""
    import jax.numpy as jnp
    P, nl = len_grid.shape
    for p, g in count_fault_sites(fp, level, P, nl):
        len_grid = len_grid.at[p, g].set(jnp.int32(COUNT_POISON))
    return len_grid


def drop_segment(fp: FaultPlan, level: int, len_grid):
    """``dropseg``: zero the victim source's whole row of the count grid."""
    P = len_grid.shape[0]
    return len_grid.at[dropseg_victim(fp, level, P)].set(0)


def nan_rows(fp: FaultPlan, level: int, rows, valid=None):
    """``nanrows``: NaN rows of a (R, ...) float slab.

    With ``valid`` (a boolean (R,) occupancy mask) the first
    :data:`N_NAN_ROWS` *occupied* rows are hit — injecting into padding
    would be silently gathered away by ``combine`` and never reach the
    layer output, which is exactly the no-op a fault test must not be.
    Without a mask, seeded static rows are hit.
    """
    import jax.numpy as jnp
    if valid is None:
        idx = jnp.asarray(nan_row_sites(fp, level, rows.shape[0]), jnp.int32)
        return rows.at[idx].set(jnp.nan)
    v = valid.astype(jnp.int32)
    hit = (jnp.cumsum(v) <= N_NAN_ROWS) & (v > 0)
    hit = hit.reshape(hit.shape + (1,) * (rows.ndim - 1))
    return jnp.where(hit, jnp.nan, rows)


def inflate_grid(fp: FaultPlan, level: int, len_grid):
    """``inflate``: bump one seeded entry of the believed (P, nl) grid.

    Unlike ``counts`` the result is still a *valid* grid (non-negative,
    in-bounds at the fault-matrix settings), so the sanitizer reports zero
    events — only the parity word's length term can catch it."""
    p, g = inflate_site(fp, level, *len_grid.shape)
    return len_grid.at[p, g].add(1)


def dup_grid(fp: FaultPlan, level: int, len_grid):
    """``dupseg``: overwrite victim row ``w`` with row ``v=(w+1)%P``."""
    P = len_grid.shape[0]
    w = wire_victim(fp, level, P)
    return len_grid.at[w].set(len_grid[(w + 1) % P])


def _wire_int_view(wire):
    """Bitcast a float wire slab to its same-width integer view."""
    import jax.numpy as jnp
    from jax import lax
    it = jnp.dtype(f"int{wire.dtype.itemsize * 8}")
    return lax.bitcast_convert_type(wire, it)


def flip_wire(fp: FaultPlan, level: int, wire, starts, data_counts, nl: int):
    """``bitflip``: XOR lanes of the victim's received wire region.

    Data rows get bit 0, parity rows bit 8 — asymmetric on purpose: a
    uniform flip of the lowest bit everywhere shifts an L=1 segment's fold
    and its stored parity word by the *same* ±1 and escapes detection.
    With ±1 on data and ±256 on parity the per-lane deltas cannot cancel
    while the segment is shorter than 256 rows."""
    import jax.numpy as jnp
    from jax import lax
    v = wire_victim(fp, level, starts.shape[0])
    iw = _wire_int_view(wire)
    r = jnp.arange(wire.shape[0], dtype=jnp.int32)
    s, c = starts[v], data_counts[v]
    in_data = (r >= s) & (r < s + c)
    in_par = (r >= s + c) & (r < s + c + nl)
    mask = jnp.where(in_data, 1, jnp.where(in_par, 256, 0)).astype(iw.dtype)
    return lax.bitcast_convert_type(iw ^ mask[:, None], wire.dtype)


def nan_wire(fp: FaultPlan, level: int, wire, starts, wire_counts):
    """Wire-mode ``nanrows``: NaN the first rows of the victim's region.

    Row 0 of a region is always either a live data row or the first
    parity row, so at least one NaN'd row is load-bearing and the
    checksum mismatch is guaranteed."""
    import jax.numpy as jnp
    v = wire_victim(fp, level, starts.shape[0])
    r = jnp.arange(wire.shape[0], dtype=jnp.int32)
    n = jnp.minimum(jnp.int32(N_NAN_ROWS), wire_counts[v])
    hit = (r >= starts[v]) & (r < starts[v] + n)
    return jnp.where(hit[:, None], jnp.nan, wire)


def copy_wire_region(fp: FaultPlan, level: int, wire, starts, wire_counts):
    """``dupseg``: replay ``v=(w+1)%P``'s wire region into victim ``w``'s.

    Paired with :func:`dup_grid` (so the two regions have equal believed
    extents); the copied parity row verifies against its own data but
    carries ``v``'s source tag where the receiver expects ``w``'s."""
    import jax.numpy as jnp
    P = starts.shape[0]
    w = wire_victim(fp, level, P)
    v = (w + 1) % P
    r = jnp.arange(wire.shape[0], dtype=jnp.int32)
    off = r - starts[w]
    in_w = (off >= 0) & (off < wire_counts[w])
    src = jnp.where(in_w, starts[v] + off, r)
    return jnp.take(wire, src, axis=0)


def apply_skew(fp: FaultPlan, level: int, dec, num_groups: int,
               loss_groups: int):
    """``skew``: collapse the route decision onto one seeded group.

    Overrides both the dispatch targets (``group_ids``) and the router
    argmax (``top1``) so the LB ``f``-vector — and the router watchdog fed
    from it — sees the storm.  Gates/probs are left untouched (finite), so
    the faulted layer stays oracle-comparable.
    """
    import dataclasses

    import jax.numpy as jnp
    g = skew_target(fp, level, num_groups)
    return dataclasses.replace(
        dec,
        group_ids=jnp.full_like(dec.group_ids, g),
        top1=jnp.full_like(dec.top1, g % max(loss_groups, 1)))

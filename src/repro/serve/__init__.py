from repro.serve.decode import (
    build_decode_step,
    build_prefill,
    decode_step_fn,
    greedy_sample,
    prefill_fn,
)

__all__ = ["build_decode_step", "build_prefill", "decode_step_fn",
           "greedy_sample", "prefill_fn"]

"""Serving subsystem: paged KV cache + continuous batching engine.

Architecture (one box per module)::

    submit() ---> waiting --admit--> prefilling --chunks--> live slots
                     |                  |                      |
                     |        [kvcache.PageAllocator]          |
                     |      reservation-based admission:       |
                     |      ceil((prompt+max_new)/page) pages  |
                     |      up front, freed on finish          |
                     v                  v                      v
    [engine.Engine.step — one tick]:
      1 prefill chunk (bucketed, compiled once per bucket length)
      1 fused batched decode step over ALL n_slots (compiled once)
                     |
                     v
    [models.layers.paged_attention per layer]:
      scatter this tick's KV -> page pool (dead rows dropped via
      sentinel page id); gather per-sequence views through the page
      table; mask ``s <= q_pos`` = causality + dirty-page hygiene
                     |
                     v
    [core MoE decode hop]: live-slot mask -> ``token_valid`` ->
      ragged dispatch carries exactly the live tokens' segments;
      MoEStats per tick -> Engine.metrics()

Three layers of state:

* **device, donated**: the per-stage page pools (``pool_k``/``pool_v``,
  no batch dim) — the only large arrays, threaded through every jitted
  step with buffer donation;
* **host, scheduler-owned**: the page table, slot liveness, per-slot
  positions — tiny int32/bool arrays rewritten between ticks and passed
  into each step as fresh arguments (``kvcache.inject_tables``);
* **host, bookkeeping**: the :class:`~repro.serve.kvcache.PageAllocator`
  free list and request queues.

``decode.py`` keeps the original fixed-batch prefill/decode pair (the
dry-run shape path and the ring-buffer oracle the paged path is tested
against); ``batcher.py`` is a deprecated shim over the engine.
"""
from repro.serve.decode import (
    build_decode_step,
    build_prefill,
    decode_step_fn,
    greedy_sample,
    prefill_fn,
)
from repro.serve.engine import Engine, Request
from repro.serve.kvcache import PageAllocator

__all__ = ["build_decode_step", "build_prefill", "decode_step_fn",
           "greedy_sample", "prefill_fn", "Engine", "Request",
           "PageAllocator"]

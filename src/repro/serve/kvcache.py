"""Paged KV cache: fixed-size pages in a preallocated pool + page tables.

The device side is a per-layer pool ``{"pool_k", "pool_v"}`` of
``(pool_pages, page_size, KV, hd)`` (see ``models.layers.init_paged_kv_cache``
and ``models.layers.paged_attention``); sequences own pages only through a
``(n_slots, max_pages)`` int32 page **table**, so "evict" is a host-side list
operation — no cache copies, no zeroing (the ``s <= q_pos`` read mask hides
whatever a previous owner left in a reused page).

The host side here is :class:`PageAllocator` — a LIFO free list (freed pages
are reused first, which is exactly what the dirty-page equivalence test wants
to stress) with reservation-based admission: a request is admitted only if
``ceil((prompt + max_new) / page_size)`` pages are free, so an admitted
sequence can never hit out-of-pages mid-flight.

The page table is deliberately NOT part of the donated device cache tree:
the scheduler rewrites rows between ticks, so the engine passes the current
table as a small per-tick argument and ``inject_tables`` broadcasts it into
each stage's stacked cache dict inside the jitted step (``strip_tables``
removes the pass-through copies from the returned tree).
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.models import layers as L
from repro.models import transformer as T
from repro.sharding.plan import MeshPlan


def pages_needed(n_tokens: int, page_size: int) -> int:
    return max(1, math.ceil(n_tokens / page_size))


class PageAllocator:
    """Host-side page bookkeeping for one shared pool."""

    def __init__(self, pool_pages: int, page_size: int):
        assert pool_pages > 0 and page_size > 0
        self.pool_pages = pool_pages
        self.page_size = page_size
        self._free: List[int] = list(range(pool_pages - 1, -1, -1))

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def occupancy(self) -> float:
        return 1.0 - len(self._free) / self.pool_pages

    def can_fit(self, n_tokens: int) -> bool:
        return pages_needed(n_tokens, self.page_size) <= len(self._free)

    def alloc(self, n_tokens: int) -> Optional[List[int]]:
        """Reserve pages for ``n_tokens``; None if the pool can't fit them."""
        n = pages_needed(n_tokens, self.page_size)
        if n > len(self._free):
            return None
        pages, self._free = self._free[-n:], self._free[:-n]
        return pages[::-1]          # LIFO: most recently freed page first

    def free(self, pages: List[int]) -> None:
        for pg in pages:
            assert 0 <= pg < self.pool_pages
        assert not set(pages) & set(self._free), "double free"
        self._free.extend(pages)


# =============================================================================
# Device cache tree (per-stage stacked pools, mirrors transformer.init_caches)
# =============================================================================

def init_paged_caches(cfg0: ModelConfig, pool_pages: int, page_size: int,
                      plan: MeshPlan) -> Tuple:
    """Per-stage stacked page pools. Attention-backed stages only — SSM/RWKV
    hybrids keep recurrent state per slot and are gated out by the engine."""
    cfg = T._model_cfg(cfg0, plan)
    stages = T.build_stages(cfg)

    def stack(tree, n):
        import jax
        return jax.tree.map(lambda x: jnp.broadcast_to(x, (n,) + x.shape), tree)

    out = []
    for st in stages:
        assert st.kind in ("dense", "moe", "pair"), (
            f"paged KV cache supports attention stages only, got {st.kind}")
        pool = L.init_paged_kv_cache(cfg, pool_pages, page_size)
        if st.kind == "pair":
            out.append({"dense": stack(pool, st.repeats),
                        "moe": stack(pool, st.repeats)})
        else:
            out.append(stack(pool, st.repeats))
    return tuple(out)


def _with_table(stacked: Dict, table) -> Dict:
    R = stacked["pool_k"].shape[0]
    return {**stacked, "table": jnp.broadcast_to(table, (R,) + table.shape)}


def inject_tables(caches: Tuple, table) -> Tuple:
    """Broadcast the (B, max_pages) page table into every stage cache dict
    (trace-time; the broadcast is free inside jit)."""
    out = []
    for c in caches:
        if "pool_k" in c:
            out.append(_with_table(c, table))
        else:
            out.append({k: _with_table(v, table) for k, v in c.items()})
    return tuple(out)


def strip_tables(caches: Tuple) -> Tuple:
    out = []
    for c in caches:
        if "pool_k" in c:
            out.append({k: v for k, v in c.items() if k != "table"})
        else:
            out.append({kk: {k: v for k, v in vv.items() if k != "table"}
                        for kk, vv in c.items()})
    return tuple(out)

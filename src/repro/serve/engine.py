"""Continuous batching engine: fused batched decode over a paged KV cache.

One engine tick is (at most) ONE prefill chunk plus ONE fused decode step:

* **decode** runs all ``n_slots`` sequences through a single jitted call
  compiled once — dead slots carry position ``-1`` (their KV scatter is
  dropped, their output ignored) and a ``live`` mask that the MoE layers
  consume as ``token_valid``, so a decode tick's ragged dispatch puts
  exactly the live tokens' segments on the expert wire;
* **prefill** is bucketed and chunked: a prompt is processed in
  ``prefill_buckets``-sized chunks, one chunk per tick, each bucket length
  compiled once — prefill/decode disaggregation in time, so a long prompt
  never stalls the decode tick of sequences already in flight;
* **admit/evict** run against the page pool (``serve.kvcache``):
  reservation-based admission (all ``ceil((prompt+max_new)/page)`` pages up
  front — no mid-flight OOM), pages freed the tick a request finishes, and
  freed pages reused without zeroing (the paged-attention read mask hides
  stale data).

Per-tick :class:`~repro.core.pipeline.MoEStats` load telemetry (drop
fractions, per-hop max load / load entropy) is surfaced via
:meth:`Engine.metrics` — the serving-side view of the router health signals
the training watchdog reads.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from functools import partial
from typing import Any, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.common.config import ModelConfig, ServeConfig
from repro.core.pipeline import zero_stats
from repro.models import transformer as T
from repro.serve import kvcache as KV
from repro.serve.decode import greedy_sample
from repro.sharding.compat import shard_map
from repro.sharding.plan import MeshPlan
from repro.sharding.specs import cache_specs, param_specs


# =============================================================================
# Jittable step functions (also the static-analyzer entrypoints)
# =============================================================================

def paged_decode_step_fn(params, tok, caches, table, seq_pos, live, *,
                         cfg: ModelConfig, plan: MeshPlan):
    """One fused batched decode tick over the paged KV cache.

    tok/seq_pos/live: (B,) current input token, its position, slot liveness.
    table: (B, max_pages) int32 page table (host-owned, passed per tick).
    Returns (next_tok (B,), logits (B, V_loc) fp32, MoEStats, caches).
    Dead slots produce finite garbage tokens the scheduler ignores.
    """
    positions = jnp.where(live, seq_pos, -1)[:, None]           # (B, 1)
    caches = KV.inject_tables(caches, table)
    _, logits, stats, caches = T.forward(params, tok[:, None], cfg, plan,
                                         positions=positions, caches=caches,
                                         token_valid=live[:, None])
    caches = KV.strip_tables(caches)
    lg = logits[:, 0, :]
    return greedy_sample(lg, plan), lg, stats, caches


def paged_prefill_fn(params, tokens, caches, table_row, start, n_real, *,
                     cfg: ModelConfig, plan: MeshPlan):
    """One bucketed prefill chunk for a single sequence.

    tokens: (1, S_bucket) — prompt slice padded to the bucket length;
    table_row: (1, max_pages); start: scalar absolute position of
    ``tokens[0, 0]``; n_real: scalar count of real tokens in the chunk.
    Returns (next_tok scalar — only meaningful on the final chunk —
    MoEStats, caches).  The same function serves every chunk of a long
    prompt: earlier chunks' KV is already in the pool and the gathered
    page-table view covers it.
    """
    S = tokens.shape[1]
    t = jnp.arange(S)
    valid = t < n_real
    positions = jnp.where(valid, start + t, -1)[None, :]        # (1, S)
    caches = KV.inject_tables(caches, table_row)
    _, logits, stats, caches = T.forward(params, tokens, cfg, plan,
                                         positions=positions, caches=caches,
                                         token_valid=valid[None, :])
    caches = KV.strip_tables(caches)
    last = jnp.clip(n_real - 1, 0, S - 1)
    nxt = greedy_sample(logits[0, last][None, :], plan)[0]
    return nxt, stats, caches


def _stats_specs():
    return jax.tree.map(lambda _: P(), zero_stats())


def build_paged_decode_step(cfg: ModelConfig, plan: MeshPlan, params_like,
                            caches_like, mesh=None):
    """Jitted fused decode tick (shard_mapped when a mesh is given).  The
    page pool is replicated over dp / KV-head-sharded over tp; the tiny
    per-tick scheduler arrays (tok, table, seq_pos, live) are replicated."""
    fn = partial(paged_decode_step_fn, cfg=cfg, plan=plan)
    if mesh is None:
        return jax.jit(fn, donate_argnums=(2,))
    pspec = param_specs(params_like, cfg, plan)
    cspec = cache_specs(caches_like, cfg, plan, 1)
    tp = plan.tp_axis
    lspec = P(None, tuple(tp) if isinstance(tp, (list, tuple)) and len(tp) > 1
              else (tp[0] if isinstance(tp, (list, tuple)) and tp else tp))
    sm = shard_map(fn, mesh=mesh,
                   in_specs=(pspec, P(None), cspec, P(None, None), P(None),
                             P(None)),
                   out_specs=(P(None), lspec, _stats_specs(), cspec))
    return jax.jit(sm, donate_argnums=(2,))


def build_paged_prefill(cfg: ModelConfig, plan: MeshPlan, params_like,
                        caches_like, mesh=None):
    fn = partial(paged_prefill_fn, cfg=cfg, plan=plan)
    if mesh is None:
        return jax.jit(fn, donate_argnums=(2,))
    pspec = param_specs(params_like, cfg, plan)
    cspec = cache_specs(caches_like, cfg, plan, 1)
    sm = shard_map(fn, mesh=mesh,
                   in_specs=(pspec, P(None, None), cspec, P(None, None),
                             P(), P()),
                   out_specs=(P(), _stats_specs(), cspec))
    return jax.jit(sm, donate_argnums=(2,))


# =============================================================================
# Requests + engine
# =============================================================================

@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                    # (S,) int32
    max_new_tokens: int
    generated: List[int] = dataclasses.field(default_factory=list)
    pages: List[int] = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_first: float = 0.0                  # wall time of the first token
    t_tokens: List[float] = dataclasses.field(default_factory=list)


def derive_buckets(cache_len: int, lo: int = 16) -> Tuple[int, ...]:
    """Doubling chunk lengths up to ``cache_len`` (each compiled once)."""
    if cache_len <= lo:
        return (cache_len,)
    out, s = [], lo
    while s < cache_len:
        out.append(s)
        s *= 2
    out.append(cache_len)
    return tuple(out)


class Engine:
    """Continuous-batching serving engine over the paged KV cache."""

    def __init__(self, params, cfg: ModelConfig, plan: MeshPlan, *,
                 serve: Optional[ServeConfig] = None, mesh=None, **overrides):
        serve = serve or ServeConfig()
        if overrides:
            serve = dataclasses.replace(serve, **overrides)
        if not (cfg.causal and cfg.num_codebooks == 1
                and cfg.attention in ("full", "sliding")
                and cfg.arch_type not in ("ssm", "hybrid")):
            raise ValueError(
                "Engine supports causal single-stream GQA attention archs "
                "(full/sliding); MLA absorbed decode and SSM/RWKV recurrent "
                "state over paged pools are ROADMAP follow-ups")
        self.params, self.cfg, self.plan, self.mesh = params, cfg, plan, mesh
        self.serve = serve
        self.cache_len = serve.resolved_cache_len()
        self.page_size = serve.page_size
        self.n_slots = serve.n_slots
        pool_pages = serve.resolved_pool_pages()
        self.max_pages = KV.pages_needed(self.cache_len, self.page_size)
        self.buckets = (tuple(int(x) for x in serve.prefill_buckets.split(","))
                        if serve.prefill_buckets
                        else derive_buckets(self.cache_len))
        assert list(self.buckets) == sorted(self.buckets)

        self.alloc = KV.PageAllocator(pool_pages, self.page_size)
        self.caches = KV.init_paged_caches(cfg, pool_pages, self.page_size,
                                           plan)
        B = self.n_slots
        self._sentinel = pool_pages                   # OOB page id == unmapped
        self.table_np = np.full((B, self.max_pages), self._sentinel, np.int32)
        self._tok = np.zeros((B,), np.int32)
        self._pos = np.zeros((B,), np.int32)
        self._live = np.zeros((B,), bool)
        self.slot_req: List[Optional[Request]] = [None] * B

        self.waiting: Deque[Request] = deque()
        self.prefilling: Deque[List] = deque()        # [req, slot, start]
        self.requests: Dict[int, Request] = {}        # uid -> Request (all)
        self.finished: Dict[int, List[int]] = {}
        self._uid = 0
        self.ticks = 0
        self.occupancy: List[float] = []
        self.telemetry: List[Dict[str, float]] = []

        self._decode = build_paged_decode_step(cfg, plan, params, self.caches,
                                               mesh)
        self._prefills: Dict[int, Any] = {}           # bucket len -> jitted fn

    # ------------------------------------------------------------------ submit
    def submit(self, prompt, max_new_tokens: int = 16) -> int:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        total = len(prompt) + max_new_tokens
        if total > self.cache_len:
            raise ValueError(f"request needs {total} positions > cache_len="
                             f"{self.cache_len}")
        if KV.pages_needed(total, self.page_size) > self.alloc.pool_pages:
            raise ValueError("request can never fit the page pool")
        self._uid += 1
        req = Request(self._uid, prompt, max_new_tokens,
                      t_submit=time.monotonic())
        self.waiting.append(req)
        self.requests[self._uid] = req
        return self._uid

    # ------------------------------------------------------------------ sched
    def _pick_waiting(self) -> Request:
        if self.serve.admit_policy == "sjf":
            best = min(self.waiting, key=lambda r: (len(r.prompt), r.uid))
            self.waiting.remove(best)
            return best
        return self.waiting.popleft()

    def _admit(self) -> None:
        while self.waiting:
            free_slots = [i for i, r in enumerate(self.slot_req) if r is None]
            if not free_slots:
                return
            nxt = (min(self.waiting, key=lambda r: (len(r.prompt), r.uid))
                   if self.serve.admit_policy == "sjf" else self.waiting[0])
            total = len(nxt.prompt) + nxt.max_new_tokens
            pages = self.alloc.alloc(total)
            if pages is None:
                return                                # head-of-line waits
            req = self._pick_waiting()
            assert req is nxt
            req.pages = pages
            slot = free_slots[0]
            self.table_np[slot] = self._sentinel
            self.table_np[slot, :len(pages)] = pages
            self.slot_req[slot] = req
            self.prefilling.append([req, slot, 0])

    def _prefill_for(self, bucket: int):
        if bucket not in self._prefills:
            self._prefills[bucket] = build_paged_prefill(
                self.cfg, self.plan, self.params, self.caches, self.mesh)
        return self._prefills[bucket]

    def _record_stats(self, stats) -> None:
        s = jax.device_get(stats)
        self.telemetry.append({
            "drop_frac": float(s.drop_frac),
            "hop_max_load": float(np.max(s.hop_max_load)),
            "hop_load_entropy": float(np.min(s.hop_load_entropy)),
            "fault_events": float(np.sum(s.fault_events)),
        })

    def _prefill_tick(self) -> None:
        if not self.prefilling:
            return
        ent = self.prefilling[0]
        req, slot, start = ent
        remaining = len(req.prompt) - start
        chunk = min(remaining, self.buckets[-1])
        bucket = next(b for b in self.buckets if b >= chunk)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :chunk] = req.prompt[start:start + chunk]
        fn = self._prefill_for(bucket)
        nxt, stats, self.caches = fn(
            self.params, jnp.asarray(toks), self.caches,
            jnp.asarray(self.table_np[slot:slot + 1]),
            jnp.int32(start), jnp.int32(chunk))
        ent[2] = start + chunk
        self._record_stats(stats)
        if ent[2] >= len(req.prompt):                 # prompt done -> go live
            self.prefilling.popleft()
            tok = int(jax.device_get(nxt))
            now = time.monotonic()
            req.t_first = now
            req.t_tokens.append(now)
            req.generated.append(tok)
            self._tok[slot] = tok
            self._pos[slot] = len(req.prompt)
            self._live[slot] = True
            self._maybe_finish(slot)                  # max_new_tokens == 1

    def _maybe_finish(self, slot: int) -> None:
        req = self.slot_req[slot]
        if req is not None and len(req.generated) >= req.max_new_tokens:
            self.finished[req.uid] = req.generated
            self.alloc.free(req.pages)
            self.table_np[slot] = self._sentinel
            self._live[slot] = False
            self.slot_req[slot] = None

    def _decode_tick(self) -> None:
        if not self._live.any():
            return
        nxt, _, stats, self.caches = self._decode(
            self.params, jnp.asarray(self._tok), self.caches,
            jnp.asarray(self.table_np), jnp.asarray(self._pos),
            jnp.asarray(self._live))
        nxt = np.asarray(jax.device_get(nxt))
        self._record_stats(stats)
        now = time.monotonic()
        for i in range(self.n_slots):
            if not self._live[i]:
                continue
            req = self.slot_req[i]
            tok = int(nxt[i])
            req.generated.append(tok)
            req.t_tokens.append(now)
            self._pos[i] += 1
            self._tok[i] = tok
            self._maybe_finish(i)

    # ------------------------------------------------------------------ drive
    def step(self) -> None:
        """One engine tick: admit -> one prefill chunk -> one fused decode."""
        self.ticks += 1
        self._admit()
        self._prefill_tick()
        self._decode_tick()
        self.occupancy.append(self.alloc.occupancy)

    @property
    def busy(self) -> bool:
        return bool(self.waiting or self.prefilling or self._live.any())

    def run(self, max_ticks: int = 100_000) -> Dict[int, List[int]]:
        while self.busy:
            assert self.ticks < max_ticks, "engine failed to drain"
            self.step()
        return dict(self.finished)

    # ---------------------------------------------------------------- metrics
    def compile_counts(self) -> Dict[str, int]:
        def n(fn):
            try:
                return int(fn._cache_size())
            except Exception:
                return -1
        return {"decode": n(self._decode),
                "prefill": {b: n(f) for b, f in self._prefills.items()}}

    def metrics(self) -> Dict[str, Any]:
        occ = np.asarray(self.occupancy or [0.0])
        tel = self.telemetry or [{}]
        def agg(key, red):
            vals = [t[key] for t in tel if key in t]
            return float(red(vals)) if vals else 0.0
        return {
            "ticks": self.ticks,
            "completed": len(self.finished),
            "page_occupancy_mean": float(occ.mean()),
            "page_occupancy_max": float(occ.max()),
            "moe_drop_frac_mean": agg("drop_frac", np.mean),
            "moe_hop_max_load_max": agg("hop_max_load", np.max),
            "moe_hop_load_entropy_min": agg("hop_load_entropy", np.min),
            "moe_fault_events": agg("fault_events", np.sum),
            "compiles": self.compile_counts(),
        }

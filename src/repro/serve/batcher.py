"""Continuous batching: slot-based request scheduling over the decode step.

Production serving runs a FIXED-shape decode step (compiled once) while
requests arrive and finish at different times. The :class:`Batcher` keeps a
pool of ``n_slots`` sequences at independent depths:

* empty slots are refilled from the waiting queue (prompt prefill into that
  slot's cache);
* every engine tick advances all active slots by one token;
* finished requests free their slot immediately — a long request never
  blocks short ones behind it (the continuous-batching win over
  run-to-completion batching).

Each slot owns a batch=1 cache and the engine reuses two jitted callables
(prefill, decode) across all slots — one compilation each. On TPU the slots
would additionally be fused into one batched call; the scheduling logic here
is the substrate that decides WHAT is in that batch each tick.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import ModelConfig
from repro.models import transformer as T
from repro.serve.decode import decode_step_fn, prefill_fn
from repro.sharding.plan import MeshPlan


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                    # (S,) int32
    max_new_tokens: int
    generated: List[int] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    cache: Optional[object] = None
    pos: int = 0
    pending: int = 0                      # next input token


class Batcher:
    def __init__(self, params, cfg: ModelConfig, plan: MeshPlan, *,
                 n_slots: int = 4, cache_len: int = 128,
                 prompt_len: int = 16):
        assert cfg.causal and cfg.num_codebooks == 1, \
            "batcher supports single-stream causal LMs"
        self.params = params
        self.cfg = cfg
        self.plan = plan
        self.cache_len = cache_len
        self.prompt_len = prompt_len
        self.slots = [_Slot() for _ in range(n_slots)]
        self.queue: Deque[Request] = deque()
        self._uid = 0
        from functools import partial
        self._prefill = jax.jit(partial(prefill_fn, cfg=cfg, plan=plan))
        self._decode = jax.jit(partial(decode_step_fn, cfg=cfg, plan=plan))
        self.ticks = 0

    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16) -> int:
        assert len(prompt) == self.prompt_len, \
            "fixed-shape engine: pad prompts to prompt_len"
        self._uid += 1
        self.queue.append(Request(self._uid, np.asarray(prompt, np.int32),
                                  max_new_tokens))
        return self._uid

    # ------------------------------------------------------------------ engine
    def _fill(self):
        for s in self.slots:
            if s.req is None and self.queue:
                req = self.queue.popleft()
                cache = T.init_caches(self.cfg, 1, self.cache_len, self.plan)
                tok, cache = self._prefill(self.params,
                                           jnp.asarray(req.prompt)[None],
                                           cache)
                s.req, s.cache = req, cache
                s.pos = len(req.prompt)
                s.pending = int(np.asarray(tok)[0])
                req.generated.append(s.pending)

    def _tick(self, out: Dict[int, List[int]]):
        self.ticks += 1
        for s in self.slots:
            if s.req is None:
                continue
            if len(s.req.generated) >= s.req.max_new_tokens:
                out[s.req.uid] = s.req.generated
                s.req, s.cache = None, None
                continue
            tok, s.cache = self._decode(self.params,
                                        jnp.asarray([s.pending], jnp.int32),
                                        s.cache, jnp.int32(s.pos))
            s.pos += 1
            s.pending = int(np.asarray(tok)[0])
            s.req.generated.append(s.pending)

    def run(self) -> Dict[int, List[int]]:
        """Run until every submitted request completes; return generations."""
        out: Dict[int, List[int]] = {}
        while self.queue or any(s.req is not None for s in self.slots):
            self._fill()
            self._tick(out)
        return out

"""DEPRECATED shim: :class:`Batcher` now wraps :class:`repro.serve.engine.Engine`.

The original Batcher was a fixed-shape toy — fixed ``prompt_len`` (asserted),
one batch-of-1 ring-buffer cache per slot, and a Python loop calling the
jitted decode once per slot per tick.  The engine replaces all three: paged
KV cache over a shared pool, variable-length bucketed prefill, and ONE fused
batched decode step per tick.  This class keeps the old constructor/submit/
run surface for existing call sites (``examples/serve_decode.py``,
``launch/serve.py``); new code should use the Engine directly.
"""
from __future__ import annotations

import warnings
from typing import Dict, List

import numpy as np

from repro.common.config import ModelConfig, ServeConfig
from repro.serve.engine import Engine, Request  # noqa: F401  (re-export)
from repro.sharding.plan import MeshPlan


class Batcher:
    def __init__(self, params, cfg: ModelConfig, plan: MeshPlan, *,
                 n_slots: int = 4, cache_len: int = 128,
                 prompt_len: int = 16):
        warnings.warn(
            "repro.serve.batcher.Batcher is deprecated; use "
            "repro.serve.engine.Engine (paged KV cache + fused batched "
            "decode). prompt_len is no longer a fixed shape — prompts of "
            "any length up to cache_len are accepted.",
            DeprecationWarning, stacklevel=2)
        serve = ServeConfig(n_slots=n_slots, cache_len=cache_len,
                            prompt_len=prompt_len,
                            page_size=min(16, cache_len))
        self.engine = Engine(params, cfg, plan, serve=serve)

    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16) -> int:
        return self.engine.submit(prompt, max_new_tokens)

    def run(self) -> Dict[int, List[int]]:
        return self.engine.run()

    @property
    def ticks(self) -> int:
        return self.engine.ticks

"""Serving: batched prefill + single-token decode steps.

``decode_step`` is what the ``decode_32k`` / ``long_500k`` dry-run shapes
lower: ONE new token per sequence against a KV/SSM cache of the configured
length. Attention archs use the ring-buffer KV cache (window-sized for
sliding-window variants); SSM archs carry O(1) recurrent state.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.common.config import ModelConfig
from repro.models import transformer as T
from repro.models.layers import gather_full_logits
from repro.sharding import comm
from repro.sharding.compat import shard_map
from repro.sharding.plan import MeshPlan
from repro.sharding.specs import batch_specs, cache_specs, param_specs


def greedy_sample(logits_sharded: jax.Array, plan: MeshPlan) -> jax.Array:
    """Distributed greedy argmax over vocab-sharded logits (..., V_loc)."""
    v_loc = logits_sharded.shape[-1]
    start = comm.axis_index(plan.tp_axis) * v_loc
    local_max = logits_sharded.max(-1)
    local_arg = logits_sharded.argmax(-1) + start
    gmax = comm.pmax(local_max, plan.tp_axis)
    cand = jnp.where(local_max >= gmax, local_arg, jnp.iinfo(jnp.int32).max)
    return comm.pmax(-cand, plan.tp_axis) * -1        # lowest winning index


def prefill_fn(params, tokens, caches, *, cfg: ModelConfig, plan: MeshPlan):
    """Run the prompt through the model, filling caches.

    tokens: (B, S) (or (B, K, S) for multi-codebook). Returns
    (next_token (B,) int32, caches).
    """
    S = tokens.shape[-1]
    positions = jnp.arange(S)
    _, logits, _, caches = T.forward(params, tokens, cfg, plan,
                                     positions=positions, caches=caches)
    nxt = greedy_sample(logits[..., -1, :] if cfg.num_codebooks <= 1
                        else logits[:, -1], plan)
    return nxt, caches


def decode_step_fn(params, token, caches, step, *, cfg: ModelConfig,
                   plan: MeshPlan):
    """One decode step. token: (B,) (or (B, K)); step: scalar position."""
    tok = token[..., None]                              # (B, 1) / (B, K, 1)
    positions = step[None] if step.ndim == 0 else step
    _, logits, _, caches = T.forward(params, tok, cfg, plan,
                                     positions=positions, caches=caches)
    if cfg.num_codebooks > 1:
        nxt = greedy_sample(logits[:, -1], plan)        # (B, K)
    else:
        nxt = greedy_sample(logits[:, -1, :], plan)     # (B,)
    return nxt, caches


def build_decode_step(cfg: ModelConfig, plan: MeshPlan, params_like,
                      token_like, caches_like, mesh=None):
    """Jitted decode step for this mesh (or single device when mesh=None)."""
    fn = partial(decode_step_fn, cfg=cfg, plan=plan)
    if mesh is None:
        return jax.jit(fn, donate_argnums=(2,))
    batch = token_like.shape[0]
    pspec = param_specs(params_like, cfg, plan)
    cspec = cache_specs(caches_like, cfg, plan, batch)
    tspec = batch_specs({"t": token_like}, plan)["t"]
    sm = shard_map(fn, mesh=mesh,
                   in_specs=(pspec, tspec, cspec, P()),
                   out_specs=(tspec, cspec))
    return jax.jit(sm, donate_argnums=(2,))


def build_prefill(cfg: ModelConfig, plan: MeshPlan, params_like,
                  tokens_like, caches_like, mesh=None):
    fn = partial(prefill_fn, cfg=cfg, plan=plan)
    if mesh is None:
        return jax.jit(fn, donate_argnums=(2,))
    batch = tokens_like.shape[0]
    pspec = param_specs(params_like, cfg, plan)
    cspec = cache_specs(caches_like, cfg, plan, batch)
    tok_spec = batch_specs({"t": tokens_like}, plan)["t"]
    out_tok = P(tok_spec[0]) if cfg.num_codebooks <= 1 else \
        P(tok_spec[0], None)
    sm = shard_map(fn, mesh=mesh,
                   in_specs=(pspec, tok_spec, cspec),
                   out_specs=(out_tok, cspec))
    return jax.jit(sm, donate_argnums=(2,))

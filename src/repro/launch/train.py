"""Training driver.

Runs real optimization steps on whatever devices exist (one CPU here; the
production mesh on TPU — the same code path, only the mesh changes). For
CPU-scale runs pass a reduced arch (``--reduced``).

  PYTHONPATH=src python -m repro.launch.train --arch smile-3.7b --reduced \
      --steps 50 --batch 16 --seq 128
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import TrainConfig
from repro.configs import get_config, get_reduced
from repro.data.pipeline import DataPipeline
from repro.models.transformer import init_model
from repro.optim import make_optimizer, make_schedule
from repro.sharding.plan import plan_from_mesh, single_device_plan
from repro.train.checkpoint import save_checkpoint
from repro.train.step import build_train_step


def train(arch: str, *, reduced: bool = True, steps: int = 50,
          batch: int = 16, seq: int = 128, lr: float = 3e-4,
          optimizer: str = "lamb", seed: int = 0, log_every: int = 10,
          ckpt: str = "", mesh=None, micro_batch: int = 0,
          log_file: str = "", zero1: bool = False, eval_every: int = 0,
          dispatch_backend: str = "", ragged_a2a: str = "",
          sort_impl: str = ""):
    cfg = get_reduced(arch) if reduced else get_config(arch)
    if dispatch_backend or ragged_a2a or sort_impl:
        from repro.configs import with_dispatch_backend
        backend = dispatch_backend or (
            cfg.moe.dispatch_backend if cfg.moe else "sort")
        cfg = with_dispatch_backend(
            cfg, backend,
            ragged_a2a=None if not ragged_a2a else ragged_a2a == "on",
            sort_impl=sort_impl or None)
    plan = plan_from_mesh(mesh) if mesh is not None else single_device_plan()
    tcfg = TrainConfig(global_batch_size=batch, seq_len=seq, steps=steps,
                       optimizer=optimizer, lr=lr, warmup_steps=max(steps // 10, 1),
                       micro_batch_size=micro_batch, seed=seed)

    key = jax.random.PRNGKey(seed)
    params = init_model(key, cfg, plan)
    opt = make_optimizer(optimizer)
    sched = make_schedule("cosine", lr, tcfg.warmup_steps, steps)
    if zero1:
        from repro.train.step import zero1_state
        opt_state = zero1_state(params, cfg, plan)
    else:
        opt_state = opt.init(params)

    pipe = DataPipeline(cfg, batch, seq, seed=seed)
    sample = next(pipe)
    batch0 = {k: jnp.asarray(v) for k, v in sample.items()}
    step_fn, _ = build_train_step(cfg, tcfg, plan, opt, sched, params,
                                  batch0, mesh=mesh, zero1=zero1)

    history = []
    t0 = time.time()
    for i in range(steps):
        b = batch0 if i == 0 else {k: jnp.asarray(v) for k, v in next(pipe).items()}
        params, opt_state, m = step_fn(params, opt_state, b, jnp.int32(i + 1))
        if (i + 1) % log_every == 0 or i == 0:
            m = {k: float(v) for k, v in m.items()}
            toks = batch * seq * (i + 1)
            dt = time.time() - t0
            print(f"step {i+1:5d} loss {m['loss']:.4f} ce {m['ce']:.4f} "
                  f"lb {m['lb']:.4f} drop {m['drop_frac']:.3f} "
                  f"gnorm {m['grad_norm']:.2f} tok/s {toks/dt:,.0f}")
            history.append({"step": i + 1, **m, "tokens_per_s": toks / dt})
        if eval_every and (i + 1) % eval_every == 0:
            from repro.train.evaluate import evaluate
            ev = evaluate(params, cfg, plan, batch=batch, seq=seq, seed=seed,
                          n_batches=2)
            print(f"  eval ce {ev['eval_ce']:.4f} ppl {ev['eval_ppl']:.1f}")
            history.append({"step": i + 1, **ev})
    pipe.close()
    if ckpt:
        save_checkpoint(ckpt, params, opt_state, steps)
        print(f"saved checkpoint -> {ckpt}")
    if log_file:
        with open(log_file, "w") as f:
            json.dump(history, f, indent=1)
    return params, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="lamb")
    ap.add_argument("--micro-batch", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--log-file", default="")
    ap.add_argument("--zero1", action="store_true",
                    help="shard optimizer state over replicated axes")
    ap.add_argument("--eval-every", type=int, default=0)
    ap.add_argument("--dispatch-backend", default="",
                    choices=["", "sort", "dense", "dropless"],
                    help="override MoEConfig.dispatch_backend "
                         "(dropless = capacity-free expert compute)")
    ap.add_argument("--ragged-a2a", default="", choices=["", "on", "off"],
                    help="dropless only: ragged (exact-segment) vs "
                         "capacity-padded All2All dispatch hops "
                         "(default: config setting, on)")
    ap.add_argument("--sort-impl", default="",
                    choices=["", "radix", "argsort"],
                    help="group sort under every dispatch hop: radix = "
                         "one-pass Pallas counting sort (TPU fast path), "
                         "argsort = XLA stable sort "
                         "(default: config setting, argsort)")
    args = ap.parse_args()
    train(args.arch, reduced=args.reduced, steps=args.steps, batch=args.batch,
          seq=args.seq, lr=args.lr, optimizer=args.optimizer, seed=args.seed,
          ckpt=args.ckpt, micro_batch=args.micro_batch,
          log_file=args.log_file, zero1=args.zero1,
          eval_every=args.eval_every, dispatch_backend=args.dispatch_backend,
          ragged_a2a=args.ragged_a2a, sort_impl=args.sort_impl)


if __name__ == "__main__":
    main()

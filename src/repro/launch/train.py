"""Training driver.

Runs real optimization steps on whatever devices exist (one CPU here; the
production mesh on TPU — the same code path, only the mesh changes). For
CPU-scale runs pass a reduced arch (``--reduced``).

  PYTHONPATH=src python -m repro.launch.train --arch smile-3.7b --reduced \
      --steps 50 --batch 16 --seq 128
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import MOE_OPTIONS, TRAIN_OPTIONS, TrainConfig
from repro.configs import get_config, get_reduced
from repro.data.pipeline import DataPipeline
from repro.models.transformer import init_model
from repro.optim import make_optimizer, make_schedule
from repro.sharding.plan import plan_from_mesh, single_device_plan
from repro.train.checkpoint import CheckpointManager, save_checkpoint
from repro.train.step import build_train_step

_UNSET = object()       # float-flag default (argparse type-converts string
                        # defaults, so "" cannot be the sentinel there)


def _float_or_off(v: str):
    """argparse type for float options: a number, or off/none -> None.
    Raising ValueError here gives the clean 'usage:' argparse error instead
    of a traceback."""
    if v in ("off", "none"):
        return None
    return float(v)


def add_option_flags(ap, options) -> None:
    """Add one CLI flag per registry entry (generic over option kinds).

    Empty string / unset = keep the config's setting; bools take
    ``on``/``off`` (bare ``--flag`` means ``on``); floats take a number or
    ``off`` (-> None); ints and strings pass through.  The registry is the
    single source of truth, so a knob registered in ``MOE_OPTIONS`` or
    ``TRAIN_OPTIONS`` cannot silently miss this launcher.
    """
    for opt in options:
        if opt.kind == "choice":
            ap.add_argument(opt.flag, default="",
                            choices=("",) + opt.choices, help=opt.help)
        elif opt.kind == "bool":
            ap.add_argument(opt.flag, default="", nargs="?", const="on",
                            choices=("", "on", "off"), help=opt.help)
        elif opt.kind == "float":
            ap.add_argument(opt.flag, default=_UNSET, type=_float_or_off,
                            help=opt.help + " (number, or 'off' for None)")
        elif opt.kind == "int":
            ap.add_argument(opt.flag, default=_UNSET, type=int,
                            help=opt.help)
        else:  # "str"
            ap.add_argument(opt.flag, default="", help=opt.help)


def parse_option_flags(args, options) -> dict:
    """Collect registry-derived flags back into a {field: value} dict —
    only the flags the user actually set."""
    opts = {}
    for opt in options:
        v = getattr(args, opt.field)
        if v is _UNSET or v == "":
            continue
        if opt.kind == "bool":
            opts[opt.field] = v == "on"
        else:       # choice/str (str) / float / int (argparse-converted)
            opts[opt.field] = v
    return opts


def add_moe_option_flags(ap) -> None:
    """MoE registry flags (``--dispatch-backend``, ``--ragged-a2a``, ...)."""
    add_option_flags(ap, MOE_OPTIONS)


def parse_moe_option_flags(args) -> dict:
    """Collect the MoE registry flags back into a with_options dict."""
    return parse_option_flags(args, MOE_OPTIONS)


def train(arch: str, *, reduced: bool = True, steps: int = 50,
          batch: int = 16, seq: int = 128, lr: float = 3e-4,
          optimizer: str = "lamb", seed: int = 0, log_every: int = 10,
          ckpt: str = "", mesh=None, micro_batch: int = 0,
          log_file: str = "", zero1: bool = False, eval_every: int = 0,
          moe_options: dict | None = None, dispatch_backend: str = "",
          ragged_a2a: str = "", sort_impl: str = "",
          sentinel: bool = False, resume: bool = False,
          ckpt_every: int = 0, ckpt_keep: int = 3, ckpt_dir: str = "",
          halt_after: int = 0):
    """Run (or resume) a training run.

    Robust-runtime knobs: ``sentinel`` turns on the in-jit step sentinel
    (bad steps skipped, anomaly counters carried + checkpointed);
    ``ckpt_dir`` + ``ckpt_every`` keep a ``ckpt_keep``-deep checksummed
    rotation; ``resume`` restores the newest valid snapshot from
    ``ckpt_dir`` (corrupt ones fall back) and fast-forwards the
    deterministic data stream so a resumed run is bit-identical to an
    uninterrupted one.  ``halt_after`` stops after that many steps while
    keeping the FULL ``steps`` schedule horizon — the crash-simulation
    hook the resume-determinism test uses.
    """
    cfg = get_reduced(arch) if reduced else get_config(arch)
    # moe_options is the registry-validated path; the three string kwargs
    # are the legacy surface, folded in for backward compatibility
    opts = dict(moe_options or {})
    if dispatch_backend:
        opts.setdefault("dispatch_backend", dispatch_backend)
    if ragged_a2a:
        opts.setdefault("ragged_a2a", ragged_a2a == "on")
    if sort_impl:
        opts.setdefault("sort_impl", sort_impl)
    if opts:
        from repro.configs import with_options
        cfg = with_options(cfg, **opts)
    plan = plan_from_mesh(mesh) if mesh is not None else single_device_plan()
    tcfg = TrainConfig(global_batch_size=batch, seq_len=seq, steps=steps,
                       optimizer=optimizer, lr=lr, warmup_steps=max(steps // 10, 1),
                       micro_batch_size=micro_batch, seed=seed,
                       sentinel=sentinel, ckpt_every=ckpt_every,
                       ckpt_keep=ckpt_keep, ckpt_dir=ckpt_dir)

    key = jax.random.PRNGKey(seed)
    params = init_model(key, cfg, plan)
    opt = make_optimizer(optimizer)
    sched = make_schedule("cosine", lr, tcfg.warmup_steps, steps)
    if zero1:
        from repro.train.step import zero1_state
        opt_state = zero1_state(params, cfg, plan)
    else:
        opt_state = opt.init(params)
    sent = None
    if sentinel:
        from repro.train.sentinel import init_sentinel_state
        sent = init_sentinel_state()

    mgr = CheckpointManager(ckpt_dir, keep=ckpt_keep) if ckpt_dir else None
    start = 0
    if resume:
        if mgr is None:
            raise ValueError("--resume needs --ckpt-dir (the rotation to "
                             "resume from)")
        got = mgr.restore_latest(params, opt_state, extra_like=sent)
        if got is not None:
            if sentinel:
                params, opt_state, start, sent = got
            else:
                params, opt_state, start = got
            print(f"resumed from step {start} ({mgr.dir})")
        else:
            print(f"no valid checkpoint in {mgr.dir} — starting fresh")

    pipe = DataPipeline(cfg, batch, seq, seed=seed)
    sample = next(pipe)                          # draw 0 (step 1's batch)
    batch0 = {k: jnp.asarray(v) for k, v in sample.items()}
    # the data stream is deterministic in (seed, draw index): skip the
    # draws the restored steps already consumed so step S+1 sees the same
    # batch it would have in the uninterrupted run
    for _ in range(max(start - 1, 0)):
        next(pipe)
    step_fn, _ = build_train_step(cfg, tcfg, plan, opt, sched, params,
                                  batch0, mesh=mesh, zero1=zero1,
                                  sentinel=sentinel)

    history = []
    t0 = time.time()
    until = min(steps, halt_after + start) if halt_after else steps
    for i in range(start, until):
        b = batch0 if i == 0 else {k: jnp.asarray(v) for k, v in next(pipe).items()}
        if sentinel:
            params, opt_state, m, sent = step_fn(params, opt_state, b,
                                                 jnp.int32(i + 1), sent)
            anomaly = float(m["skip"]) > 0
        else:
            params, opt_state, m = step_fn(params, opt_state, b,
                                           jnp.int32(i + 1))
            anomaly = False
        if (i + 1) % log_every == 0 or i == start:
            m = {k: float(v) for k, v in m.items()}
            toks = batch * seq * (i + 1 - start)
            dt = time.time() - t0
            extra = (f" skip {m['skip']:.0f}" if sentinel else "")
            print(f"step {i+1:5d} loss {m['loss']:.4f} ce {m['ce']:.4f} "
                  f"lb {m['lb']:.4f} drop {m['drop_frac']:.3f} "
                  f"gnorm {m['grad_norm']:.2f} tok/s {toks/dt:,.0f}{extra}")
            history.append({"step": i + 1, **m, "tokens_per_s": toks / dt})
        if anomaly and mgr is not None:
            # the skipped step left params bit-unchanged: this snapshot IS
            # the last good state, taken while it is still current
            mgr.save(i + 1, params, opt_state, extra=sent)
            print(f"step {i+1}: anomaly (update skipped) — snapshot saved")
        elif mgr is not None and ckpt_every and (i + 1) % ckpt_every == 0:
            mgr.save(i + 1, params, opt_state, extra=sent)
        if eval_every and (i + 1) % eval_every == 0:
            from repro.train.evaluate import evaluate
            ev = evaluate(params, cfg, plan, batch=batch, seq=seq, seed=seed,
                          n_batches=2)
            print(f"  eval ce {ev['eval_ce']:.4f} ppl {ev['eval_ppl']:.1f}")
            history.append({"step": i + 1, **ev})
    pipe.close()
    if sentinel and sent is not None:
        history.append({"sentinel": {
            k: float(getattr(sent, k)) for k in
            ("steps", "skipped", "nonfinite", "spikes", "router_alarms")}})
    if ckpt:
        save_checkpoint(ckpt, params, opt_state, until, extra=sent)
        print(f"saved checkpoint -> {ckpt}")
    if log_file:
        with open(log_file, "w") as f:
            json.dump(history, f, indent=1)
    return params, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="lamb")
    ap.add_argument("--micro-batch", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--log-file", default="")
    ap.add_argument("--zero1", action="store_true",
                    help="shard optimizer state over replicated axes")
    ap.add_argument("--eval-every", type=int, default=0)
    # MoE dispatch flags AND the robust-runtime flags (--sentinel,
    # --resume, --ckpt-every, --ckpt-keep, --ckpt-dir) are DERIVED from the
    # option registries (repro.common.config.MOE_OPTIONS / TRAIN_OPTIONS) —
    # a knob registered there is automatically reachable here, and the
    # dryrun --opt tokens stay in sync by construction
    add_moe_option_flags(ap)
    add_option_flags(ap, TRAIN_OPTIONS)
    args = ap.parse_args()
    train(args.arch, reduced=args.reduced, steps=args.steps, batch=args.batch,
          seq=args.seq, lr=args.lr, optimizer=args.optimizer, seed=args.seed,
          ckpt=args.ckpt, micro_batch=args.micro_batch,
          log_file=args.log_file, zero1=args.zero1,
          eval_every=args.eval_every,
          moe_options=parse_moe_option_flags(args),
          **parse_option_flags(args, TRAIN_OPTIONS))


if __name__ == "__main__":
    main()

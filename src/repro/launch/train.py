"""Training driver.

Runs real optimization steps on whatever devices exist (one CPU here; the
production mesh on TPU — the same code path, only the mesh changes). For
CPU-scale runs pass a reduced arch (``--reduced``).

  PYTHONPATH=src python -m repro.launch.train --arch smile-3.7b --reduced \
      --steps 50 --batch 16 --seq 128
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import MOE_OPTIONS, TrainConfig
from repro.configs import get_config, get_reduced
from repro.data.pipeline import DataPipeline
from repro.models.transformer import init_model
from repro.optim import make_optimizer, make_schedule
from repro.sharding.plan import plan_from_mesh, single_device_plan
from repro.train.checkpoint import save_checkpoint
from repro.train.step import build_train_step

_UNSET = object()       # float-flag default (argparse type-converts string
                        # defaults, so "" cannot be the sentinel there)


def _float_or_off(v: str):
    """argparse type for float options: a number, or off/none -> None.
    Raising ValueError here gives the clean 'usage:' argparse error instead
    of a traceback."""
    if v in ("off", "none"):
        return None
    return float(v)


def add_moe_option_flags(ap) -> None:
    """Add one CLI flag per registered MoE option (``--dispatch-backend``,
    ``--ragged-a2a``, ``--sort-impl``, ``--recv-bound-factor``, ...).

    Empty string = keep the config's setting; bools take on/off; floats take
    a number or ``off`` (-> None).  The registry is the single source of
    truth, so a new knob cannot silently miss this launcher.
    """
    for opt in MOE_OPTIONS:
        if opt.kind == "choice":
            ap.add_argument(opt.flag, default="",
                            choices=("",) + opt.choices, help=opt.help)
        elif opt.kind == "bool":
            ap.add_argument(opt.flag, default="",
                            choices=("", "on", "off"), help=opt.help)
        else:  # float-or-none
            ap.add_argument(opt.flag, default=_UNSET, type=_float_or_off,
                            help=opt.help + " (number, or 'off' for None)")


def parse_moe_option_flags(args) -> dict:
    """Collect the registry-derived flags back into a with_options dict."""
    opts = {}
    for opt in MOE_OPTIONS:
        v = getattr(args, opt.field)
        if v is _UNSET or v == "":
            continue
        if opt.kind == "bool":
            opts[opt.field] = v == "on"
        else:           # choice (str) / float (already converted by argparse)
            opts[opt.field] = v
    return opts


def train(arch: str, *, reduced: bool = True, steps: int = 50,
          batch: int = 16, seq: int = 128, lr: float = 3e-4,
          optimizer: str = "lamb", seed: int = 0, log_every: int = 10,
          ckpt: str = "", mesh=None, micro_batch: int = 0,
          log_file: str = "", zero1: bool = False, eval_every: int = 0,
          moe_options: dict | None = None, dispatch_backend: str = "",
          ragged_a2a: str = "", sort_impl: str = ""):
    cfg = get_reduced(arch) if reduced else get_config(arch)
    # moe_options is the registry-validated path; the three string kwargs
    # are the legacy surface, folded in for backward compatibility
    opts = dict(moe_options or {})
    if dispatch_backend:
        opts.setdefault("dispatch_backend", dispatch_backend)
    if ragged_a2a:
        opts.setdefault("ragged_a2a", ragged_a2a == "on")
    if sort_impl:
        opts.setdefault("sort_impl", sort_impl)
    if opts:
        from repro.configs import with_options
        cfg = with_options(cfg, **opts)
    plan = plan_from_mesh(mesh) if mesh is not None else single_device_plan()
    tcfg = TrainConfig(global_batch_size=batch, seq_len=seq, steps=steps,
                       optimizer=optimizer, lr=lr, warmup_steps=max(steps // 10, 1),
                       micro_batch_size=micro_batch, seed=seed)

    key = jax.random.PRNGKey(seed)
    params = init_model(key, cfg, plan)
    opt = make_optimizer(optimizer)
    sched = make_schedule("cosine", lr, tcfg.warmup_steps, steps)
    if zero1:
        from repro.train.step import zero1_state
        opt_state = zero1_state(params, cfg, plan)
    else:
        opt_state = opt.init(params)

    pipe = DataPipeline(cfg, batch, seq, seed=seed)
    sample = next(pipe)
    batch0 = {k: jnp.asarray(v) for k, v in sample.items()}
    step_fn, _ = build_train_step(cfg, tcfg, plan, opt, sched, params,
                                  batch0, mesh=mesh, zero1=zero1)

    history = []
    t0 = time.time()
    for i in range(steps):
        b = batch0 if i == 0 else {k: jnp.asarray(v) for k, v in next(pipe).items()}
        params, opt_state, m = step_fn(params, opt_state, b, jnp.int32(i + 1))
        if (i + 1) % log_every == 0 or i == 0:
            m = {k: float(v) for k, v in m.items()}
            toks = batch * seq * (i + 1)
            dt = time.time() - t0
            print(f"step {i+1:5d} loss {m['loss']:.4f} ce {m['ce']:.4f} "
                  f"lb {m['lb']:.4f} drop {m['drop_frac']:.3f} "
                  f"gnorm {m['grad_norm']:.2f} tok/s {toks/dt:,.0f}")
            history.append({"step": i + 1, **m, "tokens_per_s": toks / dt})
        if eval_every and (i + 1) % eval_every == 0:
            from repro.train.evaluate import evaluate
            ev = evaluate(params, cfg, plan, batch=batch, seq=seq, seed=seed,
                          n_batches=2)
            print(f"  eval ce {ev['eval_ce']:.4f} ppl {ev['eval_ppl']:.1f}")
            history.append({"step": i + 1, **ev})
    pipe.close()
    if ckpt:
        save_checkpoint(ckpt, params, opt_state, steps)
        print(f"saved checkpoint -> {ckpt}")
    if log_file:
        with open(log_file, "w") as f:
            json.dump(history, f, indent=1)
    return params, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="lamb")
    ap.add_argument("--micro-batch", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--log-file", default="")
    ap.add_argument("--zero1", action="store_true",
                    help="shard optimizer state over replicated axes")
    ap.add_argument("--eval-every", type=int, default=0)
    # MoE dispatch flags are DERIVED from the options registry
    # (repro.common.config.MOE_OPTIONS) — a knob registered there is
    # automatically reachable here, with validation in MoEConfig.with_options
    add_moe_option_flags(ap)
    args = ap.parse_args()
    train(args.arch, reduced=args.reduced, steps=args.steps, batch=args.batch,
          seq=args.seq, lr=args.lr, optimizer=args.optimizer, seed=args.seed,
          ckpt=args.ckpt, micro_batch=args.micro_batch,
          log_file=args.log_file, zero1=args.zero1,
          eval_every=args.eval_every,
          moe_options=parse_moe_option_flags(args))


if __name__ == "__main__":
    main()

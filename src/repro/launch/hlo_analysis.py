"""Loop-aware HLO cost extraction.

``compiled.cost_analysis()`` counts each ``while`` (scan) body ONCE, which
under-reports every scanned-layer model by ~L x n_micro. This module parses
``compiled.as_text()`` into its computations, detects while-loop trip counts
from their condition computations, and accumulates from ENTRY with the
correct multipliers:

* ``dot_flops``      — 2*M*N*K per dot (the MXU term; elementwise ignored)
* ``traffic_bytes``  — per-op operand+output bytes of top-level ops
                       (fusions count as single ops: a rough HBM proxy)
* ``collectives``    — per-class bytes and wire-seconds, DCN vs ICI

Validated against hand-computed counts in tests/test_hlo_analysis.py.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1,
                "f8e5m2": 1, "s64": 8, "s32": 4, "u64": 8, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16}

COLLECTIVE_OPS = ("all-to-all", "all-reduce", "all-gather", "reduce-scatter",
                  "collective-permute", "ragged-all-to-all")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^(?:ROOT )?(%[\w.\-]+) = (.+)$")
_HDR_RE = re.compile(r"^(ENTRY )?(%[\w.\-]+) \((.*)\) -> .* {$")
# first bare identifier followed by "(" after the shape — robust to tuple
# shapes containing /*index=N*/ comments (which defeat naive [^=] matching)
_FIRST_OP_RE = re.compile(r"(?<![%\w])([a-z][\w\-]*)\(")


def _split_op(rest: str):
    """Split "SHAPE opname(operands), attrs" -> (shape_str, op, remainder)."""
    m = _FIRST_OP_RE.search(rest)
    if not m:
        return None, None, rest
    return rest[:m.start()].strip(), m.group(1), rest[m.start():]

_SKIP_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "copy", "after-all", "iota"}


def _shapes_in(s: str) -> List[Tuple[str, List[int]]]:
    return [(dt, [int(d) for d in dims.split(",") if d])
            for dt, dims in _SHAPE_RE.findall(s)]


def _shape_bytes(s: str) -> int:
    total = 0
    for dt, dims in _shapes_in(s):
        if dt in _DTYPE_BYTES:
            total += math.prod(dims) * _DTYPE_BYTES[dt] if dims else _DTYPE_BYTES[dt]
    return total


@dataclass
class Computation:
    name: str
    lines: List[str] = field(default_factory=list)
    # resolved lazily:
    dot_flops: float = 0.0
    dot_bytes: float = 0.0
    traffic: float = 0.0
    colls: List[Dict] = field(default_factory=list)
    whiles: List[Tuple[str, str]] = field(default_factory=list)   # (cond, body)
    calls: List[str] = field(default_factory=list)
    conds: List[List[str]] = field(default_factory=list)          # branches


def split_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry = None
    for raw in hlo.splitlines():
        line = raw.strip()
        m = _HDR_RE.match(line)
        if m:
            cur = Computation(m.group(2))
            comps[cur.name] = cur
            if m.group(1):
                entry = cur.name
            continue
        if line == "}":
            cur = None
            continue
        if cur is not None and line:
            cur.lines.append(line)
    if entry:
        comps["__entry__"] = comps[entry]
    return comps


def _parse_operands(rest: str) -> List[str]:
    m = re.search(r"\(([^)]*)\)", rest)
    if not m:
        return []
    # operands print either bare ("%x") or with an inline type
    # ("f32[32,32]{1,0} %x", older XLA text) — take the %name token
    out = []
    for piece in m.group(1).split(","):
        toks = re.findall(r"%[\w.\-]+", piece)
        if toks:
            out.append(toks[-1])
    return out


def analyze_computation(comp: Computation, symtab_shapes: Dict[str, str],
                        total_devices: int, multi_pod: bool):
    """Fill dot_flops / traffic / colls / whiles / calls for one computation."""
    local_shapes: Dict[str, str] = {}
    for line in comp.lines:
        dm = _DEF_RE.match(line)
        if not dm:
            continue
        name, rest = dm.group(1), dm.group(2)
        out_shape_str, op, _ = _split_op(rest)
        if op is None:
            continue
        local_shapes[name] = out_shape_str

        if op in _SKIP_OPS:
            continue
        out_bytes = _shape_bytes(out_shape_str)

        if op == "while":
            m = re.search(r"condition=(%[\w.\-]+), body=(%[\w.\-]+)", rest)
            if m:
                comp.whiles.append((m.group(1), m.group(2)))
            continue
        if op in ("call", "custom-call"):
            m = re.search(r"to_apply=(%[\w.\-]+)", rest)
            if m:
                comp.calls.append(m.group(1))
            comp.traffic += out_bytes
            continue
        if op == "fusion":
            # recurse for dots/whiles living inside the fused computation
            # (the CPU emitter wraps nearly every op this way); the fusion's
            # own boundary traffic is what hits HBM.
            m = re.search(r"calls=(%[\w.\-]+)", rest)
            if m:
                comp.calls.append(m.group(1))
            ops_in = _parse_operands(rest)
            in_b = [_shape_bytes(local_shapes.get(o, "")) for o in ops_in]
            if "dynamic-update-slice" in name or "dynamic_update_slice" in name:
                # in-place update: only the slice region moves, not the buffer
                upd = min([b for b in in_b if b > 0], default=0)
                comp.traffic += 3 * upd
            else:
                comp.traffic += out_bytes + sum(in_b)
            continue
        if op == "dynamic-update-slice":
            ops_in = _parse_operands(rest)
            in_b = [_shape_bytes(local_shapes.get(o, "")) for o in ops_in]
            upd = sorted([b for b in in_b if b > 0])
            comp.traffic += 3 * (upd[0] if len(upd) < 2 else upd[-2])
            continue
        if op == "conditional":
            bs = re.findall(r"(?:branch_computations=\{([^}]*)\}|"
                            r"true_computation=(%[\w.\-]+)|"
                            r"false_computation=(%[\w.\-]+))", rest)
            branches = []
            for tup in bs:
                for x in tup:
                    if x:
                        branches += [b.strip() for b in x.split(",")]
            if branches:
                comp.conds.append(branches)
            continue

        ops_in = _parse_operands(rest)
        in_bytes = sum(_shape_bytes(local_shapes.get(o, "")) for o in ops_in)
        comp.traffic += out_bytes + in_bytes

        if op in COLLECTIVE_OPS:
            g = _group_size(rest, total_devices)
            comp.colls.append({"op": op, "bytes": out_bytes, "group": g,
                               "dcn": _crosses_pod(rest, g, multi_pod)})
        elif op == "dot":
            k = _dot_contract_size(rest, local_shapes)
            out_elems = sum(math.prod(d) if d else 1
                            for dt, d in _shapes_in(out_shape_str)
                            if dt in _DTYPE_BYTES)
            comp.dot_flops += 2.0 * out_elems * k
            comp.dot_bytes += out_bytes + in_bytes
        elif op == "convolution":
            # treat like dot via window size if present; rare in our models
            comp.dot_flops += 2.0 * out_bytes  # coarse lower bound


def _dot_contract_size(rest: str, shapes: Dict[str, str]) -> int:
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rest)
    ops = _parse_operands(rest)
    if not m or not ops:
        return 1
    dims = [int(x) for x in m.group(1).split(",") if x]
    lhs = shapes.get(ops[0], "")
    parsed = _shapes_in(lhs)
    if not parsed:
        return 1
    _, lhs_dims = parsed[0]
    k = 1
    for d in dims:
        if d < len(lhs_dims):
            k *= lhs_dims[d]
    return k


def _group_size(line: str, total_devices: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    return total_devices


def _crosses_pod(line: str, group: int, multi_pod: bool) -> bool:
    if not multi_pod:
        return False
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        ids = [int(x) for x in m.group(1).split(",")]
        return (max(ids) - min(ids)) >= 256
    return group in (2, 32, 512)


def _trip_count(cond: Computation) -> int:
    best = 1
    for line in cond.lines:
        for m in re.finditer(r"constant\((\d+)\)", line):
            best = max(best, int(m.group(1)))
    return best


@dataclass
class HloCosts:
    dot_flops: float = 0.0
    dot_bytes: float = 0.0      # matmul operand+output traffic (HBM proxy)
    traffic_bytes: float = 0.0  # all-op boundary traffic (upper bound)
    collectives: List[Dict] = field(default_factory=list)

    def add(self, other: "HloCosts", mult: float = 1.0):
        self.dot_flops += mult * other.dot_flops
        self.dot_bytes += mult * other.dot_bytes
        self.traffic_bytes += mult * other.traffic_bytes
        for c in other.collectives:
            cc = dict(c)
            cc["count"] = mult * c.get("count", 1.0)
            self.collectives.append(cc)


def analyze_hlo(hlo: str, total_devices: int, multi_pod: bool) -> HloCosts:
    comps = split_computations(hlo)
    entry = comps.get("__entry__")
    if entry is None:
        return HloCosts()
    analyzed = set()

    def ensure(name: str):
        c = comps.get(name)
        if c is None or name in analyzed:
            return
        analyzed.add(name)
        analyze_computation(c, {}, total_devices, multi_pod)

    memo: Dict[str, HloCosts] = {}

    def total(name: str, stack=()) -> HloCosts:
        if name in memo:
            return memo[name]
        if name in stack:
            return HloCosts()
        c = comps.get(name)
        if c is None:
            return HloCosts()
        ensure(name)
        # fusion bodies: their boundary traffic is charged at the call site;
        # internal ops stay in registers/VMEM, so drop their byte counts.
        fusion_body = ("fused" in name) or ("wrapped" in name)
        out = HloCosts(c.dot_flops, c.dot_bytes,
                       0.0 if fusion_body else c.traffic,
                       [dict(x, count=1.0) for x in c.colls])
        for cond_name, body_name in c.whiles:
            ensure(cond_name)
            trips = _trip_count(comps[cond_name]) if cond_name in comps else 1
            out.add(total(body_name, stack + (name,)), trips)
            out.add(total(cond_name, stack + (name,)), trips)
        for callee in c.calls:
            out.add(total(callee, stack + (name,)), 1.0)
        for branches in c.conds:
            subs = [total(b, stack + (name,)) for b in branches]
            if subs:   # worst-case branch
                worst = max(subs, key=lambda h: h.dot_flops + h.traffic_bytes)
                out.add(worst, 1.0)
        memo[name] = out
        return out

    return total(entry.name)


def collective_summary(costs: HloCosts, *, ici_bw=50e9, dcn_bw=25e9) -> Dict:
    per = {k: 0.0 for k in COLLECTIVE_OPS}
    per_bytes = {k: 0.0 for k in COLLECTIVE_OPS}
    dcn_s = ici_s = 0.0
    n = 0.0
    for c in costs.collectives:
        cnt = c.get("count", 1.0)
        g = max(c["group"], 1)
        # wire-bytes factor per class (ring algorithms, per-device):
        #   all-gather / all-to-all: (g-1)/g of the full buffer
        #   all-reduce: 2x (reduce-scatter then all-gather)
        #   reduce-scatter: output is the small shard -> (g-1) x output
        #   collective-permute: the whole buffer moves once
        if c["op"] == "reduce-scatter":
            factor = float(g - 1)
        elif c["op"] == "all-reduce":
            factor = 2.0 * (g - 1) / g if g > 1 else 0.0
        elif c["op"] == "collective-permute":
            factor = 1.0
        else:
            factor = (g - 1) / g if g > 1 else 0.0
        bw = dcn_bw if c["dcn"] else ici_bw
        t = cnt * c["bytes"] * factor / bw
        per[c["op"]] += t
        per_bytes[c["op"]] += cnt * c["bytes"]
        n += cnt
        if c["dcn"]:
            dcn_s += t
        else:
            ici_s += t
    return {"seconds_per_op": per, "bytes_per_op": per_bytes,
            "ici_seconds": ici_s, "dcn_seconds": dcn_s,
            "total_seconds": ici_s + dcn_s, "n_collectives": n}

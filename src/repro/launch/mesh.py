"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — jax locks the device count on first use, and
only ``dryrun.py`` is allowed to request 512 host-platform devices.
"""
from __future__ import annotations

import jax

from repro.sharding.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """Single pod: 256 chips (16x16, data x model).
    Multi-pod: 2 pods x 256 chips; the ``pod`` axis crosses the DCN."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(n_data: int = 2, n_model: int = 2,
                   pod: int = 0) -> jax.sharding.Mesh:
    """Small fake-device mesh for CPU multi-device tests."""
    if pod:
        return make_mesh((pod, n_data, n_model), ("pod", "data", "model"))
    return make_mesh((n_data, n_model), ("data", "model"))

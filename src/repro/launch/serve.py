"""Serving driver: batched prefill + greedy decode.

Fixed-batch path (compiled prefill + decode loop, all sequences in lock-step):

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-moe-30b-a3b \
      --reduced --batch 4 --prompt-len 32 --new-tokens 16

Continuous-batching engine (paged KV cache, ragged arrivals; the
``SERVE_OPTIONS`` registry derives the engine flags — ``--page-size``,
``--pool-pages``, ``--n-slots``, ``--prefill-buckets``, ``--admit-policy``):

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-moe-30b-a3b \
      --reduced --engine --requests 8 --n-slots 4
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import SERVE_OPTIONS, ServeConfig
from repro.configs import get_config, get_reduced
from repro.data.pipeline import synthetic_tokens
from repro.launch.train import add_option_flags, parse_option_flags
from repro.models.transformer import init_caches, init_model
from repro.serve.decode import build_decode_step, build_prefill
from repro.sharding.plan import plan_from_mesh, single_device_plan


def serve(arch: str, *, reduced: bool = True, batch: int = 4,
          prompt_len: int = 32, new_tokens: int = 16, seed: int = 0,
          mesh=None):
    cfg = get_reduced(arch) if reduced else get_config(arch)
    if not cfg.causal:
        raise SystemExit(f"{arch} is an encoder (MLM) model; no decode step")
    plan = plan_from_mesh(mesh) if mesh is not None else single_device_plan()

    key = jax.random.PRNGKey(seed)
    params = init_model(key, cfg, plan)
    cache_len = prompt_len + new_tokens
    caches = init_caches(cfg, batch, cache_len, plan)

    rng = np.random.default_rng(seed)
    if cfg.num_codebooks > 1:
        prompts = np.stack([synthetic_tokens(rng, batch, prompt_len,
                                             cfg.vocab_size)
                            for _ in range(cfg.num_codebooks)], 1)
    else:
        prompts = synthetic_tokens(rng, batch, prompt_len, cfg.vocab_size)
    prompts = jnp.asarray(prompts)

    prefill = build_prefill(cfg, plan, params, prompts, caches, mesh=mesh)
    t0 = time.time()
    tok, caches = prefill(params, prompts, caches)
    tok.block_until_ready()
    t_prefill = time.time() - t0

    decode = build_decode_step(cfg, plan, params, tok, caches, mesh=mesh)
    out = [np.asarray(tok)]
    t0 = time.time()
    for i in range(new_tokens - 1):
        tok, caches = decode(params, tok, caches,
                             jnp.int32(prompt_len + i))
        out.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    gen = np.stack(out, axis=-1)
    print(f"prefill {prompt_len} toks x{batch}: {t_prefill*1e3:.1f} ms; "
          f"decode {new_tokens-1} steps: {t_decode*1e3:.1f} ms "
          f"({(new_tokens-1)*batch/max(t_decode,1e-9):,.0f} tok/s)")
    print("generated (first row):", gen[0].tolist())
    return gen


def serve_engine(arch: str, *, reduced: bool = True, requests: int = 8,
                 prompt_len: int = 32, new_tokens: int = 16, seed: int = 0,
                 mesh=None, serve_opts: dict | None = None):
    """Continuous-batching engine demo: ragged synthetic requests through
    the paged-KV engine, metrics printed at the end."""
    from repro.serve.engine import Engine
    cfg = get_reduced(arch) if reduced else get_config(arch)
    plan = plan_from_mesh(mesh) if mesh is not None else single_device_plan()
    scfg = dataclasses.replace(
        ServeConfig(prompt_len=prompt_len, max_new_tokens=new_tokens),
        **(serve_opts or {}))
    params = init_model(jax.random.PRNGKey(seed), cfg, plan)
    eng = Engine(params, cfg, plan, serve=scfg, mesh=mesh)

    rng = np.random.default_rng(seed)
    t0 = time.time()
    for _ in range(requests):
        plen = int(rng.integers(max(1, prompt_len // 4), prompt_len + 1))
        nt = int(rng.integers(max(1, new_tokens // 2), new_tokens + 1))
        eng.submit(synthetic_tokens(rng, 1, plen, cfg.vocab_size)[0], nt)
    out = eng.run()
    dt = time.time() - t0
    m = eng.metrics()
    n_tok = sum(len(v) for v in out.values())
    print(f"engine: {requests} requests, {n_tok} tokens in {m['ticks']} ticks"
          f" ({dt*1e3:.0f} ms, {n_tok/max(dt, 1e-9):,.0f} tok/s)")
    print(f"  pool occupancy mean/max: {m['page_occupancy_mean']:.2f}/"
          f"{m['page_occupancy_max']:.2f}  compiles: {m['compiles']}")
    print(f"  moe: drop={m['moe_drop_frac_mean']:.3f} "
          f"max_load={m['moe_hop_max_load_max']:.2f} "
          f"entropy_min={m['moe_hop_load_entropy_min']:.2f}")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--engine", action="store_true",
                    help="continuous-batching engine (paged KV cache) "
                         "instead of the fixed-batch lock-step path")
    ap.add_argument("--requests", type=int, default=8,
                    help="engine mode: synthetic ragged requests to submit")
    add_option_flags(ap, SERVE_OPTIONS)
    args = ap.parse_args()
    if args.engine:
        serve_engine(args.arch, reduced=args.reduced, requests=args.requests,
                     prompt_len=args.prompt_len, new_tokens=args.new_tokens,
                     seed=args.seed,
                     serve_opts=parse_option_flags(args, SERVE_OPTIONS))
    else:
        serve(args.arch, reduced=args.reduced, batch=args.batch,
              prompt_len=args.prompt_len, new_tokens=args.new_tokens,
              seed=args.seed)


if __name__ == "__main__":
    main()

"""Static analyzer CLI: ``python -m repro.launch.analyze``.

Runs the three :mod:`repro.analysis` passes (jaxpr SPMD invariants, Pallas
kernel lint, AST repo lint), prints one line per finding with file:line
provenance, and exits nonzero if anything was flagged.  Wired into
``./ci.sh --static``.

The jaxpr pass traces the entrypoint grid through ``shard_map`` on a
(4, 2) mesh, so this module forces 8 fake CPU devices via ``XLA_FLAGS``
*before* jax is imported — run it as a subprocess (as ci.sh and the tests
do), not inside a process that already initialized jax.
"""
from __future__ import annotations

import os
import sys

_FLAG = "--xla_force_host_platform_device_count=8"
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") + " " + _FLAG).strip()

import argparse


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.analyze",
        description="static SPMD/collective invariant checker + Pallas lint")
    ap.add_argument("--pass", dest="passes", default="all",
                    choices=("all", "jaxpr", "pallas", "repo"),
                    help="which analysis pass to run (default: all)")
    ap.add_argument("--vmem-budget-mib", type=float, default=16.0,
                    help="per-grid-step VMEM budget for pallas_lint (MiB)")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="suppress progress lines; print findings only")
    args = ap.parse_args(argv)

    log = (lambda _msg: None) if args.quiet else (lambda msg: print(msg, flush=True))

    from repro.analysis import format_findings

    findings = []
    if args.passes in ("all", "repo"):
        log("[analyze] repo lint (AST)...")
        from repro.analysis import repo_lint
        findings += repo_lint.run(log=log)
    if args.passes in ("all", "pallas"):
        log("[analyze] pallas lint (tracing kernel registry)...")
        from repro.analysis import pallas_lint
        budget = int(args.vmem_budget_mib * 1024 * 1024)
        findings += pallas_lint.run(vmem_budget=budget, log=log)
    if args.passes in ("all", "jaxpr"):
        log("[analyze] jaxpr lint (tracing entrypoint grid)...")
        from repro.analysis import jaxpr_lint
        findings += jaxpr_lint.run(log=log)

    print(format_findings(findings), flush=True)
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())

"""ShapeDtypeStruct stand-ins for every model input — the dry-run's fuel.

Nothing here allocates device memory: params, optimizer state, batches and
decode caches are all ``jax.ShapeDtypeStruct`` with attached NamedShardings,
which is exactly what ``jit(...).lower()`` needs.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.common.config import InputShape, ModelConfig
from repro.models import transformer as T
from repro.sharding.plan import MeshPlan
from repro.sharding.specs import (batch_dim_spec, batch_specs, cache_specs,
                                  param_specs)


def _sds(tree, spec_tree, mesh):
    def one(x, s):
        sh = NamedSharding(mesh, s) if mesh is not None else None
        return jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=sh)
    return jax.tree.map(one, tree, spec_tree,
                        is_leaf=lambda x: hasattr(x, "shape"))


def params_struct(cfg: ModelConfig, plan: MeshPlan, mesh=None,
                  dtype=None):
    """Abstract params (+ their specs) without allocating. ``dtype``
    overrides floating leaves (bf16 serving weights)."""
    shapes = jax.eval_shape(
        lambda k: T.init_model(k, cfg, plan), jax.random.PRNGKey(0))
    if dtype is not None:
        shapes = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(
                x.shape, dtype if jnp.issubdtype(x.dtype, jnp.floating)
                else x.dtype), shapes)
    specs = param_specs(shapes, cfg, plan)
    return _sds(shapes, specs, mesh), specs


def train_batch_struct(cfg: ModelConfig, shape: InputShape, plan: MeshPlan,
                       mesh=None):
    B, S = shape.global_batch, shape.seq_len
    batch: Dict[str, Any] = {}
    if cfg.num_codebooks > 1:
        batch["tokens"] = jax.ShapeDtypeStruct((B, cfg.num_codebooks, S),
                                               jnp.int32)
        batch["labels"] = jax.ShapeDtypeStruct((B, cfg.num_codebooks, S),
                                               jnp.int32)
    else:
        batch["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        batch["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if cfg.vision_tokens:
        batch["image_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.vision_tokens, cfg.vision_embed_dim), jnp.float32)
        batch["image_pos"] = jax.ShapeDtypeStruct(
            (B, cfg.vision_tokens), jnp.int32)
    specs = batch_specs(batch, plan)
    return _sds(batch, specs, mesh), specs


def prefill_batch_struct(cfg: ModelConfig, shape: InputShape, plan: MeshPlan,
                         mesh=None):
    B, S = shape.global_batch, shape.seq_len
    if cfg.num_codebooks > 1:
        toks = jax.ShapeDtypeStruct((B, cfg.num_codebooks, S), jnp.int32)
    else:
        toks = jax.ShapeDtypeStruct((B, S), jnp.int32)
    spec = batch_specs({"t": toks}, plan)["t"]
    return _sds(toks, spec, mesh), spec


def cache_length(cfg: ModelConfig, shape: InputShape) -> int:
    L = shape.seq_len
    if cfg.attention == "sliding":
        L = min(L, cfg.window)
    return L


def decode_state_struct(cfg: ModelConfig, shape: InputShape, plan: MeshPlan,
                        mesh=None):
    """(token, caches, step) structs for the decode step."""
    B = shape.global_batch
    caches = jax.eval_shape(
        lambda: T.init_caches(cfg, B, cache_length(cfg, shape), plan))
    cspecs = cache_specs(caches, cfg, plan, B)
    if cfg.num_codebooks > 1:
        tok = jax.ShapeDtypeStruct((B, cfg.num_codebooks), jnp.int32)
        tspec = P(batch_dim_spec(B, plan), None)
    else:
        tok = jax.ShapeDtypeStruct((B,), jnp.int32)
        tspec = P(batch_dim_spec(B, plan))
    step = jax.ShapeDtypeStruct((), jnp.int32)
    return (_sds(tok, tspec, mesh), _sds(caches, cspecs, mesh),
            (jax.ShapeDtypeStruct((), jnp.int32, sharding=NamedSharding(mesh, P()))
             if mesh is not None else step)), (tspec, cspecs, P())

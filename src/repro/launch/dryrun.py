import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

DOC = """Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).

For each combination this:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod);
  2. lowers the REAL step function (train_step / prefill / decode_step —
     chosen by the input shape's kind) against ShapeDtypeStruct stand-ins
     (zero device allocation);
  3. compiles, printing ``memory_analysis()`` (fits-or-not evidence) and
     ``cost_analysis()`` (FLOPs / bytes for the roofline);
  4. parses the compiled HLO for collective ops and sums their bytes per
     class (all-to-all / all-reduce / ...), attributing DCN vs ICI by
     replica-group span;
  5. writes everything to ``experiments/dryrun/<arch>__<shape>__<mesh>.json``
     — the §Roofline and §Perf analyses read these files.

Usage:
  python -m repro.launch.dryrun --arch deepseek-v3-671b --shape train_4k
  python -m repro.launch.dryrun --arch ... --shape ... --multi-pod
  python -m repro.launch.dryrun --all --jobs 6          # full 10x4x2 sweep
"""

import argparse
import json
import re
import subprocess
import sys
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.common.config import (INPUT_SHAPES, MOE_DRYRUN_OPTS,
                                 TRAIN_DRYRUN_OPTS, TrainConfig)
from repro.configs import config_for_shape, supports_shape
from repro.launch import inputs as I
from repro.launch.hlo_analysis import analyze_hlo, collective_summary
from repro.launch.mesh import make_production_mesh
from repro.optim import make_optimizer, make_schedule
from repro.serve.decode import build_decode_step, build_prefill
from repro.sharding.plan import plan_from_mesh
from repro.train.step import build_train_step

def lower_one(arch: str, shape_name: str, multi_pod: bool,
              smile: Optional[bool] = None, opts: str = ""):
    shape = INPUT_SHAPES[shape_name]
    cfg = config_for_shape(arch, shape)
    if smile is not None and cfg.moe is not None:
        import dataclasses
        cfg = cfg.replace(moe=dataclasses.replace(
            cfg.moe, router="smile" if smile else "switch"))
    opt_set = set(o for o in opts.split(",") if o)
    if "rsc" in opt_set:
        cfg = cfg.replace(remat_save_collectives=True)
    if "kvseq" in opt_set:
        cfg = cfg.replace(kv_seq_shard=True)
    # MoE --opt tokens are DERIVED from the options registry
    # (repro.common.config.MOE_DRYRUN_OPTS): "dropless", "padded_a2a",
    # "radix_sort", "recv_bound", "tightcap", ... — a knob registered there
    # is automatically reachable here, validated by MoEConfig.with_options.
    # Each token carries its prerequisites (recv_bound implies dropless +
    # ragged hops); contradictory tokens (e.g. padded_a2a + recv_bound)
    # fail loudly instead of one silently overriding the other.
    moe_kw, moe_src = {}, {}
    for tok in sorted(opt_set & MOE_DRYRUN_OPTS.keys()):
        for fld, val in MOE_DRYRUN_OPTS[tok].items():
            if fld in moe_kw and moe_kw[fld] != val:
                raise ValueError(
                    f"--opt tokens {moe_src[fld]!r} and {tok!r} disagree "
                    f"on {fld} ({moe_kw[fld]!r} vs {val!r})")
            moe_kw[fld] = val
            moe_src.setdefault(fld, tok)
    if moe_kw and cfg.moe is not None:
        from repro.configs import with_options
        cfg = with_options(cfg, **moe_kw)
    mesh = make_production_mesh(multi_pod=multi_pod)
    inter = ("pod", "data") if "epxpod" in opt_set else None
    plan = plan_from_mesh(mesh, smile_inter_axes=inter)
    pdtype = jnp.bfloat16 if "bf16p" in opt_set else None
    pstruct, pspec = I.params_struct(cfg, plan, mesh, dtype=pdtype)

    if shape.kind == "train":
        # train-loop --opt tokens come from the SAME registry contract as
        # the MoE ones (TRAIN_DRYRUN_OPTS): "sentinel" lowers the guarded
        # 5-ary step so its mesh cost/memory is measurable like any knob
        train_kw = {}
        for tok in sorted(opt_set & TRAIN_DRYRUN_OPTS.keys()):
            train_kw.update(TRAIN_DRYRUN_OPTS[tok])
        sentinel = bool(train_kw.get("sentinel", False))
        tcfg = TrainConfig(global_batch_size=shape.global_batch,
                           seq_len=shape.seq_len, micro_batch_size=1,
                           optimizer="lamb", sentinel=sentinel)
        opt = make_optimizer("lamb")
        sched = make_schedule("cosine", 3e-4, 100, 10000)
        bstruct, _ = I.train_batch_struct(cfg, shape, plan, mesh)
        zero1 = "zero1" in opt_set
        if zero1:
            from repro.optim.zero1 import state_specs
            from repro.sharding.specs import shard_axes, sharded_axes_only
            from repro.train.step import zero1_state
            ostruct = jax.eval_shape(
                lambda: zero1_state(pstruct, cfg, plan))
            ospec = state_specs(pspec, shard_axes(pspec, plan),
                                sharded_axes_only(pspec, plan))
            ostruct = I._sds(ostruct, ospec, mesh)
        else:
            ostruct = jax.eval_shape(opt.init, pstruct)
            ospec = {"m": pspec, "v": pspec, "step": P()}
            ostruct = I._sds(ostruct, ospec, mesh)
        sstruct = jax.ShapeDtypeStruct((), jnp.int32,
                                       sharding=NamedSharding(mesh, P()))
        step, _ = build_train_step(cfg, tcfg, plan, opt, sched, pstruct,
                                   bstruct, mesh=mesh, zero1=zero1,
                                   sentinel=sentinel)
        if sentinel:
            from repro.train.sentinel import init_sentinel_state
            xstruct = jax.eval_shape(init_sentinel_state)
            xstruct = I._sds(xstruct, jax.tree.map(lambda _: P(), xstruct),
                             mesh)
            lowered = step.lower(pstruct, ostruct, bstruct, sstruct, xstruct)
        else:
            lowered = step.lower(pstruct, ostruct, bstruct, sstruct)
    elif shape.kind == "prefill":
        from repro.models.transformer import init_caches
        from repro.sharding.specs import cache_specs
        tstruct, _ = I.prefill_batch_struct(cfg, shape, plan, mesh)
        cshapes = jax.eval_shape(lambda: init_caches(
            cfg, shape.global_batch, I.cache_length(cfg, shape), plan))
        cspec = cache_specs(cshapes, cfg, plan, shape.global_batch)
        cstruct = I._sds(cshapes, cspec, mesh)
        fn = build_prefill(cfg, plan, pstruct, tstruct, cstruct, mesh=mesh)
        lowered = fn.lower(pstruct, tstruct, cstruct)
    else:  # decode
        (tstruct, cstruct, sstruct), _ = I.decode_state_struct(
            cfg, shape, plan, mesh)
        fn = build_decode_step(cfg, plan, pstruct, tstruct, cstruct, mesh=mesh)
        lowered = fn.lower(pstruct, tstruct, cstruct, sstruct)
    return lowered, mesh, cfg


def run_one(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
            smile: Optional[bool] = None, tag: str = "",
            opts: str = "") -> Dict:
    shape = INPUT_SHAPES[shape_name]
    if not supports_shape(arch, shape):
        return {"skipped": True}
    t0 = time.time()
    lowered, mesh, cfg = lower_one(arch, shape_name, multi_pod, smile=smile,
                                   opts=opts)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    print(mem)
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):        # older jax: list of per-device dicts
        ca = ca[0] if ca else {}
    print({k: ca.get(k) for k in ("flops", "bytes accessed")})
    hlo = compiled.as_text()
    ndev = 512 if multi_pod else 256
    costs = analyze_hlo(hlo, ndev, multi_pod)
    csec = collective_summary(costs)
    by_group = {}
    for c in costs.collectives:
        key = f"{c['op']}|g{c['group']}|{'dcn' if c['dcn'] else 'ici'}"
        by_group[key] = by_group.get(key, 0.0) + c["bytes"] * c.get("count", 1.0)

    res = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "router": (cfg.moe.router if cfg.moe else None),
        "flops": float(ca.get("flops", 0.0)),            # scan bodies once!
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "dot_flops_corrected": costs.dot_flops,          # loop-aware
        "dot_bytes_corrected": costs.dot_bytes,          # HBM proxy (matmuls)
        "traffic_bytes_corrected": costs.traffic_bytes,  # upper bound
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
        "collectives": csec,
        "collectives_by_group": by_group,
        "lower_s": t_lower, "compile_s": t_compile,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    fn = os.path.join(out_dir, f"{arch}__{shape_name}__"
                      f"{'multi' if multi_pod else 'single'}{suffix}.json")
    with open(fn, "w") as f:
        json.dump(res, f, indent=1)
    print(f"[dryrun] {arch} {shape_name} "
          f"{'2x16x16' if multi_pod else '16x16'}{suffix}: "
          f"flops={res['flops']:.3e} a2a_bytes="
          f"{csec['bytes_per_op']['all-to-all']:.3e} "
          f"compile={t_compile:.1f}s -> {fn}")
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--router", choices=["smile", "switch"], default=None,
                    help="override MoE router (baseline comparisons)")
    ap.add_argument("--tag", default="")
    ap.add_argument("--opt", default="",
                    help="comma list: rsc,kvseq,zero1,bf16p,epxpod + the "
                         "registry-derived MoE tokens "
                         f"({','.join(sorted(MOE_DRYRUN_OPTS))}) + train "
                         f"tokens ({','.join(sorted(TRAIN_DRYRUN_OPTS))})")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--jobs", type=int, default=4)
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    if not args.all:
        smile = None if args.router is None else (args.router == "smile")
        run_one(args.arch, args.shape, args.multi_pod, args.out,
                smile=smile, tag=args.tag, opts=args.opt)
        return

    # full sweep via subprocesses (each gets a fresh 512-device runtime)
    from repro.configs import ASSIGNED
    jobs = []
    for arch in ASSIGNED:
        for shape in INPUT_SHAPES:
            for mp in (False, True):
                fn = os.path.join(args.out, f"{arch}__{shape}__"
                                  f"{'multi' if mp else 'single'}.json")
                if args.skip_existing and os.path.exists(fn):
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape, "--out", args.out]
                if mp:
                    cmd.append("--multi-pod")
                jobs.append((arch, shape, mp, cmd))

    running: List = []
    fails = []
    while jobs or running:
        while jobs and len(running) < args.jobs:
            arch, shape, mp, cmd = jobs.pop(0)
            p = subprocess.Popen(cmd, stdout=subprocess.PIPE,
                                 stderr=subprocess.STDOUT, text=True)
            running.append((arch, shape, mp, p))
        time.sleep(2)
        still = []
        for arch, shape, mp, p in running:
            if p.poll() is None:
                still.append((arch, shape, mp, p))
                continue
            out = p.stdout.read()
            if p.returncode != 0:
                fails.append((arch, shape, mp))
                print(f"FAIL {arch} {shape} mp={mp}:\n{out[-2000:]}")
            else:
                print(out.strip().splitlines()[-1])
        running = still
    print(f"\n{len(fails)} failures: {fails}")
    sys.exit(1 if fails else 0)


if __name__ == "__main__":
    main()

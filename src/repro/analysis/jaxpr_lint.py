"""Trace-time SPMD/collective invariant checks over closed jaxprs.

The pass walks a jaxpr (recursing into every sub-jaxpr: ``cond`` branches,
``while``/``scan`` bodies, ``pjit``/``shard_map``/``custom_vjp`` calls),
collects every collective equation with its axis names, operand types and
source provenance, and applies four rules — see the package docstring
(:mod:`repro.analysis`) for the rationale of each:

* ``cond-collective-mismatch`` — all branches of a ``lax.cond`` must run
  the same collective sequence, unless the cond was lowered through
  :func:`repro.sharding.comm.uniform_cond` (mesh-uniform predicate).
* ``unknown-axis-name`` — collective axis names must exist on the mesh.
* ``collective-int-dtype`` — integer collective operands must be int32.
* ``collective-outside-comm`` — collectives may only be introduced by
  ``sharding/comm.py``-lowered code.

Entrypoint tracing (:func:`iter_entrypoints` / :func:`run`) needs the
8-fake-device mesh, so the full pass runs from ``python -m
repro.launch.analyze`` (which forces the device count before importing
jax); :func:`lint_jaxpr` itself is mesh-free and is what the seeded-bad
fixtures in ``tests/test_analysis.py`` drive in-process.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Iterator, List, Optional, Sequence, Tuple

import jax
from jax import core as jcore

from repro.analysis import Finding

# Primitive names (jax 0.4.x) of cross-device collectives.  pmean lowers to
# psum; psum_scatter lowers to reduce_scatter; ragged_all_to_all is the
# native ragged op of jax >= 0.4.38 (absent here, checked for the future).
COLLECTIVE_PRIMS = frozenset({
    "psum", "pmax", "pmin", "ppermute", "pshuffle", "all_to_all",
    "all_gather", "reduce_scatter", "psum_scatter", "ragged_all_to_all",
    "pgather",
})

# The one module allowed to introduce collective primitives.
COMM_SUFFIX = "repro/sharding/comm.py"

_JAXPR_TYPES = (jcore.Jaxpr, jcore.ClosedJaxpr)


def _as_jaxpr(v) -> Optional[jcore.Jaxpr]:
    if isinstance(v, jcore.ClosedJaxpr):
        return v.jaxpr
    if isinstance(v, jcore.Jaxpr):
        return v
    return None


def _sub_jaxprs(params: dict) -> Iterator[Tuple[str, jcore.Jaxpr]]:
    """Yield (param_key, jaxpr) for every sub-jaxpr in an eqn's params."""
    for key, v in params.items():
        j = _as_jaxpr(v)
        if j is not None:
            yield key, j
        elif isinstance(v, (list, tuple)):
            for item in v:
                j = _as_jaxpr(item)
                if j is not None:
                    yield key, j


def user_frame(eqn: jcore.JaxprEqn) -> Tuple[Optional[str], Optional[int]]:
    """Innermost non-jax stack frame of an equation (file, line)."""
    tb = eqn.source_info.traceback if eqn.source_info else None
    if tb is None:
        return None, None
    for fr in tb.frames:
        fn = fr.file_name
        if "site-packages" in fn or fn.startswith("<") or "/jax/" in fn:
            continue
        return fn, fr.line_num
    return None, None


def _axes_of(eqn: jcore.JaxprEqn) -> Tuple[str, ...]:
    """Normalized axis-name tuple of a collective equation."""
    axes = eqn.params.get("axes", eqn.params.get("axis_name"))
    if axes is None:
        return ()
    if isinstance(axes, (str, int)):
        return (str(axes),)
    return tuple(str(a) for a in axes)


@dataclasses.dataclass(frozen=True)
class CollectiveSite:
    """One collective equation: what, over which axes, on what, and where."""

    prim: str
    axes: Tuple[str, ...]
    in_types: Tuple[str, ...]      # "f32[64,32]"-style operand types
    path: str                      # jaxpr nesting path, e.g. "/shard_map/cond"
    file: Optional[str]
    line: Optional[int]

    @property
    def signature(self) -> Tuple[str, Tuple[str, ...], Tuple[str, ...]]:
        """Congruence key: primitive + axis names + operand types, in order."""
        return (self.prim, self.axes, self.in_types)


def _site(eqn: jcore.JaxprEqn, path: str) -> CollectiveSite:
    f, ln = user_frame(eqn)
    types = tuple(str(v.aval) for v in eqn.invars
                  if isinstance(v, jcore.Var) or hasattr(v, "aval"))
    return CollectiveSite(eqn.primitive.name, _axes_of(eqn), types, path,
                          f, ln)


def collect_collectives(jaxpr: jcore.Jaxpr, path: str = ""
                        ) -> List[CollectiveSite]:
    """All collective sites in ``jaxpr``, recursing into sub-jaxprs."""
    sites: List[CollectiveSite] = []
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name in COLLECTIVE_PRIMS:
            sites.append(_site(eqn, path))
        for key, sub in _sub_jaxprs(eqn.params):
            sites.extend(collect_collectives(sub, f"{path}/{name}"))
    return sites


# =============================================================================
# Rules
# =============================================================================

def check_cond_congruence(jaxpr: jcore.Jaxpr, entry: str = "",
                          path: str = "") -> List[Finding]:
    """Every ``cond`` branch pair must run identical collective sequences.

    Waived for conds whose innermost user frame lives in ``comm.py`` —
    i.e. conds lowered through :func:`repro.sharding.comm.uniform_cond`,
    whose contract is a mesh-uniform predicate (every device takes the
    same branch, so asymmetric collectives cannot diverge the mesh).
    """
    findings: List[Finding] = []
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "cond" and "branches" in eqn.params:
            seqs = [tuple(s.signature for s in collect_collectives(b))
                    for b in (_as_jaxpr(br) for br in eqn.params["branches"])]
            if len(set(seqs)) > 1:
                f, ln = user_frame(eqn)
                if not (f and f.endswith(COMM_SUFFIX)):
                    desc = " vs ".join(
                        "[" + ", ".join(f"{p} over {a}" for p, a, _ in s) + "]"
                        for s in seqs)
                    findings.append(Finding(
                        "jaxpr", "cond-collective-mismatch",
                        f"{entry}: cond at {path or '/'} runs different "
                        f"collective sequences per branch ({desc}); either "
                        f"make the branches congruent or route the cond "
                        f"through comm.uniform_cond after proving the "
                        f"predicate mesh-uniform", f, ln))
        for key, sub in _sub_jaxprs(eqn.params):
            findings.extend(
                check_cond_congruence(sub, entry, f"{path}/{name}"))
    return findings


def check_axis_names(sites: Sequence[CollectiveSite],
                     mesh_axes: Sequence[str], entry: str = ""
                     ) -> List[Finding]:
    """Collective axis names must all exist on the mesh."""
    known = set(mesh_axes)
    findings = []
    for s in sites:
        unknown = [a for a in s.axes if a not in known]
        if unknown:
            findings.append(Finding(
                "jaxpr", "unknown-axis-name",
                f"{entry}: {s.prim} at {s.path or '/'} names mesh axes "
                f"{unknown} not in the mesh spec {sorted(known)}",
                s.file, s.line))
    return findings


def check_count_dtypes(sites: Sequence[CollectiveSite], entry: str = ""
                       ) -> List[Finding]:
    """Integer operands of collectives (count grids) must be int32."""
    findings = []
    for s in sites:
        bad = [t for t in s.in_types
               if t.startswith(("int", "uint")) and not t.startswith(
                   ("int32", "uint32", "int8", "int16", "uint8", "uint16"))]
        if bad:
            findings.append(Finding(
                "jaxpr", "collective-int-dtype",
                f"{entry}: {s.prim} at {s.path or '/'} moves non-int32 "
                f"integer operand(s) {bad} across the wire — count grids "
                f"must be exactly int32 at every collective boundary "
                f"(silent x64 promotion doubles exchange bytes and breaks "
                f"the native ragged-A2A offset contract)",
                s.file, s.line))
    return findings


def check_provenance(sites: Sequence[CollectiveSite], entry: str = ""
                     ) -> List[Finding]:
    """Collectives may only be introduced by comm.py-lowered code."""
    findings = []
    for s in sites:
        if s.file is None:
            continue               # no traceback (synthetic jaxpr): skip
        if not s.file.endswith(COMM_SUFFIX):
            findings.append(Finding(
                "jaxpr", "collective-outside-comm",
                f"{entry}: {s.prim} at {s.path or '/'} is introduced "
                f"outside sharding/comm.py — all collectives must go "
                f"through the comm helpers (single-device oracle identity, "
                f"remat save-policy tagging, and this analyzer's waivers "
                f"all key off that provenance)", s.file, s.line))
    return findings


def lint_jaxpr(closed: jcore.ClosedJaxpr, *, mesh_axes: Sequence[str],
               entry: str = "", provenance: bool = True) -> List[Finding]:
    """Run all jaxpr rules over one traced entrypoint."""
    jaxpr = closed.jaxpr
    sites = collect_collectives(jaxpr)
    findings = check_cond_congruence(jaxpr, entry)
    findings += check_axis_names(sites, mesh_axes, entry)
    findings += check_count_dtypes(sites, entry)
    if provenance:
        findings += check_provenance(sites, entry)
    return findings


# =============================================================================
# Entrypoint grid: both routers x all backends x ragged/padded wire, plus
# the train step with the sentinel on and off.  Shapes derive from the
# paper configs in repro.configs, scaled onto the 8-device test mesh.
# =============================================================================

MESH_SHAPE = (4, 2)
MESH_AXES = ("data", "model")


def _moe_cases():
    import dataclasses as dc

    from repro.configs import get_reduced

    for router, arch in (("switch", "switch-3.7b"), ("smile", "smile-3.7b")):
        base = get_reduced(arch).moe
        base = dc.replace(base, num_experts=8, d_ff_expert=64,
                          grid=MESH_SHAPE, capacity_factor=2.0)
        for backend, ragged in (("sort", True), ("dense", True),
                                ("dropless", True), ("dropless", False)):
            cfg = base.with_options(dispatch_backend=backend,
                                    ragged_a2a=ragged)
            name = f"moe/{router}/{backend}"
            if backend == "dropless":
                name += "/ragged" if ragged else "/padded"
            yield name, cfg
        # the fused routing megakernel feeding the same dropless/ragged hop:
        # the route decision moves through the kernel (or its oracle below
        # the row threshold) but every collective it feeds must stay
        # congruent with the unfused chain
        yield (f"moe/{router}/dropless/fused",
               base.with_options(dispatch_backend="dropless", ragged_a2a=True,
                                 router_impl="fused"))
        # wire-integrity policies ride the ragged hops only: the parity
        # rows and per-segment verdicts must obey every collective rule
        # (int32 words, comm.py provenance, no divergent conds)
        for pol in ("detect", "quarantine"):
            yield (f"moe/{router}/dropless/ragged/wire-{pol}",
                   base.with_options(dispatch_backend="dropless",
                                     ragged_a2a=True, wire_integrity=pol))


def _trace_moe(cfg, mesh, plan):
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.core.moe import init_moe_params, moe_layer
    from repro.sharding.compat import shard_map

    d, t = 32, 64
    params = init_moe_params(jax.random.PRNGKey(0), cfg, d, plan)
    x = jnp.zeros((t, d), jnp.float32)
    espec = P("data", "model", None, None)
    pspecs = {"experts": {k: espec for k in params["experts"]}}
    for k in params:
        if k.startswith("router"):
            pspecs[k] = {"w": P(None, None)}

    def f(p, xx):
        y, st = moe_layer(p, xx, cfg, plan, act="gelu")
        return y, st.lb_loss, st.drop_frac

    fsm = shard_map(f, mesh=mesh,
                    in_specs=(pspecs, P(("data", "model"), None)),
                    out_specs=(P(("data", "model"), None), P(), P()))
    return jax.make_jaxpr(fsm)(params, x)


def _trace_train(sentinel: bool, mesh, plan):
    import jax.numpy as jnp

    from repro.common.config import TrainConfig
    from repro.configs import get_reduced
    from repro.data.pipeline import make_batch
    from repro.models.transformer import init_model
    from repro.optim import make_optimizer, make_schedule
    from repro.sharding.plan import single_device_plan
    from repro.train.sentinel import init_sentinel_state
    from repro.train.step import build_train_step

    cfg = get_reduced("smile-3.7b").replace(remat=False)
    tcfg = TrainConfig(global_batch_size=8, seq_len=32, optimizer="lamb",
                       lr=1e-3, warmup_steps=2, sentinel=sentinel)
    params = init_model(jax.random.PRNGKey(0), cfg, single_device_plan())
    batch = {k: jnp.asarray(v)
             for k, v in make_batch(cfg, 8, 32, 0, 0).items()}
    opt = make_optimizer("lamb")
    sched = make_schedule("cosine", 1e-3, 2, 100)
    step, _ = build_train_step(cfg, tcfg, plan, opt, sched, params, batch,
                               mesh=mesh, sentinel=sentinel)
    args = (params, opt.init(params), batch, jnp.int32(1))
    if sentinel:
        args += (init_sentinel_state(),)
    return jax.make_jaxpr(lambda *a: step(*a))(*args)


def _trace_serve(mesh, plan):
    """Serving entrypoints: the fused batched decode tick (paged KV scatter/
    gather + the masked MoE decode hop) and one bucketed prefill chunk.
    Unregistered entrypoints are invisible to ``./ci.sh --static`` — these
    are the jitted callables ``repro.serve.engine.Engine`` drives."""
    import jax.numpy as jnp

    from repro.configs import get_reduced
    from repro.models.transformer import init_model
    from repro.serve import kvcache as KVC
    from repro.serve.engine import (build_paged_decode_step,
                                    build_paged_prefill)

    cfg = get_reduced("qwen3-moe-30b-a3b")     # MoE arch -> decode hop traced
    params = init_model(jax.random.PRNGKey(0), cfg, plan)
    page, pool_pages, n_slots, mp = 4, 16, 4, 4
    caches = KVC.init_paged_caches(cfg, pool_pages, page, plan)
    table = jnp.zeros((n_slots, mp), jnp.int32)

    decode = build_paged_decode_step(cfg, plan, params, caches, mesh)
    dargs = (params, jnp.zeros((n_slots,), jnp.int32), caches, table,
             jnp.zeros((n_slots,), jnp.int32),
             jnp.ones((n_slots,), bool))
    yield "serve/decode_tick", jax.make_jaxpr(lambda *a: decode(*a))(*dargs)

    prefill = build_paged_prefill(cfg, plan, params, caches, mesh)
    pargs = (params, jnp.zeros((1, 8), jnp.int32), caches, table[:1],
             jnp.int32(0), jnp.int32(8))
    yield ("serve/prefill_chunk",
           jax.make_jaxpr(lambda *a: prefill(*a))(*pargs))


def iter_entrypoints() -> Iterator[Tuple[str, jcore.ClosedJaxpr]]:
    """Trace the registered entrypoint grid on the 8-fake-device mesh."""
    from repro.sharding.compat import make_mesh
    from repro.sharding.plan import test_plan

    if len(jax.devices()) < 8:
        raise RuntimeError(
            "jaxpr_lint needs >= 8 devices to trace the entrypoint grid; "
            "run via `python -m repro.launch.analyze`, which forces "
            "XLA_FLAGS=--xla_force_host_platform_device_count=8 before "
            "importing jax")
    mesh = make_mesh(MESH_SHAPE, MESH_AXES)
    plan = test_plan(*MESH_SHAPE)
    for name, cfg in _moe_cases():
        yield name, _trace_moe(cfg, mesh, plan)
    train_mesh = make_mesh((2, 2), MESH_AXES)
    train_plan = test_plan(2, 2)
    for sentinel in (False, True):
        name = f"train_step/{'sentinel' if sentinel else 'plain'}"
        yield name, _trace_train(sentinel, train_mesh, train_plan)
    yield from _trace_serve(train_mesh, train_plan)


def run(log=None) -> List[Finding]:
    """Trace and lint every registered entrypoint; return all findings."""
    findings: List[Finding] = []
    for name, closed in iter_entrypoints():
        got = lint_jaxpr(closed, mesh_axes=MESH_AXES, entry=name)
        if log:
            n = len(collect_collectives(closed.jaxpr))
            log(f"  jaxpr: {name}: {n} collective sites, "
                f"{len(got)} finding(s)")
        findings += got
    return findings

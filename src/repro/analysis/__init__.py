"""Static analysis: trace-time SPMD/collective invariants + Pallas lint.

**Why a static analyzer.**  PR 6 made the runtime fault-contained, but its
hardest bug class — an SPMD hazard such as a collective appearing in only
one branch of a ``lax.cond``-gated optimizer apply, or a psum axis-set
mismatch between a verdict and the grad sync — is only caught
*dynamically*, if a test happens to hit the deadlock/wrong-value path.
Communication-schedule correctness is exactly what production MoE training
lives or dies on at scale (MegaScale-MoE), and the roadmap's next tentpoles
(fused routing megakernel, micro-chunked comm/compute overlap) add more
Pallas kernels and more collective choreography.  This package checks those
invariants at *trace time*, over closed jaxprs and kernel BlockSpecs, with
no devices beyond the fake-CPU mesh and no execution of the traced code.

**Architecture.**  Three independent passes, one driver:

* :mod:`repro.analysis.jaxpr_lint` — traces the registered entrypoint grid
  (both routers x every dispatch backend x ragged/padded wire x the train
  step with the sentinel on/off, shapes derived from ``repro.configs``)
  through ``shard_map`` on an 8-fake-device mesh to closed jaxprs, then
  verifies SPMD invariants on the result:

  - **cond-branch congruence** — every ``lax.cond`` executes an *identical*
    collective sequence (primitive, axis names, operand shapes, order) in
    all branches.  A mesh-uniform predicate makes asymmetric branches safe
    (the sentinel's gated apply relies on this), so the rule is waived for
    conds lowered through :func:`repro.sharding.comm.uniform_cond` — the
    one blessed place that asserts the uniformity contract in its docs.
  - **axis-name consistency** — every collective's axis names are a subset
    of the mesh's axis names.
  - **int32 collective boundaries** — integer operands of collectives
    (count grids) must be exactly int32: silent x64 promotion doubles
    count-exchange bytes and breaks the native ragged-A2A offset contract.
  - **collective provenance** — no collective primitive is introduced
    outside code lowered from :mod:`repro.sharding.comm` (the repo's one
    blessed collective module; everything else must call through it).

* :mod:`repro.analysis.pallas_lint` — traces each kernel wrapper in
  ``repro.kernels`` at representative static shapes and checks every
  ``pallas_call`` equation:

  - **VMEM footprint** — ~2x (double-buffered) sum of per-grid-step block
    bytes + scratch bytes against a configurable budget;
  - **tile alignment** — (sublane, 128)-style alignment of the trailing two
    block dims by dtype (full-dim and size-1 blocks are exempt);
  - **index-map bounds** — grid-only index maps are evaluated over the
    (corner-sampled) grid and flagged if any block index falls outside the
    padded operand bounds (scalar-prefetch-dependent maps are runtime
    contracts and are skipped);
  - **grid races** — an output revisited along a grid axis (its index map
    constant in that axis) or VMEM scratch carried across the grid requires
    explicit ``dimension_semantics`` with that axis ``"arbitrary"``
    (sequential); missing or contradicting annotations are findings.

* :mod:`repro.analysis.repo_lint` — AST-level repo invariants, no tracing:
  every non-structural ``MoEConfig``/``TrainConfig`` knob is registered in
  ``MOE_OPTIONS``/``TRAIN_OPTIONS`` (and vice versa), every public Pallas
  kernel has an ``ops.py`` wrapper and a ``ref.py`` oracle twin, and no
  direct ``lax.<collective>`` call site exists outside
  ``sharding/comm.py``.

* :mod:`repro.launch.analyze` — the CLI driver
  (``python -m repro.launch.analyze``): runs all passes over the entrypoint
  grid, prints per-finding reports with file:line provenance, and exits
  nonzero on any finding.  Wired into ``./ci.sh --static`` (part of the
  default CI run).

Each pass returns a flat list of :class:`Finding`; passes never raise on
bad code — a finding is data, so seeded-bad fixtures
(``tests/test_analysis.py``) can assert exact rule hits.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

__all__ = ["Finding", "format_findings"]


@dataclasses.dataclass(frozen=True)
class Finding:
    """One analyzer finding: which pass, which rule, where, and why."""

    pass_name: str                 # "jaxpr" | "pallas" | "repo"
    rule: str                      # stable rule id (kebab-case)
    message: str
    file: Optional[str] = None     # provenance when recoverable
    line: Optional[int] = None

    def format(self) -> str:
        loc = ""
        if self.file:
            loc = f" ({self.file}:{self.line})" if self.line else f" ({self.file})"
        return f"[{self.pass_name}] {self.rule}: {self.message}{loc}"


def format_findings(findings: Sequence[Finding]) -> str:
    """Render a finding list as the per-line report the CLI prints."""
    if not findings:
        return "no findings"
    lines: List[str] = [f.format() for f in findings]
    lines.append(f"{len(findings)} finding(s)")
    return "\n".join(lines)

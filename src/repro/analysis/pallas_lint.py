"""Static checker over the Pallas kernels in ``repro.kernels``.

Each kernel wrapper is traced (never executed) at representative static
shapes; the resulting ``pallas_call`` equations expose the grid, every
``BlockMapping`` (block shape + index-map jaxpr + operand shape) and the
compiler params, which is everything the four rules need:

* ``vmem-budget`` — per-grid-step VMEM footprint, estimated as 2x the sum
  of block bytes (Mosaic double-buffers every pipelined block) plus
  scratch bytes, against a configurable budget (default 16 MiB — one
  TPUv4/v5 core's VMEM).
* ``tile-alignment`` — the trailing block dim must be the full array dim,
  a multiple of 128 (lanes), or 1; the second-to-last must be the full
  dim, a multiple of the dtype's sublane count (fp32: 8, bf16: 16,
  int8/fp8: 32), or 1.  Misaligned tiles compile to padded/strided Mosaic
  windows that silently waste VMEM and VPU lanes.
* ``index-map-oob`` — index maps that depend only on grid indices are
  evaluated over the (corner-sampled) grid; a returned block index outside
  the padded operand bounds reads/writes out of bounds.  Maps that read
  scalar-prefetch operands (e.g. the ragged FFN's ``gid[i]``) are runtime
  contracts — validated dynamically by their callers — and are skipped.
* ``grid-race`` / ``missing-dimension-semantics`` — an output whose index
  map is constant along a grid axis is *revisited* across that axis (its
  block stays resident while the axis advances: the radix sort's running
  histogram, the combine gather's accumulator, the grouped FFN's f-axis
  accumulation).  Revisiting is only sound when that axis is sequential,
  so it must be declared ``"arbitrary"`` in ``dimension_semantics``; a
  ``"parallel"`` marking there is a data race on a real TPU (interpret
  mode runs sequentially and hides it).  Every kernel must declare
  ``dimension_semantics`` explicitly — VMEM scratch persists across the
  whole grid, so implicit semantics make carried state an accident.
"""
from __future__ import annotations

import itertools
import math
from typing import Callable, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import core as jcore

from repro.analysis import Finding
from repro.analysis.jaxpr_lint import _sub_jaxprs

DEFAULT_VMEM_BUDGET = 16 * 1024 * 1024      # one core's VMEM
_SUBLANE = {8: 4, 4: 8, 2: 16, 1: 32}       # itemsize -> sublane multiple
_MAX_FULL_GRID = 4096                       # full enumeration cap for probes


def _pallas_eqns(jaxpr: jcore.Jaxpr) -> Iterator[jcore.JaxprEqn]:
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            yield eqn
        for _key, sub in _sub_jaxprs(eqn.params):
            yield from _pallas_eqns(sub)


def _src_of(eqn: jcore.JaxprEqn) -> Tuple[Optional[str], Optional[int]]:
    """file:line of the kernel body from pallas_call's name_and_src_info."""
    info = str(eqn.params.get("name_and_src_info", ""))
    # format: "<kernel_name> at <file>:<line>"
    if " at " in info:
        loc = info.rsplit(" at ", 1)[1]
        if ":" in loc:
            f, _, ln = loc.rpartition(":")
            if ln.isdigit():
                return f, int(ln)
    return None, None


def _block_dims(bm) -> Tuple[int, ...]:
    return tuple(int(b) if isinstance(b, int) else 1 for b in bm.block_shape)


def _is_output(bm) -> bool:
    return str(getattr(bm, "origin", "")).startswith("output")


def _index_map_args(bm, grid_len: int):
    """(extra_avals, uses_extra): prefetch operands of the index map."""
    invars = bm.index_map_jaxpr.jaxpr.invars
    extra = invars[grid_len:]
    used = set()
    for eqn in bm.index_map_jaxpr.jaxpr.eqns:
        used.update(v for v in eqn.invars if isinstance(v, jcore.Var))
    used.update(v for v in bm.index_map_jaxpr.jaxpr.outvars
                if isinstance(v, jcore.Var))
    return extra, any(v in used for v in extra)


def _eval_index_map(bm, point: Sequence[int], extra) -> Optional[Tuple[int, ...]]:
    args = [jnp.int32(i) for i in point]
    for v in extra:
        aval = v.aval
        try:
            args.append(jnp.zeros(aval.shape, aval.dtype))
        except Exception:
            return None
    try:
        out = jcore.eval_jaxpr(bm.index_map_jaxpr.jaxpr,
                               bm.index_map_jaxpr.consts, *args)
    except Exception:
        return None
    return tuple(int(x) for x in out)


def _probe_points(grid: Sequence[int]) -> List[Tuple[int, ...]]:
    if math.prod(grid) <= _MAX_FULL_GRID:
        return list(itertools.product(*(range(g) for g in grid)))
    corners = [sorted({0, 1, g - 1}) for g in grid]
    return list(itertools.product(*corners))


def lint_pallas_call(eqn: jcore.JaxprEqn, *, name: str,
                     vmem_budget: int = DEFAULT_VMEM_BUDGET) -> List[Finding]:
    """Apply all kernel rules to one traced ``pallas_call`` equation."""
    findings: List[Finding] = []
    gm = eqn.params["grid_mapping"]
    grid = tuple(int(g) for g in gm.grid)
    bms = list(gm.block_mappings)
    src_file, src_line = _src_of(eqn)

    def add(rule: str, msg: str):
        findings.append(Finding("pallas", rule, f"{name}: {msg}",
                                src_file, src_line))

    # ---- VMEM footprint ----------------------------------------------------
    block_bytes = 0
    for bm in bms:
        dims = _block_dims(bm)
        block_bytes += math.prod(dims) * bm.array_shape_dtype.dtype.itemsize
    body: jcore.Jaxpr = eqn.params["jaxpr"]
    n_scratch = gm.num_scratch_operands
    scratch_bytes = 0
    for v in (body.invars[len(body.invars) - n_scratch:] if n_scratch else ()):
        aval = v.aval
        scratch_bytes += math.prod(aval.shape) * jnp.dtype(aval.dtype).itemsize
    est = 2 * block_bytes + scratch_bytes
    if est > vmem_budget:
        add("vmem-budget",
            f"estimated per-grid-step VMEM {est / 2**20:.1f} MiB "
            f"(2 x {block_bytes / 2**20:.1f} MiB blocks "
            f"+ {scratch_bytes / 2**20:.1f} MiB scratch) exceeds the "
            f"{vmem_budget / 2**20:.0f} MiB budget")

    # ---- tile alignment ----------------------------------------------------
    for bm in bms:
        dims = _block_dims(bm)
        arr = bm.array_shape_dtype.shape
        if not dims:
            continue
        itemsize = bm.array_shape_dtype.dtype.itemsize
        sub = _SUBLANE.get(itemsize, 8)
        b_last, a_last = dims[-1], arr[-1]
        if not (b_last == a_last or b_last % 128 == 0 or b_last == 1):
            add("tile-alignment",
                f"{bm.origin}: trailing block dim {b_last} (array dim "
                f"{a_last}) is neither the full dim, a multiple of 128 "
                f"lanes, nor 1")
        if len(dims) >= 2:
            b2, a2 = dims[-2], arr[-2]
            if not (b2 == a2 or b2 % sub == 0 or b2 == 1):
                add("tile-alignment",
                    f"{bm.origin}: second-to-last block dim {b2} (array "
                    f"dim {a2}) is not a multiple of the {sub}-row "
                    f"sublane tile for itemsize {itemsize}")

    # ---- index-map OOB + output revisit detection --------------------------
    points = _probe_points(grid)
    revisited_axes: dict = {}
    for bm in bms:
        dims = _block_dims(bm)
        arr = bm.array_shape_dtype.shape
        extra, uses_extra = _index_map_args(bm, len(grid))
        if uses_extra:
            continue            # data-dependent map: a runtime contract
        results = {}
        oob_hit = None
        for pt in points:
            out = _eval_index_map(bm, pt, extra)
            if out is None:
                break
            results[pt] = out
            if oob_hit is None and len(out) == len(dims):
                for d, (idx, b, a) in enumerate(zip(out, dims, arr)):
                    nblocks = max(1, -(-a // b))
                    if idx < 0 or idx >= nblocks:
                        oob_hit = (pt, d, idx, nblocks)
                        break
        if oob_hit:
            pt, d, idx, nblocks = oob_hit
            add("index-map-oob",
                f"{bm.origin}: index map returns block index {idx} on "
                f"dim {d} at grid point {pt}, outside the padded operand "
                f"bound of {nblocks} block(s)")
        if _is_output(bm) and results and len(results) == len(points):
            for a, g in enumerate(grid):
                if g <= 1:
                    continue
                def drop(pt):      # grid point with axis a removed
                    return pt[:a] + pt[a + 1:]
                groups: dict = {}
                for pt, out in results.items():
                    groups.setdefault(drop(pt), set()).add(out)
                if all(len(v) == 1 for v in groups.values()):
                    revisited_axes.setdefault(a, []).append(str(bm.origin))

    # ---- dimension_semantics: presence + revisited axes sequential ---------
    cp = eqn.params.get("compiler_params") or {}
    sem = (cp.get("mosaic") or {}).get("dimension_semantics")
    if sem is None:
        detail = ""
        if revisited_axes:
            ax = sorted(revisited_axes)
            detail = (f" — and grid axis(es) {ax} revisit outputs "
                      f"{sorted(set(sum(revisited_axes.values(), [])))}, "
                      f"which is a data race unless those axes are "
                      f"declared \"arbitrary\"")
        if n_scratch and not revisited_axes:
            detail = (" — and the kernel carries VMEM scratch across the "
                      "grid, which implicit semantics make an accident")
        add("missing-dimension-semantics",
            f"pallas_call has no explicit dimension_semantics for its "
            f"{len(grid)}-axis grid{detail}")
    else:
        sem = tuple(sem)
        if len(sem) != len(grid):
            add("missing-dimension-semantics",
                f"dimension_semantics {sem} has {len(sem)} entries for a "
                f"{len(grid)}-axis grid")
        else:
            for a, outs in sorted(revisited_axes.items()):
                if sem[a] != "arbitrary":
                    add("grid-race",
                        f"grid axis {a} is marked {sem[a]!r} but outputs "
                        f"{sorted(set(outs))} are revisited across it "
                        f"(index map constant in axis {a}): carried "
                        f"VMEM state across a parallel axis is a data "
                        f"race — declare the axis \"arbitrary\"")
    return findings


def lint_pallas_jaxpr(closed: jcore.ClosedJaxpr, *, name: str,
                      vmem_budget: int = DEFAULT_VMEM_BUDGET
                      ) -> List[Finding]:
    """Lint every pallas_call reachable from a traced wrapper call."""
    findings: List[Finding] = []
    n = 0
    for eqn in _pallas_eqns(closed.jaxpr):
        n += 1
        findings.extend(lint_pallas_call(eqn, name=name,
                                         vmem_budget=vmem_budget))
    if n == 0:
        findings.append(Finding(
            "pallas", "no-pallas-call",
            f"{name}: traced wrapper contains no pallas_call equation "
            f"(registry case is stale?)"))
    return findings


# =============================================================================
# Kernel registry: every kernel in repro.kernels at representative shapes.
# Shapes mirror what the dispatch/attention paths actually feed them (lane-
# sized domains, 128-row tiles) while staying small enough to trace fast.
# =============================================================================

def kernel_cases() -> Iterator[Tuple[str, Callable[[], jcore.ClosedJaxpr]]]:
    from repro.kernels.flash_attn import flash_attention_pallas
    from repro.kernels.grouped_ffn import (grouped_ffn_pallas,
                                           grouped_ffn_ragged_pallas)
    from repro.kernels.moe_dispatch import (combine_gather_pallas,
                                            dispatch_gather_pallas)
    from repro.kernels.radix_sort import group_sort_pallas
    from repro.kernels.router_fused import router_fused_pallas
    from repro.kernels.rwkv6_scan import rwkv6_scan_pallas
    from repro.kernels.ssd_chunk import ssd_chunk_pallas

    f32, i32 = jnp.float32, jnp.int32

    yield "group_sort", lambda: jax.make_jaxpr(
        lambda keys: group_sort_pallas(keys, 64))(
            jnp.zeros((1024,), i32))
    # routing megakernel: token-tiled sequential grid carrying the expert
    # histogram in VMEM scratch and revisiting the histogram output on the
    # last step — the grid-race + scratch rules both bite here
    yield "router_fused", lambda: jax.make_jaxpr(
        lambda x, w: router_fused_pallas(x, w, 2))(
            jnp.zeros((1024, 64), f32), jnp.zeros((64, 16), f32))
    # f = 1024 with bf = 512 keeps the innermost f axis at 2 grid steps so
    # the output-revisit detector exercises the accumulation axis
    yield "grouped_ffn", lambda: jax.make_jaxpr(
        lambda x, w1, w2: grouped_ffn_pallas(x, w1, None, w2))(
            jnp.zeros((4, 256, 256), f32), jnp.zeros((4, 256, 1024), f32),
            jnp.zeros((4, 1024, 256), f32))
    yield "grouped_ffn_ragged", lambda: jax.make_jaxpr(
        lambda r, g, w1, w2: grouped_ffn_ragged_pallas(r, g, w1, None, w2))(
            jnp.zeros((1024, 256), f32), jnp.zeros((8,), i32),
            jnp.zeros((4, 256, 1024), f32), jnp.zeros((4, 1024, 256), f32))
    yield "dispatch_gather", lambda: jax.make_jaxpr(
        lambda x, src: dispatch_gather_pallas(x, src))(
            jnp.zeros((256, 256), f32), jnp.zeros((512,), i32))
    yield "combine_gather", lambda: jax.make_jaxpr(
        lambda rows, src, sc: combine_gather_pallas(rows, src, sc))(
            jnp.zeros((512, 256), f32), jnp.zeros((256, 2), i32),
            jnp.zeros((256, 2), f32))
    yield "flash_attention", lambda: jax.make_jaxpr(
        lambda q, k, v: flash_attention_pallas(q, k, v))(
            *(jnp.zeros((2, 256, 4, 64), f32),) * 3)
    yield "rwkv6_scan", lambda: jax.make_jaxpr(
        lambda r, k, v, w, u, s0: rwkv6_scan_pallas(r, k, v, w, u, s0))(
            *(jnp.zeros((2, 128, 4, 64), f32),) * 4,
            jnp.zeros((4, 64), f32), jnp.zeros((2, 4, 64, 64), f32))
    yield "ssd_chunk", lambda: jax.make_jaxpr(
        lambda xh, dt, loga, Bc, Cc: ssd_chunk_pallas(xh, dt, loga, Bc, Cc))(
            jnp.zeros((2, 2, 128, 4, 64), f32),
            jnp.zeros((2, 2, 128, 4), f32), jnp.zeros((2, 2, 128, 4), f32),
            jnp.zeros((2, 2, 128, 64), f32), jnp.zeros((2, 2, 128, 64), f32))


def run(vmem_budget: int = DEFAULT_VMEM_BUDGET, log=None) -> List[Finding]:
    """Trace and lint every registered kernel; return all findings."""
    findings: List[Finding] = []
    for name, build in kernel_cases():
        got = lint_pallas_jaxpr(build(), name=name, vmem_budget=vmem_budget)
        if log:
            log(f"  pallas: {name}: {len(got)} finding(s)")
        findings += got
    return findings

"""AST-level repo invariants — no tracing, no jax import required.

Three rules, each over the repo source tree:

* ``unregistered-config-knob`` / ``registry-orphan`` — every runtime-
  tunable field of ``MoEConfig``/``TrainConfig`` must be registered in
  ``MOE_OPTIONS``/``TRAIN_OPTIONS`` (the registries both launchers derive
  their flags from — an unregistered knob is unreachable from every entry
  point), and every registry entry must name a real config field.
  Structural fields (architecture shape, loss coefficients) are
  whitelisted; ``resume`` is a launcher action without a config field.
* ``kernel-missing-wrapper`` / ``kernel-missing-ref`` — every public
  ``*_pallas`` kernel must be wrapped in ``kernels/ops.py`` (the
  interpret-mode/backend selection layer every caller goes through) and
  have a ``*_ref`` oracle twin in ``kernels/ref.py`` (what the conformance
  suite diffs it against).
* ``rogue-collective`` — no direct ``lax.<collective>`` call site outside
  ``sharding/comm.py``: comm is the single module allowed to issue wire
  primitives (this is the static twin of jaxpr_lint's trace-time
  provenance rule, and catches code the entrypoint grid doesn't reach).
"""
from __future__ import annotations

import ast
import os
from typing import Iterable, List, Optional, Sequence, Set, Tuple

from repro.analysis import Finding

# MoEConfig fields that are architecture structure, not runtime knobs:
# changing them changes the model, so they are launched via configs/, not
# via the options registry.
MOE_STRUCTURAL = frozenset({
    "num_experts", "top_k", "top_g", "renorm_gates", "d_ff_expert",
    "num_shared_experts", "capacity_factor", "router", "lb_alpha",
    "lb_beta", "router_z_coef", "every_n_layers", "first_dense_layers",
    "grid",
})

# TrainConfig fields that are training-run structure (batch/optimizer/
# schedule shape), not launcher-registry knobs.
TRAIN_STRUCTURAL = frozenset({
    "global_batch_size", "micro_batch_size", "seq_len", "steps",
    "optimizer", "lr", "warmup_steps", "weight_decay", "grad_clip", "eps",
    "b1", "b2", "schedule", "mlm_mask_prob", "seed", "log_every",
})

# ServeConfig fields that describe the workload shape (request geometry /
# sampling), not engine knobs; the engine knobs (page pool geometry, slots,
# buckets, admission) live in SERVE_OPTIONS.
SERVE_STRUCTURAL = frozenset({
    "batch_size", "prompt_len", "max_new_tokens", "cache_len", "temperature",
})

# Registry entries that are launcher actions, not config fields.
LAUNCHER_ONLY = frozenset({"resume"})

# lax primitives that move bytes between devices.
COLLECTIVE_CALLS = frozenset({
    "psum", "pmean", "pmax", "pmin", "all_gather", "psum_scatter",
    "all_to_all", "ppermute", "pshuffle", "ragged_all_to_all",
})


def _parse(path: str) -> Optional[ast.Module]:
    try:
        with open(path, "r") as f:
            return ast.parse(f.read(), filename=path)
    except (OSError, SyntaxError):
        return None


def _dataclass_fields(tree: ast.Module, cls_name: str) -> Set[str]:
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == cls_name:
            return {st.target.id for st in node.body
                    if isinstance(st, ast.AnnAssign)
                    and isinstance(st.target, ast.Name)}
    return set()


def _registry_fields(tree: ast.Module, registry_name: str) -> Set[str]:
    """First-arg strings of MoEOption(...) calls in a registry tuple."""
    for node in ast.walk(tree):
        if (isinstance(node, ast.AnnAssign)
                and isinstance(node.target, ast.Name)
                and node.target.id == registry_name):
            out = set()
            for call in ast.walk(node):
                if (isinstance(call, ast.Call) and call.args
                        and isinstance(call.args[0], ast.Constant)
                        and isinstance(call.args[0].value, str)):
                    out.add(call.args[0].value)
            return out
    return set()


def check_config_registry(config_path: str) -> List[Finding]:
    """Two-way check: config fields <-> options-registry entries."""
    tree = _parse(config_path)
    if tree is None:
        return [Finding("repo", "parse-error",
                        f"cannot parse {config_path}", config_path)]
    findings: List[Finding] = []
    for cls, registry, structural, prefix in (
            ("MoEConfig", "MOE_OPTIONS", MOE_STRUCTURAL, "MOE"),
            ("TrainConfig", "TRAIN_OPTIONS", TRAIN_STRUCTURAL, "TRAIN"),
            ("ServeConfig", "SERVE_OPTIONS", SERVE_STRUCTURAL, "SERVE")):
        fields = _dataclass_fields(tree, cls)
        registered = _registry_fields(tree, registry)
        if not fields or not registered:
            findings.append(Finding(
                "repo", "parse-error",
                f"could not locate {cls} fields or {registry} entries in "
                f"{config_path}", config_path))
            continue
        for f in sorted(fields - registered - structural):
            findings.append(Finding(
                "repo", "unregistered-config-knob",
                f"{cls}.{f} is neither registered in {registry} nor in the "
                f"structural whitelist — an unregistered knob is "
                f"unreachable from both launchers (register it, or add it "
                f"to {prefix}_STRUCTURAL "
                f"in repro.analysis.repo_lint if it is model structure)",
                config_path))
        for f in sorted(registered - fields - LAUNCHER_ONLY):
            findings.append(Finding(
                "repo", "registry-orphan",
                f"{registry} registers {f!r} but {cls} has no such field",
                config_path))
    return findings


def _public_pallas_defs(path: str) -> List[Tuple[str, int]]:
    tree = _parse(path)
    if tree is None:
        return []
    return [(node.name, node.lineno) for node in tree.body
            if isinstance(node, ast.FunctionDef)
            and node.name.endswith("_pallas")
            and not node.name.startswith("_")]


def check_kernel_twins(kernels_dir: str,
                       ops_path: Optional[str] = None,
                       ref_path: Optional[str] = None) -> List[Finding]:
    """Every public ``*_pallas`` kernel is wrapped in ops.py with a ref twin."""
    ops_path = ops_path or os.path.join(kernels_dir, "ops.py")
    ref_path = ref_path or os.path.join(kernels_dir, "ref.py")
    findings: List[Finding] = []
    try:
        with open(ops_path) as f:
            ops_src = f.read()
    except OSError:
        return [Finding("repo", "parse-error", f"missing {ops_path}",
                        ops_path)]
    ref_tree = _parse(ref_path)
    ref_defs = ({node.name for node in ast.walk(ref_tree)
                 if isinstance(node, ast.FunctionDef)}
                if ref_tree is not None else set())
    for fname in sorted(os.listdir(kernels_dir)):
        if not fname.endswith(".py") or fname in ("ops.py", "ref.py",
                                                  "__init__.py"):
            continue
        path = os.path.join(kernels_dir, fname)
        for name, lineno in _public_pallas_defs(path):
            if name not in ops_src:
                findings.append(Finding(
                    "repo", "kernel-missing-wrapper",
                    f"{name} has no wrapper call site in kernels/ops.py — "
                    f"every Pallas kernel must go through the ops layer "
                    f"(interpret-mode fallback + backend selection)",
                    path, lineno))
            twin = name[: -len("_pallas")] + "_ref"
            if twin not in ref_defs:
                findings.append(Finding(
                    "repo", "kernel-missing-ref",
                    f"{name} has no {twin} oracle twin in kernels/ref.py — "
                    f"the conformance suite needs a pure-jnp reference for "
                    f"every kernel", path, lineno))
    return findings


def _is_lax_attr(node: ast.AST) -> bool:
    """True for ``lax.X`` / ``jax.lax.X`` attribute chains."""
    if not isinstance(node, ast.Attribute):
        return False
    v = node.value
    if isinstance(v, ast.Name):
        return v.id == "lax"
    if isinstance(v, ast.Attribute):
        return v.attr == "lax"
    return False


def check_collective_callsites(paths: Iterable[str],
                               allow_suffix: str = "sharding/comm.py"
                               ) -> List[Finding]:
    """No direct ``lax.<collective>`` call outside sharding/comm.py."""
    findings: List[Finding] = []
    for path in paths:
        if path.replace(os.sep, "/").endswith(allow_suffix):
            continue
        tree = _parse(path)
        if tree is None:
            continue
        for node in ast.walk(tree):
            if (isinstance(node, ast.Call) and _is_lax_attr(node.func)
                    and node.func.attr in COLLECTIVE_CALLS):
                findings.append(Finding(
                    "repo", "rogue-collective",
                    f"direct lax.{node.func.attr} call outside "
                    f"sharding/comm.py — route it through the comm helpers "
                    f"(oracle identity on empty axes, remat save-policy "
                    f"tagging, analyzer provenance)", path, node.lineno))
    return findings


def _py_files(root: str) -> List[str]:
    out = []
    for dirpath, _dirnames, filenames in os.walk(root):
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                out.append(os.path.join(dirpath, fn))
    return out


def run(src_root: Optional[str] = None, log=None) -> List[Finding]:
    """All repo rules over the live source tree."""
    if src_root is None:
        src_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    config_path = os.path.join(src_root, "common", "config.py")
    kernels_dir = os.path.join(src_root, "kernels")
    findings = check_config_registry(config_path)
    findings += check_kernel_twins(kernels_dir)
    findings += check_collective_callsites(_py_files(src_root))
    if log:
        log(f"  repo: {len(_py_files(src_root))} files scanned, "
            f"{len(findings)} finding(s)")
    return findings

"""Evaluation: held-out cross-entropy / perplexity on the synthetic stream.

Used by the training loop (``--eval-every``) and the convergence benchmark.
The eval stream uses a disjoint seed space from training (seed + 10_000), so
loss reductions reflect generalizable structure (the n-gram repeats), not
memorized batches.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import ModelConfig
from repro.data.pipeline import make_batch
from repro.models import transformer as T
from repro.models.layers import vocab_parallel_xent
from repro.sharding import comm
from repro.sharding.plan import MeshPlan

IGNORE = -1
EVAL_SEED_OFFSET = 10_000


def eval_step_fn(params, batch, *, cfg: ModelConfig, plan: MeshPlan):
    """Returns (sum CE, token count) over one batch (psum'd over dp)."""
    tokens, labels = batch["tokens"], batch["labels"]
    S = tokens.shape[-1]
    extra = {k: batch[k] for k in ("image_embeds", "image_pos") if k in batch}
    _, logits, _, _ = T.forward(params, tokens, cfg, plan,
                                positions=jnp.arange(S), extra=extra or None)
    if cfg.num_codebooks > 1:
        labels = jnp.swapaxes(labels, 1, 2)
    ce = vocab_parallel_xent(logits, labels, plan)
    mask = labels != IGNORE
    s = comm.psum(jnp.sum(ce * mask), plan.dp_axes)
    n = comm.psum(jnp.sum(mask).astype(jnp.float32), plan.dp_axes)
    return s, n


def evaluate(params, cfg: ModelConfig, plan: MeshPlan, *, batch: int,
             seq: int, seed: int = 0, n_batches: int = 4,
             step_fn=None) -> Dict[str, float]:
    """Average CE + perplexity over ``n_batches`` held-out batches."""
    if step_fn is None:
        step_fn = jax.jit(partial(eval_step_fn, cfg=cfg, plan=plan))
    tot, cnt = 0.0, 0.0
    for i in range(n_batches):
        b = make_batch(cfg, batch, seq, seed + EVAL_SEED_OFFSET, i)
        b = {k: jnp.asarray(v) for k, v in b.items()}
        s, n = step_fn(params, b)
        tot += float(s)
        cnt += float(n)
    ce = tot / max(cnt, 1.0)
    return {"eval_ce": ce, "eval_ppl": math.exp(min(ce, 30.0)),
            "eval_tokens": cnt}

"""Checkpointing: pytree <-> compressed npz with path-flattened keys,
plus the hardened keep-last-K rotation the fault-contained runtime uses.

No orbax dependency (not installed offline). Arrays are gathered to host;
for multi-device runs call on fully-addressable arrays (the CPU dry-run and
single-process training used here always are).

**Hardening.**  A checkpoint that cannot be restored is worse than none —
it is the moment the run is already in trouble.  Three layers:

* :func:`load_checkpoint` raises :class:`CheckpointError` (never a bare
  ``assert`` — those vanish under ``python -O`` — and never an opaque
  ``KeyError``) with the offending key, the shape mismatch, or the nearest
  candidate keys when a flattened name is missing.
* :class:`CheckpointManager` keeps the last K snapshots under a run
  directory with a ``manifest.json`` recording each file's SHA-256; saves
  are atomic (tempfile + ``os.replace``) so a crash mid-save can never
  clobber the previous good snapshot.
* :meth:`CheckpointManager.restore_latest` walks the rotation newest-first,
  rejecting entries whose checksum no longer matches or whose npz fails to
  load/validate — a corrupt or truncated newest snapshot falls back to the
  previous good one instead of killing the resume.
"""
from __future__ import annotations

import difflib
import hashlib
import json
import os
import re
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

MANIFEST = "manifest.json"
_CKPT_RE = re.compile(r"^ckpt_(\d+)\.npz$")


class CheckpointError(RuntimeError):
    """A checkpoint file is missing keys, shape-mismatched, or unreadable."""


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def _atomic_write_npz(path: str, payload: Dict[str, Any]):
    # atomic write (savez appends .npz only when missing, so force it)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               suffix=".npz")
    os.close(fd)
    np.savez_compressed(tmp, **payload)
    os.replace(tmp, path)


def save_checkpoint(path: str, params, opt_state=None, step: int = 0,
                    extra=None):
    """Write one snapshot. ``extra`` is an optional pytree of small arrays
    (e.g. the sentinel carry) stored under the ``x/`` namespace."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    payload = {"__step__": np.int64(step)}
    payload.update({f"p/{k}": v for k, v in _flatten(params).items()})
    if opt_state is not None:
        payload.update({f"o/{k}": v for k, v in _flatten(opt_state).items()})
    if extra is not None:
        payload.update({f"x/{k}": v for k, v in _flatten(extra).items()})
    _atomic_write_npz(path, payload)


def load_checkpoint(path: str, params_like, opt_like=None, extra_like=None):
    """Restore into the structure of ``params_like`` (names must match).

    Returns ``(params, opt_state, step)`` — or ``(params, opt_state, step,
    extra)`` when ``extra_like`` is given.  Raises :class:`CheckpointError`
    on an unreadable file, a missing key (named, with the nearest stored
    candidates), or a shape mismatch.
    """
    try:
        data = np.load(path, allow_pickle=False)
    except Exception as e:                      # zipfile/OSError/ValueError
        raise CheckpointError(f"cannot read checkpoint {path!r}: {e}") from e
    with data:
        try:
            keys = set(data.files)
            if "__step__" not in keys:
                raise CheckpointError(
                    f"checkpoint {path!r} has no '__step__' entry — not a "
                    f"checkpoint produced by save_checkpoint")
            step = int(data["__step__"])

            def restore(prefix, like):
                flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
                leaves = []
                for pth, leaf in flat_like:
                    key = prefix + "/".join(
                        str(getattr(k, "key",
                                    getattr(k, "idx", getattr(k, "name", k))))
                        for k in pth)
                    if key not in keys:
                        near = difflib.get_close_matches(key, keys, n=3)
                        hint = f"; nearest stored keys: {near}" if near else ""
                        raise CheckpointError(
                            f"checkpoint {path!r} is missing key {key!r}"
                            f"{hint}")
                    arr = data[key]
                    if arr.shape != tuple(leaf.shape):
                        raise CheckpointError(
                            f"checkpoint {path!r} key {key!r}: stored shape "
                            f"{arr.shape} != expected {tuple(leaf.shape)}")
                    leaves.append(arr.astype(leaf.dtype))
                return jax.tree_util.tree_unflatten(treedef, leaves)

            params = restore("p/", params_like)
            opt_state = restore("o/", opt_like) if opt_like is not None else None
            if extra_like is None:
                return params, opt_state, step
            return params, opt_state, step, restore("x/", extra_like)
        except CheckpointError:
            raise
        except Exception as e:                  # truncated member mid-read
            raise CheckpointError(
                f"checkpoint {path!r} is corrupt: {e}") from e


# =============================================================================
# Keep-last-K rotation with a checksummed manifest
# =============================================================================

def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


class CheckpointManager:
    """Rotating checkpoints under one run directory.

    ``save(step, ...)`` writes ``ckpt_<step>.npz``, records its SHA-256 in
    ``manifest.json`` (both atomically), and prunes beyond ``keep``
    snapshots.  ``restore_latest(...)`` returns the newest snapshot that
    passes checksum + structural validation, falling back through the
    rotation — ``None`` if no valid snapshot exists.  Files present in the
    directory but absent from the manifest (e.g. hand-copied) are still
    considered, unverified, after all manifest entries.
    """

    def __init__(self, directory: str, keep: int = 3):
        if keep < 1:
            raise ValueError(f"keep must be >= 1, got {keep}")
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- manifest
    @property
    def manifest_path(self) -> str:
        return os.path.join(self.dir, MANIFEST)

    def _read_manifest(self) -> List[Dict[str, Any]]:
        try:
            with open(self.manifest_path) as f:
                m = json.load(f)
            entries = m.get("checkpoints", [])
            return [e for e in entries
                    if isinstance(e, dict) and "file" in e and "step" in e]
        except (OSError, ValueError):
            return []

    def _write_manifest(self, entries: List[Dict[str, Any]]):
        fd, tmp = tempfile.mkstemp(dir=self.dir, suffix=".json")
        with os.fdopen(fd, "w") as f:
            json.dump({"checkpoints": entries}, f, indent=1)
        os.replace(tmp, self.manifest_path)

    # ----------------------------------------------------------------- save
    def path_for(self, step: int) -> str:
        return os.path.join(self.dir, f"ckpt_{step:08d}.npz")

    def save(self, step: int, params, opt_state=None, extra=None) -> str:
        path = self.path_for(step)
        save_checkpoint(path, params, opt_state, step, extra=extra)
        entries = [e for e in self._read_manifest()
                   if e["file"] != os.path.basename(path)]
        entries.append({"file": os.path.basename(path), "step": int(step),
                        "sha256": _sha256(path),
                        "bytes": os.path.getsize(path)})
        entries.sort(key=lambda e: e["step"])
        while len(entries) > self.keep:
            victim = entries.pop(0)
            try:
                os.remove(os.path.join(self.dir, victim["file"]))
            except OSError:
                pass
        self._write_manifest(entries)
        return path

    # -------------------------------------------------------------- restore
    def candidates(self) -> List[Tuple[str, Optional[str]]]:
        """(path, expected_sha256 | None) newest-first: manifest entries
        first, then unmanifested ckpt_*.npz strays (unverifiable)."""
        entries = sorted(self._read_manifest(), key=lambda e: -e["step"])
        out = [(os.path.join(self.dir, e["file"]), e.get("sha256"))
               for e in entries]
        known = {p for p, _ in out}
        strays = []
        for name in os.listdir(self.dir):
            m = _CKPT_RE.match(name)
            p = os.path.join(self.dir, name)
            if m and p not in known:
                strays.append((int(m.group(1)), p))
        out += [(p, None) for _, p in sorted(strays, reverse=True)]
        return out

    def restore_latest(self, params_like, opt_like=None, extra_like=None,
                       log=print):
        """Newest valid snapshot, or ``None``.  Corrupt/mismatched entries
        are reported via ``log`` and skipped — the fallback walk."""
        for path, sha in self.candidates():
            if not os.path.exists(path):
                continue
            if sha is not None and _sha256(path) != sha:
                log(f"checkpoint {path} fails its manifest checksum — "
                    f"skipping (falling back to previous snapshot)")
                continue
            try:
                return load_checkpoint(path, params_like, opt_like,
                                       extra_like)
            except CheckpointError as e:
                log(f"checkpoint {path} is unrestorable ({e}) — falling "
                    f"back to previous snapshot")
        return None

"""Checkpointing: pytree <-> compressed npz with path-flattened keys.

No orbax dependency (not installed offline). Arrays are gathered to host;
for multi-device runs call on fully-addressable arrays (the CPU dry-run and
single-process training used here always are).
"""
from __future__ import annotations

import os
import tempfile
from typing import Any, Dict

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(path: str, params, opt_state=None, step: int = 0):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    payload = {"__step__": np.int64(step)}
    payload.update({f"p/{k}": v for k, v in _flatten(params).items()})
    if opt_state is not None:
        payload.update({f"o/{k}": v for k, v in _flatten(opt_state).items()})
    # atomic write (savez appends .npz only when missing, so force it)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               suffix=".npz")
    os.close(fd)
    np.savez_compressed(tmp, **payload)
    os.replace(tmp, path)


def load_checkpoint(path: str, params_like, opt_like=None):
    """Restore into the structure of ``params_like`` (names must match)."""
    data = np.load(path, allow_pickle=False)
    step = int(data["__step__"])

    def restore(prefix, like):
        flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for pth, leaf in flat_like:
            key = prefix + "/".join(
                str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
                for k in pth)
            arr = data[key]
            assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
            leaves.append(arr.astype(leaf.dtype))
        return jax.tree_util.tree_unflatten(treedef, leaves)

    params = restore("p/", params_like)
    opt_state = restore("o/", opt_like) if opt_like is not None else None
    return params, opt_state, step

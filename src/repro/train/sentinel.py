"""Step sentinel: per-step health verdict + guarded optimizer apply.

**Architecture.**  Production MoE training is defined as much by the bad
steps as the fast ones: a single NaN gradient poisons the optimizer
moments forever, one data glitch puts a 100x loss spike through LAMB's
trust ratios, and a collapsed router silently wastes the whole expert
grid.  The containment strategy here is the MegaScale-style "never lose
the run" stance, entirely *inside* the jitted step so it costs no host
round-trip:

* **Non-finite verdict** — a global any-NaN/Inf check over the loss and
  the (already synced, clipped) gradient tree.  Expert-grid gradient
  shards differ per device, so the local flag is psum'd over **all** mesh
  axes: every device reaches the same verdict, which is what makes the
  ``lax.cond`` below safe in SPMD (both branches trace; the uniform
  predicate guarantees every device takes the same one, so the
  collectives inside the optimizer update stay matched).

* **Loss-spike verdict** — an EMA of the (replicated) total loss; after
  ``WARMUP_STEPS`` healthy steps, a loss above ``SPIKE_FACTOR x`` the EMA
  is an anomaly.  The EMA only absorbs *accepted* steps, so a spike does
  not drag its own baseline up.

* **Guarded apply** (:func:`gated_update`) — ``lax.cond`` picks between
  the real optimizer update and the identity: on a bad step params and
  opt-state pass through bit-unchanged (the step is *skipped*, not
  zeroed — skipping preserves LAMB/Adam moment integrity) and the
  anomaly counters bump.

* **Router-collapse watchdog** — fed from ``MoEStats.hop_max_load`` /
  ``hop_load_entropy`` (the psum'd LB f-vector, so already global): a
  max-load fraction above ``MAX_LOAD_THRESH`` or a normalized load
  entropy below ``ENTROPY_THRESH`` counts a ``router_alarm``.  Alarms are
  *observability*, not a skip condition — a collapsing router needs MORE
  LB-loss gradient steps, not fewer; the counter (and the metrics feed)
  is what lets the launcher/operator react (checkpoint-on-anomaly does).

:class:`SentinelState` is a plain registered pytree of fp32 scalars: it
rides the jit boundary next to the optimizer state, lands in checkpoints
under the ``x/`` extras namespace, and costs 7 floats.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from repro.sharding import comm

EMA_DECAY = 0.99          # loss EMA decay per accepted step
SPIKE_FACTOR = 10.0       # loss > factor * EMA  ->  spike verdict
WARMUP_STEPS = 10         # accepted steps before the spike detector arms
MAX_LOAD_THRESH = 0.9     # f-vector max above this -> router alarm
ENTROPY_THRESH = 0.05     # normalized load entropy below this -> router alarm


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SentinelState:
    """Sentinel carry (fp32 scalars; checkpointed alongside opt state)."""
    loss_ema: jax.Array       # EMA of accepted-step losses
    ema_steps: jax.Array      # accepted steps absorbed by the EMA
    steps: jax.Array          # total steps judged
    skipped: jax.Array        # steps whose update was skipped
    nonfinite: jax.Array      # non-finite verdicts
    spikes: jax.Array         # loss-spike verdicts
    router_alarms: jax.Array  # router-collapse watchdog alarms


def init_sentinel_state() -> SentinelState:
    z = jnp.float32(0.0)
    return SentinelState(z, z, z, z, z, z, z)


def _tree_nonfinite(tree) -> jax.Array:
    """True if any leaf of ``tree`` holds a NaN/Inf (local shards only)."""
    bad = jnp.bool_(False)
    for leaf in jax.tree.leaves(tree):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            bad = bad | ~jnp.isfinite(leaf).all()
    return bad


def step_verdict(loss: jax.Array, grads, sent: SentinelState, sync_axes):
    """Judge one step. Returns ``(ok, nonfinite, spike)`` — all scalar
    bools, identical on every device (the non-finite flag is psum'd over
    ``sync_axes``; the loss is already replicated)."""
    bad_local = (~jnp.isfinite(loss)) | _tree_nonfinite(grads)
    nonfinite = comm.psum(bad_local.astype(jnp.float32), sync_axes) > 0
    armed = sent.ema_steps >= WARMUP_STEPS
    spike = armed & jnp.isfinite(loss) & (loss > SPIKE_FACTOR * sent.loss_ema)
    return ~(nonfinite | spike), nonfinite, spike


def router_alarm(max_load: jax.Array, load_entropy: jax.Array) -> jax.Array:
    """Watchdog verdict from the layer-worst MoEStats watchdog fields."""
    return (max_load > MAX_LOAD_THRESH) | (load_entropy < ENTROPY_THRESH)


def update_sentinel(sent: SentinelState, loss: jax.Array, ok: jax.Array,
                    nonfinite: jax.Array, spike: jax.Array,
                    alarm: jax.Array) -> SentinelState:
    """Fold one verdict into the carry. The EMA moves only on accepted
    steps (a spike must not raise its own baseline); the first accepted
    steps seed it with the running mean rather than decaying from 0."""
    f = lambda b: b.astype(jnp.float32)
    n = sent.ema_steps
    seed_w = 1.0 / jnp.maximum(n + 1.0, 1.0)
    w = jnp.maximum(1.0 - EMA_DECAY, seed_w)       # seed phase, then EMA
    ema = jnp.where(ok, (1.0 - w) * sent.loss_ema + w * loss, sent.loss_ema)
    return SentinelState(
        loss_ema=ema,
        ema_steps=n + f(ok),
        steps=sent.steps + 1.0,
        skipped=sent.skipped + f(~ok),
        nonfinite=sent.nonfinite + f(nonfinite),
        spikes=sent.spikes + f(spike),
        router_alarms=sent.router_alarms + f(alarm))


def gated_update(ok: jax.Array, update_fn, grads, opt_state, params):
    """``uniform_cond``-guarded optimizer apply.

    ``update_fn(grads, opt_state, params) -> (params, opt_state)`` runs
    only when ``ok``; otherwise both trees pass through bit-unchanged.
    ``ok`` MUST be replicated across the mesh (see :func:`step_verdict`) —
    optimizer updates contain collectives (LAMB trust-ratio norms), and a
    divergent predicate would deadlock the mesh.  Routing through
    :func:`repro.sharding.comm.uniform_cond` both documents that contract
    and tells the static analyzer the branch asymmetry is intentional.
    """
    return comm.uniform_cond(ok,
                             lambda g, o, p: update_fn(g, o, p),
                             lambda g, o, p: (p, o),
                             grads, opt_state, params)

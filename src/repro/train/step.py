"""The training step: loss + backward + grad-sync + clip + optimizer update,
all inside a single ``shard_map`` over the production mesh.

Because every collective is explicit (manual-collectives style), gradient
reduction is also explicit: each parameter leaf's gradient is psum'd over
exactly the mesh axes the leaf is *replicated* on (``specs.shard_axes``).
Expert leaves are sharded over the expert grid, so their gradients are only
reduced over ``pod`` (and ``model`` for replicated-expert layouts) — the
data-parallel AllReduce never touches expert weights, which is the hybrid
data+expert parallelism of the paper (§2).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.common.config import ModelConfig, TrainConfig
from repro.models import transformer as T
from repro.models.layers import vocab_parallel_xent
from repro.optim.optimizers import Optimizer, clip_by_global_norm
from repro.optim.zero1 import (Zero1State, init_state_shapes, state_specs,
                               zero1_apply, zero1_reduce_and_clip)
from repro.train import sentinel as SEN
from repro.sharding import comm
from repro.sharding.compat import shard_map
from repro.sharding.plan import MeshPlan
from repro.sharding.specs import (batch_specs, param_specs, shard_axes,
                                  sharded_axes_only)

IGNORE = -1
MTP_LAMBDA = 0.1


def _ce_loss(params, batch, cfg: ModelConfig, plan: MeshPlan,
             use_kernel: bool = False):
    tokens, labels = batch["tokens"], batch["labels"]
    S = tokens.shape[-1]
    positions = jnp.arange(S)
    extra = {k: batch[k] for k in ("image_embeds", "image_pos") if k in batch}
    h, logits, stats, _ = T.forward(params, tokens, cfg, plan,
                                    positions=positions, extra=extra or None,
                                    remat=cfg.remat, use_kernel=use_kernel)
    if cfg.num_codebooks > 1:
        labels_t = jnp.swapaxes(labels, 1, 2)            # (B,S,K)
        ce = vocab_parallel_xent(logits, labels_t, plan)
        mask = labels_t != IGNORE
    else:
        ce = vocab_parallel_xent(logits, labels, plan)
        mask = labels != IGNORE
    loss_sum = jnp.sum(ce * mask)
    cnt = jnp.sum(mask).astype(jnp.float32)
    # tokens are distinct across dp axes only (replicated over tp)
    cnt_global = comm.psum(cnt, plan.dp_axes)
    ce_mean = comm.psum(loss_sum, plan.dp_axes) / jnp.maximum(cnt_global, 1.0)
    # --- partition loss for the gradient path --------------------------------
    # Under shard_map autodiff (check_vma=False) the backward pass effectively
    # differentiates the SUM of every device's loss output. A replicated loss
    # would therefore scale all gradients by the device count. Instead the
    # grad-path loss is each device's *share*: local_sum / (tp * global_count)
    # — shares sum to the true global mean across the mesh, so the assembled
    # (psum'd) gradients are exact. Verified against the single-device oracle.
    n_dev = 1
    for _, s in plan.axis_sizes:
        n_dev *= s
    tp = max(plan.tp, 1)
    ce_part = loss_sum / tp / jnp.maximum(cnt_global, 1.0)

    mtp_loss = jnp.float32(0.0)
    mtp_part = jnp.float32(0.0)
    if cfg.mtp_depth and cfg.causal and "mtp" in params:
        nxt = jnp.where(labels == IGNORE, 0, labels)     # token t+1
        tgt = jnp.full_like(labels, IGNORE)
        tgt = tgt.at[:, :-1].set(labels[:, 1:])          # token t+2
        ml = T.mtp_logits(params, h, nxt, cfg, plan, positions)
        mce = vocab_parallel_xent(ml, tgt, plan)
        mmask = (tgt != IGNORE) & (labels != IGNORE)
        ms = jnp.sum(mce * mmask)
        mc = comm.psum(jnp.sum(mmask).astype(jnp.float32), plan.dp_axes)
        mtp_loss = comm.psum(ms, plan.dp_axes) / jnp.maximum(mc, 1.0)
        mtp_part = ms / tp / jnp.maximum(mc, 1.0)

    # aux losses are computed replicated (internally psum'd) -> share = /n_dev
    aux_part = (stats.lb_loss + stats.z_loss) / n_dev
    total_grad = ce_part + aux_part + MTP_LAMBDA * mtp_part
    total = ce_mean + stats.lb_loss + stats.z_loss + MTP_LAMBDA * mtp_loss
    metrics = {"ce": ce_mean, "lb": stats.lb_loss, "z": stats.z_loss,
               "mtp": mtp_loss, "drop_frac": stats.drop_frac,
               "loss": total,
               # robustness feed: global sanitizer rejections + the
               # layer-worst router watchdog inputs (see train/sentinel.py)
               "fault_events": stats.fault_events.sum(),
               "wire_faults": stats.wire_faults.sum(),
               "max_load": jnp.max(stats.hop_max_load),
               "load_entropy": jnp.min(stats.hop_load_entropy)}
    return total_grad, metrics


def train_step_fn(params, opt_state, batch, step, sent=None, *,
                  cfg: ModelConfig, tcfg: TrainConfig, plan: MeshPlan,
                  opt: Optimizer, schedule, sync_axes_tree, norm_axes_tree,
                  n_micro: int = 1, use_kernel: bool = False,
                  zero1: bool = False, sentinel: bool = False):
    """One optimizer step (call inside shard_map or on a single device).

    With ``sentinel=True`` the step takes/returns a fifth value — the
    :class:`repro.train.sentinel.SentinelState` carry — and the optimizer
    apply is ``lax.cond``-guarded by the step verdict: a non-finite
    loss/grad or a loss spike leaves params and opt-state bit-unchanged
    and bumps the anomaly counters instead (metrics gain ``"skip"``).
    """

    loss = partial(_ce_loss, cfg=cfg, plan=plan, use_kernel=use_kernel)

    if n_micro <= 1:
        grads, metrics = jax.grad(lambda p: loss(p, batch), has_aux=True)(params)
    else:
        def micro(carry, mb):
            acc, _ = carry
            g, m = jax.grad(lambda p: loss(p, mb), has_aux=True)(params)
            acc = jax.tree.map(jnp.add, acc, g)
            return (acc, m), None
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        mb_batch = jax.tree.map(
            lambda x: x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:]),
            batch)
        m0 = {k: jnp.float32(0.0) for k in
              ("ce", "lb", "z", "mtp", "drop_frac", "loss",
               "fault_events", "wire_faults", "max_load", "load_entropy")}
        (grads, metrics), _ = jax.lax.scan(micro, (zeros, m0), mb_batch)
        grads = jax.tree.map(lambda g: g / n_micro, grads)

    lr = schedule(step)
    if zero1:
        # ZeRO-1: reduce-scatter raw grads + global clip scale first; the
        # apply (moments + owned-chunk update + re-gather) is a separate
        # stage so the sentinel can gate it (see optim/zero1.py)
        g_upd, gnorm, scale = zero1_reduce_and_clip(
            grads, sync_axes_tree=sync_axes_tree,
            norm_axes_tree=norm_axes_tree, plan=plan,
            grad_clip=tcfg.grad_clip)
        apply_fn = lambda g, o, p: zero1_apply(
            g, scale, o, p, lr, sync_axes_tree=sync_axes_tree,
            norm_axes_tree=norm_axes_tree, plan=plan, b1=tcfg.b1,
            b2=tcfg.b2, eps=tcfg.eps, weight_decay=tcfg.weight_decay)
    else:
        # ---- explicit gradient reduction over replicated axes ---------------
        grads = jax.tree.map(
            lambda g, a: comm.psum(g, a) if a else g, grads, sync_axes_tree,
            is_leaf=lambda x: isinstance(x, jax.Array))
        g_upd, gnorm = clip_by_global_norm(grads, tcfg.grad_clip,
                                           norm_axes_tree)
        apply_fn = lambda g, o, p: opt.update(g, o, p, lr,
                                              shard_axes=norm_axes_tree)
    if sentinel:
        # verdict AFTER grad reduction (+ clip / owned-chunk scatter —
        # non-finite values survive both), BEFORE the moments see
        # anything: the guarded apply leaves params/opt-state (including
        # the ZeRO-1 sharded chunks and step clock) bit-unchanged on a
        # bad step
        ok, nonfin, spike = SEN.step_verdict(metrics["loss"], g_upd,
                                             sent, plan.all_axes)
        params, opt_state = SEN.gated_update(ok, apply_fn, g_upd,
                                             opt_state, params)
        alarm = SEN.router_alarm(metrics["max_load"],
                                 metrics["load_entropy"])
        sent = SEN.update_sentinel(sent, metrics["loss"], ok, nonfin,
                                   spike, alarm)
        metrics = dict(metrics)
        metrics["skip"] = (~ok).astype(jnp.float32)
    else:
        params, opt_state = apply_fn(g_upd, opt_state, params)
    metrics = dict(metrics)
    metrics["grad_norm"] = gnorm
    metrics["lr"] = lr
    if sentinel:
        return params, opt_state, metrics, sent
    return params, opt_state, metrics


def build_train_step(cfg: ModelConfig, tcfg: TrainConfig, plan: MeshPlan,
                     opt: Optimizer, schedule, params_like, batch_like,
                     mesh=None, use_kernel: bool = False,
                     zero1: bool = False, sentinel: bool = False):
    """Return a jitted step(params, opt_state, batch, step) for this mesh.

    ``params_like`` / ``batch_like`` may be ShapeDtypeStructs (for lowering)
    or real arrays. With ``mesh=None`` the step runs on one device (oracle).
    With ``zero1=True`` optimizer state is sharded over each leaf's
    replicated axes (init with ``zero1_state(...)``).  With
    ``sentinel=True`` the step is 5-ary — ``step(params, opt_state, batch,
    step, sent) -> (params, opt_state, metrics, sent)`` where ``sent`` is
    ``repro.train.sentinel.init_sentinel_state()`` — and bad steps are
    skipped instead of applied (see ``train_step_fn``).
    """
    pspec = param_specs(params_like, cfg, plan)
    sync_tree = shard_axes(pspec, plan)
    norm_tree = sharded_axes_only(pspec, plan)
    n_micro = 1
    if tcfg.micro_batch_size:
        local_b = batch_like["tokens"].shape[0] // max(plan.dp, 1)
        n_micro = max(1, local_b // tcfg.micro_batch_size)

    fn = partial(train_step_fn, cfg=cfg, tcfg=tcfg, plan=plan, opt=opt,
                 schedule=schedule, sync_axes_tree=sync_tree,
                 norm_axes_tree=norm_tree, n_micro=n_micro,
                 use_kernel=use_kernel, zero1=zero1, sentinel=sentinel)
    if mesh is None:
        return jax.jit(fn, donate_argnums=(0, 1)), pspec

    if zero1:
        ospec = state_specs(pspec, sync_tree, norm_tree)
    else:
        ospec = {"m": pspec, "v": pspec, "step": P()}
    bspec = batch_specs(batch_like, plan)
    mkeys = ["ce", "lb", "z", "mtp", "drop_frac", "loss", "grad_norm", "lr",
             "fault_events", "wire_faults", "max_load", "load_entropy"]
    if sentinel:
        mkeys.append("skip")
    mspec = {k: P() for k in mkeys}
    if sentinel:
        from repro.train.sentinel import init_sentinel_state
        sspec = jax.tree.map(lambda _: P(), init_sentinel_state())
        sm = shard_map(fn, mesh=mesh,
                       in_specs=(pspec, ospec, bspec, P(), sspec),
                       out_specs=(pspec, ospec, mspec, sspec))
    else:
        sm = shard_map(fn, mesh=mesh,
                       in_specs=(pspec, ospec, bspec, P()),
                       out_specs=(pspec, ospec, mspec))
    return jax.jit(sm, donate_argnums=(0, 1)), pspec


def zero1_state(params_like, cfg: ModelConfig, plan: MeshPlan):
    """Init the ZeRO-1 optimizer state (global shapes; shard via its specs)."""
    pspec = param_specs(params_like, cfg, plan)
    sync_tree = shard_axes(pspec, plan)
    norm_tree = sharded_axes_only(pspec, plan)
    return init_state_shapes(params_like, sync_tree, norm_tree, plan)

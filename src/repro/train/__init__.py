from repro.train.step import build_train_step, train_step_fn
from repro.train.checkpoint import (CheckpointError, CheckpointManager,
                                    load_checkpoint, save_checkpoint)
from repro.train.sentinel import SentinelState, init_sentinel_state

__all__ = ["build_train_step", "train_step_fn", "save_checkpoint",
           "load_checkpoint", "CheckpointError", "CheckpointManager",
           "SentinelState", "init_sentinel_state"]

from repro.train.step import build_train_step, train_step_fn
from repro.train.checkpoint import load_checkpoint, save_checkpoint

__all__ = ["build_train_step", "train_step_fn", "save_checkpoint",
           "load_checkpoint"]

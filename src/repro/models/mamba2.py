"""Mamba2 (SSD — state-space dual) block, single-group, tensor-parallel.

Used by the zamba2 hybrid architecture. Heads (= d_inner/head_dim) are
sharded over the tensor-parallel axis; the B/C state projections are shared
across heads (single group) and computed replicated.

Training/prefill uses the chunked SSD algorithm (intra-chunk quadratic term +
inter-chunk state recurrence via ``lax.scan``); decode is the O(1) recurrent
step. Both maintain the same ``(ssm, conv_*)`` cache structure so prefill can
hand off to decode.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.common.config import ModelConfig, SSMConfig
from repro.models.layers import dense_init, apply_norm
from repro.sharding import comm
from repro.sharding.plan import MeshPlan


def init_mamba2(key, cfg: ModelConfig) -> Dict:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    nh = d_in // s.head_dim
    ks = jax.random.split(key, 10)
    return {
        "wx": dense_init(ks[0], (d, d_in)),
        "wz": dense_init(ks[1], (d, d_in)),
        "wB": dense_init(ks[2], (d, s.d_state)),
        "wC": dense_init(ks[3], (d, s.d_state)),
        "wdt": dense_init(ks[4], (d, nh)),
        "conv_x": dense_init(ks[5], (d_in, s.d_conv), scale=0.5),
        "conv_B": dense_init(ks[6], (s.d_state, s.d_conv), scale=0.5),
        "conv_C": dense_init(ks[7], (s.d_state, s.d_conv), scale=0.5),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": {"scale": jnp.ones((d_in,), jnp.float32)},
        "wo": dense_init(ks[8], (d_in, d)),
    }


def _causal_conv(x: jax.Array, w: jax.Array,
                 state: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv. x: (B, T, C); w: (C, W). Returns (y, new_state)
    where state carries the trailing W-1 inputs."""
    B, T, C = x.shape
    W = w.shape[1]
    if state is None:
        state = jnp.zeros((B, W - 1, C), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)                   # (B, T+W-1, C)
    y = sum(xp[:, j:j + T, :] * w[:, j].astype(x.dtype) for j in range(W))
    return y, xp[:, -(W - 1):, :]


def mamba2_forward(p: Dict, x: jax.Array, cfg: ModelConfig, plan: MeshPlan,
                   *, cache: Optional[Dict] = None
                   ) -> Tuple[jax.Array, Optional[Dict]]:
    """x: (B, T, d) -> (B, T, d). Chunked SSD; heads sharded over tp."""
    s = cfg.ssm
    B, T, d = x.shape
    hd, ds = s.head_dim, s.d_state

    xs = jnp.einsum("btd,di->bti", x, p["wx"].astype(x.dtype))  # (B,T,d_in_loc)
    z = jnp.einsum("btd,di->bti", x, p["wz"].astype(x.dtype))
    Bp = jnp.einsum("btd,dn->btn", x, p["wB"].astype(x.dtype))  # replicated
    Cp = jnp.einsum("btd,dn->btn", x, p["wC"].astype(x.dtype))
    dt = jnp.einsum("btd,dh->bth", x, p["wdt"].astype(x.dtype)) # (B,T,nh_loc)

    conv_state = cache or {}
    xs, st_x = _causal_conv(xs, p["conv_x"], conv_state.get("conv_x"))
    Bp, st_B = _causal_conv(Bp, p["conv_B"], conv_state.get("conv_B"))
    Cp, st_C = _causal_conv(Cp, p["conv_C"], conv_state.get("conv_C"))
    xs, Bp, Cp = jax.nn.silu(xs), jax.nn.silu(Bp), jax.nn.silu(Cp)

    nh = dt.shape[-1]
    xh = xs.reshape(B, T, nh, hd).astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,T,nh)
    A = -jnp.exp(p["A_log"])                                     # (nh,)
    loga = dt * A                                                # (B,T,nh) <= 0
    Bf, Cf = Bp.astype(jnp.float32), Cp.astype(jnp.float32)

    ssm0 = None
    if cache is not None and "ssm" in cache:
        ssm0 = cache["ssm"].astype(jnp.float32)                  # (B,nh,hd,ds)
    if ssm0 is None:
        ssm0 = jnp.zeros((B, nh, hd, ds), jnp.float32)

    if T == 1 and cache is not None:
        # O(1) decode step
        a = jnp.exp(loga[:, 0])                                  # (B,nh)
        dx = dt[:, 0, :, None] * xh[:, 0]                        # (B,nh,hd)
        ssm = (a[..., None, None] * ssm0
               + dx[..., None] * Bf[:, 0, None, None, :])
        y = jnp.einsum("bhpn,bn->bhp", ssm, Cf[:, 0])
        y = y + p["D"][None, :, None] * xh[:, 0]
        y = y.reshape(B, 1, nh * hd)
    else:
        Q = min(s.chunk, T)
        assert T % Q == 0, f"T={T} must be divisible by ssd chunk {Q}"
        nc = T // Q
        xq = xh.reshape(B, nc, Q, nh, hd)
        dq = dt.reshape(B, nc, Q, nh)
        lq = loga.reshape(B, nc, Q, nh)
        Bq = Bf.reshape(B, nc, Q, ds)
        Cq = Cf.reshape(B, nc, Q, ds)
        cs = jnp.cumsum(lq, axis=2)                              # (B,nc,Q,nh)

        # intra-chunk: Y[i] = sum_{j<=i} (C_i.B_j) exp(cs_i - cs_j) dt_j x_j
        scores = jnp.einsum("bcin,bcjn->bcij", Cq, Bq)           # (B,nc,Q,Q)
        decay = cs[:, :, :, None, :] - cs[:, :, None, :, :]      # (B,nc,i,j,nh)
        mask = (jnp.arange(Q)[:, None] >= jnp.arange(Q)[None, :])
        decay = jnp.where(mask[None, None, :, :, None], decay, -jnp.inf)
        w_ij = jnp.exp(decay) * scores[..., None]                # (B,nc,i,j,nh)
        y_intra = jnp.einsum("bcijh,bcjh,bcjhp->bcihp", w_ij, dq, xq)

        # chunk summary states + inter-chunk recurrence
        tail = cs[:, :, -1:, :] - cs                             # decay to end
        sB = jnp.einsum("bcjh,bcjh,bcjhp,bcjn->bchpn",
                        jnp.exp(tail), dq, xq, Bq)               # (B,nc,nh,hd,ds)
        a_chunk = jnp.exp(cs[:, :, -1, :])                       # (B,nc,nh)

        def scan_fn(h, inp):
            sB_c, a_c = inp
            h_new = a_c[..., None, None] * h + sB_c
            return h_new, h                                      # emit state BEFORE chunk
        (h_last, h_prev) = lax.scan(
            scan_fn, ssm0,
            (sB.transpose(1, 0, 2, 3, 4), a_chunk.transpose(1, 0, 2)))
        h_prev = h_prev.transpose(1, 0, 2, 3, 4)                 # (B,nc,nh,hd,ds)

        y_inter = jnp.einsum("bcin,bchpn,bcih->bcihp",
                             Cq, h_prev, jnp.exp(cs))
        y = y_intra + y_inter + p["D"][None, None, None, :, None] * xq
        y = y.reshape(B, T, nh * hd)
        ssm = h_last

    y = y.astype(x.dtype) * jax.nn.silu(z)
    # gated RMSNorm over the FULL d_inner — the feature dim is tp-sharded,
    # so the mean-square is psum'd across the tensor-parallel axis.
    yf = y.astype(jnp.float32)
    d_in_local = yf.shape[-1]
    ss = comm.psum(jnp.sum(yf * yf, axis=-1, keepdims=True), plan.tp_axis)
    denom = d_in_local * max(plan.tp, 1)
    y = (yf * lax.rsqrt(ss / denom + 1e-5)
         * p["norm"]["scale"]).astype(x.dtype)
    out = jnp.einsum("bti,id->btd", y, p["wo"].astype(x.dtype))
    out = comm.name_saved(comm.psum(out, plan.tp_axis))

    new_cache = None
    if cache is not None:
        new_cache = {"ssm": ssm.astype(jnp.float32),
                     "conv_x": st_x, "conv_B": st_B, "conv_C": st_C}
    return out, new_cache


def init_mamba2_cache(cfg: ModelConfig, batch: int, plan: MeshPlan,
                      dtype=jnp.bfloat16) -> Dict:
    # GLOBAL shapes; sharded over tp by the cache PartitionSpec rules.
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nh = d_in // s.head_dim
    return {
        "ssm": jnp.zeros((batch, nh, s.head_dim, s.d_state), jnp.float32),
        "conv_x": jnp.zeros((batch, s.d_conv - 1, d_in), dtype),
        "conv_B": jnp.zeros((batch, s.d_conv - 1, s.d_state), dtype),
        "conv_C": jnp.zeros((batch, s.d_conv - 1, s.d_state), dtype),
    }

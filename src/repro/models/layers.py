"""Model building blocks, written against local shards inside ``shard_map``.

Conventions
-----------
* Activations are ``(B_loc, S, d)`` — batch sharded over the data-parallel
  axes, full model dim, replicated over the tensor-parallel axis.
* Tensor-parallel weights are stored with *global* shapes and sharded by the
  PartitionSpec rules in :mod:`repro.sharding.specs`; inside ``shard_map``
  each leaf arrives as its local shard, and the code reads dims off the
  arrays, never off the config.
* All collectives go through :mod:`repro.sharding.comm`, so with an empty
  plan this file is the pure-jnp single-device oracle.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.common.config import ModelConfig
from repro.sharding import comm
from repro.sharding.plan import MeshPlan


def _norm_init(d: int, kind: str):
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(p: Dict, x: jax.Array, kind: str, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:
        var = (xf * xf).mean(-1, keepdims=True)
        y = xf * lax.rsqrt(var + eps) * p["scale"]
    return y.astype(x.dtype)


def dense_init(key, shape, scale=None, dtype=jnp.float32):
    scale = scale if scale is not None else 1.0 / math.sqrt(shape[0])
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# =============================================================================
# Rotary position embedding
# =============================================================================

def rope_frequencies(dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., T, H, hd); positions: (T,) shared or (B, T) per-row absolute
    positions (continuous-batching decode, where every slot sits at its own
    sequence position)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                       # (hd/2,)
    ang = positions.astype(jnp.float32)[..., :, None] * freqs  # (..., T, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    if positions.ndim == 1:
        cos, sin = cos[None], sin[None]
    cos = cos[..., :, None, :]                    # (B|1, T, 1, hd/2)
    sin = sin[..., :, None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# =============================================================================
# Vocab-parallel embedding + LM head + cross-entropy
# =============================================================================

def init_embedding(key, cfg: ModelConfig, plan: MeshPlan) -> Dict:
    p = {"table": dense_init(key, (cfg.vocab_size, cfg.d_model), scale=0.02)}
    return p


def embed_tokens(p: Dict, ids: jax.Array, plan: MeshPlan,
                 dtype=jnp.bfloat16) -> jax.Array:
    """Vocab-parallel lookup: table sharded on vocab dim over tp."""
    table = p["table"]
    v_loc = table.shape[0]
    start = comm.axis_index(plan.tp_axis) * v_loc
    local = ids - start
    hit = (local >= 0) & (local < v_loc)
    emb = jnp.take(table, jnp.clip(local, 0, v_loc - 1), axis=0)
    emb = emb * hit[..., None].astype(table.dtype)
    return comm.psum(emb, plan.tp_axis).astype(dtype)


def output_logits(p: Dict, x: jax.Array, plan: MeshPlan) -> jax.Array:
    """Vocab-sharded logits (..., V_loc); fp32."""
    w = p["table"] if "table" in p else p["w"]                # tied or separate
    return jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                      w.astype(jnp.float32))


def vocab_parallel_xent(logits: jax.Array, labels: jax.Array,
                        plan: MeshPlan) -> jax.Array:
    """Cross-entropy over vocab-sharded fp32 logits (..., V_loc).

    ``labels`` are global vocab ids. Returns per-position loss (...,).
    Megatron-style: max/sum-exp/label-pick are each reduced over tp.
    """
    v_loc = logits.shape[-1]
    start = comm.axis_index(plan.tp_axis) * v_loc
    # the max shift is a numerical-stability constant; keep it out of AD
    # (lax.pmax has no differentiation rule, and its gradient is zero anyway)
    m = comm.pmax(lax.stop_gradient(logits.max(-1)), plan.tp_axis)
    lse = jnp.log(comm.psum(jnp.exp(logits - m[..., None]).sum(-1),
                            plan.tp_axis)) + m
    local = labels - start
    hit = (local >= 0) & (local < v_loc)
    picked = jnp.take_along_axis(
        logits, jnp.clip(local, 0, v_loc - 1)[..., None], axis=-1)[..., 0]
    picked = comm.psum(picked * hit.astype(logits.dtype), plan.tp_axis)
    return lse - picked


def gather_full_logits(logits: jax.Array, plan: MeshPlan) -> jax.Array:
    """(..., V_loc) -> (..., V). Used only at the sampling point in serving."""
    return comm.all_gather(logits, plan.tp_axis, axis=logits.ndim - 1)


# =============================================================================
# Dense FFN (Megatron tensor parallel: col-shard up, row-shard down, psum)
# =============================================================================

def init_ffn(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w1": dense_init(k1, (d, f)), "w2": dense_init(k2, (f, d))}
    if cfg.glu:
        p["w3"] = dense_init(k3, (d, f))
    return p


def ffn_forward(p: Dict, x: jax.Array, cfg: ModelConfig,
                plan: MeshPlan) -> jax.Array:
    actf = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    h = actf(jnp.einsum("...d,df->...f", x, p["w1"].astype(x.dtype)))
    if "w3" in p:
        h = h * jnp.einsum("...d,df->...f", x, p["w3"].astype(x.dtype))
    y = jnp.einsum("...f,fd->...d", h, p["w2"].astype(x.dtype))
    return comm.name_saved(comm.psum(y, plan.tp_axis))


# =============================================================================
# Streaming-softmax ("flash"-style) attention core, pure jnp
# =============================================================================

def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      q_pos: jax.Array, k_pos: jax.Array,
                      *, causal: bool, window: int = 0,
                      chunk: int = 1024, use_kernel: bool = False,
                      return_partial: bool = False) -> jax.Array:
    """O(S*chunk)-memory attention.

    q: (B, Tq, H, hd); k/v: (B, Tk, KV, hd) with KV | H (GQA).
    ``q_pos``: (Tq,), ``k_pos``: (Tk,) absolute positions; invalid cache slots
    carry a negative position and are masked out.
    """
    if use_kernel and causal and window == 0 and q.shape[1] == k.shape[1]:
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v)
    B, Tq, H, hd = q.shape
    Tk, KV = k.shape[1], k.shape[2]
    dv = v.shape[-1]                     # may differ from hd (MLA)
    g = H // KV
    scale = 1.0 / math.sqrt(hd)
    qf = (q * scale).astype(jnp.float32).reshape(B, Tq, KV, g, hd)

    pad = (-Tk) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=-1)
    n_chunks = k.shape[1] // chunk
    kc = k.reshape(B, n_chunks, chunk, KV, hd).astype(jnp.float32)
    vc = v.reshape(B, n_chunks, chunk, KV, dv).astype(jnp.float32)
    pc = k_pos.reshape(n_chunks, chunk)

    def step(carry, inp):
        m, l, acc = carry
        kb, vb, pb = inp                                       # (B,c,KV,hd)...
        s = jnp.einsum("btkgh,bckh->btkgc", qf, kb)            # (B,Tq,KV,g,c)
        mask = pb[None, None, None, None, :] >= 0
        if causal:
            mask &= q_pos[None, :, None, None, None] >= pb[None, None, None, None, :]
        if window:
            mask &= (q_pos[None, :, None, None, None]
                     - pb[None, None, None, None, :]) < window
        s = jnp.where(mask, s, -1e30)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum("btkgc,bckh->btkgh", p, vb)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Tq, KV, g), -1e30, jnp.float32)
    l0 = jnp.zeros((B, Tq, KV, g), jnp.float32)
    a0 = jnp.zeros((B, Tq, KV, g, dv), jnp.float32)
    (m, l, acc), _ = lax.scan(
        step, (m0, l0, a0),
        (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4), pc))
    if return_partial:
        return m, l, acc                     # caller merges across shards
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, Tq, H, dv).astype(q.dtype)


def merge_attention_partials(m, l, acc, axes, out_shape, dtype):
    """Flash-decoding style merge of per-shard softmax partials over ``axes``
    (the KV cache is sequence-sharded across the tensor-parallel axis)."""
    m_g = comm.pmax(m, axes)
    corr = jnp.exp(m - m_g)
    l_g = comm.psum(l * corr, axes)
    acc_g = comm.psum(acc * corr[..., None], axes)
    out = acc_g / jnp.maximum(l_g, 1e-30)[..., None]
    return out.reshape(out_shape).astype(dtype)


# =============================================================================
# GQA attention (tensor parallel over heads) with ring-buffer KV cache
# =============================================================================

def init_attention(key, cfg: ModelConfig) -> Dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, KV = cfg.num_heads, cfg.num_kv_heads
    k1, k2, k3, k4, kb = jax.random.split(key, 5)
    p = {
        "wq": dense_init(k1, (d, H, hd)),
        "wk": dense_init(k2, (d, KV, hd)),
        "wv": dense_init(k3, (d, KV, hd)),
        "wo": dense_init(k4, (H, hd, d), scale=1.0 / math.sqrt(H * hd)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), jnp.float32)
        p["bk"] = jnp.zeros((KV, hd), jnp.float32)
        p["bv"] = jnp.zeros((KV, hd), jnp.float32)
    return p


def _kv_slice_for_my_heads(kv: jax.Array, h_loc: int, H: int, KV: int,
                           plan: MeshPlan) -> jax.Array:
    """When KV heads could not be sharded (KV < tp), slice the ones backing
    this device's query heads out of the replicated KV projection."""
    kv_here = kv.shape[2]
    need = max(1, (h_loc * KV) // H)
    if kv_here == need:          # already sharded to exactly our heads
        return kv
    i = comm.axis_index(plan.tp_axis)
    start = (i * h_loc * KV) // H
    return lax.dynamic_slice_in_dim(kv, start, need, axis=2)


def attention_forward(p: Dict, x: jax.Array, cfg: ModelConfig, plan: MeshPlan,
                      *, positions: jax.Array, cache: Optional[Dict] = None,
                      window: int = 0, use_kernel: bool = False
                      ) -> Tuple[jax.Array, Optional[Dict]]:
    """x: (B, T, d) -> (B, T, d). If ``cache`` given, appends this step's KV
    (ring buffer) and attends over the cache; otherwise attends over x."""
    B, T, _ = x.shape
    H, KV = cfg.num_heads, cfg.num_kv_heads
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("btd,dhk->bthk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("btd,dhk->bthk", x, p["wv"].astype(x.dtype))
    if "bq" in p:
        # biases are sharded exactly like the matching projection outputs
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    h_loc = q.shape[2]
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    if cache is None:
        k_use = _kv_slice_for_my_heads(k, h_loc, H, KV, plan)
        v_use = _kv_slice_for_my_heads(v, h_loc, H, KV, plan)
        out = chunked_attention(q, k_use, v_use, positions, positions,
                                causal=cfg.causal, window=window,
                                use_kernel=use_kernel)
        new_cache = None
    elif "pool_k" in cache:
        out, new_cache = paged_attention(q, k, v, cache, positions, cfg, plan,
                                         h_loc=h_loc, window=window)
    elif cfg.kv_seq_shard and plan.tp > 1:
        # beyond-paper: the cache's SEQUENCE dim is sharded over tp (flash-
        # decoding style). Each rank owns a W/tp slice, scatters this step's
        # KV into it iff the ring slot falls in its slice, attends over its
        # slice only, and the softmax partials are merged with pmax/psum.
        # Removes the KV-cache replication forced by kv_heads < tp and cuts
        # per-chip cache memory and read traffic by ~tp.
        Wl = cache["k"].shape[1]                 # local slice length
        i = comm.axis_index(plan.tp_axis)
        slot = positions % (Wl * max(plan.tp, 1)) - i * Wl      # (T,)
        mine = (slot >= 0) & (slot < Wl)
        safe = jnp.where(mine, slot, Wl)         # OOB -> dropped
        ck = jax.vmap(lambda c, u: c.at[safe].set(u, mode="drop"),
                      in_axes=(0, 0))(cache["k"], k)
        cv = jax.vmap(lambda c, u: c.at[safe].set(u, mode="drop"),
                      in_axes=(0, 0))(cache["v"], v)
        cpos = cache["pos"].at[safe].set(positions, mode="drop")
        # heads are ALSO tp-sharded, so per-rank partials would cover
        # different heads: all-gather the (tiny: one token) queries, compute
        # all-head partials over the local chunk, merge, slice our heads back.
        q_full = comm.all_gather(q, plan.tp_axis, axis=2)       # (B,T,H,hd)
        m, l, acc = chunked_attention(q_full, ck, cv, positions, cpos,
                                      causal=cfg.causal, window=window,
                                      return_partial=True)
        out_full = merge_attention_partials(
            m, l, acc, plan.tp_axis,
            (q.shape[0], q.shape[1], q_full.shape[2], cv.shape[-1]), q.dtype)
        out = lax.dynamic_slice_in_dim(
            out_full, comm.axis_index(plan.tp_axis) * h_loc, h_loc, axis=2)
        new_cache = {"k": ck, "v": cv, "pos": cpos}
    else:
        W = cache["k"].shape[1]
        slot = positions % W                                    # (T,)
        ck = jax.vmap(lambda c, u: c.at[slot].set(u), in_axes=(0, 0))(cache["k"], k)
        cv = jax.vmap(lambda c, u: c.at[slot].set(u), in_axes=(0, 0))(cache["v"], v)
        cpos = cache["pos"].at[slot].set(positions)
        k_use = _kv_slice_for_my_heads(ck, h_loc, H, KV, plan)
        v_use = _kv_slice_for_my_heads(cv, h_loc, H, KV, plan)
        out = chunked_attention(q, k_use, v_use, positions, cpos,
                                causal=cfg.causal, window=window)
        new_cache = {"k": ck, "v": cv, "pos": cpos}

    y = jnp.einsum("bthk,hkd->btd", out, p["wo"].astype(x.dtype))
    return comm.name_saved(comm.psum(y, plan.tp_axis)), new_cache


def init_attention_cache(cfg: ModelConfig, batch: int, length: int,
                         plan: MeshPlan, dtype=jnp.bfloat16) -> Dict:
    """Ring-buffer cache sized ``length`` (= window for sliding attention).

    GLOBAL shapes — the PartitionSpec rules in ``sharding.specs`` shard the
    KV-head dim over tp; inside ``shard_map`` the leaf arrives local.
    """
    KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, length, KV, hd), dtype),
        "v": jnp.zeros((batch, length, KV, hd), dtype),
        "pos": jnp.full((length,), -1, jnp.int32),
    }


# =============================================================================
# Paged KV cache — page-pool scatter write + page-table gather read
# =============================================================================

def init_paged_kv_cache(cfg: ModelConfig, pool_pages: int, page_size: int,
                        dtype=jnp.bfloat16) -> Dict:
    """One layer's slice of the shared page pool (no batch dim — sequences
    own pages through the per-tick page ``table``, not through a slot dim).

    GLOBAL shapes; ``sharding.specs`` shards the KV-head dim over tp when it
    divides. The page table itself is NOT part of the cache tree: the host
    scheduler owns it (admit/evict rewrite rows between ticks) and the engine
    injects a broadcast copy per layer each step (see ``serve.kvcache``).
    """
    KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "pool_k": jnp.zeros((pool_pages, page_size, KV, hd), dtype),
        "pool_v": jnp.zeros((pool_pages, page_size, KV, hd), dtype),
    }


def paged_attention(q: jax.Array, k: jax.Array, v: jax.Array, cache: Dict,
                    positions: jax.Array, cfg: ModelConfig, plan: MeshPlan,
                    *, h_loc: int, window: int = 0
                    ) -> Tuple[jax.Array, Dict]:
    """Paged-KV attention step: scatter this tick's KV into the page pool,
    gather each sequence's view through its page-table row, attend with a
    direct fp32 softmax over the gathered view.

    q/k/v: (B, T, h, hd) fresh (rope-applied) projections. ``positions``:
    (B, T) int32 per-row absolute positions; **-1 marks dead rows** — their
    KV scatter is dropped and their output is finite garbage the caller must
    ignore. cache: ``pool_k``/``pool_v`` (P, page, KVs, hd) plus ``table``
    (B, max_pages) int32 of sequence-ordered page ids (entries >= P or < 0
    are unmapped).

    Gathered index ``s`` of a row's view IS sequence position ``s`` (pages
    are sequence-ordered in the table), so the single mask ``s <= q_pos``
    enforces causality AND hides stale data in reused ("dirty") pages — a
    freed page re-allocated to a new sequence needs no zeroing because
    positions the new sequence hasn't written yet are all ``> q_pos``.
    """
    assert cfg.causal, "paged attention path is causal-only"
    pool_k, pool_v, table = cache["pool_k"], cache["pool_v"], cache["table"]
    P, page = pool_k.shape[0], pool_k.shape[1]
    B, T = q.shape[:2]
    mp = table.shape[1]
    H, KV = cfg.num_heads, cfg.num_kv_heads
    KVs, hd = k.shape[2], q.shape[-1]

    # ---- scatter write: token (b, t) at position s -> (table[b, s//page],
    # s % page); dead rows and rows past the table extent get the sentinel
    # page id P, which is out of range -> mode="drop" discards the write
    # (NOT -1: negative indices would wrap to the end of the pool).
    ps = jnp.clip(positions, 0, None)
    slot = ps // page
    pidx = jnp.take_along_axis(table, jnp.clip(slot, 0, mp - 1), axis=1)
    ok = (positions >= 0) & (slot < mp) & (pidx >= 0) & (pidx < P)
    pidx = jnp.where(ok, pidx, P).reshape(-1)                  # (B*T,)
    off = (ps % page).reshape(-1)
    pool_k = pool_k.at[pidx, off].set(k.reshape(B * T, KVs, hd), mode="drop")
    pool_v = pool_v.at[pidx, off].set(v.reshape(B * T, KVs, hd), mode="drop")

    # ---- gather read: (B, mp, page, KVs, hd) -> per-sequence (B, Lk) view
    tbl = jnp.clip(table, 0, P - 1)
    Lk = mp * page
    k_view = jnp.take(pool_k, tbl, axis=0).reshape(B, Lk, KVs, hd)
    v_view = jnp.take(pool_v, tbl, axis=0).reshape(B, Lk, KVs, hd)
    k_use = _kv_slice_for_my_heads(k_view, h_loc, H, KV, plan)
    v_use = _kv_slice_for_my_heads(v_view, h_loc, H, KV, plan)

    # ---- direct fp32 softmax over the gathered view (decode ticks are one
    # token x a short view; the streaming chunked kernel buys nothing here)
    KVl = k_use.shape[2]
    g = h_loc // KVl
    scale = 1.0 / math.sqrt(hd)
    qf = (q * scale).astype(jnp.float32).reshape(B, T, KVl, g, hd)
    s = jnp.einsum("btkgh,bskh->btkgs", qf, k_use.astype(jnp.float32))
    sidx = jnp.arange(Lk)
    mask = sidx[None, None, None, None, :] <= positions[:, :, None, None, None]
    if window:
        mask &= (positions[:, :, None, None, None]
                 - sidx[None, None, None, None, :]) < window
    s = jnp.where(mask, s, -1e30)
    e = jnp.exp(s - s.max(-1, keepdims=True))
    out = jnp.einsum("btkgs,bskh->btkgh", e, v_use.astype(jnp.float32))
    out = out / jnp.maximum(e.sum(-1), 1e-30)[..., None]
    out = out.reshape(B, T, h_loc, v_use.shape[-1]).astype(q.dtype)
    # table rides through unchanged so the scan-carried cache tree matches
    return out, {"pool_k": pool_k, "pool_v": pool_v, "table": table}


# =============================================================================
# MLA — Multi-head Latent Attention (deepseek-v3)
# =============================================================================

def init_mla(key, cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    H = cfg.num_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    nope, rope, vhd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq_a": dense_init(ks[0], (d, qr)),
        "wq_b": dense_init(ks[1], (qr, H, nope + rope)),
        "wkv_a": dense_init(ks[2], (d, kvr + rope)),
        "wk_b": dense_init(ks[3], (kvr, H, nope)),
        "wv_b": dense_init(ks[4], (kvr, H, vhd)),
        "wo": dense_init(ks[5], (H, vhd, d), scale=1.0 / math.sqrt(H * vhd)),
    }


def mla_forward(p: Dict, x: jax.Array, cfg: ModelConfig, plan: MeshPlan,
                *, positions: jax.Array, cache: Optional[Dict] = None,
                window: int = 0) -> Tuple[jax.Array, Optional[Dict]]:
    """Latent attention: KV compressed to (kv_rank + rope) per token.

    The cache stores only the compressed latent — MLA's whole point: the
    500k-token cache is ~64x smaller than full GQA KV.
    """
    B, T, _ = x.shape
    nope, rope = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    kvr = cfg.kv_lora_rank
    q = jnp.einsum("btd,dr->btr", x, p["wq_a"].astype(x.dtype))
    q = jnp.einsum("btr,rhk->bthk", q, p["wq_b"].astype(x.dtype))
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)             # (B,T,Hloc,n+r)

    kv = jnp.einsum("btd,dr->btr", x, p["wkv_a"].astype(x.dtype))
    ckv, k_pe = kv[..., :kvr], kv[..., kvr:]
    k_pe = apply_rope(k_pe[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]

    if cache is not None:
        W = cache["ckv"].shape[1]
        slot = positions % W
        ckv_all = jax.vmap(lambda c, u: c.at[slot].set(u))(cache["ckv"], ckv)
        kpe_all = jax.vmap(lambda c, u: c.at[slot].set(u))(cache["kpe"], k_pe)
        cpos = cache["pos"].at[slot].set(positions)
        new_cache = {"ckv": ckv_all, "kpe": kpe_all, "pos": cpos}
    else:
        ckv_all, kpe_all, cpos = ckv, k_pe, positions
        new_cache = None

    if cache is not None and T == 1:
        # ABSORBED decode (beyond-paper; EXPERIMENTS.md §Perf-3): fold W_UK
        # into the query and W_UV into the output so attention runs directly
        # over the compressed latent — the cache is never expanded to
        # per-head K/V, cutting decode HBM reads ~H*(nope+v)/(kv_rank+rope).
        scale = 1.0 / math.sqrt(nope + rope)
        q_lat = jnp.einsum("bthk,rhk->bthr", q_nope,
                           p["wk_b"].astype(x.dtype))           # (B,1,H,kvr)
        s = (jnp.einsum("bthr,bsr->bths", q_lat, ckv_all)
             + jnp.einsum("bthk,bsk->bths", q_rope, kpe_all))   # (B,1,H,W)
        s = (s * scale).astype(jnp.float32)
        mask = (cpos >= 0) & (cpos <= positions[-1])
        if window:
            mask &= (positions[-1] - cpos) < window
        s = jnp.where(mask[None, None, None, :], s, -1e30)
        a = jax.nn.softmax(s, axis=-1)                          # fp32 weights
        o_lat = jnp.einsum("bths,bsr->bthr", a,
                           ckv_all.astype(jnp.float32))         # (B,1,H,kvr)
        out = jnp.einsum("bthr,rhk->bthk", o_lat.astype(x.dtype),
                         p["wv_b"].astype(x.dtype))             # (B,1,H,vhd)
    else:
        # naive path (prefill/training): reconstruct per-head K/V from the
        # latent. wk_b/wv_b are head-sharded so this yields local heads.
        k_nope = jnp.einsum("btr,rhk->bthk", ckv_all, p["wk_b"].astype(x.dtype))
        v = jnp.einsum("btr,rhk->bthk", ckv_all, p["wv_b"].astype(x.dtype))
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kpe_all[:, :, None, :],
                                      k_nope.shape[:3] + (rope,))], axis=-1)
        out = chunked_attention(q, k, v, positions, cpos, causal=cfg.causal,
                                window=window)
    y = jnp.einsum("bthk,hkd->btd", out, p["wo"].astype(x.dtype))
    return comm.name_saved(comm.psum(y, plan.tp_axis)), new_cache


def init_mla_cache(cfg: ModelConfig, batch: int, length: int,
                   plan: MeshPlan, dtype=jnp.bfloat16) -> Dict:
    return {
        "ckv": jnp.zeros((batch, length, cfg.kv_lora_rank), dtype),
        "kpe": jnp.zeros((batch, length, cfg.qk_rope_head_dim), dtype),
        "pos": jnp.full((length,), -1, jnp.int32),
    }

"""RWKV6 ("Finch") block — attention-free, data-dependent decay.

Time-mix: per-head matrix-valued state ``S in R^{hd x hd}`` with
``S_t[i,j] = w_t[i] * S_{t-1}[i,j] + k_t[i] v_t[j]`` and readout
``y_t[j] = sum_i r_t[i] (S_{t-1}[i,j] + u[i] k_t[i] v_t[j])`` where the decay
``w_t = exp(-exp(w0 + lora_w(x)))`` is data-dependent (the Finch change vs
RWKV5). Channel-mix is the squared-ReLU RWKV FFN.

Heads are sharded over the tensor-parallel axis; channel-mix hidden dim is
sharded Megatron-style. The sequential recurrence uses ``lax.scan`` (the
Pallas chunked kernel in ``repro.kernels.rwkv6_scan`` is the TPU fast path
and is validated against this reference).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.common.config import ModelConfig
from repro.models.layers import dense_init
from repro.sharding import comm
from repro.sharding.plan import MeshPlan

MIXES = ("r", "k", "v", "w", "g")


def init_rwkv_tmix(key, cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    r = cfg.rwkv
    nh = d // r.head_dim
    ks = jax.random.split(key, 12)
    p = {
        "mu": jnp.full((5, d), 0.5, jnp.float32),         # static shift mixes
        "mix_a": dense_init(ks[0], (d, 5 * r.mix_lora), scale=0.01),
        "mix_b": dense_init(ks[1], (5, r.mix_lora, d), scale=0.01),
        "wr": dense_init(ks[2], (d, nh, r.head_dim)),
        "wk": dense_init(ks[3], (d, nh, r.head_dim)),
        "wv": dense_init(ks[4], (d, nh, r.head_dim)),
        "wg": dense_init(ks[5], (d, nh, r.head_dim)),
        "w0": jnp.full((nh, r.head_dim), -1.0, jnp.float32),
        "decay_a": dense_init(ks[6], (d, r.decay_lora), scale=0.01),
        "decay_b": dense_init(ks[7], (r.decay_lora, nh, r.head_dim), scale=0.01),
        "u": jnp.zeros((nh, r.head_dim), jnp.float32),    # bonus ("time_faaaa")
        "ln_x": {"scale": jnp.ones((nh, r.head_dim), jnp.float32),
                 "bias": jnp.zeros((nh, r.head_dim), jnp.float32)},
        "wo": dense_init(ks[8], (nh, r.head_dim, d)),
    }
    return p


def _token_shift(x: jax.Array, x_prev: Optional[jax.Array]) -> jax.Array:
    """Return the previous token's features (zeros / cache at position 0)."""
    if x_prev is None:
        x_prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([x_prev, x[:, :-1]], axis=1)


def rwkv_tmix_forward(p: Dict, x: jax.Array, cfg: ModelConfig, plan: MeshPlan,
                      *, cache: Optional[Dict] = None,
                      use_kernel: bool = False
                      ) -> Tuple[jax.Array, Optional[Dict]]:
    B, T, d = x.shape
    r = cfg.rwkv
    hd = r.head_dim
    xf = x.astype(jnp.float32)
    prev = _token_shift(xf, None if cache is None else cache["x_prev_t"])
    dx = prev - xf
    # data-dependent interpolation between x and x_prev, one mix per use
    lora = jnp.tanh(jnp.einsum("btd,dl->btl", xf, p["mix_a"])
                    .reshape(B, T, 5, r.mix_lora))
    mixes = p["mu"][None, None] + jnp.einsum("btml,mld->btmd", lora, p["mix_b"])
    xs = xf[:, :, None, :] + dx[:, :, None, :] * mixes        # (B,T,5,d)
    xr, xk, xv, xw, xg = [xs[:, :, i] for i in range(5)]

    rv = jnp.einsum("btd,dhk->bthk", xr, p["wr"])              # (B,T,nh_loc,hd)
    kv = jnp.einsum("btd,dhk->bthk", xk, p["wk"])
    vv = jnp.einsum("btd,dhk->bthk", xv, p["wv"])
    gv = jax.nn.silu(jnp.einsum("btd,dhk->bthk", xg, p["wg"]))
    dec = p["w0"][None, None] + jnp.einsum(
        "btl,lhk->bthk", jnp.tanh(xw @ p["decay_a"]), p["decay_b"])
    w = jnp.exp(-jnp.exp(dec))                                 # (B,T,nh,hd) in (0,1)

    nh = rv.shape[2]
    s0 = (cache["wkv"].astype(jnp.float32) if cache is not None
          else jnp.zeros((B, nh, hd, hd), jnp.float32))

    if use_kernel and cache is None:
        from repro.kernels import ops as kops
        y, s_last = kops.rwkv6_scan(rv, kv, vv, w, p["u"], s0)
    else:
        def step(s, inp):
            rt, kt, vt, wt = inp                                # (B,nh,hd)
            kvt = kt[..., :, None] * vt[..., None, :]           # (B,nh,hd,hd)
            y = jnp.einsum("bhi,bhij->bhj", rt,
                           s + p["u"][None, :, :, None] * kvt)
            s_new = wt[..., :, None] * s + kvt
            return s_new, y
        (s_last, ys) = lax.scan(
            step, s0, (rv.transpose(1, 0, 2, 3), kv.transpose(1, 0, 2, 3),
                       vv.transpose(1, 0, 2, 3), w.transpose(1, 0, 2, 3)))
        y = ys.transpose(1, 0, 2, 3)                            # (B,T,nh,hd)

    # per-head group norm, then gate and output projection.
    #
    # GN_EPS is deliberately larger than a dense-activation LayerNorm's 1e-5:
    # early in the sequence the WKV state holds few (k v) outer products, so
    # ``y`` is near rank-1 across hd and ``var`` can be ~0 while |y| is O(10).
    # With eps=1e-5 the normalization multiplies by up to rsqrt(eps) ~ 316,
    # amplifying last-ulp differences in ``y`` (XLA compiles the upstream
    # einsums differently per local shard shape, so dp/tp sharding perturbs
    # the last bit) into ~1e-4 per layer — the rwkv6 distributed-equivalence
    # failure tracked in ROADMAP.md.  Upstream RWKV caps the same blow-up by
    # scaling GroupNorm's eps with the head size (head_size_divisor^2 * 1e-5
    # = 64e-5); measured on the (2,2) train-equiv harness that value still
    # leaves rel_gnorm at 1.3e-1 (threshold 6e-2), so this repro uses 1e-3
    # (~16x upstream), which bounds the amplification to ~32x and lands
    # rel_gnorm at 1.4e-2..2.8e-2 across seeds (EXPERIMENTS.md §Num-1).
    # Negligible wherever var is non-degenerate, but NOTE: weights ported
    # from upstream RWKV6 checkpoints will see slightly different
    # activations at the degenerate early-sequence slots.
    GN_EPS = 1e-3
    mu = y.mean(-1, keepdims=True)
    var = ((y - mu) ** 2).mean(-1, keepdims=True)
    y = (y - mu) * lax.rsqrt(var + GN_EPS) * p["ln_x"]["scale"] + p["ln_x"]["bias"]
    y = (y * gv).astype(x.dtype)
    out = jnp.einsum("bthk,hkd->btd", y, p["wo"].astype(x.dtype))
    out = comm.name_saved(comm.psum(out, plan.tp_axis))

    new_cache = None
    if cache is not None:
        new_cache = {"wkv": s_last, "x_prev_t": xf[:, -1:]}
    return out, new_cache


def init_rwkv_cmix(key, cfg: ModelConfig) -> Dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu_k": jnp.full((d,), 0.5, jnp.float32),
        "mu_r": jnp.full((d,), 0.5, jnp.float32),
        "wk": dense_init(ks[0], (d, f)),
        "wv": dense_init(ks[1], (f, d)),
        "wr": dense_init(ks[2], (d, d)),
    }


def rwkv_cmix_forward(p: Dict, x: jax.Array, cfg: ModelConfig, plan: MeshPlan,
                      *, cache: Optional[Dict] = None
                      ) -> Tuple[jax.Array, Optional[Dict]]:
    xf = x.astype(jnp.float32)
    prev = _token_shift(xf, None if cache is None else cache["x_prev_c"])
    dx = prev - xf
    xk = xf + dx * p["mu_k"]
    xr = xf + dx * p["mu_r"]
    k = jnp.square(jax.nn.relu(xk @ p["wk"]))                  # (B,T,f_loc)
    kv = comm.name_saved(comm.psum(k @ p["wv"], plan.tp_axis))
    rr = jax.nn.sigmoid(xr @ p["wr"])
    out = (rr * kv).astype(x.dtype)
    new_cache = {"x_prev_c": xf[:, -1:]} if cache is not None else None
    return out, new_cache


def init_rwkv_cache(cfg: ModelConfig, batch: int, plan: MeshPlan) -> Dict:
    # GLOBAL shapes; sharded over tp by the cache PartitionSpec rules.
    d = cfg.d_model
    r = cfg.rwkv
    nh = d // r.head_dim
    return {
        "wkv": jnp.zeros((batch, nh, r.head_dim, r.head_dim), jnp.float32),
        "x_prev_t": jnp.zeros((batch, 1, d), jnp.float32),
        "x_prev_c": jnp.zeros((batch, 1, d), jnp.float32),
    }

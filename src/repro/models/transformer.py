"""Model assembly: stages of scanned blocks covering all six arch families.

A model is a list of *stages*; each stage is a homogeneous stack of blocks
whose parameters are stacked on a leading ``(repeats, ...)`` axis and executed
with ``lax.scan`` (keeps HLO size independent of depth — essential for
126-layer dry-runs on 512 devices). Heterogeneous architectures compose
multiple stages:

* dense archs                 -> [dense x L]
* deepseek-v3                 -> [dense x 3, moe x 58] (+ MTP head)
* qwen3-moe                   -> [moe x 48]
* paper SMILE/Switch (MLM)    -> [pair(dense, moe) x L/2]  (every-other-FFN MoE)
* zamba2 (hybrid)             -> [mamba_group x 9] (6 mamba2 + shared attn)
* rwkv6                       -> [rwkv x 24]
* musicgen / phi-3-vision     -> dense stacks + modality input handling
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.common.config import ModelConfig
from repro.core.moe import MoEStats, init_moe_params, moe_layer, zero_stats
from repro.models import layers as L
from repro.models import mamba2 as M2
from repro.models import rwkv6 as RW
from repro.sharding import comm
from repro.sharding.plan import MeshPlan


# =============================================================================
# Stage plan
# =============================================================================

@dataclasses.dataclass(frozen=True)
class Stage:
    kind: str        # dense | moe | pair | mamba_group | rwkv
    repeats: int


def build_stages(cfg: ModelConfig) -> List[Stage]:
    if cfg.arch_type in ("ssm",) and cfg.rwkv is not None:
        return [Stage("rwkv", cfg.num_layers)]
    if cfg.arch_type == "hybrid":
        g = cfg.ssm_layers_per_attn
        assert cfg.num_layers % g == 0
        return [Stage("mamba_group", cfg.num_layers // g)]
    if cfg.moe is not None and cfg.moe.num_experts:
        stages = []
        fd = cfg.moe.first_dense_layers
        if fd:
            stages.append(Stage("dense", fd))
        rest = cfg.num_layers - fd
        if cfg.moe.every_n_layers == 2:
            assert rest % 2 == 0
            stages.append(Stage("pair", rest // 2))
        else:
            stages.append(Stage("moe", rest))
        return stages
    return [Stage("dense", cfg.num_layers)]


def _phys_heads(cfg: ModelConfig, plan: MeshPlan) -> int:
    """Pad query heads up to a tp multiple (e.g. deepseek-coder 56 -> 64)."""
    tp = max(plan.tp, 1)
    return ((cfg.num_heads + tp - 1) // tp) * tp


def _model_cfg(cfg: ModelConfig, plan: MeshPlan) -> ModelConfig:
    h = _phys_heads(cfg, plan)
    if h != cfg.num_heads:
        hd = cfg.resolved_head_dim
        cfg = cfg.replace(num_heads=h, head_dim=hd)
    return cfg


# =============================================================================
# Block init
# =============================================================================

def _init_attn(key, cfg: ModelConfig) -> Dict:
    if cfg.attention == "mla":
        return L.init_mla(key, cfg)
    return L.init_attention(key, cfg)


def init_block(key, cfg: ModelConfig, kind: str, plan: MeshPlan) -> Dict:
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    if kind == "rwkv":
        return {
            "ln1": L._norm_init(d, "layernorm"),
            "tmix": RW.init_rwkv_tmix(ks[0], cfg),
            "ln2": L._norm_init(d, "layernorm"),
            "cmix": RW.init_rwkv_cmix(ks[1], cfg),
        }
    if kind == "mamba":
        return {
            "ln1": L._norm_init(d, cfg.norm),
            "mamba": M2.init_mamba2(ks[0], cfg),
        }
    p = {
        "ln1": L._norm_init(d, cfg.norm),
        "attn": _init_attn(ks[0], cfg),
        "ln2": L._norm_init(d, cfg.norm),
    }
    if kind == "dense":
        p["ffn"] = L.init_ffn(ks[1], cfg)
    elif kind == "moe":
        p["moe"] = init_moe_params(ks[1], cfg.moe, d, plan, glu=cfg.glu)
        if cfg.moe.num_shared_experts:
            p["shared"] = L.init_ffn(
                ks[2], cfg, d_ff=cfg.moe.num_shared_experts * cfg.moe.d_ff_expert)
    return p


# =============================================================================
# Block forward
# =============================================================================

def _attn_fwd(p, x, cfg, plan, positions, cache, window, use_kernel=False):
    if cfg.attention == "mla":
        return L.mla_forward(p, x, cfg, plan, positions=positions,
                             cache=cache, window=window)
    return L.attention_forward(p, x, cfg, plan, positions=positions,
                               cache=cache, window=window,
                               use_kernel=use_kernel)


def _zero_stats() -> MoEStats:
    return zero_stats()


def _add_stats(a: MoEStats, b: MoEStats) -> MoEStats:
    # losses/drops/fault events sum across layers; the watchdog fields keep
    # the worst layer (max load fraction, min load entropy) — a single
    # collapsed layer must not be averaged away by healthy siblings
    return MoEStats(a.lb_loss + b.lb_loss, a.z_loss + b.z_loss,
                    a.drop_frac + b.drop_frac,
                    a.hop_drop_frac + b.hop_drop_frac,
                    a.fault_events + b.fault_events,
                    jnp.maximum(a.hop_max_load, b.hop_max_load),
                    jnp.minimum(a.hop_load_entropy, b.hop_load_entropy),
                    a.wire_faults + b.wire_faults)


def dense_block(p, x, cfg, plan, positions, cache, *, use_kernel=False,
                token_valid=None):
    window = cfg.window if cfg.attention == "sliding" else 0
    h, cache = _attn_fwd(p["attn"], L.apply_norm(p["ln1"], x, cfg.norm), cfg,
                         plan, positions, cache, window, use_kernel)
    x = x + h
    h = L.ffn_forward(p["ffn"], L.apply_norm(p["ln2"], x, cfg.norm), cfg, plan)
    x = x + h
    return x, _zero_stats(), cache


def moe_block(p, x, cfg, plan, positions, cache, *, use_kernel=False,
              token_valid=None):
    window = cfg.window if cfg.attention == "sliding" else 0
    h, cache = _attn_fwd(p["attn"], L.apply_norm(p["ln1"], x, cfg.norm), cfg,
                         plan, positions, cache, window, use_kernel)
    x = x + h
    hn = L.apply_norm(p["ln2"], x, cfg.norm)
    B, T, d = hn.shape
    flat = hn.reshape(B * T, d)
    loc, _ = comm.split_tokens(flat, plan.tp_axis, max(plan.tp, 1))
    # decode-tick validity rides the same token split as the activations,
    # so each shard masks exactly its own rows (split padding lands False)
    valid_loc = None
    if token_valid is not None:
        valid_loc, _ = comm.split_tokens(token_valid.reshape(B * T),
                                         plan.tp_axis, max(plan.tp, 1))
    y_loc, stats = moe_layer(p["moe"], loc, cfg.moe, plan, act=cfg.act,
                             use_kernel=use_kernel, token_valid=valid_loc)
    if "shared" in p:
        # shared ("always-on") expert computed on the token-split shard with
        # REPLICATED weights: same FLOPs/device as the tensor-parallel
        # formulation (tokens/tp x full d_ff vs tokens x d_ff/tp) but ZERO
        # collectives — removes one psum per MoE layer (EXPERIMENTS §Perf-2c).
        ps = p["shared"]
        actf = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
        hh = actf(loc @ ps["w1"].astype(loc.dtype))
        if "w3" in ps:
            hh = hh * (loc @ ps["w3"].astype(loc.dtype))
        y_loc = y_loc + hh @ ps["w2"].astype(loc.dtype)
    y = comm.name_saved(
        comm.unsplit_tokens(y_loc, plan.tp_axis, B * T)).reshape(B, T, d)
    x = x + y
    return x, stats, cache


def rwkv_block(p, x, cfg, plan, positions, cache, *, use_kernel=False,
               token_valid=None):
    c_t = None if cache is None else cache
    h, c1 = RW.rwkv_tmix_forward(p["tmix"],
                                 L.apply_norm(p["ln1"], x, "layernorm"),
                                 cfg, plan, cache=c_t, use_kernel=use_kernel)
    x = x + h
    h, c2 = RW.rwkv_cmix_forward(p["cmix"],
                                 L.apply_norm(p["ln2"], x, "layernorm"),
                                 cfg, plan, cache=c_t)
    x = x + h
    cache = None if cache is None else {**c1, **c2}
    return x, _zero_stats(), cache


def mamba_block(p, x, cfg, plan, positions, cache, *, use_kernel=False,
                token_valid=None):
    h, cache = M2.mamba2_forward(p["mamba"],
                                 L.apply_norm(p["ln1"], x, cfg.norm),
                                 cfg, plan, cache=cache)
    return x + h, _zero_stats(), cache


BLOCK_FNS = {"dense": dense_block, "moe": moe_block, "rwkv": rwkv_block,
             "mamba": mamba_block}


# =============================================================================
# Stage init / forward (scan over stacked block params)
# =============================================================================

def init_stage(key, cfg: ModelConfig, stage: Stage, plan: MeshPlan) -> Dict:
    R = stage.repeats
    keys = jax.random.split(key, R + 2)
    if stage.kind == "pair":
        dense = jax.vmap(lambda k: init_block(k, cfg, "dense", plan))(keys[:R])
        moe = jax.vmap(lambda k: init_block(k, cfg, "moe", plan))(
            jax.random.split(keys[R], R))
        return {"dense": dense, "moe": moe}
    if stage.kind == "mamba_group":
        g = cfg.ssm_layers_per_attn
        def group_init(k):
            kk = jax.random.split(k, g)
            return jax.vmap(lambda kx: init_block(kx, cfg, "mamba", plan))(kk)
        blocks = jax.vmap(group_init)(keys[:R])               # (R, g, ...)
        shared = init_block(keys[R], cfg, "dense", plan)      # shared attn+ffn
        return {"mamba": blocks, "shared_attn": shared}
    blocks = jax.vmap(lambda k: init_block(k, cfg, stage.kind, plan))(keys[:R])
    return {"blocks": blocks}


def stage_forward(params: Dict, x, cfg: ModelConfig, stage: Stage,
                  plan: MeshPlan, positions, caches, *, remat: bool,
                  use_kernel: bool = False, token_valid=None):
    """Scan the stage's blocks over the stacked leading axis."""

    def run(kind, p_stacked, x, caches):
        fn = BLOCK_FNS[kind]

        def body(carry, inp):
            x, acc = carry
            p, cache = inp
            y, stats, cache = fn(p, x, cfg, plan, positions, cache,
                                 use_kernel=use_kernel,
                                 token_valid=token_valid)
            return (y, _add_stats(acc, stats)), cache

        if remat:
            policy = (comm.save_collectives_policy()
                      if cfg.remat_save_collectives else None)
            body = jax.checkpoint(body, policy=policy)
        (x, acc), new_caches = lax.scan(body, (x, _zero_stats()),
                                        (p_stacked, caches))
        return x, acc, new_caches

    if stage.kind == "pair":
        x, s1, c1 = run("dense", params["dense"], x,
                        None if caches is None else caches["dense"])
        x, s2, c2 = run("moe", params["moe"], x,
                        None if caches is None else caches["moe"])
        cc = None if caches is None else {"dense": c1, "moe": c2}
        return x, _add_stats(s1, s2), cc

    if stage.kind == "mamba_group":
        shared = params["shared_attn"]

        def body(carry, inp):
            x, acc = carry
            p_group, cache = inp
            # inner: g mamba blocks
            def inner(c2, inp2):
                xx, acc2 = c2
                pb, cb = inp2
                y, st, cb = mamba_block(pb, xx, cfg, plan, positions, cb)
                return (y, _add_stats(acc2, st)), cb
            (x, acc), mcache = lax.scan(
                inner, (x, acc),
                (p_group, None if cache is None else cache["mamba"]))
            # shared attention block (same params every group)
            x, st, acache = dense_block(shared, x, cfg, plan, positions,
                                        None if cache is None else cache["attn"])
            acc = _add_stats(acc, st)
            return (x, acc), (None if cache is None
                              else {"mamba": mcache, "attn": acache})

        if remat:
            policy = (comm.save_collectives_policy()
                      if cfg.remat_save_collectives else None)
            body = jax.checkpoint(body, policy=policy)
        (x, acc), new_caches = lax.scan(body, (x, _zero_stats()),
                                        (params["mamba"], caches))
        return x, acc, new_caches

    return run(stage.kind, params["blocks"], x, caches)


# =============================================================================
# Whole model
# =============================================================================

def init_model(key: jax.Array, cfg0: ModelConfig, plan: MeshPlan) -> Dict:
    cfg = _model_cfg(cfg0, plan)
    stages = build_stages(cfg)
    keys = jax.random.split(key, len(stages) + 6)
    params: Dict[str, Any] = {}
    if cfg.num_codebooks > 1:
        params["embed"] = {"table": L.dense_init(
            keys[-1], (cfg.num_codebooks, cfg.vocab_size, cfg.d_model),
            scale=0.02)}
        params["heads"] = {"w": L.dense_init(
            keys[-2], (cfg.num_codebooks, cfg.vocab_size, cfg.d_model),
            scale=0.02)}
    else:
        params["embed"] = L.init_embedding(keys[-1], cfg, plan)
        if not cfg.tie_embeddings:
            params["lm_head"] = {"w": L.dense_init(
                keys[-2], (cfg.vocab_size, cfg.d_model), scale=0.02)}
    if cfg.vision_tokens:
        params["vision_proj"] = {
            "w": L.dense_init(keys[-3], (cfg.vision_embed_dim, cfg.d_model))}
    params["stages"] = tuple(
        init_stage(k, cfg, st, plan) for k, st in zip(keys, stages))
    params["final_norm"] = L._norm_init(cfg.d_model, cfg.norm)
    if cfg.mtp_depth:
        params["mtp"] = {
            "proj": L.dense_init(keys[-4], (2 * cfg.d_model, cfg.d_model)),
            "block": init_block(keys[-5], cfg, "dense", plan),
            "norm_h": L._norm_init(cfg.d_model, cfg.norm),
            "norm_e": L._norm_init(cfg.d_model, cfg.norm),
        }
    return params


def embed_inputs(params: Dict, tokens: jax.Array, cfg: ModelConfig,
                 plan: MeshPlan, extra: Optional[Dict] = None,
                 dtype=jnp.bfloat16) -> jax.Array:
    """Token (and modality) embedding. musicgen: tokens (B, K, S) summed over
    codebooks; phi-3-vision: image patch embeddings merged at given positions."""
    if cfg.num_codebooks > 1:
        table = params["embed"]["table"]                 # (K, V_loc, d) sharded
        v_loc = table.shape[1]
        start = comm.axis_index(plan.tp_axis) * v_loc
        local = tokens - start                           # (B, K, S)
        hit = (local >= 0) & (local < v_loc)
        emb = jax.vmap(lambda tab, ids: jnp.take(tab, ids, axis=0),
                       in_axes=(0, 1), out_axes=1)(
            table, jnp.clip(local, 0, v_loc - 1))        # (B, K, S, d)
        emb = emb * hit[..., None].astype(table.dtype)
        x = comm.psum(emb.sum(axis=1), plan.tp_axis).astype(dtype)
        return x
    x = L.embed_tokens(params["embed"], tokens, plan, dtype)
    if cfg.vision_tokens and extra is not None and "image_embeds" in extra:
        proj = jnp.einsum("bpe,ed->bpd", extra["image_embeds"].astype(dtype),
                          params["vision_proj"]["w"].astype(dtype))
        pos = extra["image_pos"]                          # (B, P) int32
        x = jax.vmap(lambda xb, pb, vb: xb.at[pb].set(vb))(x, pos, proj)
    return x


def model_logits(params: Dict, x: jax.Array, cfg: ModelConfig,
                 plan: MeshPlan) -> jax.Array:
    """Vocab-sharded fp32 logits. musicgen: (B, T, K, V_loc)."""
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    if cfg.num_codebooks > 1:
        return jnp.einsum("btd,kvd->btkv", x.astype(jnp.float32),
                          params["heads"]["w"].astype(jnp.float32))
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return L.output_logits(head, x, plan)


def forward(params: Dict, tokens: jax.Array, cfg0: ModelConfig,
            plan: MeshPlan, *, positions: jax.Array,
            caches: Optional[Tuple] = None, extra: Optional[Dict] = None,
            remat: bool = False, use_kernel: bool = False,
            token_valid: Optional[jax.Array] = None):
    """Full forward. Returns (hidden (B,T,d), logits, MoEStats, new_caches).

    ``token_valid`` (B, T) bool, optional: live-token mask for decode-shaped
    calls (continuous batching, bucketed prefill tails).  Only the MoE blocks
    consume it — invalid tokens route nowhere and are excluded from the
    router losses; attention over dead rows is masked by the caller via
    negative ``positions`` (see ``serve/engine.py``)."""
    cfg = _model_cfg(cfg0, plan)
    stages = build_stages(cfg)
    x = embed_inputs(params, tokens, cfg, plan, extra)
    acc = _zero_stats()
    new_caches = []
    for i, st in enumerate(stages):
        c = None if caches is None else caches[i]
        x, stats, c = stage_forward(params["stages"][i], x, cfg, st, plan,
                                    positions, c, remat=remat,
                                    use_kernel=use_kernel,
                                    token_valid=token_valid)
        acc = _add_stats(acc, stats)
        new_caches.append(c)
    logits = model_logits(params, x, cfg, plan)
    return x, logits, acc, (None if caches is None else tuple(new_caches))


def mtp_logits(params: Dict, hidden: jax.Array, next_tokens: jax.Array,
               cfg0: ModelConfig, plan: MeshPlan,
               positions: jax.Array) -> jax.Array:
    """DeepSeek-V3 multi-token prediction head (depth 1): predict t+2 from
    (h_t, Emb(t+1)). Returns vocab-sharded logits."""
    cfg = _model_cfg(cfg0, plan)
    p = params["mtp"]
    e = L.embed_tokens(params["embed"], next_tokens, plan, hidden.dtype)
    h = jnp.concatenate([L.apply_norm(p["norm_h"], hidden, cfg.norm),
                         L.apply_norm(p["norm_e"], e, cfg.norm)], axis=-1)
    h = jnp.einsum("btd,dk->btk", h, p["proj"].astype(h.dtype))
    h, _, _ = dense_block(p["block"], h, cfg, plan, positions, None)
    return model_logits(params, h, cfg, plan)


# =============================================================================
# Caches
# =============================================================================

def init_caches(cfg0: ModelConfig, batch: int, length: int, plan: MeshPlan):
    """Per-stage stacked decode caches sized ``length`` (window for sliding)."""
    cfg = _model_cfg(cfg0, plan)
    stages = build_stages(cfg)
    if cfg.attention == "sliding":
        length = min(length, cfg.window)

    def attn_cache():
        if cfg.attention == "mla":
            return L.init_mla_cache(cfg, batch, length, plan)
        return L.init_attention_cache(cfg, batch, length, plan)

    def stack(tree, n):
        return jax.tree.map(lambda x: jnp.broadcast_to(x, (n,) + x.shape), tree)

    out = []
    for st in stages:
        if st.kind == "rwkv":
            out.append(stack(RW.init_rwkv_cache(cfg, batch, plan), st.repeats))
        elif st.kind == "mamba_group":
            g = cfg.ssm_layers_per_attn
            out.append(stack({"mamba": stack(M2.init_mamba2_cache(cfg, batch, plan), g),
                              "attn": attn_cache()}, st.repeats))
        elif st.kind == "pair":
            out.append({"dense": stack(attn_cache(), st.repeats),
                        "moe": stack(attn_cache(), st.repeats)})
        else:
            out.append(stack(attn_cache(), st.repeats))
    return tuple(out)

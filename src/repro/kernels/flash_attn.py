"""Pallas TPU kernel: causal flash attention (streaming softmax).

Backbone attention hot spot for the dense/train paths. Grid
``(B, H, Tq/bq)``; each step owns a ``(bq, hd)`` query tile and loops over KV
tiles up to the causal frontier with an online-softmax accumulator held in
VMEM. ``bq = bk = 128`` aligns both MXU contractions ((bq,hd)x(hd,bk) and
(bq,bk)x(bk,hd)); the working set per step is
``bq*hd + 2*bk*hd + bq*bk + bq*hd`` ~ 0.6 MB at hd=128 — far under VMEM,
leaving room for the compiler to double-buffer the KV stream from HBM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(q_ref, k_ref, v_ref, o_ref, *, bq: int, bk: int, scale: float):
    qi = pl.program_id(2)
    q = q_ref[0, 0] * scale                         # (bq, hd)
    T = k_ref.shape[1]
    hd = q.shape[-1]

    m = jnp.full((bq,), -1e30, jnp.float32)
    l = jnp.zeros((bq,), jnp.float32)
    acc = jnp.zeros((bq, hd), jnp.float32)
    q_pos = qi * bq + jax.lax.iota(jnp.int32, bq)

    def body(j, carry):
        m, l, acc = carry
        k = k_ref[0, 0, pl.dslice(j * bk, bk), :]               # (bk, hd)
        v = v_ref[0, 0, pl.dslice(j * bk, bk), :]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)      # (bq, bk)
        k_pos = j * bk + jax.lax.iota(jnp.int32, bk)
        s = jnp.where(q_pos[:, None] >= k_pos[None, :], s, -1e30)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[:, None] + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    # causal: only KV tiles at or before this query tile
    n_kv = qi + 1
    m, l, acc = jax.lax.fori_loop(0, n_kv, body, (m, l, acc))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                           *, block_q: int = 128, block_k: int = 128,
                           interpret: bool = False) -> jax.Array:
    """Causal attention. q/k/v: (B, T, H, hd) (same H — GQA pre-expanded).

    Returns (B, T, H, hd).
    """
    B, T, H, hd = q.shape
    bq = min(block_q, T)
    bk = min(block_k, T)
    assert T % bq == 0 and T % bk == 0, (T, bq, bk)
    scale = 1.0 / math.sqrt(hd)
    # layout: (B, H, T, hd) so the head is a grid dim
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    grid = (B, H, T // bq)

    out = pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, scale=scale),
        out_shape=jax.ShapeDtypeStruct((B, H, T, hd), q.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, T, hd), lambda b, h, i: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, T, hd), lambda b, h, i: (b, h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i: (b, h, i, 0)),
        # online-softmax state lives in kernel-local accumulators within one
        # grid step; no output or scratch crosses grid steps
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel")),
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)

"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def grouped_ffn_ref(x, w1, w3, w2, *, act: str = "gelu"):
    """x: (G, T, d); w1/w3: (G, d, f); w2: (G, f, d)."""
    actf = jax.nn.silu if act == "silu" else jax.nn.gelu
    h = jnp.einsum("gtd,gdf->gtf", x.astype(jnp.float32),
                   w1.astype(jnp.float32))
    h = actf(h)
    if w3 is not None:
        h = h * jnp.einsum("gtd,gdf->gtf", x.astype(jnp.float32),
                           w3.astype(jnp.float32))
    y = jnp.einsum("gtf,gfd->gtd", h.astype(x.dtype).astype(jnp.float32),
                   w2.astype(jnp.float32))
    return y.astype(x.dtype)


def grouped_ffn_ragged_ref(rows, group_starts, w1, w3, w2, *, act: str = "gelu"):
    """Ragged grouped FFN oracle over the tile-aligned dropless layout.

    rows: (R, d) flat row array sorted by group (alignment padding rows are
    zero); group_starts: (G+1,) aligned segment offsets; w1/w3: (G, d, f);
    w2: (G, f, d).  Each row is pushed through its own group's expert via a
    per-row weight gather — O(R * d * f) memory, clarity over speed.
    """
    R = rows.shape[0]
    gid = jnp.searchsorted(group_starts,
                           jnp.arange(R, dtype=jnp.int32), side="right") - 1
    gid = jnp.clip(gid, 0, w1.shape[0] - 1)
    actf = jax.nn.silu if act == "silu" else jax.nn.gelu
    xf = rows.astype(jnp.float32)
    h = actf(jnp.einsum("rd,rdf->rf", xf, jnp.take(w1, gid, axis=0)
                        .astype(jnp.float32)))
    if w3 is not None:
        h = h * jnp.einsum("rd,rdf->rf", xf, jnp.take(w3, gid, axis=0)
                           .astype(jnp.float32))
    y = jnp.einsum("rf,rfd->rd", h.astype(rows.dtype).astype(jnp.float32),
                   jnp.take(w2, gid, axis=0).astype(jnp.float32))
    return y.astype(rows.dtype)


def group_sort_ref(keys, num_keys):
    """Stable small-domain key sort: the argsort oracle of
    :func:`repro.kernels.radix_sort.group_sort_pallas`.

    ``keys``: (A,) int32 in ``[0, num_keys)``.  Returns ``(ranks, starts)``
    — each element's stable sorted position and the (num_keys + 1,)
    exclusive prefix counts (``starts[d]`` = #keys < d) — bit-identical to
    the Pallas counting-sort kernel (a stable sort of integers is unique).

    Fast path: (key, arrival-index) packed into one int32 so position
    assignment needs a single-operand ``lax.sort`` instead of the stable
    variadic argsort (~4x faster on CPU); packing order-preserves within
    each key by construction.  Falls back to ``jnp.argsort(stable=True)``
    when the packing would overflow int32.
    """
    if num_keys < 1:
        raise ValueError(f"num_keys must be >= 1, got {num_keys}")
    A = keys.shape[0]
    if A == 0:
        return (jnp.zeros((0,), jnp.int32),
                jnp.zeros((num_keys + 1,), jnp.int32))
    k32 = keys.astype(jnp.int32)
    idx = jnp.arange(A, dtype=jnp.int32)
    if num_keys * A < 2**31:
        sp = jax.lax.sort(k32 * A + idx)
        order = (sp % A).astype(jnp.int32)
        skeys = (sp // A).astype(jnp.int32)
    else:                                       # int32 packing would overflow
        order = jnp.argsort(k32, stable=True).astype(jnp.int32)
        skeys = jnp.take(k32, order)
    starts = jnp.searchsorted(
        skeys, jnp.arange(num_keys + 1, dtype=jnp.int32)).astype(jnp.int32)
    ranks = jnp.zeros((A,), jnp.int32).at[order].set(idx)
    return ranks, starts


def router_fused_ref(x, w, k, *, renorm=False):
    """Pure-jnp oracle of :func:`repro.kernels.router_fused
    .router_fused_pallas` — the fused routing prologue.

    ``x``: (t, d) tokens; ``w``: (d, E) router weights.  Returns
    ``(gates (t,k), idx (t,k), probs (t,E), logits (t,E), ranks (t*k,),
    starts (E+1,))``, each stage mirroring the unfused path bit for bit:
    fp32 einsum + ``jax.nn.softmax`` (== ``core.moe.router_probs``),
    ``k`` max-extraction rounds with the EXPLICIT lowest-expert-index
    tie-break ``lax.top_k`` guarantees (pinned here and in the kernel so
    the impls can never silently disagree on tied logits), optional gate
    renormalization (== ``core.moe.topk_gates``), and the counting-sort
    position contract over the chosen ids (== :func:`group_sort_ref`).
    """
    E = w.shape[1]
    if not 1 <= k <= E:
        raise ValueError(f"top-k {k} must be in [1, num_experts {E}]")
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32),
                        w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    lane = jnp.arange(E, dtype=jnp.int32)[None, :]
    work = probs
    gsel, isel = [], []
    for _ in range(k):
        g = jnp.max(work, axis=-1, keepdims=True)
        sel = jnp.min(jnp.where(work == g, lane, E), axis=-1, keepdims=True)
        gsel.append(g)
        isel.append(sel)
        work = jnp.where(lane == sel, -jnp.inf, work)
    gates = jnp.concatenate(gsel, axis=1)
    idx = jnp.concatenate(isel, axis=1)
    if renorm and k > 1:
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    ranks, starts = group_sort_ref(idx.reshape(-1), E)
    return gates, idx, probs, logits, ranks, starts


def dispatch_gather_ref(x, src):
    """MoE dispatch gather. x: (T, d); src: (R,) int32 source row per
    buffer slot, -1 = empty slot -> zeros. Returns (R, d)."""
    rows = jnp.take(x, jnp.maximum(src, 0), axis=0)
    return rows * (src >= 0)[:, None].astype(x.dtype)


def combine_gather_ref(rows, src, scale):
    """MoE combine gather-reduce. rows: (R, d) flat capacity buffer;
    src/scale: (t, k) buffer row per assignment (-1 = dropped) and gate
    weight. Returns (t, d) = sum_k scale * rows[src]."""
    t, k = src.shape
    got = jnp.take(rows, jnp.maximum(src, 0).reshape(-1), axis=0)  # (t*k, d)
    w = jnp.where(src >= 0, scale, 0).reshape(-1, 1).astype(rows.dtype)
    return (got * w).reshape(t, k, -1).sum(axis=1)


def flash_attention_ref(q, k, v):
    """Causal softmax attention. q/k/v: (B, T, H, hd)."""
    B, T, H, hd = q.shape
    s = jnp.einsum("bthk,bshk->bhts", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    mask = jnp.tril(jnp.ones((T, T), bool))
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhts,bshk->bthk", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def rwkv6_scan_ref(r, k, v, w, u, s0):
    """Sequential WKV6. r/k/v/w: (B,T,nh,hd); u: (nh,hd); s0: (B,nh,hd,hd)."""
    rf, kf, vf, wf = (a.astype(jnp.float32) for a in (r, k, v, w))

    def step(s, inp):
        rt, kt, vt, wt = inp                             # (B, nh, hd)
        kv = kt[..., :, None] * vt[..., None, :]
        y = jnp.einsum("bhi,bhij->bhj", rt,
                       s + u.astype(jnp.float32)[None, :, :, None] * kv)
        return wt[..., :, None] * s + kv, y

    s_last, ys = jax.lax.scan(
        step, s0.astype(jnp.float32),
        tuple(a.transpose(1, 0, 2, 3) for a in (rf, kf, vf, wf)))
    return ys.transpose(1, 0, 2, 3), s_last


def ssd_chunk_ref(xh, dt, loga, Bc, Cc):
    """Intra-chunk SSD terms (mirrors models/mamba2.py chunked math).

    xh: (B,nc,Q,nh,hd); dt/loga: (B,nc,Q,nh); Bc/Cc: (B,nc,Q,ds)."""
    xq = xh.astype(jnp.float32)
    dq = dt.astype(jnp.float32)
    lq = loga.astype(jnp.float32)
    Bq = Bc.astype(jnp.float32)
    Cq = Cc.astype(jnp.float32)
    Q = xq.shape[2]
    cs = jnp.cumsum(lq, axis=2)
    scores = jnp.einsum("bcin,bcjn->bcij", Cq, Bq)
    decay = cs[:, :, :, None, :] - cs[:, :, None, :, :]
    mask = (jnp.arange(Q)[:, None] >= jnp.arange(Q)[None, :])
    decay = jnp.where(mask[None, None, :, :, None], decay, -jnp.inf)
    w_ij = jnp.exp(decay) * scores[..., None]
    y_intra = jnp.einsum("bcijh,bcjh,bcjhp->bcihp", w_ij, dq, xq)
    tail = cs[:, :, -1:, :] - cs
    sB = jnp.einsum("bcjh,bcjh,bcjhp,bcjn->bchpn",
                    jnp.exp(tail), dq, xq, Bq)
    a_chunk = jnp.exp(cs[:, :, -1, :])
    return y_intra, sB, a_chunk

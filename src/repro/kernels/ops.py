"""Jitted public wrappers for the Pallas kernels.

On CPU (this offline container) every kernel runs in ``interpret=True`` mode
— the kernel body executes exactly as written, validating the Pallas code
against the :mod:`repro.kernels.ref` oracles; on TPU the same calls compile
to Mosaic.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attn import flash_attention_pallas
from repro.kernels.grouped_ffn import (grouped_ffn_pallas,
                                       grouped_ffn_ragged_pallas)
from repro.kernels.moe_dispatch import (combine_gather_pallas,
                                        dispatch_gather_pallas)
from repro.kernels.radix_sort import group_sort_pallas
from repro.kernels.router_fused import router_fused_pallas
from repro.kernels.rwkv6_scan import rwkv6_scan_pallas
from repro.kernels.ssd_chunk import ssd_chunk_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# the two stable group-sort implementations behind MoEConfig.sort_impl
SORT_IMPLS = ("radix", "argsort")
# below this many rows the O(A log A) vs O(A) gap is noise and the
# kernel-launch (or CPU interpret) overhead dominates: route to the
# argsort oracle, exactly as the other wrappers route tiny shapes to ref.
# Module-level so tests can force the kernel on small inputs.
RADIX_MIN_ROWS = 1024


def group_sort(keys, num_keys: int, *, impl: str = "argsort"):
    """Stable sort of small-domain int32 keys — the primitive under every
    dispatch hop's group sort.  Returns ``(ranks, starts)``: each element's
    stable sorted position, and the (num_keys + 1,) exclusive prefix counts
    (``starts[d]`` = #keys < d; ``starts[num_keys]`` = A).

    ``impl="radix"`` runs the one-pass Pallas counting sort
    (:mod:`repro.kernels.radix_sort`; interpret mode off-TPU) for inputs of
    at least ``RADIX_MIN_ROWS`` rows; ``"argsort"`` — and every small input
    — runs the packed single-operand ``lax.sort`` oracle.  Both are exact
    stable integer sorts, so the outputs are bit-identical.
    """
    if impl not in SORT_IMPLS:
        raise ValueError(f"unknown sort_impl {impl!r}; "
                         f"expected one of {SORT_IMPLS}")
    if impl == "radix" and keys.shape[0] >= RADIX_MIN_ROWS:
        return group_sort_pallas(keys, num_keys, interpret=_interpret())
    return ref.group_sort_ref(keys, num_keys)


# the two routing-stage implementations behind MoEConfig.router_impl
ROUTER_IMPLS = ("unfused", "fused")
# below this many tokens the kernel-launch (or CPU interpret) overhead
# dominates the fused win: route to the pure-jnp oracle, exactly as
# group_sort routes tiny inputs to argsort.  Module-level so tests can
# force the kernel on small inputs.
ROUTER_FUSED_MIN_ROWS = 1024
# degenerate expert counts stay on the oracle regardless of token count:
# at E <= 2 the padded kernel GEMM and the unfused mat-vec associate the
# contraction differently (1-ulp logit drift — measured, see
# tests/test_router_fused.py), which would silently break the documented
# bit-compatibility contract (e.g. SMILE inter-node routing on a 2-node
# mesh clears ROUTER_FUSED_MIN_ROWS easily).
ROUTER_FUSED_MIN_EXPERTS = 3


try:        # jax 0.4.x: public stop_gradient passes integer arrays through
    from jax._src.ad_util import stop_gradient_p as _stop_gradient_p

    def _stop_int_grads(x):
        return _stop_gradient_p.bind(x)
except ImportError:      # pragma: no cover - newer jax covers all dtypes
    _stop_int_grads = jax.lax.stop_gradient


def _router_fused_impl(x, w, k, renorm):
    if (x.shape[0] >= ROUTER_FUSED_MIN_ROWS
            and w.shape[1] >= ROUTER_FUSED_MIN_EXPERTS):
        return router_fused_pallas(x, w, k, renorm=renorm,
                                   interpret=_interpret())
    return ref.router_fused_ref(x, w, k, renorm=renorm)


@partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _router_fused(x, w, k, renorm):
    return _router_fused_impl(x, w, k, renorm)


def _router_fused_fwd(x, w, k, renorm):
    return _router_fused_impl(x, w, k, renorm), (x, w)


def _router_fused_bwd(k, renorm, res, cts):
    # Backward = the VJP of the pure-jnp oracle, which is bit-identical to
    # the kernel forward, so the gradients are exact; the integer outputs
    # (ids, ranks, starts) carry no cotangents.  This also keeps autodiff
    # out of the Pallas body — the histogram/top-k kernel is a forward-only
    # fusion, like the unfused chain's sort it replaces.
    x, w = res
    ct_gates, _ct_idx, ct_probs, ct_logits, _ct_ranks, _ct_starts = cts

    def _float_outs(xx, ww):
        gates, _i, probs, logits, _r, _s = ref.router_fused_ref(
            xx, ww, k, renorm=renorm)
        return gates, probs, logits

    _, vjp = jax.vjp(_float_outs, x, w)
    return vjp((ct_gates, ct_probs, ct_logits))


_router_fused.defvjp(_router_fused_fwd, _router_fused_bwd)


def router_fused(x, w, k, *, renorm: bool = False):
    """Fused routing prologue — router GEMM, softmax, top-k, histogram and
    dispatch positions in one pass (:mod:`repro.kernels.router_fused`;
    interpret mode off-TPU) for inputs of at least ``ROUTER_FUSED_MIN_ROWS``
    tokens and ``ROUTER_FUSED_MIN_EXPERTS`` experts; smaller inputs — and
    degenerate E <= 2 routers, where the padded kernel GEMM drifts 1 ulp
    from the unfused mat-vec — run the bit-identical pure-jnp oracle.  Under
    autodiff the backward pass is the oracle chain's VJP (custom_vjp), so
    the router-weight gradient is exact on both routes.

    Returns ``(gates (t,k), idx (t,k), probs (t,E), logits (t,E),
    ranks (t*k,), starts (E+1,))`` — the loss inputs bit-compatible with
    the unfused ``router_probs``/``topk_gates`` chain, the positions with
    ``group_sort`` over the chosen ids (per-expert counts are
    ``starts[1:] - starts[:-1]``).
    """
    E = w.shape[-1]
    if not 1 <= k <= E:
        raise ValueError(f"top-k k={k} out of range for {E} experts")
    gates, idx, probs, logits, ranks, starts = _router_fused(
        x, w, int(k), bool(renorm))
    # The integer outputs are routing decisions, not differentiable values.
    # Under remat, custom_vjp instantiates their tangents as concrete float0
    # arrays, which blow up in any downstream multiply (e.g. the combine
    # path's group_ids * cap); jax.lax.stop_gradient is a no-op on integer
    # dtypes, so bind the underlying primitive to restore symbolic-zero
    # tangents — matching what the unfused chain's sort outputs carry.
    return (gates, _stop_int_grads(idx), probs, logits,
            _stop_int_grads(ranks), _stop_int_grads(starts))


def grouped_ffn(x, w1, w3, w2, *, act: str = "gelu"):
    """Grouped expert FFN; falls back to the jnp oracle for tiny shapes
    (interpret-mode overhead dominates below one MXU tile)."""
    G, T, d = x.shape
    if T < 16 or d % 8:
        return ref.grouped_ffn_ref(x, w1, w3, w2, act=act)
    return grouped_ffn_pallas(x, w1.astype(x.dtype),
                              None if w3 is None else w3.astype(x.dtype),
                              w2.astype(x.dtype), act=act,
                              interpret=_interpret())


def grouped_ffn_ragged(rows, group_starts, w1, w3, w2, *, block: int,
                       act: str = "gelu"):
    """Ragged grouped FFN over the dropless tile-aligned layout.
    rows: (R, d) with R a multiple of ``block``; group_starts: (G+1,)
    aligned segment offsets.  Falls back to the jnp oracle for
    tiny/misaligned shapes."""
    from repro.core.dispatch import ragged_tile_gids
    R, d = rows.shape
    if R == 0 or R < 16 or d % 8 or block < 8:
        return ref.grouped_ffn_ragged_ref(rows, group_starts, w1, w3, w2,
                                          act=act)
    tile_gid = ragged_tile_gids(group_starts, R // block, block)
    return grouped_ffn_ragged_pallas(rows, tile_gid, w1.astype(rows.dtype),
                                     None if w3 is None else w3.astype(rows.dtype),
                                     w2.astype(rows.dtype), act=act,
                                     interpret=_interpret())


def dispatch_gather(x, src):
    """MoE dispatch: gather token rows into the flat capacity buffer.
    Falls back to the jnp oracle for tiny shapes (interpret-mode / grid
    overhead dominates below a few VPU rows)."""
    T, d = x.shape
    R = src.shape[0]
    if T == 0:
        return jnp.zeros((R, d), x.dtype)
    if R < 16 or d % 8:
        return ref.dispatch_gather_ref(x, src)
    return dispatch_gather_pallas(x, src.astype(jnp.int32),
                                  interpret=_interpret())


def combine_gather(rows, src, scale):
    """MoE combine: gate-weighted gather-reduce of expert outputs back to
    token order. rows: (R, d); src/scale: (t, k)."""
    t, k = src.shape
    d = rows.shape[-1]
    if rows.shape[0] == 0 or t == 0:
        return jnp.zeros((t, d), rows.dtype)
    if t < 16 or d % 8:
        return ref.combine_gather_ref(rows, src, scale)
    return combine_gather_pallas(rows, src.astype(jnp.int32),
                                 scale.astype(jnp.float32),
                                 interpret=_interpret())


def flash_attention(q, k, v):
    """Causal attention with GQA expansion. q: (B,T,H,hd); k/v: (B,T,KV,hd)."""
    H, KV = q.shape[2], k.shape[2]
    if KV != H:
        rep = H // KV
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    return flash_attention_pallas(q, k, v, interpret=_interpret())


def rwkv6_scan(r, k, v, w, u, s0):
    return rwkv6_scan_pallas(r, k, v, w, u, s0, interpret=_interpret())


def ssd_chunk(xh, dt, loga, Bc, Cc):
    """Mamba2 SSD intra-chunk terms (see kernels/ssd_chunk.py)."""
    return ssd_chunk_pallas(xh, dt, loga, Bc, Cc, interpret=_interpret())

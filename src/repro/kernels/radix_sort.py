"""Pallas TPU kernel: one-pass counting (radix) sort over small-domain keys.

Every dispatch hop in :mod:`repro.core.dispatch` — the sort backend's
position assignment, the dropless sender layout, and the ragged receiver
re-compaction — reduces to ONE primitive: a *stable* sort of ``A`` int32
group ids drawn from a tiny domain (``num_groups`` experts, or
``ranks x groups_per_rank`` after rank-major relabeling; never more than a
few hundred values).  ``jnp.argsort``/``lax.sort`` lowers that to XLA's
generic comparison sort — O(A log A) compare-and-swap passes that know
nothing about the key domain.  A counting sort is O(A + E): histogram the
keys, exclusive-prefix-sum the histogram, and hand each element
``starts[key] + (#earlier equal keys)`` — its final sorted position,
stability for free because "earlier" is arrival order.

:func:`group_sort_pallas` does this in **one pass over the data**.  The TPU
grid is sequential, so a VMEM scratch accumulator can carry the running
per-key histogram across row tiles:

* tile ``i`` compares its ``bt`` keys against the domain iota
  (``(bt, D)`` one-hot, the TPU-native form of a histogram — no scatter
  hardware needed);
* the *within-tile* exclusive equal-key count is a pairwise compare of the
  tile's keys against themselves under a strictly-lower-triangular mask —
  O(bt) VPU ops per element, no domain factor, no MXU matmul;
* the *cross-tile* count is read off the running histogram scratch with an
  int32 masked reduce — exact for any int32-sized ``A``, unlike an fp32
  pick, which would silently round past ``A = 2^24`` — and the tile then
  bumps the histogram;

Everything is int32 elementwise VPU work: ``bt + 2 * lane_pad(D)`` ops per
element (the exact terms :func:`benchmarks.cost_model.sort_time_report`
charges), so the win over a comparison sort shrinks as the lane-padded
domain widens — the kernel is built for dispatch's small domains, not as a
general sort.
* the per-element local rank (``#earlier equal keys``, over the whole
  array) streams out tile by tile, and the final histogram flushes once on
  the last step.

The wrapper turns ``(local_rank, histogram)`` into the canonical
``(ranks, starts)`` contract with one tiny O(E) cumsum and one O(A)
gather-add — no sort network, no scatter, five A-sized streaming int32
transfers total (kernel: keys in, local ranks out; wrapper: local + keys
in, ranks out) vs the comparison sort's ~log2(A) read+write passes.
Output is
bit-identical to ``jnp.argsort(..., stable=True)`` position arithmetic: a
stable sort of integers is unique, so the radix and argsort paths agree
bit for bit (asserted across the whole dispatch conformance matrix in
``tests/test_dispatch_conformance.py``).

Padding: ``A`` is padded up to a whole number of row tiles with the
sentinel key ``num_keys``, which sorts after every real key and is excluded
from ``starts`` — the pad tail is sliced off before returning.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# default row-tile: the within-tile pairwise term costs bt ops/element, so
# keep bt at one lane width; the (bt, D) one-hot and (bt, bt) pair mask
# stay far under VMEM at the largest supported domain (D ~ a few hundred)
BLOCK_ROWS = 128


def _group_sort_kernel(keys_ref, local_ref, hist_ref, count_ref, *,
                       n_tiles: int):
    """One grid step = one (1, bt) tile of keys.

    ``count_ref``: (1, D) int32 VMEM scratch — running per-key histogram of
    every tile BEFORE this one (persists across the sequential grid).
    ``local_ref``: (1, bt) int32 — this tile's per-element count of earlier
    equal keys over the whole array.  ``hist_ref``: (1, D) int32 — final
    histogram, written once on the last step.
    """
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        count_ref[...] = jnp.zeros_like(count_ref)

    bt = local_ref.shape[1]
    D = count_ref.shape[1]
    kt = keys_ref[...]                                        # (1, bt) int32
    keys = kt.reshape(bt, 1)
    dom = jax.lax.broadcasted_iota(jnp.int32, (bt, D), 1)
    onehot = (keys == dom).astype(jnp.int32)                  # (bt, D)

    # within-tile exclusive equal-key count: pairwise compare of the tile's
    # keys against themselves under a strictly-lower-triangular mask (row r
    # counts rows r' < r with the same key) — O(bt) elementwise VPU ops per
    # element, no domain factor, no matmul
    row = jax.lax.broadcasted_iota(jnp.int32, (bt, bt), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (bt, bt), 1)
    eq_pair = (keys == kt) & (col < row)                      # (bt, bt)
    within = eq_pair.astype(jnp.int32).sum(axis=1)            # (bt,)

    # cross-tile count: pick this element's key out of the running
    # histogram (masked reduce — no vector gather needed on TPU).  Kept in
    # int32: the running count reaches A, and an fp32 pick would silently
    # round once A exceeds 2^24.
    run_pick = (count_ref[...] * onehot).sum(axis=1)          # (bt,) int32
    local_ref[...] = (within + run_pick).reshape(1, bt)

    count_ref[...] = count_ref[...] + onehot.sum(axis=0, keepdims=True)

    @pl.when(i == n_tiles - 1)
    def _flush():
        hist_ref[...] = count_ref[...]


def group_sort_pallas(keys: jax.Array, num_keys: int, *,
                      block: int = BLOCK_ROWS,
                      interpret: bool = False
                      ) -> tuple[jax.Array, jax.Array]:
    """Stable counting sort of int32 ``keys`` with domain ``[0, num_keys)``.

    Returns ``(ranks, starts)``:

    * ``ranks`` (A,) int32 — each element's position in the stable sorted
      order (the inverse of ``jnp.argsort(keys, stable=True)``);
    * ``starts`` (num_keys + 1,) int32 — exclusive prefix counts:
      ``starts[d]`` = number of keys ``< d``; ``starts[num_keys] == A``.

    ``ranks[i] = starts[keys[i]] + #{j < i : keys[j] == keys[i]}`` — the
    counting-sort identity, stability by construction.
    """
    if num_keys < 1:
        raise ValueError(f"num_keys must be >= 1, got {num_keys}")
    A = keys.shape[0]
    if A == 0:
        return (jnp.zeros((0,), jnp.int32),
                jnp.zeros((num_keys + 1,), jnp.int32))
    # the tile is never shrunk below ``block``: Mosaic wants lane-aligned
    # block shapes, so a short input pads up to one full tile of sentinels
    # rather than compiling a ragged (1, A) block
    bt = block
    pad = (-A) % bt
    k32 = keys.astype(jnp.int32)
    kp = jnp.concatenate(
        [k32, jnp.full((pad,), num_keys, jnp.int32)]) if pad else k32
    n_tiles = kp.shape[0] // bt
    # histogram domain includes the pad sentinel; lane-align for VMEM
    D = ((num_keys + 1 + 127) // 128) * 128
    local, hist = pl.pallas_call(
        functools.partial(_group_sort_kernel, n_tiles=n_tiles),
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((1, bt), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((1, bt), lambda i: (i, 0)),
                   pl.BlockSpec((1, D), lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((n_tiles, bt), jnp.int32),
                   jax.ShapeDtypeStruct((1, D), jnp.int32)],
        scratch_shapes=[pltpu.VMEM((1, D), jnp.int32)],
        # the running histogram (scratch + revisited hist output) is
        # carried across the tile axis: it must execute sequentially
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(kp.reshape(n_tiles, bt))
    # pad-sentinel counts live at hist[num_keys] and are excluded by
    # construction: starts only prefixes the real domain
    starts = jnp.concatenate([
        jnp.zeros((1,), jnp.int32),
        jnp.cumsum(hist[0, :num_keys]).astype(jnp.int32)])
    ranks = local.reshape(-1)[:A] + jnp.take(starts, k32)
    return ranks, starts

"""Pallas TPU kernels: fused MoE dispatch gather / combine gather-reduce.

These are the two data movements bracketing expert compute (the "routing"
slice of the paper's Table 3 breakdown).  The sort backend in
:mod:`repro.core.dispatch` reduces both to row gathers with data-dependent
indices, which is exactly the shape scalar prefetch is built for: the index
arrays are prefetched into SMEM, each grid step's ``BlockSpec`` index map
reads one index, and the pipeline DMAs the selected (1, d) row HBM->VMEM
while the previous row is being written.  No (A, V) one-hot, no scatter —
every byte moved is a byte the buffer needs.

* :func:`dispatch_gather_pallas` — fill the flat capacity buffer
  ``(R = num_groups*cap, d)``: slot ``i`` copies token row ``src[i]`` from
  ``x``, or zeros when ``src[i] < 0`` (empty slot).  The empty-slot zeroing
  is fused into the same kernel (predicated write).

* :func:`combine_gather_pallas` — token ``i`` accumulates its k assignments:
  ``y[i] = sum_j scale[i, j] * rows[src[i, j]]`` with dropped assignments
  (``src < 0``) contributing zero.  Gate weighting and the k-way reduction
  are fused with the gather (grid ``(t, k)``, output revisited over j with
  fp32 accumulation).

Both kernels are layout-agnostic row gathers, so they serve the capacity
buffers (``R = num_groups * cap``, slot-major) and the dropless tile-aligned
ragged layout (``R = ragged_rows(...)``, segment-major with ``-1`` alignment
padding) without change — the backends differ only in the ``src`` maps they
prefetch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _dispatch_kernel(src_ref, x_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(src_ref[i] >= 0)
    def _copy():
        o_ref[...] = x_ref[...]

    @pl.when(src_ref[i] < 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)


def dispatch_gather_pallas(x: jax.Array, src: jax.Array, *,
                           interpret: bool = False) -> jax.Array:
    """x: (T, d); src: (R,) int32 source row ids (-1 = empty) -> (R, d)."""
    T, d = x.shape
    R = src.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(R,),
        # index map sees the prefetched src ref: block i streams row src[i]
        in_specs=[pl.BlockSpec((1, d), lambda i, src: (jnp.maximum(src[i], 0),
                                                       0))],
        out_specs=pl.BlockSpec((1, d), lambda i, src: (i, 0)),
    )
    return pl.pallas_call(
        _dispatch_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((R, d), x.dtype),
        # pure gather: every destination row is written exactly once
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(src, x)


def _combine_kernel(src_ref, scale_ref, rows_ref, o_ref, acc_ref):
    i, j = pl.program_id(0), pl.program_id(1)
    w = jnp.where(src_ref[i, j] >= 0, scale_ref[i, j], 0.0)
    contrib = rows_ref[...].astype(jnp.float32) * w.astype(jnp.float32)

    # accumulate in the fp32 scratch tile; the output dtype is only touched
    # once, on the last k step (j is innermost, so acc is consumed before
    # the next token reuses it)
    @pl.when(j == 0)
    def _init():
        acc_ref[...] = contrib

    @pl.when(j != 0)
    def _acc():
        acc_ref[...] = acc_ref[...] + contrib

    @pl.when(j == pl.num_programs(1) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def combine_gather_pallas(rows: jax.Array, src: jax.Array, scale: jax.Array,
                          *, interpret: bool = False) -> jax.Array:
    """rows: (R, d); src/scale: (t, k) -> (t, d) gate-weighted k-reduction."""
    R, d = rows.shape
    t, k = src.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(t, k),
        in_specs=[pl.BlockSpec(
            (1, d), lambda i, j, src, sc: (jnp.maximum(src[i, j], 0), 0))],
        # j is innermost: token i's accumulator tile stays resident in VMEM
        # across its k accumulation steps
        out_specs=pl.BlockSpec((1, d), lambda i, j, src, sc: (i, 0)),
        scratch_shapes=[pltpu.VMEM((1, d), jnp.float32)],
    )
    return pl.pallas_call(
        _combine_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, d), rows.dtype),
        # the k axis accumulates into the scratch tile: sequential; token
        # tiles are independent
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(src, scale, rows)

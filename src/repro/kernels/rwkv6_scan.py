"""Pallas TPU kernel: RWKV6 (WKV) recurrence.

The attention-free time-mix recurrence is the rwkv6 arch's compute hot spot
and is inherently sequential in T — the TPU-native formulation keeps the
per-head state matrix ``S (hd, hd)`` resident in VMEM/VREGs and streams the
(r, k, v, w) time series through it in T-steps, materializing nothing of
O(T^2). Grid ``(B, nh)``: heads and batches are independent, so the kernel
parallelizes across them (heads are also the tensor-parallel shard dim).

For hd=64 the state is 16 KB fp32; r/k/v/w tiles for a 4k sequence are
4 x 1 MB — comfortably VMEM-resident.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, s_out_ref):
    T, hd = r_ref.shape[1], r_ref.shape[3]
    u = u_ref[0].astype(jnp.float32)                 # (hd,)
    s0 = s0_ref[0, 0].astype(jnp.float32)            # (hd, hd)

    def body(t, s):
        r = r_ref[0, t, 0].astype(jnp.float32)        # (hd,)
        k = k_ref[0, t, 0].astype(jnp.float32)
        v = v_ref[0, t, 0].astype(jnp.float32)
        w = w_ref[0, t, 0].astype(jnp.float32)
        kv = k[:, None] * v[None, :]                  # (hd_k, hd_v)
        y = ((s + u[:, None] * kv) * r[:, None]).sum(axis=0)
        y_ref[0, t, 0] = y.astype(y_ref.dtype)
        return w[:, None] * s + kv

    s_last = jax.lax.fori_loop(0, T, body, s0)
    s_out_ref[0, 0] = s_last.astype(s_out_ref.dtype)


def rwkv6_scan_pallas(r: jax.Array, k: jax.Array, v: jax.Array,
                      w: jax.Array, u: jax.Array, s0: jax.Array,
                      *, interpret: bool = False):
    """r/k/v/w: (B, T, nh, hd); u: (nh, hd); s0: (B, nh, hd, hd).

    Returns (y (B, T, nh, hd), s_last (B, nh, hd, hd)).
    """
    B, T, nh, hd = r.shape
    grid = (B, nh)
    seq_spec = pl.BlockSpec((1, T, 1, hd), lambda b, h: (b, 0, h, 0))
    y, s_last = pl.pallas_call(
        _kernel,
        out_shape=(jax.ShapeDtypeStruct((B, T, nh, hd), jnp.float32),
                   jax.ShapeDtypeStruct((B, nh, hd, hd), jnp.float32)),
        grid=grid,
        in_specs=[seq_spec, seq_spec, seq_spec, seq_spec,
                  pl.BlockSpec((1, hd), lambda b, h: (h, 0)),
                  pl.BlockSpec((1, 1, hd, hd), lambda b, h: (b, h, 0, 0))],
        out_specs=(seq_spec,
                   pl.BlockSpec((1, 1, hd, hd), lambda b, h: (b, h, 0, 0))),
        # the time recurrence runs inside one grid step (fori over T);
        # (batch, head) grid steps are independent
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return y, s_last

"""Pallas TPU kernel: Mamba2 SSD intra-chunk block.

The chunked state-space-dual computation is zamba2's compute hot spot. For
one (batch, chunk, head) cell it fuses:

    cs       = cumsum(loga)                       (Q,)
    scores   = C B^T                              (Q, Q)   MXU
    w        = tril(exp(cs_i - cs_j)) * scores
    y_intra  = (w * dt_j) x                       (Q, hd)  MXU
    sB       = (exp(cs_Q - cs) * dt * x)^T B      (hd, ds) MXU
    a_chunk  = exp(cs_Q)

materializing the (Q, Q) decay matrix only in VMEM (the jnp reference builds
a (B, nc, Q, Q, nh) tensor in HBM). The sequential inter-chunk recurrence
(tiny: (hd, ds) state per head) stays in ``lax.scan`` outside the kernel.

Working set at Q=128, hd=64, ds=64: Q*hd + 2*Q*ds + Q*Q + hd*ds fp32
~ 160 KB — far under VMEM; both matmul shapes are 128-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, loga_ref, b_ref, c_ref,
            y_ref, sb_ref, ac_ref):
    x = x_ref[0, 0, :, 0, :].astype(jnp.float32)        # (Q, hd)
    dt = dt_ref[0, 0, :, 0].astype(jnp.float32)         # (Q,)
    loga = loga_ref[0, 0, :, 0].astype(jnp.float32)     # (Q,)
    B = b_ref[0, 0].astype(jnp.float32)                 # (Q, ds)
    C = c_ref[0, 0].astype(jnp.float32)                 # (Q, ds)
    Q = x.shape[0]

    cs = jnp.cumsum(loga)                               # (Q,)
    scores = jnp.dot(C, B.T, preferred_element_type=jnp.float32)  # (Q,Q)
    decay = cs[:, None] - cs[None, :]
    mask = jax.lax.iota(jnp.int32, Q)[:, None] >= \
        jax.lax.iota(jnp.int32, Q)[None, :]
    w = jnp.where(mask, jnp.exp(decay), 0.0) * scores   # (Q, Q)
    y = jnp.dot(w * dt[None, :], x,
                preferred_element_type=jnp.float32)     # (Q, hd)
    tail = jnp.exp(cs[-1] - cs)                         # (Q,)
    sb = jnp.dot((tail * dt)[:, None].T * x.T, B,
                 preferred_element_type=jnp.float32)    # (hd, ds)
    y_ref[0, 0, :, 0, :] = y
    sb_ref[0, 0, 0] = sb
    ac_ref[0, 0, 0] = jnp.exp(cs[-1])


def ssd_chunk_pallas(xh, dt, loga, Bc, Cc, *, interpret: bool = False):
    """Intra-chunk SSD terms.

    xh: (B, nc, Q, nh, hd); dt/loga: (B, nc, Q, nh); Bc/Cc: (B, nc, Q, ds).
    Returns (y_intra (B,nc,Q,nh,hd), sB (B,nc,nh,hd,ds), a_chunk (B,nc,nh)).
    """
    B, nc, Q, nh, hd = xh.shape
    ds = Bc.shape[-1]
    grid = (B * nc, nh)
    xr = xh.reshape(B * nc, 1, Q, nh, hd)
    dtr = dt.reshape(B * nc, 1, Q, nh)
    lr = loga.reshape(B * nc, 1, Q, nh)
    br = Bc.reshape(B * nc, 1, Q, ds)
    cr = Cc.reshape(B * nc, 1, Q, ds)

    y, sb, ac = pl.pallas_call(
        _kernel,
        out_shape=(jax.ShapeDtypeStruct((B * nc, 1, Q, nh, hd), jnp.float32),
                   jax.ShapeDtypeStruct((B * nc, 1, nh, hd, ds), jnp.float32),
                   jax.ShapeDtypeStruct((B * nc, 1, nh), jnp.float32)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Q, 1, hd), lambda g, h: (g, 0, 0, h, 0)),
            pl.BlockSpec((1, 1, Q, 1), lambda g, h: (g, 0, 0, h)),
            pl.BlockSpec((1, 1, Q, 1), lambda g, h: (g, 0, 0, h)),
            pl.BlockSpec((1, 1, Q, ds), lambda g, h: (g, 0, 0, 0)),
            pl.BlockSpec((1, 1, Q, ds), lambda g, h: (g, 0, 0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, 1, Q, 1, hd), lambda g, h: (g, 0, 0, h, 0)),
            pl.BlockSpec((1, 1, 1, hd, ds), lambda g, h: (g, 0, h, 0, 0)),
            pl.BlockSpec((1, 1, 1), lambda g, h: (g, 0, h)),
        ),
        # intra-chunk recurrence runs inside one grid step; the cross-chunk
        # stitch happens in the outer associative scan, not in this kernel
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(xr, dtr, lr, br, cr)
    return (y.reshape(B, nc, Q, nh, hd), sb.reshape(B, nc, nh, hd, ds),
            ac.reshape(B, nc, nh))

"""Pallas TPU kernels: grouped (per-expert) FFN — dense and ragged.

This is the expert-compute hot spot of the MoE layer — the "FFN Expert" slice
of the paper's Table 3 time breakdown.  Two variants share one tile body
(``act(x @ w1) [* (x @ w3)] @ w2`` with MXU-aligned VMEM tiles, fp32
accumulation, output revisiting over the innermost ``f`` grid axis):

* :func:`grouped_ffn_pallas` — capacity-buffer layout ``(G, T, d)``: every
  group holds the same (padded) number of rows.  Grid ``(G, T/bt, f/bf)``.

* :func:`grouped_ffn_ragged_pallas` — the dropless tile-aligned ragged
  layout from :mod:`repro.core.dispatch`: a flat ``(R, d)`` row array where
  each group's segment starts at a ``block``-aligned offset and holds exactly
  its own tokens (MegaBlocks-style).  Grid ``(R/bt, f/bf)``; the per-tile
  group id (derived from the ragged ``group_starts`` offsets) is
  scalar-prefetched into SMEM, and each step's ``BlockSpec`` index map reads
  it to DMA that group's weight tiles — no capacity padding is ever touched
  by the MXU, and no per-tile weight copy is materialized in HBM (the
  indirection happens in the DMA descriptor, which is exactly what scalar
  prefetch is for).  Alignment-padding rows arrive zeroed by the dispatch
  gather and stay zero through the FFN (``act(0) == 0`` for gelu/silu and
  the GLU product keeps them zero), so the kernel needs no row masks.

Tiling: ``bt=128``/``bf=512`` keeps the working set
``bt*d + 2*d*bf + bf*d + bt*bf + bt*d`` under ~8 MB VMEM at d=8192 and hits
the 128-lane MXU shape on every contraction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ffn_tile(x, w1, w3, w2, *, act: str):
    """One (bt, d) output tile's contribution for one (d, bf) weight slice."""
    h = jnp.dot(x, w1, preferred_element_type=jnp.float32)
    h = jax.nn.silu(h) if act == "silu" else jax.nn.gelu(h)
    if w3 is not None:
        h = h * jnp.dot(x, w3, preferred_element_type=jnp.float32)
    return jnp.dot(h.astype(x.dtype), w2, preferred_element_type=jnp.float32)


def _accumulate(o_ref, contrib, f_id):
    """Init the output tile on the first f step, accumulate afterwards
    (the f axis is innermost, so the tile stays resident in VMEM)."""
    @pl.when(f_id == 0)
    def _init():
        o_ref[0] = contrib.astype(o_ref.dtype)

    @pl.when(f_id != 0)
    def _acc():
        o_ref[0] = (o_ref[0] + contrib).astype(o_ref.dtype)


def _kernel_glu(x_ref, w1_ref, w3_ref, w2_ref, o_ref, *, act: str):
    contrib = _ffn_tile(x_ref[0], w1_ref[0], w3_ref[0], w2_ref[0], act=act)
    _accumulate(o_ref, contrib, pl.program_id(2))


def _kernel_mlp(x_ref, w1_ref, w2_ref, o_ref, *, act: str):
    contrib = _ffn_tile(x_ref[0], w1_ref[0], None, w2_ref[0], act=act)
    _accumulate(o_ref, contrib, pl.program_id(2))


def _kernel_glu_ragged(gid_ref, x_ref, w1_ref, w3_ref, w2_ref, o_ref,
                       *, act: str):
    contrib = _ffn_tile(x_ref[0], w1_ref[0], w3_ref[0], w2_ref[0], act=act)
    _accumulate(o_ref, contrib, pl.program_id(1))


def _kernel_mlp_ragged(gid_ref, x_ref, w1_ref, w2_ref, o_ref, *, act: str):
    contrib = _ffn_tile(x_ref[0], w1_ref[0], None, w2_ref[0], act=act)
    _accumulate(o_ref, contrib, pl.program_id(1))


def _pick_bf(f: int, bf: int, w1, w3, w2):
    """Resolve the f-axis tile: shrink to a divisor of f when possible.

    f % bf != 0 used to silently truncate the tail columns (grid = f // bf).
    Prefer shrinking bf to the largest divisor of f (no data movement); only
    a pathological f with no lane-sized divisor falls back to zero-padding
    the weights (exact: act(0) == 0 for gelu/silu and padded w2 rows are 0,
    but it copies the expert weights every call).
    """
    pad_f = 0
    if f % bf:
        div = max(d_ for d_ in range(1, bf + 1) if f % d_ == 0)
        if div >= min(128, f):
            bf = div
        else:
            pad_f = (-f) % bf
            w1 = jnp.pad(w1, ((0, 0), (0, 0), (0, pad_f)))
            if w3 is not None:
                w3 = jnp.pad(w3, ((0, 0), (0, 0), (0, pad_f)))
            w2 = jnp.pad(w2, ((0, 0), (0, pad_f), (0, 0)))
    return bf, f + pad_f, w1, w3, w2


def grouped_ffn_pallas(x: jax.Array, w1: jax.Array, w3, w2: jax.Array,
                       *, act: str = "gelu", block_t: int = 128,
                       block_f: int = 512, interpret: bool = False
                       ) -> jax.Array:
    """x: (G, T, d); w1/w3: (G, d, f); w2: (G, f, d) -> (G, T, d)."""
    G, T, d = x.shape
    f = w1.shape[-1]
    bt = min(block_t, T)
    bf = min(block_f, f)
    pad_t = (-T) % bt
    if pad_t:
        x = jnp.pad(x, ((0, 0), (0, pad_t), (0, 0)))
    Tp = x.shape[1]
    bf, fp, w1, w3, w2 = _pick_bf(f, bf, w1, w3, w2)
    grid = (G, Tp // bt, fp // bf)

    x_spec = pl.BlockSpec((1, bt, d), lambda g, t, j: (g, t, 0))
    w1_spec = pl.BlockSpec((1, d, bf), lambda g, t, j: (g, 0, j))
    w2_spec = pl.BlockSpec((1, bf, d), lambda g, t, j: (g, j, 0))
    o_spec = pl.BlockSpec((1, bt, d), lambda g, t, j: (g, t, 0))

    if w3 is not None:
        kern = functools.partial(_kernel_glu, act=act)
        in_specs = [x_spec, w1_spec, w1_spec, w2_spec]
        args = (x, w1, w3, w2)
    else:
        kern = functools.partial(_kernel_mlp, act=act)
        in_specs = [x_spec, w1_spec, w2_spec]
        args = (x, w1, w2)

    out = pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((G, Tp, d), x.dtype),
        grid=grid,
        in_specs=in_specs,
        out_specs=o_spec,
        # the output block accumulates over the f axis (innermost): that
        # axis is sequential; group and row-tile axes are independent
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*args)
    return out[:, :T]


def grouped_ffn_ragged_pallas(rows: jax.Array, tile_gid: jax.Array,
                              w1: jax.Array, w3, w2: jax.Array,
                              *, act: str = "gelu", block_f: int = 512,
                              interpret: bool = False) -> jax.Array:
    """Ragged grouped FFN over the tile-aligned dropless layout.

    ``rows``: (R, d) flat row array, R a multiple of the row-tile size;
    ``tile_gid``: (R // bt,) int32 group id per row tile (scalar-prefetched;
    see :func:`repro.core.dispatch.ragged_tile_gids`); weights as in
    :func:`grouped_ffn_pallas`.  Returns (R, d).
    """
    R, d = rows.shape
    n_tiles = tile_gid.shape[0]
    assert R % n_tiles == 0, (R, n_tiles)
    bt = R // n_tiles
    f = w1.shape[-1]
    bf = min(block_f, f)
    bf, fp, w1, w3, w2 = _pick_bf(f, bf, w1, w3, w2)
    grid = (n_tiles, fp // bf)

    x3 = rows.reshape(n_tiles, bt, d)
    x_spec = pl.BlockSpec((1, bt, d), lambda i, j, gid: (i, 0, 0))
    w1_spec = pl.BlockSpec((1, d, bf), lambda i, j, gid: (gid[i], 0, j))
    w2_spec = pl.BlockSpec((1, bf, d), lambda i, j, gid: (gid[i], j, 0))
    o_spec = pl.BlockSpec((1, bt, d), lambda i, j, gid: (i, 0, 0))

    if w3 is not None:
        kern = functools.partial(_kernel_glu_ragged, act=act)
        in_specs = [x_spec, w1_spec, w1_spec, w2_spec]
        args = (x3, w1, w3, w2)
    else:
        kern = functools.partial(_kernel_mlp_ragged, act=act)
        in_specs = [x_spec, w1_spec, w2_spec]
        args = (x3, w1, w2)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=o_spec,
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_tiles, bt, d), rows.dtype),
        # each row tile's output accumulates over the f axis (innermost):
        # sequential; row tiles are independent
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(tile_gid.astype(jnp.int32), *args)
    return out.reshape(R, d)

"""Pallas TPU kernel: grouped (per-expert) FFN.

This is the expert-compute hot spot of the MoE layer — the "FFN Expert" slice
of the paper's Table 3 time breakdown. After dispatch, each device holds
``(G, T, d)`` tokens grouped by local expert; the kernel fuses
``act(x @ w1) [* (x @ w3)] @ w2`` with MXU-aligned VMEM tiles.

Tiling: grid ``(G, T/bt, f/bf)``. Each step loads an ``(bt, d)`` token tile
and ``(d, bf)/(bf, d)`` weight tiles, accumulating the second matmul into the
``(bt, d)`` output tile across the ``f`` grid dimension (output revisiting —
the f axis is innermost, so the accumulator tile stays resident in VMEM).
``bt=128``/``bf=512`` keeps the working set
``bt*d + 2*d*bf + bf*d + bt*bf + bt*d`` under ~8 MB VMEM at d=8192 and hits
the 128-lane MXU shape on every contraction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel_glu(x_ref, w1_ref, w3_ref, w2_ref, o_ref, *, act: str):
    x = x_ref[0]                                 # (bt, d)
    h = jnp.dot(x, w1_ref[0], preferred_element_type=jnp.float32)
    h = jax.nn.silu(h) if act == "silu" else jax.nn.gelu(h)
    h = h * jnp.dot(x, w3_ref[0], preferred_element_type=jnp.float32)
    contrib = jnp.dot(h.astype(x.dtype), w2_ref[0],
                      preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[0] = contrib.astype(o_ref.dtype)

    @pl.when(pl.program_id(2) != 0)
    def _acc():
        o_ref[0] = (o_ref[0] + contrib).astype(o_ref.dtype)


def _kernel_mlp(x_ref, w1_ref, w2_ref, o_ref, *, act: str):
    x = x_ref[0]
    h = jnp.dot(x, w1_ref[0], preferred_element_type=jnp.float32)
    h = jax.nn.silu(h) if act == "silu" else jax.nn.gelu(h)
    contrib = jnp.dot(h.astype(x.dtype), w2_ref[0],
                      preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[0] = contrib.astype(o_ref.dtype)

    @pl.when(pl.program_id(2) != 0)
    def _acc():
        o_ref[0] = (o_ref[0] + contrib).astype(o_ref.dtype)


def grouped_ffn_pallas(x: jax.Array, w1: jax.Array, w3, w2: jax.Array,
                       *, act: str = "gelu", block_t: int = 128,
                       block_f: int = 512, interpret: bool = False
                       ) -> jax.Array:
    """x: (G, T, d); w1/w3: (G, d, f); w2: (G, f, d) -> (G, T, d)."""
    G, T, d = x.shape
    f = w1.shape[-1]
    bt = min(block_t, T)
    bf = min(block_f, f)
    pad_t = (-T) % bt
    if pad_t:
        x = jnp.pad(x, ((0, 0), (0, pad_t), (0, 0)))
    Tp = x.shape[1]
    # f % bf != 0 used to silently truncate the tail columns (grid = f // bf).
    # Prefer shrinking bf to the largest divisor of f (no data movement); only
    # a pathological f with no lane-sized divisor falls back to zero-padding
    # the weights (exact: act(0) == 0 for gelu/silu and padded w2 rows are 0,
    # but it copies the expert weights every call).
    pad_f = 0
    if f % bf:
        div = max(d_ for d_ in range(1, bf + 1) if f % d_ == 0)
        if div >= min(128, f):
            bf = div
        else:
            pad_f = (-f) % bf
            w1 = jnp.pad(w1, ((0, 0), (0, 0), (0, pad_f)))
            if w3 is not None:
                w3 = jnp.pad(w3, ((0, 0), (0, 0), (0, pad_f)))
            w2 = jnp.pad(w2, ((0, 0), (0, pad_f), (0, 0)))
    fp = f + pad_f
    grid = (G, Tp // bt, fp // bf)

    x_spec = pl.BlockSpec((1, bt, d), lambda g, t, j: (g, t, 0))
    w1_spec = pl.BlockSpec((1, d, bf), lambda g, t, j: (g, 0, j))
    w2_spec = pl.BlockSpec((1, bf, d), lambda g, t, j: (g, j, 0))
    o_spec = pl.BlockSpec((1, bt, d), lambda g, t, j: (g, t, 0))

    if w3 is not None:
        kern = functools.partial(_kernel_glu, act=act)
        in_specs = [x_spec, w1_spec, w1_spec, w2_spec]
        args = (x, w1, w3, w2)
    else:
        kern = functools.partial(_kernel_mlp, act=act)
        in_specs = [x_spec, w1_spec, w2_spec]
        args = (x, w1, w2)

    out = pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((G, Tp, d), x.dtype),
        grid=grid,
        in_specs=in_specs,
        out_specs=o_spec,
        interpret=interpret,
    )(*args)
    return out[:, :T]

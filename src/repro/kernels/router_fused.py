"""Pallas TPU kernel: the fused routing megakernel.

Every hop of the MoE pipeline opens with the same four-stage routing
prologue: router GEMM (``(t, d) @ (d, E)``), softmax, top-k expert
selection, and the dispatch position math (histogram + exclusive prefix
counts over the chosen expert ids).  Unfused, each stage is its own XLA
op with an HBM round trip between them — the logits tensor alone is
written and re-read twice (softmax, then ``lax.top_k``), and the group
sort adds its own streaming passes over the assignment ids.  MegaScale-MoE
(PAPERS.md) reports fusing exactly this dispatch stage as a headline win.

:func:`router_fused_pallas` runs the whole prologue in **one pass over the
token tiles**, everything after the GEMM staying in VMEM:

* tile ``i`` computes its logits block on the MXU
  (``jnp.dot(..., preferred_element_type=f32)`` — bit-identical to the
  unfused fp32 ``einsum``), writes it out once (the z-loss needs it), and
  immediately derives the softmax in-register — max-subtracted, exactly
  the :func:`jax.nn.softmax` primitive sequence, so ``probs`` (the LB-loss
  input) is bit-compatible with the unfused path;
* top-k is ``k`` unrolled max-extraction rounds over the VMEM probs block.
  Ties are broken by an **explicit lowest-expert-index rule** (mask the
  max's candidates against the lane iota and take the minimum index) —
  the same order ``lax.top_k`` guarantees, pinned here so the fused and
  unfused impls can never silently disagree on tied logits (asserted
  bit-for-bit under deliberate bf16 ties in ``tests/test_router_fused.py``);
* the chosen ids feed the radix-sort histogram idiom
  (:mod:`repro.kernels.radix_sort`): a one-hot compare against the domain
  iota bumps a running per-expert int32 histogram carried across the
  sequential grid in VMEM scratch, the within-tile exclusive equal-key
  count is the strictly-lower-triangular pairwise compare, and the final
  histogram flushes once on the last step.

The wrapper turns the per-element local ranks + final histogram into the
canonical ``(ranks, starts)`` contract of :func:`repro.kernels.ops
.group_sort` — each assignment's stable dispatch position
(``ranks[a] = starts[idx[a]] + #earlier-equal``) feeding straight into the
dispatch gather, with no separate sort pass over the ids.  When a hop
relabels groups (rank-major perms, SMILE's virtual-group mapping), the
relabel is a pure label permutation applied downstream of these ids — the
positions here are over the raw expert domain, which is the dispatch
domain whenever group ids coincide with expert ids.

Outputs (``t`` tokens, ``E`` experts, ``A = t*k`` assignments):
``gates (t, k)`` — top-k probabilities, optionally renormalized;
``idx (t, k)`` int32 — chosen expert ids, descending by probability;
``probs (t, E)`` / ``logits (t, E)`` fp32 — the loss inputs, bit-compatible
with the unfused ``router_probs``; ``ranks (A,)`` / ``starts (E + 1,)``
int32 — the counting-sort position contract (per-expert counts are
``starts[1:] - starts[:-1]``).

Padding: ``t`` pads up to whole row tiles; pad rows are masked out of the
histogram (their gates/ids are sliced off before returning), so no
sentinel key is needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# row-tile: one lane width of tokens per grid step keeps the (bt*k, bt*k)
# within-tile pair mask and the (bt, E) logits/probs blocks far under VMEM
# at every supported expert count (E <= a few hundred)
BLOCK_ROWS = 128


def _router_fused_kernel(x_ref, w_ref, logits_ref, probs_ref, gates_ref,
                         idx_ref, local_ref, hist_ref, count_ref, *,
                         n_tiles: int, k: int, rows: int):
    """One grid step = one (bt, d) tile of tokens.

    ``count_ref``: (1, D) int32 VMEM scratch — running per-expert histogram
    of every tile BEFORE this one (persists across the sequential grid).
    ``local_ref``: (bt, k) int32 — per-assignment count of earlier equal
    expert ids over the whole array.  ``hist_ref``: (1, D) int32 — final
    histogram, written once on the last step.  ``rows`` = real token count
    (rows past it are padding, masked from the histogram).
    """
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        count_ref[...] = jnp.zeros_like(count_ref)

    bt = x_ref.shape[0]
    E = w_ref.shape[1]
    D = count_ref.shape[1]

    # ---- router GEMM tile (MXU) + in-VMEM softmax ---------------------------
    logits = jnp.dot(x_ref[...].astype(jnp.float32),
                     w_ref[...].astype(jnp.float32),
                     preferred_element_type=jnp.float32)       # (bt, E)
    logits_ref[...] = logits
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    probs = e / jnp.sum(e, axis=-1, keepdims=True)             # == jax.nn.softmax
    probs_ref[...] = probs

    # ---- top-k: k max-extraction rounds, lowest-index tie-break -------------
    lane = jax.lax.broadcasted_iota(jnp.int32, (bt, E), 1)
    work = probs
    gsel, isel = [], []
    for _ in range(k):
        g = jnp.max(work, axis=-1, keepdims=True)              # (bt, 1)
        # ties: the minimum expert index attaining the max — the order
        # lax.top_k guarantees, pinned explicitly (see module docstring)
        sel = jnp.min(jnp.where(work == g, lane, E), axis=-1, keepdims=True)
        gsel.append(g)
        isel.append(sel)
        work = jnp.where(lane == sel, -jnp.inf, work)
    gates = jnp.concatenate(gsel, axis=1)                      # (bt, k)
    idx = jnp.concatenate(isel, axis=1)                        # (bt, k) int32
    # NOTE: gate renormalization happens in the wrapper epilogue — the
    # k-element sum must associate exactly as the unfused XLA reduce does
    # for bit-compatibility, which an in-kernel reduce cannot guarantee
    gates_ref[...] = gates
    idx_ref[...] = idx

    # ---- one-pass histogram + element-side positions (radix-sort idiom) -----
    # flat assignment order is token-major, slot-minor — exactly the (A,)
    # order the dispatch gather consumes
    A = bt * k
    keys = idx.reshape(A, 1)
    tok = jax.lax.broadcasted_iota(jnp.int32, (bt, k), 0)
    valid = ((tok + i * bt) < rows).reshape(A, 1)              # pad-row mask
    dom = jax.lax.broadcasted_iota(jnp.int32, (A, D), 1)
    onehot = ((keys == dom) & valid).astype(jnp.int32)         # (A, D)

    row = jax.lax.broadcasted_iota(jnp.int32, (A, A), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (A, A), 1)
    eq_pair = (keys == keys.reshape(1, A)) & (col < row) & valid.reshape(1, A)
    within = eq_pair.astype(jnp.int32).sum(axis=1)             # (A,)

    # cross-tile count off the running histogram (int32 masked reduce — an
    # fp32 pick would silently round past A = 2^24)
    run_pick = (count_ref[...] * onehot).sum(axis=1)           # (A,) int32
    local_ref[...] = (within + run_pick).reshape(bt, k)

    count_ref[...] = count_ref[...] + onehot.sum(axis=0, keepdims=True)

    @pl.when(i == n_tiles - 1)
    def _flush():
        hist_ref[...] = count_ref[...]


def router_fused_pallas(x: jax.Array, w: jax.Array, k: int, *,
                        renorm: bool = False, block: int = BLOCK_ROWS,
                        interpret: bool = False):
    """Fused routing prologue over tokens ``x`` (t, d) and router weights
    ``w`` (d, E).

    Returns ``(gates, idx, probs, logits, ranks, starts)`` — see the module
    docstring for shapes and the bit-compatibility contract with the
    unfused ``router_probs`` + ``topk_gates`` + ``ops.group_sort`` chain.
    """
    t, d = x.shape
    E = w.shape[1]
    if not 1 <= k <= E:
        raise ValueError(f"top-k {k} must be in [1, num_experts {E}]")
    if t == 0:
        f32 = jnp.float32
        return (jnp.zeros((0, k), f32), jnp.zeros((0, k), jnp.int32),
                jnp.zeros((0, E), f32), jnp.zeros((0, E), f32),
                jnp.zeros((0,), jnp.int32), jnp.zeros((E + 1,), jnp.int32))
    bt = block
    pad = (-t) % bt
    xp = jnp.concatenate([x, jnp.zeros((pad, d), x.dtype)]) if pad else x
    n_tiles = xp.shape[0] // bt
    D = ((E + 127) // 128) * 128                  # lane-aligned domain
    logits, probs, gates, idx, local, hist = pl.pallas_call(
        functools.partial(_router_fused_kernel, n_tiles=n_tiles, k=k, rows=t),
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((bt, d), lambda i: (i, 0)),
                  pl.BlockSpec((d, E), lambda i: (0, 0))],
        out_specs=[pl.BlockSpec((bt, E), lambda i: (i, 0)),
                   pl.BlockSpec((bt, E), lambda i: (i, 0)),
                   pl.BlockSpec((bt, k), lambda i: (i, 0)),
                   pl.BlockSpec((bt, k), lambda i: (i, 0)),
                   pl.BlockSpec((bt, k), lambda i: (i, 0)),
                   pl.BlockSpec((1, D), lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((n_tiles * bt, E), jnp.float32),
                   jax.ShapeDtypeStruct((n_tiles * bt, E), jnp.float32),
                   jax.ShapeDtypeStruct((n_tiles * bt, k), jnp.float32),
                   jax.ShapeDtypeStruct((n_tiles * bt, k), jnp.int32),
                   jax.ShapeDtypeStruct((n_tiles * bt, k), jnp.int32),
                   jax.ShapeDtypeStruct((1, D), jnp.int32)],
        scratch_shapes=[pltpu.VMEM((1, D), jnp.int32)],
        # the running histogram (scratch + revisited hist output) is
        # carried across the tile axis: it must execute sequentially
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(xp, w)
    starts = jnp.concatenate([
        jnp.zeros((1,), jnp.int32),
        jnp.cumsum(hist[0, :E]).astype(jnp.int32)])
    gates, idx = gates[:t], idx[:t]
    if renorm and k > 1:
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    ranks = local[:t].reshape(-1) + jnp.take(starts, idx.reshape(-1))
    return gates, idx, probs[:t], logits[:t], ranks, starts

"""PartitionSpec rules: map every parameter / cache / batch leaf to its spec.

Rules are keyed on the leaf's path tail (parent key + leaf name) and specify
the spec of the *trailing* dims; leading stacked dims (scan-over-layers) are
padded with ``None``. ``shard_axes(...)`` inverts a spec tree into "which mesh
axes is this leaf replicated over" — exactly the axes its gradient must be
psum'd over inside the manual-collectives train step.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.common.config import InputShape, ModelConfig
from repro.sharding.plan import MeshPlan


def _expert_spec(cfg: ModelConfig, plan: MeshPlan) -> Tuple:
    from repro.core.layout import make_layout
    from repro.core.moe import _grid
    n_g, m_g = _grid(cfg.moe, plan)
    layout = make_layout(cfg.moe.num_experts, n_g, m_g)
    inter = tuple(plan.ep_inter) or None
    intra = (tuple(plan.ep_intra) or None) if layout.shard_intra else None
    return (inter if inter and len(inter) > 1 else (inter[0] if inter else None),
            intra if intra and len(intra) > 1 else (intra[0] if intra else None),
            None, None)


def param_spec_rules(cfg: ModelConfig, plan: MeshPlan):
    """Return fn(path_tuple, ndim) -> PartitionSpec for parameter leaves."""
    tp = plan.tp_axis
    # under kv_seq_shard the cache keeps ALL KV heads locally (the sequence
    # dim is the sharded one), so the KV projections must stay replicated
    kv_ok = (cfg.num_kv_heads % max(plan.tp, 1) == 0
             and not getattr(cfg, "kv_seq_shard", False))
    nh_rwkv_ok = (cfg.rwkv is None
                  or (cfg.d_model // cfg.rwkv.head_dim) % max(plan.tp, 1) == 0)
    espec = _expert_spec(cfg, plan) if (cfg.moe and cfg.moe.num_experts) else None

    def base(parent: str, name: str) -> Optional[Tuple]:
        # --- embeddings / heads -------------------------------------------
        if parent == "embed" and name == "table":
            if cfg.num_codebooks > 1:
                return (None, tp, None)
            return (tp, None)
        if parent == "heads" and name == "w":
            return (None, tp, None)
        if parent == "lm_head" and name == "w":
            return (tp, None)
        if parent == "vision_proj":
            return (None, None)
        # --- MoE ------------------------------------------------------------
        if parent == "experts":
            return espec
        if parent in ("router", "router_inter", "router_intra"):
            return (None, None)
        # --- rwkv (parent-specific; must precede generic attention rules) ---
        if parent == "tmix":
            if name in ("wr", "wk", "wv", "wg"):
                return (None, tp if nh_rwkv_ok else None, None)
            if name == "wo":
                return (tp if nh_rwkv_ok else None, None, None)
        if parent == "cmix":
            if name == "wk":
                return (None, tp)
            if name == "wv":
                return (tp, None)
            return None                     # wr, mu_* replicated
        # --- attention -------------------------------------------------------
        if name == "wq":
            return (None, tp, None)
        if name in ("wk", "wv"):
            return (None, tp if kv_ok else None, None)
        if name == "wo" and parent in ("attn", "block"):
            return (tp, None, None)
        if name == "bq":
            return (tp, None)
        if name in ("bk", "bv"):
            return (tp if kv_ok else None, None)
        # MLA
        if name in ("wq_a", "wkv_a"):
            return (None, None)
        if name in ("wq_b", "wk_b", "wv_b"):
            return (None, tp, None)
        # --- dense / shared FFN ------------------------------------------------
        if parent == "shared":
            return None      # shared expert runs on token-split shards,
                             # weights replicated (see moe_block)
        if name in ("w1", "w3"):
            return (None, tp)
        if name == "w2":
            return (tp, None)
        # --- mamba2 --------------------------------------------------------------
        if name in ("wx", "wz", "wdt"):
            return (None, tp)
        if name in ("wB", "wC", "conv_B", "conv_C"):
            return (None, None)
        if name == "conv_x":
            return (tp, None)
        if name in ("A_log", "D", "dt_bias"):
            return (tp,)
        if parent == "mamba" and name == "wo":
            return (tp, None)
        if parent == "norm" and name == "scale":
            return (tp,)                       # mamba gated norm over d_in
        # --- rwkv (remaining time-mix leaves) --------------------------------
        if name in ("w0", "u"):
            return (tp if nh_rwkv_ok else None, None)
        if name == "decay_b":
            return (None, tp if nh_rwkv_ok else None, None)
        if parent == "ln_x":
            return (tp if nh_rwkv_ok else None, None)
        if name in ("mu", "mix_a", "mix_b", "decay_a"):
            return None
        # --- misc ----------------------------------------------------------------
        if name in ("scale", "bias", "proj"):
            return None
        return None

    def rule(path: Tuple[str, ...], ndim: int) -> P:
        parent = path[-2] if len(path) >= 2 else ""
        name = path[-1]
        b = base(parent, name)
        if b is None:
            return P()
        pad = ndim - len(b)
        assert pad >= 0, (path, ndim, b)
        return P(*((None,) * pad + tuple(b)))

    return rule


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        elif hasattr(k, "name"):
            out.append(str(k.name))
        else:
            out.append(str(k))
    return tuple(out)


def param_specs(params_tree, cfg: ModelConfig, plan: MeshPlan):
    """Spec pytree matching ``params_tree`` (arrays or ShapeDtypeStructs)."""
    rule = param_spec_rules(cfg, plan)
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_tree)
    specs = [rule(_path_names(p), np.ndim(l) if not hasattr(l, "ndim") else l.ndim)
             for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def shard_axes(spec_tree, plan: MeshPlan):
    """For each leaf: the mesh axes it is REPLICATED over (grad-sync axes)."""
    every = set(plan.all_axes)

    def one(spec: P):
        used = set()
        for entry in spec:
            if entry is None:
                continue
            if isinstance(entry, (tuple, list)):
                used.update(entry)
            else:
                used.add(entry)
        return tuple(a for a in plan.all_axes if a in (every - used))

    return jax.tree.map(one, spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def sharded_axes_only(spec_tree, plan: MeshPlan):
    """For each leaf: the mesh axes it IS sharded over (norm-sync axes)."""
    def one(spec: P):
        used = []
        for entry in spec:
            if entry is None:
                continue
            if isinstance(entry, (tuple, list)):
                used.extend(entry)
            else:
                used.append(entry)
        return tuple(used)

    return jax.tree.map(one, spec_tree, is_leaf=lambda x: isinstance(x, P))


# =============================================================================
# Batch / cache specs
# =============================================================================

def batch_dim_spec(batch: int, plan: MeshPlan):
    """Shard the batch over dp axes when divisible, else replicate."""
    dp = plan.dp_axes
    if dp and batch % plan.dp == 0:
        return tuple(dp) if len(dp) > 1 else dp[0]
    return None


def batch_specs(batch_tree, plan: MeshPlan):
    def one(leaf):
        b = leaf.shape[0] if leaf.ndim else 1
        return P(*((batch_dim_spec(b, plan),) + (None,) * max(leaf.ndim - 1, 0)))
    return jax.tree.map(one, batch_tree)


def cache_specs(cache_tree, cfg: ModelConfig, plan: MeshPlan, batch: int):
    """Decode caches: batch over dp; head-ish dims over tp where divisible.

    Cache layouts (after per-stage stacking prepends 1-2 scan dims):
      attn k/v: (B, W, KV, hd)   mla ckv/kpe: (B, W, r)   pos: (W,)
      mamba ssm: (B, nh, hd, ds) conv_*: (B, W-1, C)
      rwkv wkv: (B, nh, hd, hd)  x_prev_*: (B, 1, d)
    """
    tp = plan.tp_axis
    bspec = batch_dim_spec(batch, plan)
    tpn = max(plan.tp, 1)
    kv_ok = cfg.num_kv_heads % tpn == 0
    seq_shard = getattr(cfg, "kv_seq_shard", False) and tpn > 1

    def one(path, leaf):
        names = _path_names(path)
        name = names[-1]
        nd = leaf.ndim
        if name == "pos":
            if seq_shard:
                return P(*((None,) * (nd - 1) + (tp,)))
            return P(*((None,) * nd))
        if name in ("pool_k", "pool_v"):
            # paged KV pool (P_pages, page, KV, hd): NO batch dim — pages are
            # owned via the page table, so the pool replicates over dp and
            # shards only the KV-head dim over tp (when it divides)
            b = (None, None, tp if kv_ok else None, None)
            return P(*((None,) * (nd - len(b)) + b))
        if name == "table":
            return P(*((None,) * nd))       # page table: tiny, replicated
        if name in ("k", "v"):
            if seq_shard:
                b = (bspec, tp, None, None)     # sequence-sharded cache
            else:
                b = (bspec, None, tp if kv_ok else None, None)
        elif name in ("ckv", "kpe"):
            b = (bspec, None, None)
        elif name == "ssm":
            b = (bspec, tp, None, None)
        elif name == "conv_x":
            b = (bspec, None, tp)
        elif name in ("conv_B", "conv_C"):
            b = (bspec, None, None)
        elif name == "wkv":
            nh_ok = (cfg.rwkv is not None
                     and (cfg.d_model // cfg.rwkv.head_dim) % tpn == 0)
            b = (bspec, tp if nh_ok else None, None, None)
        elif name.startswith("x_prev"):
            b = (bspec, None, None)
        else:
            b = (bspec,) + (None,) * (nd - 1)
        pad = nd - len(b)
        return P(*((None,) * pad + tuple(b)))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_tree)
    return jax.tree_util.tree_unflatten(
        treedef, [one(p, l) for p, l in flat])

"""Collective-communication abstraction.

Every collective the framework issues goes through these helpers. Passing a
plan whose axes are empty (``single_device_plan()``) turns each helper into the
identity, so the exact same model code doubles as the pure-jnp single-device
oracle used by unit tests and kernel references.

This mirrors the paper's process-group design (Fig. 5): instead of
``inter_node_process_group`` / ``intra_node_process_group`` objects, a named
mesh axis *is* the process group, and ``jax.lax`` collectives over an axis
tuple are the group collectives.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax import lax
from jax.ad_checkpoint import checkpoint_name

Axes = Union[None, str, Tuple[str, ...]]


def _norm(axes: Axes) -> Tuple[str, ...]:
    if axes is None:
        return ()
    if isinstance(axes, str):
        return (axes,)
    return tuple(axes)


def psum(x, axes: Axes, axis_index_groups=None):
    axes = _norm(axes)
    if not axes:
        return x
    return lax.psum(x, axes, axis_index_groups=axis_index_groups)


def pmean(x, axes: Axes):
    axes = _norm(axes)
    if not axes:
        return x
    return lax.pmean(x, axes)


def pmax(x, axes: Axes):
    axes = _norm(axes)
    if not axes:
        return x
    return lax.pmax(x, axes)


def all_gather(x, axes: Axes, *, axis: int = 0, tiled: bool = True):
    axes = _norm(axes)
    if not axes:
        return x
    return lax.all_gather(x, axes, axis=axis, tiled=tiled)


def psum_scatter(x, axes: Axes, *, scatter_dimension: int = 0, tiled: bool = True):
    axes = _norm(axes)
    if not axes:
        return x
    return lax.psum_scatter(x, axes, scatter_dimension=scatter_dimension,
                            tiled=tiled)


def all_to_all(x, axes: Axes, *, split_axis: int, concat_axis: int,
               tiled: bool = False):
    """All2All over ``axes``. Identity when the group size is 1.

    With ``tiled=False`` the ``split_axis`` dim must equal the group size and
    is consumed/produced whole: local ``(G, ...)`` -> received ``(G, ...)``
    where the leading index becomes the *source* group rank.
    """
    axes = _norm(axes)
    if not axes:
        return x
    return lax.all_to_all(x, axes, split_axis=split_axis,
                          concat_axis=concat_axis, tiled=tiled)


def axis_index(axes: Axes):
    axes = _norm(axes)
    if not axes:
        return jnp.int32(0)
    return lax.axis_index(axes)


SAVED_COLLECTIVE = "tp_collective"


def name_saved(x):
    """Tag a collective output for the ``remat_save_collectives`` policy:
    under remat, the backward pass replays the forward — including its
    psums/all_gathers, doubling wire traffic. Saving exactly these outputs
    keeps remat's memory win while removing the re-communication."""
    return checkpoint_name(x, SAVED_COLLECTIVE)


def save_collectives_policy():
    return jax.checkpoint_policies.save_only_these_names(SAVED_COLLECTIVE)


def ppermute(x, axes: Axes, perm):
    axes = _norm(axes)
    if not axes:
        return x
    return lax.ppermute(x, axes, perm=perm)


# ---------------------------------------------------------------- token split
def split_tokens(x, plan_axes: Axes, size: int):
    """Evenly split the leading (token) dim of ``x`` across ``plan_axes``.

    Pads to a multiple of ``size`` when needed; returns ``(local, pad)`` where
    ``pad`` is the number of padding rows appended *globally* (the local shard
    of this device may or may not contain padding — callers mask via the
    returned valid length arithmetic). Used to convert tensor-parallel
    replicated activations into expert-parallel token shards for the MoE block
    (the paper's "each worker owns a slice of the batch").
    """
    axes = _norm(plan_axes)
    t = x.shape[0]
    pad = (-t) % size
    if pad:
        x = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1))
    if not axes:
        return x, pad
    idx = lax.axis_index(axes)
    per = x.shape[0] // size
    local = lax.dynamic_slice_in_dim(x, idx * per, per, axis=0)
    return local, pad


def unsplit_tokens(local, plan_axes: Axes, orig_len: int):
    """Inverse of :func:`split_tokens`: all_gather shards and drop padding."""
    axes = _norm(plan_axes)
    if axes:
        local = lax.all_gather(local, axes, axis=0, tiled=True)
    return local[:orig_len]

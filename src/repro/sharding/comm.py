"""Collective-communication abstraction.

Every collective the framework issues goes through these helpers. Passing a
plan whose axes are empty (``single_device_plan()``) turns each helper into the
identity, so the exact same model code doubles as the pure-jnp single-device
oracle used by unit tests and kernel references.

This mirrors the paper's process-group design (Fig. 5): instead of
``inter_node_process_group`` / ``intra_node_process_group`` objects, a named
mesh axis *is* the process group, and ``jax.lax`` collectives over an axis
tuple are the group collectives.

**Wire-integrity format (parity rows).**  The checksummed ragged exchange
(:func:`checksummed_ragged_all_to_all`) makes every ragged wire segment
individually accountable without a second collective: each sender appends,
after the data rows of each destination's segment, ``nl`` *parity rows* —
one per (destination, local-group) sub-segment — so the wire segment for
peer ``p`` is ``send_counts[p]`` data rows followed by ``nl`` parity rows
and the wire counts are simply ``send_counts + nl``.  A parity row is the
segment's int32 integrity word per model lane, stored bitcast into the
payload dtype: ``word[lane] = fold[lane] + len * WIRE_LEN_MULT + tag *
WIRE_TAG_MULT`` (wrapping int32), where ``fold`` is the sum over the
segment's occupied rows of the lanes' bitcast integer views, ``len`` is
the segment's occupied-row count and ``tag`` encodes (src rank, dst rank,
group).  The receiver recomputes the word from the believed counts and
payload (:func:`segment_parity_words`) and compares in the stored domain
(:func:`stored_words` — the low 16 bits for 16-bit payload dtypes): the
fold term catches value corruption, the length term catches in-bounds
count inflation the grid sanitizer provably cannot see, and the tag term
catches replayed/duplicated segments.  Verification, quarantine and event
accounting live in ``core/pipeline``; this module only defines the wire
format and moves the bytes.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax import lax
from jax.ad_checkpoint import checkpoint_name

Axes = Union[None, str, Tuple[str, ...]]


def _norm(axes: Axes) -> Tuple[str, ...]:
    if axes is None:
        return ()
    if isinstance(axes, str):
        return (axes,)
    return tuple(axes)


def psum(x, axes: Axes, axis_index_groups=None):
    axes = _norm(axes)
    if not axes:
        return x
    return lax.psum(x, axes, axis_index_groups=axis_index_groups)


def pmean(x, axes: Axes):
    axes = _norm(axes)
    if not axes:
        return x
    return lax.pmean(x, axes)


def pmax(x, axes: Axes):
    axes = _norm(axes)
    if not axes:
        return x
    return lax.pmax(x, axes)


def all_gather(x, axes: Axes, *, axis: int = 0, tiled: bool = True):
    axes = _norm(axes)
    if not axes:
        return x
    return lax.all_gather(x, axes, axis=axis, tiled=tiled)


def psum_scatter(x, axes: Axes, *, scatter_dimension: int = 0, tiled: bool = True):
    axes = _norm(axes)
    if not axes:
        return x
    return lax.psum_scatter(x, axes, scatter_dimension=scatter_dimension,
                            tiled=tiled)


def all_to_all(x, axes: Axes, *, split_axis: int, concat_axis: int,
               tiled: bool = False):
    """All2All over ``axes``. Identity when the group size is 1.

    With ``tiled=False`` the ``split_axis`` dim must equal the group size and
    is consumed/produced whole: local ``(G, ...)`` -> received ``(G, ...)``
    where the leading index becomes the *source* group rank.
    """
    axes = _norm(axes)
    if not axes:
        return x
    return lax.all_to_all(x, axes, split_axis=split_axis,
                          concat_axis=concat_axis, tiled=tiled)


def axis_index(axes: Axes):
    axes = _norm(axes)
    if not axes:
        return jnp.int32(0)
    return lax.axis_index(axes)


SAVED_COLLECTIVE = "tp_collective"


def name_saved(x):
    """Tag a collective output for the ``remat_save_collectives`` policy:
    under remat, the backward pass replays the forward — including its
    psums/all_gathers, doubling wire traffic. Saving exactly these outputs
    keeps remat's memory win while removing the re-communication."""
    return checkpoint_name(x, SAVED_COLLECTIVE)


def save_collectives_policy():
    return jax.checkpoint_policies.save_only_these_names(SAVED_COLLECTIVE)


def ppermute(x, axes: Axes, perm):
    axes = _norm(axes)
    if not axes:
        return x
    return lax.ppermute(x, axes, perm=perm)


def uniform_cond(pred, true_fn, false_fn, *operands):
    """``lax.cond`` whose predicate the caller guarantees is mesh-uniform.

    A cond whose branches run *different* collective sequences deadlocks
    (or silently mismatches) the moment devices disagree on the predicate:
    some ranks enter the branch's psum, the rest never arrive.  The static
    analyzer (:mod:`repro.analysis.jaxpr_lint`) therefore flags every cond
    with asymmetric branch collectives — EXCEPT conds lowered through this
    wrapper, the one blessed site asserting the uniformity contract: the
    predicate must be computed from collectively reduced values (e.g. a
    psum'd verdict) so every rank takes the same branch and the asymmetry
    is unobservable.  The sentinel's gated optimizer apply
    (``train/sentinel.py``) is the canonical user: its predicate is the
    step verdict, psum'd over every sync axis before the branch.
    """
    return lax.cond(pred, true_fn, false_fn, *operands)


# ------------------------------------------------------------- ragged All2All
def excl_cumsum(c: jax.Array) -> jax.Array:
    """Exclusive int32 cumsum — the segment-offset idiom every ragged
    layout shares (comm, pipeline)."""
    return jnp.concatenate([jnp.zeros((1,), jnp.int32),
                            jnp.cumsum(c).astype(jnp.int32)])[:-1]


def clamped_segment_counts(m: jax.Array, recv_rows: int) -> jax.Array:
    """Paired clamped sizes of a truncating ragged exchange.

    ``m``: the full (P, P) count matrix (``m[s, d]`` = rows source ``s``
    ships to destination ``d`` — every rank holds it after the native
    path's ``all_gather``); ``recv_rows``: the static receive bound every
    destination applies.  Segments land at their *unclamped* source-major
    offsets (the exclusive cumsum down each column) and whatever falls past
    the bound is prefix-truncated, so ``kept[s, d] = clip(recv_rows -
    off[s, d], 0, m[s, d])``.

    Row ``me`` of the result is a rank's clamped SEND sizes, column ``me``
    its clamped RECV sizes: because every rank computes the same matrix,
    sender and receiver agree on every pair — the paired offset/size
    contract ``lax.ragged_all_to_all`` requires, with exactly the
    emulations' truncation semantics.  Pure integer math (unit-tested
    against the emulation oracles in ``tests/distributed/_ragged_a2a.py``).
    """
    off = jnp.cumsum(m, axis=0) - m           # per-column exclusive cumsum
    return jnp.clip(recv_rows - off, 0, m)


def native_truncation_plan(m, me, recv_rows: int):
    """Per-rank arguments of the native truncating ragged exchange.

    From the replicated (P, P) count matrix ``m`` and a rank index ``me``,
    derive the ``(send_sizes, out_off, recv_sizes)`` triple rank ``me``
    hands to ``lax.ragged_all_to_all`` under ``allow_truncate=True``.  All
    three come from the one :func:`clamped_segment_counts` matrix every
    rank computes identically, which is what makes the op's paired
    contract hold across ranks:

    * ``send_sizes`` — row ``me``: my clamped outgoing segment sizes,
      indexed by DESTINATION rank;
    * ``recv_sizes`` — column ``me``: my clamped incoming segment sizes,
      indexed by SOURCE rank, equal pair-for-pair to each sender's
      ``send_sizes[me]`` because both read the same matrix cell;
    * ``out_off`` — where my outgoing segments land in each destination's
      buffer: the *unclamped* source-major offsets (prefix truncation
      keeps them valid — each kept part is a segment prefix), indexed by
      destination like ``send_sizes``.  A fully truncated segment has
      size 0 but an offset past the bound; pin it with its PAIRED send
      size (same destination index space) so ``out_off + send_sizes <=
      recv_rows`` always holds.

    Pure integer math, so the cross-rank pairing is asserted numerically
    in ``tests/distributed/_ragged_a2a.py`` even where the installed jax
    predates the native op.
    """
    kept = clamped_segment_counts(m, recv_rows)
    send_sizes = jnp.take(kept, me, axis=0)
    recv_sizes = jnp.take(kept, me, axis=1)
    out_off = jnp.take(jnp.cumsum(m, axis=0) - m, me, axis=0)
    out_off = jnp.minimum(out_off, recv_rows - send_sizes)
    return send_sizes, out_off, recv_sizes


def _fit_counts(counts: jax.Array, seg_cap: int) -> jax.Array:
    """Clamp per-peer segment counts into the statically valid range.

    Counts arrive over the wire, so the layout math below must not trust
    them: the fused emulation's compaction gather reads ``seg * S +
    within`` — a count beyond the per-segment staging bound ``S`` would
    silently hand back a *different peer's* rows (no crash, ``jnp.take``
    clamps, just wrong-expert data), and a negative count corrupts every
    later peer's cumsum offset.  Semantic validation (and event
    accounting) lives in ``pipeline.sanitize_len_grid``; this is comm's
    own belt-and-braces guarantee that NO count value can make the wire
    primitive read rows it wasn't sent.  Pure integer clip — identity,
    and bit-identical, on healthy counts.
    """
    return jnp.clip(counts, 0, seg_cap)


def assert_count_i32(counts: jax.Array, what: str) -> None:
    """Trace-time dtype gate for count grids at the collective boundary.

    The wire contract is int32 everywhere: silent promotion (x64 mode, a
    stray python-int arithmetic) doubles count-exchange bytes and breaks
    the native ragged-A2A paired offset/size contract.  The static
    analyzer enforces the same rule on traced jaxprs
    (``collective-int-dtype``); this is its dynamic twin for call paths
    the entrypoint grid doesn't reach.
    """
    if counts.dtype != jnp.int32:
        raise TypeError(
            f"{what} must be int32 at the collective boundary, got "
            f"{counts.dtype} (silent x64/promotion?)")


def exchange_counts(send_counts: jax.Array, axes: Axes) -> jax.Array:
    """Tiny int32 All2All: tell every peer how many rows it will receive.

    ``send_counts``: (P,) — entry ``p`` is how many rows this device sends to
    joint rank ``p`` of ``axes``.  Returns (P,) where entry ``p`` is how many
    rows rank ``p`` sends to *this* device.  Identity when the group is 1.
    """
    assert_count_i32(send_counts, "exchange_counts(send_counts)")
    naxes = _norm(axes)
    P = send_counts.shape[0]
    if not naxes or P == 1:
        return send_counts
    return lax.all_to_all(send_counts.reshape(P, 1), naxes, split_axis=0,
                          concat_axis=0).reshape(P)


def ragged_all_to_all(rows: jax.Array, send_counts: jax.Array, axes: Axes,
                      *, recv_rows: int, seg_rows: Optional[int] = None,
                      recv_counts: Optional[jax.Array] = None,
                      emulation: str = "auto", allow_truncate: bool = False
                      ) -> Tuple[jax.Array, jax.Array]:
    """All2All of *exact* per-peer row segments — no capacity padding on the
    wire (the SMILE bottleneck fix MegaScale-MoE ships in production).

    ``rows``: (R, ...) staging buffer holding, contiguously and in rank order,
    the segment destined for each of the P joint ranks of ``axes``: peer ``p``'s
    segment occupies rows ``[off[p], off[p] + send_counts[p])`` where ``off``
    is the exclusive cumsum of ``send_counts`` (P,).  ``recv_rows`` is the
    static bound of the received layout (callers pass ``P * R``: every source
    can send at most its whole staging buffer).  ``seg_rows`` optionally
    tightens the static bound on any SINGLE per-peer segment (default: all
    of ``rows``) — the reverse of a hop passes the forward layout's row
    count, since no returning segment can exceed what was originally sent;
    without it the emulations would stage ``P x recv_rows`` slabs.
    ``recv_counts`` skips the count exchange when the caller already knows
    the per-source segment lengths (e.g. derived from a counts grid it
    exchanged anyway, or the mirrored counts of a forward hop).

    Returns ``(recv, recv_counts)``: ``recv`` (recv_rows, ...) holds source
    ``p``'s segment at the exclusive cumsum of ``recv_counts`` (source-major),
    zero elsewhere; ``recv_counts`` (P,) is the per-source segment length.
    Calling again with ``send_counts=recv_counts`` and ``recv_rows=R`` routes
    each segment back to its origin at the original offsets — the reverse hop.

    Three wire strategies behind the same contract, picked by ``emulation``:

    * ``"auto"`` + ``lax.ragged_all_to_all`` available (jax >= 0.4.38) —
      the native op; exact segment bytes move.
    * ``"auto"``/``"a2a"`` otherwise — the P rotation rounds fused into ONE
      ``lax.all_to_all`` of the ``(P, R)`` staging slab (entry ``p`` is the
      buffer rolled so peer ``p``'s segment starts at row 0), followed by a
      single count-driven compaction gather.  Ships ``P * R`` rows but as
      one fused collective — the fast emulation.
    * ``"ppermute"`` — P-1 explicit rotation rounds: round ``s`` sends each
      rank's segment for peer ``rank+s``, validity carried by the exchanged
      counts.  Same bytes as ``"a2a"`` spread over P-1 neighbor rounds — the
      schedule a ring fabric (or a future Pallas remote-DMA kernel) wants,
      kept selectable and tested; slower under CPU emulation.

    Identity when the group size is 1 (``recv = rows`` zero-padded to
    ``recv_rows``).

    ``allow_truncate=True`` permits a ``recv_rows`` bound SMALLER than the
    worst case: arriving segments whose offsets fall past the bound are
    truncated (rows simply never materialize) — the mechanism behind the
    receive-bound factor of :mod:`repro.core.pipeline`.  Both emulations
    truncate naturally (their compaction indexes past the buffer are
    dropped); the native op's paired offset/size contract cannot express an
    out-of-bounds write, so the native path instead *pre-clamps* both sides
    from one replicated computation: every rank derives the full (P, P)
    count matrix it already all_gathers, applies
    :func:`clamped_segment_counts`, and uses row ``me`` as its send sizes
    and column ``me`` as its receive sizes — sender and receiver agree on
    every pair by construction, and exactly the emulations'
    prefix-truncation semantics move on the wire (asserted against both
    emulation oracles in ``tests/distributed/_ragged_a2a.py``).  Callers
    are responsible for knowing which rows survived — the cumsum of
    ``recv_counts`` clipped to ``recv_rows``.

    The ``REPRO_RAGGED_A2A_EMULATION`` environment variable overrides an
    ``"auto"`` selection (values: ``auto``/``a2a``/``ppermute``) — the
    recoverable escape hatch if a future jax's native op misbehaves (it is
    auto-selected the moment the installed jax provides it, which no CI
    here can exercise): forcing an oracle-verified emulation keeps the wire
    semantics instead of falling all the way back to padded capacity hops.
    """
    import os
    if emulation == "auto":
        emulation = os.environ.get("REPRO_RAGGED_A2A_EMULATION", "auto")
    assert_count_i32(send_counts, "ragged_all_to_all(send_counts)")
    if recv_counts is not None:
        assert_count_i32(recv_counts, "ragged_all_to_all(recv_counts)")
    naxes = _norm(axes)
    P = send_counts.shape[0]
    rest = rows.shape[1:]
    if not naxes or P == 1:
        out = jnp.zeros((recv_rows,) + rest, rows.dtype)
        n = min(recv_rows, rows.shape[0])
        out = out.at[:n].set(rows[:n])
        return out, send_counts
    send_off = excl_cumsum(send_counts)
    if emulation == "auto" and hasattr(lax, "ragged_all_to_all"):
        # native path: my segment for peer p lands on p at the offset where
        # p expects MY slice — sum over sources before me of what they send
        # to p, i.e. row ``me`` of the source-exclusive cumsum of the full
        # (src, dst) count matrix (which also supplies recv_counts as
        # column ``me`` — no separate count exchange)
        me = lax.axis_index(naxes)
        m = lax.all_gather(send_counts, naxes, axis=0, tiled=False)  # (P, P)
        if recv_counts is None:
            recv_counts = jnp.take(m, me, axis=1)
        recv_counts = _fit_counts(recv_counts, recv_rows)
        if allow_truncate:
            send_sizes, out_off, recv_sizes = native_truncation_plan(
                m, me, recv_rows)
        else:
            out_off = jnp.take(jnp.cumsum(m, axis=0) - m, me, axis=0)
            send_sizes = send_counts
            recv_sizes = recv_counts
        out = jnp.zeros((recv_rows,) + rest, rows.dtype)
        return lax.ragged_all_to_all(
            rows, out, send_off.astype(jnp.int32),
            send_sizes.astype(jnp.int32), out_off.astype(jnp.int32),
            recv_sizes.astype(jnp.int32),
            axis_name=naxes if len(naxes) > 1 else naxes[0]), recv_counts
    if recv_counts is None:
        recv_counts = exchange_counts(send_counts, naxes)
    R = rows.shape[0]
    S = R if seg_rows is None else min(seg_rows, R)
    recv_counts = _fit_counts(recv_counts, S)
    recv_off = excl_cumsum(recv_counts)
    ar = jnp.arange(S, dtype=jnp.int32)
    bshape = (-1,) + (1,) * len(rest)
    if emulation in ("auto", "a2a"):
        # fused emulation: staging slab (P, S) with peer p's segment rolled
        # to row 0 of entry p; one all_to_all; then a single gather compacts
        # the (src, S)-strided arrivals to the cumsum layout, validity from
        # the exchanged counts (lazy import: layout math lives with the
        # dispatch helpers, and comm must stay importable standalone)
        from repro.core.dispatch import ragged_row_membership
        idx = (ar[None, :] + send_off[:, None]) % R              # (P, S)
        staging = jnp.take(rows, idx.reshape(-1), axis=0
                           ).reshape((P, S) + rest)
        got = lax.all_to_all(staging, naxes, split_axis=0, concat_axis=0)
        coff = jnp.concatenate([recv_off,
                                recv_off[-1:] + recv_counts[-1:]])  # (P+1,)
        seg, within, valid = ragged_row_membership(coff, recv_counts,
                                                   recv_rows)
        src_row = jnp.where(valid, seg * S + within, 0)
        out = jnp.take(got.reshape((P * S,) + rest), src_row, axis=0)
        return jnp.where(valid.reshape(bshape), out, 0), recv_counts
    if emulation != "ppermute":
        raise ValueError(f"unknown emulation {emulation!r}")
    # ppermute rounds: rotation round s pairs every rank i with dst i+s and
    # src i-s (mod P); the slab is the staging buffer rolled so the outgoing
    # segment starts at row 0, and the receiver keeps the first
    # recv_counts[src] rows
    me = lax.axis_index(naxes)
    out = jnp.zeros((recv_rows,) + rest, rows.dtype)
    for s in range(P):
        dst = (me + s) % P
        src = (me - s) % P
        slab = jnp.take(rows, (ar + send_off[dst]) % R, axis=0)  # (S, ...)
        if s:
            slab = lax.ppermute(slab, naxes,
                                perm=[(j, (j + s) % P) for j in range(P)])
        cnt = recv_counts[src]
        idx = jnp.where(ar < cnt, recv_off[src] + ar, recv_rows)  # OOB = drop
        out = out.at[idx].add(
            jnp.where((ar < cnt).reshape(bshape), slab, 0), mode="drop")
    return out, recv_counts


# --------------------------------------------------- wire-integrity (parity)
# Fold multipliers of the per-segment integrity word (module docstring).
# Both odd (units mod 2^32, so distinct lengths/tags map to distinct
# residues) and far apart so a single-row value delta cannot mimic either.
WIRE_LEN_MULT = 1000003
WIRE_TAG_MULT = 777767777


def _lane_int_dtype(dtype) -> jnp.dtype:
    """The same-width integer dtype of a payload lane."""
    return jnp.dtype(f"int{jnp.dtype(dtype).itemsize * 8}")


def int_lane_view(rows: jax.Array) -> jax.Array:
    """Bitcast a float slab to int32 lanes (sign-extending 16-bit dtypes).

    The integrity fold is wrapping int32 arithmetic over this view, so the
    fold of a bf16 slab and of its f32 upcast differ — folds only compare
    against folds of the same payload dtype, which the wire guarantees.
    """
    it = _lane_int_dtype(rows.dtype)
    return lax.bitcast_convert_type(rows, it).astype(jnp.int32)


def words_to_rows(words: jax.Array, dtype) -> jax.Array:
    """Store int32 integrity words as rows of a ``dtype``-typed slab.

    32-bit payloads hold the whole word; 16-bit payloads hold its low half
    (``bitcast_convert_type`` to int16 splits little-endian, index 0 is the
    low half) — 16 bits of fold still make an accidental collision a
    1-in-65536 event per lane, and every lane must collide at once.
    """
    assert_count_i32(words, "words_to_rows(words)")
    it = _lane_int_dtype(dtype)
    if it == jnp.int32:
        return lax.bitcast_convert_type(words, dtype)
    return lax.bitcast_convert_type(
        lax.bitcast_convert_type(words, it)[..., 0], dtype)


def stored_words(words: jax.Array, dtype) -> jax.Array:
    """Project int32 words onto the domain a ``dtype`` slab round-trips.

    Expected words must be compared to received parity rows in this domain
    — comparing the full int32 word against a 16-bit stored half would
    flag every healthy segment.
    """
    return int_lane_view(words_to_rows(words, dtype))


def segment_parity_words(rows: jax.Array, bounds: jax.Array,
                         lens: jax.Array, tags: jax.Array) -> jax.Array:
    """Integrity word of each segment of a concatenated-segments slab.

    ``rows``: (R, d) payload; ``bounds``: (S+1,) ascending segment start
    offsets (segment ``s`` spans ``[bounds[s], bounds[s+1])``, first
    ``lens[s]`` rows occupied); ``tags``: (S,) int32 identity tag folded
    into each word.  Returns (S, d) int32 words.  Pure jnp scatter-add —
    both sides of a wire recompute it from the counts they believe, so a
    disagreement in payload bits, occupancy or identity lands in the word.
    """
    from repro.core.dispatch import ragged_row_membership
    assert_count_i32(lens, "segment_parity_words(lens)")
    assert_count_i32(tags, "segment_parity_words(tags)")
    S = lens.shape[0]
    seg, _, valid = ragged_row_membership(bounds, lens, rows.shape[0])
    contrib = jnp.where(valid[:, None], int_lane_view(rows), 0)
    fold = jnp.zeros((S, rows.shape[1]), jnp.int32).at[
        jnp.where(valid, seg, 0)].add(contrib)
    return fold + (lens * WIRE_LEN_MULT + tags * WIRE_TAG_MULT)[:, None]


def checksummed_ragged_all_to_all(rows: jax.Array, parity: jax.Array,
                                  send_counts: jax.Array, axes: Axes, *,
                                  recv_rows: int, recv_counts: jax.Array,
                                  nl: int, allow_truncate: bool = False
                                  ) -> Tuple[jax.Array, jax.Array]:
    """Ragged All2All with per-segment parity rows riding the same slab.

    ``rows``: (R, d) rank-major staged data (exactly as
    :func:`ragged_all_to_all` takes it); ``parity``: (P*nl, d) parity rows
    in payload dtype, destination-major (rows ``p*nl:(p+1)*nl`` ride at
    the tail of peer ``p``'s segment).  ``recv_counts`` are the believed
    per-source DATA counts; the wire moves ``send_counts + nl`` rows per
    peer and ``recv_rows`` must bound the WIRE layout (data bound plus
    ``P * nl``).  Returns ``(wire_recv, wire_recv_counts)`` — split back
    into payload + parity with :func:`split_checksummed_recv`.

    One gather builds the interleaved wire staging from ``concat([rows,
    parity])``; the exchange itself is one ordinary
    :func:`ragged_all_to_all` of the widened counts — no extra collective,
    no extra count exchange, and the parity rows are subject to exactly
    the same wire hazards as the data they guard (that is the point).
    """
    from repro.core.dispatch import ragged_row_membership
    assert_count_i32(send_counts, "checksummed_ragged_all_to_all(send_counts)")
    assert_count_i32(recv_counts, "checksummed_ragged_all_to_all(recv_counts)")
    P = send_counts.shape[0]
    R = rows.shape[0]
    rest = rows.shape[1:]
    scw = send_counts + jnp.int32(nl)
    woff = excl_cumsum(scw)
    bounds = jnp.concatenate([woff, woff[-1:] + scw[-1:]])
    w_send = R + P * nl
    seg, within, valid = ragged_row_membership(bounds, scw, w_send)
    send_off = excl_cumsum(send_counts)
    sc_seg = jnp.take(send_counts, seg)
    is_data = within < sc_seg
    src = jnp.where(is_data, jnp.take(send_off, seg) + within,
                    R + seg * nl + (within - sc_seg))
    ext = jnp.concatenate([rows, parity.astype(rows.dtype)], axis=0)
    wire = jnp.where(valid.reshape((-1,) + (1,) * len(rest)),
                     jnp.take(ext, jnp.where(valid, src, 0), axis=0), 0)
    return ragged_all_to_all(wire, scw, axes, recv_rows=recv_rows,
                             recv_counts=recv_counts + jnp.int32(nl),
                             allow_truncate=allow_truncate)


def split_checksummed_recv(wire: jax.Array, recv_counts: jax.Array, nl: int,
                           recv_rows: int
                           ) -> Tuple[jax.Array, jax.Array]:
    """Split a checksummed receive back into payload slab + parity rows.

    ``recv_counts``: believed per-source DATA counts (P,); ``recv_rows``:
    the DATA slab bound.  Returns ``(data, parity)`` — ``data``
    (recv_rows, d) laid out exactly as the plain :func:`ragged_all_to_all`
    receive (source ``p`` at the exclusive cumsum of ``recv_counts``, zero
    elsewhere), ``parity`` (P, nl, d) the received parity rows.  Gathers
    clamp at the slab edge, so callers that truncated the wire bound must
    mask out sources whose region did not fully arrive before trusting
    either piece.
    """
    from repro.core.dispatch import ragged_row_membership
    assert_count_i32(recv_counts, "split_checksummed_recv(recv_counts)")
    P = recv_counts.shape[0]
    rest = wire.shape[1:]
    woff = excl_cumsum(recv_counts + jnp.int32(nl))
    doff = excl_cumsum(recv_counts)
    bounds = jnp.concatenate([doff, doff[-1:] + recv_counts[-1:]])
    seg, within, valid = ragged_row_membership(bounds, recv_counts, recv_rows)
    src = jnp.where(valid, jnp.take(woff, seg) + within, 0)
    data = jnp.where(valid.reshape((-1,) + (1,) * len(rest)),
                     jnp.take(wire, src, axis=0), 0)
    pidx = (woff[:, None] + recv_counts[:, None]
            + jnp.arange(nl, dtype=jnp.int32)[None, :])
    parity = jnp.take(wire, pidx.reshape(-1), axis=0
                      ).reshape((P, nl) + rest)
    return data, parity


# ---------------------------------------------------------------- token split
def split_tokens(x, plan_axes: Axes, size: int):
    """Evenly split the leading (token) dim of ``x`` across ``plan_axes``.

    Pads to a multiple of ``size`` when needed; returns ``(local, pad)`` where
    ``pad`` is the number of padding rows appended *globally* (the local shard
    of this device may or may not contain padding — callers mask via the
    returned valid length arithmetic). Used to convert tensor-parallel
    replicated activations into expert-parallel token shards for the MoE block
    (the paper's "each worker owns a slice of the batch").
    """
    axes = _norm(plan_axes)
    t = x.shape[0]
    pad = (-t) % size
    if pad:
        x = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1))
    if not axes:
        return x, pad
    idx = lax.axis_index(axes)
    per = x.shape[0] // size
    local = lax.dynamic_slice_in_dim(x, idx * per, per, axis=0)
    return local, pad


def unsplit_tokens(local, plan_axes: Axes, orig_len: int):
    """Inverse of :func:`split_tokens`: all_gather shards and drop padding."""
    axes = _norm(plan_axes)
    if axes:
        local = lax.all_gather(local, axes, axis=0, tiled=True)
    return local[:orig_len]

"""MeshPlan: how logical parallelism roles map onto mesh axes.

The paper's bi-level routing factorizes a flat expert-parallel All2All over
``N = n x m`` workers into two levels: an inter-node level (slow fabric) and an
intra-node level (fast fabric).  On TPU we express both levels as *mesh axes*.

A :class:`MeshPlan` names, for one concrete mesh:

* ``dp_axes``   — pure data-parallel axes (batch sharding + gradient reduction)
* ``tp_axis``   — tensor-parallel axis for dense blocks (Megatron style)
* ``ep_inter``  — SMILE level-1 ("node") axes. All2All #1 runs here.
* ``ep_intra``  — SMILE level-2 ("GPU-within-node") axes. All2All #2 runs here.

For the production single-pod mesh ``(data=16, model=16)``:
``dp=("data",), tp="model", ep_inter=("data",), ep_intra=("model",)`` —
expert grid 16 x 16 = 256 slots, exactly the paper's ``n x m`` layout where a
worker owns one expert *and* a slice of the batch (hybrid data+expert
parallelism, paper §2).

With mesh axes of size one (or no mesh at all) every collective in
:mod:`repro.sharding.comm` degenerates to the identity, giving the
single-device oracle used by unit tests — one code path for both.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import jax


@dataclass(frozen=True)
class MeshPlan:
    dp_axes: Tuple[str, ...] = ()
    tp_axis: Optional[str] = None
    ep_inter: Tuple[str, ...] = ()
    ep_intra: Tuple[str, ...] = ()
    axis_sizes: Tuple[Tuple[str, int], ...] = ()   # frozen dict of axis -> size

    # ------------------------------------------------------------------ sizes
    def size(self, axes) -> int:
        if axes is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        d = dict(self.axis_sizes)
        p = 1
        for a in axes:
            p *= d.get(a, 1)
        return p

    @property
    def dp(self) -> int:
        return self.size(self.dp_axes)

    @property
    def tp(self) -> int:
        return self.size(self.tp_axis)

    @property
    def n_inter(self) -> int:
        """Number of "nodes" (paper's n)."""
        return self.size(self.ep_inter)

    @property
    def n_intra(self) -> int:
        """Workers per node (paper's m)."""
        return self.size(self.ep_intra)

    @property
    def ep(self) -> int:
        """Total expert-parallel grid slots N = n x m."""
        return self.n_inter * self.n_intra

    @property
    def ep_axes(self) -> Tuple[str, ...]:
        return tuple(self.ep_inter) + tuple(self.ep_intra)

    @property
    def all_axes(self) -> Tuple[str, ...]:
        return tuple(a for a, _ in self.axis_sizes)

    def tp_axes(self) -> Tuple[str, ...]:
        return (self.tp_axis,) if self.tp_axis else ()


def plan_from_mesh(mesh: jax.sharding.Mesh,
                   *,
                   smile_inter_axes: Optional[Tuple[str, ...]] = None) -> MeshPlan:
    """Build the canonical plan for a mesh.

    Axis conventions: ``model`` is tensor-parallel / SMILE-intra; all remaining
    axes (``pod``, ``data``) are data-parallel; SMILE-inter defaults to
    ``("data",)`` so that the expert grid is ``data x model``. Pass
    ``smile_inter_axes=("pod", "data")`` to route level-1 across the DCN pod
    axis too (512-slot grid on the multi-pod mesh).
    """
    names = tuple(mesh.axis_names)
    sizes = tuple((a, int(mesh.shape[a])) for a in names)
    tp = "model" if "model" in names else None
    dp = tuple(a for a in names if a != "model")
    if smile_inter_axes is None:
        smile_inter_axes = ("data",) if "data" in names else dp
    inter = tuple(a for a in smile_inter_axes if a in names)
    intra = ("model",) if tp else ()
    return MeshPlan(dp_axes=dp, tp_axis=tp, ep_inter=inter, ep_intra=intra,
                    axis_sizes=sizes)


def single_device_plan() -> MeshPlan:
    """Oracle plan: no named axes; every collective is the identity."""
    return MeshPlan()


def test_plan(n_inter: int = 2, n_intra: int = 2, pod: int = 0) -> MeshPlan:
    """Plan + axis sizes for small fake-device test meshes."""
    sizes = []
    if pod:
        sizes.append(("pod", pod))
    sizes += [("data", n_inter), ("model", n_intra)]
    dp = tuple(a for a, _ in sizes if a != "model")
    return MeshPlan(dp_axes=dp, tp_axis="model", ep_inter=("data",),
                    ep_intra=("model",), axis_sizes=tuple(sizes))

"""JAX version compatibility shims for mesh/shard_map construction.

The repo targets current JAX (``jax.make_mesh(..., axis_types=...)``,
``jax.shard_map(..., check_vma=...)``); this container pins jax 0.4.37
where those spellings don't exist yet (``axis_types`` keyword,
``jax.sharding.AxisType``, top-level ``jax.shard_map`` and its
``check_vma`` kwarg all landed later — 0.4.37 has
``jax.experimental.shard_map.shard_map(check_rep=...)``).  Route every
mesh/shard_map construction through here so the rest of the code is
version-agnostic.
"""
from __future__ import annotations

import jax


def make_mesh(axis_shapes, axis_names) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with explicit Auto axis types where supported."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            axis_shapes, axis_names,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names))
    return jax.make_mesh(axis_shapes, axis_names)


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
    """Top-level ``jax.shard_map`` when available, else the experimental
    one; ``check`` maps onto check_vma / check_rep respectively."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check)

from repro.optim.optimizers import (
    Optimizer,
    adamw,
    lamb,
    make_optimizer,
)
from repro.optim.schedule import make_schedule

__all__ = ["Optimizer", "adamw", "lamb", "make_optimizer", "make_schedule"]

"""Optimizers, built from scratch in JAX (no optax dependency).

LAMB is the paper's optimizer (§4.1, [20]); AdamW is provided for the
assigned decoder archs. Both operate leaf-wise on sharded parameters, so the
update runs inside ``shard_map`` without extra communication (the trust-ratio
norms in LAMB are per-leaf; sharded leaves psum their norms over the axes the
leaf is sharded on — supplied by the caller via ``shard_axes``).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.sharding import comm


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[..., Any]      # (grads, state, params, lr, shard_axes) -> (params, state)


class _Up:
    """Per-leaf update carrier (params pytrees contain tuples, so plain
    tuples cannot be used as tree.map leaf markers)."""
    __slots__ = ("p", "m", "v")

    def __init__(self, p, m, v):
        self.p, self.m, self.v = p, m, v


def _split_updates(out):
    is_up = lambda t: isinstance(t, _Up)
    return (jax.tree.map(lambda t: t.p, out, is_leaf=is_up),
            jax.tree.map(lambda t: t.m, out, is_leaf=is_up),
            jax.tree.map(lambda t: t.v, out, is_leaf=is_up))


def _moments_init(params):
    z = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params),
            "step": jnp.zeros((), jnp.int32)}


def _adam_dir(g, m, v, step, b1, b2, eps):
    g = g.astype(jnp.float32)
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * jnp.square(g)
    mh = m / (1 - b1 ** step)
    vh = v / (1 - b2 ** step)
    return mh / (jnp.sqrt(vh) + eps), m, v


def adamw(b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.01) -> Optimizer:
    def init(params):
        return _moments_init(params)

    def update(grads, state, params, lr, shard_axes=None):
        step = state["step"] + 1

        def leaf(g, m, v, p):
            d, m2, v2 = _adam_dir(g, m, v, step.astype(jnp.float32), b1, b2, eps)
            if weight_decay and p.ndim >= 2:
                d = d + weight_decay * p.astype(jnp.float32)
            return _Up((p.astype(jnp.float32) - lr * d).astype(p.dtype), m2, v2)

        out = jax.tree.map(leaf, grads, state["m"], state["v"], params)
        new_p, new_m, new_v = _split_updates(out)
        return new_p, {"m": new_m, "v": new_v, "step": step}

    return Optimizer(init, update)


def lamb(b1=0.9, b2=0.999, eps=1e-6, weight_decay=0.01,
         min_trust=0.0, max_trust=10.0) -> Optimizer:
    """LAMB [You et al. 2019] — the paper's optimizer.

    ``shard_axes`` maps each leaf to the mesh axes its data is sharded over;
    the trust-ratio norms are psum'd over those axes so sharded leaves see
    their *global* norms (a leaf sharded over 'model' computes the same trust
    ratio every shard — required for replicated-consistent updates).
    """
    def init(params):
        return _moments_init(params)

    def update(grads, state, params, lr, shard_axes=None):
        step = state["step"] + 1

        def leaf(g, m, v, p, axes):
            d, m2, v2 = _adam_dir(g, m, v, step.astype(jnp.float32), b1, b2, eps)
            pf = p.astype(jnp.float32)
            if weight_decay and p.ndim >= 2:
                d = d + weight_decay * pf
            wn = comm.psum(jnp.sum(jnp.square(pf)), axes)
            dn = comm.psum(jnp.sum(jnp.square(d)), axes)
            wn, dn = jnp.sqrt(wn), jnp.sqrt(dn)
            trust = jnp.where((wn > 0) & (dn > 0),
                              jnp.clip(wn / jnp.maximum(dn, 1e-12),
                                       min_trust, max_trust), 1.0)
            return _Up((pf - lr * trust * d).astype(p.dtype), m2, v2)

        if shard_axes is None:
            shard_axes = jax.tree.map(lambda _: (), params)
        out = jax.tree.map(leaf, grads, state["m"], state["v"], params,
                           shard_axes,
                           is_leaf=lambda x: isinstance(x, jax.Array))
        new_p, new_m, new_v = _split_updates(out)
        return new_p, {"m": new_m, "v": new_v, "step": step}

    return Optimizer(init, update)


def make_optimizer(name: str, *, weight_decay=0.01, b1=0.9, b2=0.999,
                   eps=1e-6) -> Optimizer:
    if name == "lamb":
        return lamb(b1=b1, b2=b2, eps=eps, weight_decay=weight_decay)
    if name == "adamw":
        return adamw(b1=b1, b2=b2, eps=eps, weight_decay=weight_decay)
    raise ValueError(f"unknown optimizer {name!r}")


def clip_by_global_norm(grads, max_norm: float, shard_axes=None):
    """Global-norm clip; norms psum'd per-leaf over the leaf's shard axes
    (leaves replicated elsewhere contribute identically on every device)."""
    if shard_axes is None:
        shard_axes = jax.tree.map(lambda _: (), grads)
    sq = jax.tree.map(
        lambda g, a: comm.psum(jnp.sum(jnp.square(g.astype(jnp.float32))), a),
        grads, shard_axes, is_leaf=lambda x: isinstance(x, jax.Array))
    total = jnp.sqrt(sum(jax.tree.leaves(sq)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(total, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), total

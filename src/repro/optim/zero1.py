"""ZeRO-1 optimizer-state sharding, manual-collectives style.

The dry-run (§Dry-run) shows fp32 training state dominating per-chip memory
(deepseek-v3 52 GB, llama3-405b 328 GB — both over a v5e's 16 GB). ZeRO-1
shards the optimizer moments (and the update computation) across the axes a
parameter is REPLICATED on:

  per leaf:  grad --reduce_scatter(sync_axes)--> owned 1/dp chunk
             update m/v/param chunk (LAMB trust ratio via psum'd chunk norms)
             new param --all_gather(sync_axes)--> replicated again

Wire cost per step equals the plain psum it replaces (reduce-scatter +
all-gather = all-reduce), while m/v memory and the update FLOPs drop by the
replication factor. Leaves that are fully sharded already (expert weights on
the expert grid) keep the dense update (their ``sync_axes`` are empty).

Gradient clipping must see the TRUE (post-reduction) gradient, so the whole
clip+update pipeline lives here rather than in ``train/step.py``.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.optim.optimizers import _adam_dir
from repro.sharding import comm
from repro.sharding.plan import MeshPlan


def _pad_len(n: int, parts: int) -> int:
    return ((n + parts - 1) // parts) * parts


def _flatten_pad(x: jax.Array, parts: int) -> jax.Array:
    flat = x.reshape(-1).astype(jnp.float32)
    pad = _pad_len(flat.shape[0], parts) - flat.shape[0]
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat


class Zero1State(NamedTuple):
    m: Any
    v: Any
    step: jax.Array


def init_state_shapes(params, sync_axes_tree, norm_axes_tree,
                      plan: MeshPlan):
    """GLOBAL moment shapes for ZeRO-sharded leaves.

    Inside ``shard_map`` the update flattens the LOCAL param shard (size
    prod(shape)/norm_parts) and splits it into sync_parts chunks; the global
    moment array is therefore ``chunk x sync_parts x norm_parts`` with dim0
    sharded over (norm axes, sync axes) — each device owns exactly its chunk.
    The element->position mapping inside the flat array is an internal layout
    detail (the state is opaque and device-stable on a fixed mesh)."""
    def one(p, sync, norm):
        if sync:
            norm_parts = plan.size(norm)
            sync_parts = plan.size(sync)
            local = int(math.prod(p.shape)) // norm_parts
            chunk = _pad_len(local, sync_parts) // sync_parts
            return jnp.zeros((chunk * sync_parts * norm_parts,), jnp.float32)
        return jnp.zeros(p.shape, jnp.float32)
    m = jax.tree.map(one, params, sync_axes_tree, norm_axes_tree,
                     is_leaf=lambda x: hasattr(x, "shape"))
    return Zero1State(m=m, v=jax.tree.map(jnp.copy, m),
                      step=jnp.zeros((), jnp.int32))


def state_specs(param_spec_tree, sync_axes_tree, norm_axes_tree):
    """Spec tree for the flattened moments: dim0 over (norm + sync) axes."""
    from jax.sharding import PartitionSpec as P

    def one(spec, sync, norm):
        if sync:
            axes = tuple(norm) + tuple(sync)
            return P(axes if len(axes) > 1 else axes[0])
        return spec
    s = jax.tree.map(one, param_spec_tree, sync_axes_tree, norm_axes_tree,
                     is_leaf=lambda x: isinstance(x, P))
    return Zero1State(m=s, v=jax.tree.map(lambda x: x, s,
                                          is_leaf=lambda x: isinstance(x, P)),
                      step=P())


class _Leaf:
    __slots__ = ("p", "m", "v")

    def __init__(self, p, m, v):
        self.p, self.m, self.v = p, m, v


def zero1_reduce_and_clip(grads, *, sync_axes_tree, norm_axes_tree,
                          plan: MeshPlan, grad_clip: float = 1.0):
    """Stages 1+2 of the ZeRO-1 step: reduce RAW per-device gradients into
    owned chunks and compute the global clip scale.

    Returns ``(g_own, gnorm, scale)``.  Split out from the apply so a step
    sentinel can judge the TRUE (post-reduction) gradients and
    ``lax.cond``-gate :func:`zero1_apply` on the verdict — the clip scale
    carries no optimizer state, so computing it on a step that is later
    skipped is side-effect-free.
    """
    # 1) reduce: scatter true grads into owned chunks (or plain psum when the
    #    leaf is fully sharded / axes empty)
    def reduce(g, axes):
        if axes:
            parts = plan.size(axes)
            flat = _flatten_pad(g, parts)
            return comm.psum_scatter(flat, axes, scatter_dimension=0,
                                     tiled=True)
        return g.astype(jnp.float32)
    g_own = jax.tree.map(reduce, grads, sync_axes_tree,
                         is_leaf=lambda x: isinstance(x, jax.Array))

    # 2) global grad-norm from owned chunks: each element counted once
    #    (chunks over sync axes + shards over the leaf's sharded axes)
    def sq(g, sync, shard):
        axes = tuple(dict.fromkeys(tuple(sync) + tuple(shard)))
        return comm.psum(jnp.sum(jnp.square(g)), axes)
    sq_tree = jax.tree.map(sq, g_own, sync_axes_tree, norm_axes_tree,
                           is_leaf=lambda x: isinstance(x, jax.Array))
    gnorm = jnp.sqrt(sum(jax.tree.leaves(sq_tree)))
    scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-12))
    return g_own, gnorm, scale


def zero1_apply(g_own, scale, state: Zero1State, params, lr, *,
                sync_axes_tree, norm_axes_tree, plan: MeshPlan,
                b1=0.9, b2=0.999, eps=1e-6, weight_decay=0.01,
                min_trust=0.0, max_trust=10.0):
    """Stage 3 of the ZeRO-1 step: moment update + owned-chunk apply +
    param re-gather, over the ALREADY-reduced chunks of
    :func:`zero1_reduce_and_clip`.

    The step counter bumps here, not in the reduce — a sentinel-skipped
    step must leave the whole :class:`Zero1State` (moments AND bias-
    correction clock) bit-unchanged.  Returns ``(params, Zero1State)``.
    """
    step = state.step + 1

    # 3) per-leaf update on owned chunks
    def upd(g, m, v, p, sync, shard):
        axes = tuple(dict.fromkeys(tuple(sync) + tuple(shard)))
        g = g * scale
        if sync:
            parts = plan.size(sync)
            p_flat = _flatten_pad(p, parts)
            chunk = p_flat.shape[0] // parts
            idx = comm.axis_index(sync)
            p_own = jax.lax.dynamic_slice_in_dim(p_flat, idx * chunk, chunk)
        else:
            p_own = p.astype(jnp.float32)
        d, m2, v2 = _adam_dir(g, m, v, step.astype(jnp.float32), b1, b2, eps)
        if weight_decay and p.ndim >= 2:
            d = d + weight_decay * p_own
        wn = jnp.sqrt(comm.psum(jnp.sum(jnp.square(p_own)), axes))
        dn = jnp.sqrt(comm.psum(jnp.sum(jnp.square(d)), axes))
        trust = jnp.where((wn > 0) & (dn > 0),
                          jnp.clip(wn / jnp.maximum(dn, 1e-12),
                                   min_trust, max_trust), 1.0)
        new_own = p_own - lr * trust * d
        if sync:
            full = comm.all_gather(new_own, sync, axis=0, tiled=True)
            n = int(math.prod(p.shape))
            new_p = full[:n].reshape(p.shape).astype(p.dtype)
        else:
            new_p = new_own.astype(p.dtype)
        return _Leaf(new_p, m2, v2)

    out = jax.tree.map(upd, g_own, state.m, state.v, params,
                       sync_axes_tree, norm_axes_tree,
                       is_leaf=lambda x: isinstance(x, jax.Array))
    is_leaf = lambda t: isinstance(t, _Leaf)
    new_p = jax.tree.map(lambda t: t.p, out, is_leaf=is_leaf)
    new_m = jax.tree.map(lambda t: t.m, out, is_leaf=is_leaf)
    new_v = jax.tree.map(lambda t: t.v, out, is_leaf=is_leaf)
    return new_p, Zero1State(new_m, new_v, step)


def zero1_lamb_step(grads, state: Zero1State, params, lr, *,
                    sync_axes_tree, norm_axes_tree, plan: MeshPlan,
                    grad_clip: float = 1.0, b1=0.9, b2=0.999, eps=1e-6,
                    weight_decay=0.01, min_trust=0.0, max_trust=10.0):
    """One ZeRO-1 LAMB step over RAW (unreduced) per-device gradients.

    Composition of :func:`zero1_reduce_and_clip` + :func:`zero1_apply`
    (bit-identical to the pre-split fused step)."""
    g_own, gnorm, scale = zero1_reduce_and_clip(
        grads, sync_axes_tree=sync_axes_tree, norm_axes_tree=norm_axes_tree,
        plan=plan, grad_clip=grad_clip)
    new_p, new_state = zero1_apply(
        g_own, scale, state, params, lr, sync_axes_tree=sync_axes_tree,
        norm_axes_tree=norm_axes_tree, plan=plan, b1=b1, b2=b2, eps=eps,
        weight_decay=weight_decay, min_trust=min_trust, max_trust=max_trust)
    return new_p, new_state, gnorm

"""Learning-rate schedules (pure functions of the int step)."""
from __future__ import annotations

import jax.numpy as jnp


def make_schedule(kind: str, base_lr: float, warmup: int, total: int):
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        w = float(warmup)
        warm = base_lr * jnp.minimum(step / max(w, 1.0), 1.0)
        frac = jnp.clip((step - w) / max(total - w, 1.0), 0.0, 1.0)
        if kind == "cosine":
            decay = 0.5 * (1 + jnp.cos(jnp.pi * frac))
        elif kind == "linear":
            decay = 1.0 - frac
        else:
            decay = 1.0
        return jnp.where(step < w, warm, base_lr * decay)
    return fn

"""stablelm-12b [dense]. [hf:stabilityai/stablelm-2-1_6b (family card)]

40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352.
"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    arch_type="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    d_ff=13824,
    vocab_size=100352,
    attention="full",
    act="silu",
    glu=True,
    norm="layernorm",
    rope_theta=10000.0,
    source="hf:stabilityai/stablelm-2-12b",
)

REDUCED = CONFIG.replace(num_layers=2, d_model=256, num_heads=4,
                         num_kv_heads=2, d_ff=512, vocab_size=512)

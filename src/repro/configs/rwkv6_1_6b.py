"""rwkv6-1.6b [ssm] — Finch, data-dependent decay. [arXiv:2404.05892]

24L d_model=2048 (attention-free) d_ff=7168 vocab=65536.
"""
from repro.common.config import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    arch_type="ssm",
    num_layers=24,
    d_model=2048,
    num_heads=32,                 # d_model / rwkv head_dim
    num_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    attention="none",
    use_rope=False,
    act="relu",
    glu=False,
    norm="layernorm",
    rwkv=RWKVConfig(head_dim=64, decay_lora=64, mix_lora=32),
    source="arXiv:2404.05892",
)

REDUCED = CONFIG.replace(num_layers=2, d_model=256, num_heads=4,
                         num_kv_heads=4, d_ff=512, vocab_size=512,
                         rwkv=RWKVConfig(head_dim=64, decay_lora=16,
                                         mix_lora=8))

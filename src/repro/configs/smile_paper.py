"""The paper's own model configurations (§4, Table 2).

BERT-style MLM encoders; every OTHER feed-forward layer is replaced with a
MoE layer of 128 experts, top-1 (Switch-style). ``smile-*`` uses bi-level
routing with the additive LB loss (alpha = beta = 0.005); ``switch-*`` is the
one-hop baseline (alpha = 0.01). ``bert-*`` are the dense FLOP/param-matched
baselines from Table 1.

Sizes (Table 2): 3.7B (12L/768/3072), 13B (24L/1024/4096),
48B (36L/1600/6400) — all with 128 experts.
"""
import dataclasses

from repro.common.config import ModelConfig, MoEConfig


def _moe(router: str) -> MoEConfig:
    return MoEConfig(
        num_experts=128,
        top_k=1,
        d_ff_expert=0,               # filled per-size below
        capacity_factor=2.0,         # paper §4.2
        router=router,
        lb_alpha=0.01 if router == "switch" else 0.005,
        lb_beta=0.005,
        every_n_layers=2,
    )


def _base(name, L, d, H, ff, moe_router=None) -> ModelConfig:
    moe = None
    if moe_router:
        moe = dataclasses.replace(_moe(moe_router), d_ff_expert=ff)
    return ModelConfig(
        name=name,
        arch_type="mlm",
        num_layers=L,
        d_model=d,
        num_heads=H,
        num_kv_heads=H,
        d_ff=ff,
        vocab_size=32128,            # T5 vocabulary (paper §4.1)
        attention="full",
        causal=False,                # bidirectional (masked LM)
        use_rope=False,              # BERT-style learned-free: plain abs? keep rope off
        act="gelu",
        glu=False,
        norm="layernorm",
        moe=moe,
        source="SMILE paper §4 / Table 2",
    )


CONFIGS = {
    # Table 1/Fig. 6 models (BERT_base backbone, 128 experts -> 3.7B total)
    "smile-3.7b": _base("smile-3.7b", 12, 768, 12, 3072, "smile"),
    "switch-3.7b": _base("switch-3.7b", 12, 768, 12, 3072, "switch"),
    "bert-110m": _base("bert-110m", 12, 768, 12, 3072),
    "bert-3.7b": _base("bert-3.7b", 12, 2560, 40, 10240),   # param-matched dense
    # Table 2 scaling sizes
    "smile-13b": _base("smile-13b", 24, 1024, 16, 4096, "smile"),
    "smile-48b": _base("smile-48b", 36, 1600, 32, 6400, "smile"),
}

_red_moe_smile = MoEConfig(num_experts=4, top_k=1, d_ff_expert=128,
                           capacity_factor=4.0, router="smile",
                           lb_alpha=0.005, lb_beta=0.005, every_n_layers=2,
                           grid=(2, 2))
_red_moe_switch = MoEConfig(num_experts=4, top_k=1, d_ff_expert=128,
                            capacity_factor=4.0, router="switch",
                            lb_alpha=0.01, every_n_layers=2, grid=(2, 2))

REDUCEDS = {
    "smile-3.7b": CONFIGS["smile-3.7b"].replace(
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=4, d_ff=512,
        vocab_size=512, moe=_red_moe_smile),
    "switch-3.7b": CONFIGS["switch-3.7b"].replace(
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=4, d_ff=512,
        vocab_size=512, moe=_red_moe_switch),
    "bert-110m": CONFIGS["bert-110m"].replace(
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=4, d_ff=512,
        vocab_size=512),
    "bert-3.7b": CONFIGS["bert-3.7b"].replace(
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=4, d_ff=512,
        vocab_size=512),
    "smile-13b": CONFIGS["smile-13b"].replace(
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=4, d_ff=512,
        vocab_size=512, moe=_red_moe_smile),
    "smile-48b": CONFIGS["smile-48b"].replace(
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=4, d_ff=512,
        vocab_size=512, moe=_red_moe_smile),
}

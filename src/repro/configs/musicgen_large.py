"""musicgen-large [audio] — decoder-only over EnCodec tokens. [arXiv:2306.05284]

48L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=2048 (per codebook),
4 codebooks with the delay interleaving pattern applied by the data pipeline.
Per the spec carve-out, the EnCodec conv codec is NOT built; the backbone
consumes codec token ids directly.
"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    arch_type="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    attention="full",
    act="gelu",
    glu=False,                    # plain MLP, as in the MusicGen decoder
    norm="layernorm",
    num_codebooks=4,
    source="arXiv:2306.05284",
)

REDUCED = CONFIG.replace(num_layers=2, d_model=256, num_heads=4,
                         num_kv_heads=4, d_ff=512, vocab_size=256,
                         num_codebooks=4)

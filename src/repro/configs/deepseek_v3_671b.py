"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8, MTP.

61L d_model=7168 128H (MLA) moe d_ff=2048 vocab=129280, 256 experts top-8.
[arXiv:2412.19437]
"""
from repro.common.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    arch_type="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    head_dim=192,                    # qk_nope(128) + qk_rope(64)
    d_ff=18432,                      # dense FFN in the first 3 layers
    vocab_size=129280,
    attention="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    act="silu",
    glu=True,
    norm="rmsnorm",
    rope_theta=10000.0,
    mtp_depth=1,
    moe=MoEConfig(
        num_experts=256,
        top_k=8,
        top_g=4,                     # bi-level: 4 nodes x 2 local experts
        renorm_gates=True,
        d_ff_expert=2048,
        num_shared_experts=1,
        capacity_factor=2.0,
        router="smile",              # the paper's technique, first-class
        lb_alpha=0.005,
        lb_beta=0.005,
        every_n_layers=1,
        first_dense_layers=3,
    ),
    source="arXiv:2412.19437",
)

REDUCED = CONFIG.replace(
    num_layers=2,
    d_model=256,
    num_heads=4,
    num_kv_heads=4,
    head_dim=48,
    d_ff=512,
    vocab_size=512,
    q_lora_rank=64,
    kv_lora_rank=32,
    qk_nope_head_dim=32,
    qk_rope_head_dim=16,
    v_head_dim=32,
    mtp_depth=1,
    moe=CONFIG.moe and CONFIG.moe.__class__(
        num_experts=4, top_k=2, top_g=2, renorm_gates=True, d_ff_expert=128,
        num_shared_experts=1, capacity_factor=4.0, router="smile",
        lb_alpha=0.005, lb_beta=0.005, every_n_layers=1,
        first_dense_layers=1, grid=(2, 2)),
)

"""qwen1.5-0.5b [dense] — QKV bias. [hf:Qwen/Qwen1.5-0.5B]

24L d_model=1024 16H (GQA kv=16) d_ff=2816 vocab=151936.
"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    arch_type="dense",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=2816,
    vocab_size=151936,
    attention="full",
    qkv_bias=True,
    act="silu",
    glu=True,
    norm="rmsnorm",
    rope_theta=1000000.0,
    tie_embeddings=True,
    source="hf:Qwen/Qwen1.5-0.5B",
)

REDUCED = CONFIG.replace(num_layers=2, d_model=256, num_heads=4,
                         num_kv_heads=4, d_ff=512, vocab_size=512)

"""zamba2-2.7b [hybrid] — Mamba2 + shared attention blocks. [arXiv:2411.15242]

54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000, ssm_state=64.
Six Mamba2 layers per shared-attention invocation (shared parameters).
"""
from repro.common.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    arch_type="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    attention="full",            # the shared attention block
    act="gelu",
    glu=True,
    norm="rmsnorm",
    ssm_layers_per_attn=6,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=128),
    source="arXiv:2411.15242",
)

REDUCED = CONFIG.replace(
    num_layers=4, d_model=256, num_heads=4, num_kv_heads=4, d_ff=512,
    vocab_size=512, ssm_layers_per_attn=2,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=32, chunk=32))

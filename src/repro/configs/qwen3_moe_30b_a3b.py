"""qwen3-moe-30b-a3b [moe] — 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B]

48L d_model=2048 32H (GQA kv=4) moe d_ff=768 vocab=151936.
On the 16x16 production grid the 128 experts are replicated r=2
(load-spreading layout, see core/layout.py).
"""
from repro.common.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    arch_type="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=6144,                        # unused (all layers MoE); kept for ref
    vocab_size=151936,
    attention="full",
    act="silu",
    glu=True,
    norm="rmsnorm",
    rope_theta=1000000.0,
    moe=MoEConfig(
        num_experts=128,
        top_k=8,
        top_g=4,
        renorm_gates=True,
        d_ff_expert=768,
        capacity_factor=2.0,
        router="smile",
        lb_alpha=0.005,
        lb_beta=0.005,
        every_n_layers=1,
    ),
    source="hf:Qwen/Qwen3-30B-A3B",
)

REDUCED = CONFIG.replace(
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
    d_ff=512, vocab_size=512,
    moe=MoEConfig(num_experts=4, top_k=2, top_g=2, renorm_gates=True,
                  d_ff_expert=128, capacity_factor=4.0, router="smile",
                  lb_alpha=0.005, lb_beta=0.005, every_n_layers=1,
                  grid=(2, 4)),     # exercises the replication layout (r=2)
)

"""deepseek-coder-33b [dense] — llama-arch. [arXiv:2401.14196]

62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256.
Note: 56 query heads are padded to 64 on tp=16 meshes (zero-init extra
heads; +2.2%% attention params) — see DESIGN.md §Simplifications.
"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    arch_type="dense",
    num_layers=62,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=19200,
    vocab_size=32256,
    attention="full",
    act="silu",
    glu=True,
    norm="rmsnorm",
    rope_theta=100000.0,
    source="arXiv:2401.14196",
)

REDUCED = CONFIG.replace(num_layers=2, d_model=256, num_heads=7,
                         num_kv_heads=1, head_dim=32, d_ff=512,
                         vocab_size=512)

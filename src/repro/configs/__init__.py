"""Architecture registry: the 10 assigned architectures + the paper's own
SMILE/Switch MLM configs. Every module exports ``CONFIG`` (the exact assigned
configuration, source cited) and ``REDUCED`` (2-layer smoke-test variant).
"""
from __future__ import annotations

import importlib
from typing import Dict

from repro.common.config import INPUT_SHAPES, InputShape, ModelConfig

_MODULES = {
    "deepseek-v3-671b": "deepseek_v3_671b",
    "phi-3-vision-4.2b": "phi3_vision_4_2b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "zamba2-2.7b": "zamba2_2_7b",
    "musicgen-large": "musicgen_large",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "llama3-405b": "llama3_405b",
    "stablelm-12b": "stablelm_12b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    # the paper's own models (reproduction targets)
    "smile-3.7b": "smile_paper",
    "switch-3.7b": "smile_paper",
    "smile-13b": "smile_paper",
    "smile-48b": "smile_paper",
    "bert-110m": "smile_paper",
    "bert-3.7b": "smile_paper",
}

ASSIGNED = list(_MODULES)[:10]
PAPER = list(_MODULES)[10:]


def _mod(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get_config(name: str) -> ModelConfig:
    m = _mod(name)
    if hasattr(m, "CONFIGS"):
        return m.CONFIGS[name]
    return m.CONFIG


def get_reduced(name: str) -> ModelConfig:
    m = _mod(name)
    if hasattr(m, "REDUCEDS"):
        return m.REDUCEDS[name]
    return m.REDUCED


def with_options(cfg: ModelConfig, **options) -> ModelConfig:
    """Rebuild ``cfg`` with MoE dispatch options swapped; no-op for dense
    architectures.

    The single entry point for runtime MoE knobs: every option is validated
    against :data:`repro.common.config.MOE_OPTIONS` (the same registry both
    launchers derive their flags from), e.g. ``with_options(cfg,
    dispatch_backend="dropless", recv_bound_factor=2.0)``.
    """
    if cfg.moe is None:
        return cfg
    return cfg.replace(moe=cfg.moe.with_options(**options))


def with_dispatch_backend(cfg: ModelConfig, backend: str,
                          ragged_a2a: bool | None = None,
                          sort_impl: str | None = None) -> ModelConfig:
    """Deprecated shim: use :func:`with_options` instead.

    Kept so pre-pipeline callers keep working (with a DeprecationWarning);
    forwards to ``with_options``, which validates against the options
    registry.
    """
    import warnings
    warnings.warn(
        "with_dispatch_backend is deprecated; use "
        "configs.with_options(cfg, dispatch_backend=..., ...) — options are "
        "validated against repro.common.config.MOE_OPTIONS",
        DeprecationWarning, stacklevel=2)
    kw = {"dispatch_backend": backend}
    if ragged_a2a is not None:
        kw["ragged_a2a"] = ragged_a2a
    if sort_impl is not None:
        kw["sort_impl"] = sort_impl
    if cfg.moe is None:
        # preserve the old contract: validate even for dense archs
        from repro.common.config import MoEConfig
        MoEConfig().with_options(**kw)
        return cfg
    return with_options(cfg, **kw)


def config_for_shape(name: str, shape: InputShape) -> ModelConfig:
    """Adapt a config to an input shape.

    ``long_500k`` requires sub-quadratic attention: SSM/hybrid archs run
    natively; attention archs switch to the documented sliding-window
    variant (ring-buffer KV cache, window 8192 — see DESIGN.md).
    """
    cfg = get_config(name)
    if shape.name == "long_500k":
        if cfg.attention in ("full", "mla"):
            cfg = cfg.replace(attention="sliding" if cfg.attention == "full"
                              else cfg.attention, window=8192)
        if cfg.arch_type == "hybrid":
            cfg = cfg.replace(attention="sliding", window=4096)
    return cfg


def supports_shape(name: str, shape: InputShape) -> bool:
    cfg = get_config(name)
    if shape.kind == "decode" and not cfg.causal:
        return False          # encoder-only MLM archs have no decode step
    return True

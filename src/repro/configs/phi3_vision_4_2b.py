"""phi-3-vision-4.2b [vlm] — phi3-mini decoder + CLIP stub.
[hf:microsoft/Phi-3-vision-128k-instruct]

32L d_model=3072 32H (GQA kv=32) d_ff=8192 vocab=32064.
Per the spec carve-out the vision tower is a stub: ``input_specs`` provides
pre-computed CLIP patch embeddings (dim 1024); the projector + decoder are real.
"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    arch_type="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    attention="full",
    act="silu",
    glu=True,
    norm="rmsnorm",
    rope_theta=10000.0,
    vision_tokens=576,            # 24x24 CLIP-L/14 patch grid
    vision_embed_dim=1024,
    source="hf:microsoft/Phi-3-vision-128k-instruct",
)

REDUCED = CONFIG.replace(num_layers=2, d_model=256, num_heads=4,
                         num_kv_heads=4, d_ff=512, vocab_size=512,
                         vision_tokens=16, vision_embed_dim=64)

"""Data pipeline: deterministic synthetic corpus + task-specific batching.

The paper pretrains MLM on C4 (129B tokens, T5 vocab). Offline we substitute
a *statistically C4-like* synthetic stream: Zipf-distributed unigrams mixed
with short repeated n-grams so that models can actually reduce loss (there is
learnable structure), which is what the convergence experiments (§Convergence)
need. The pipeline is deterministic in (seed, step) — restart-safe without
checkpointing reader state — and double-buffered via a background thread
(the "pre-fetching mechanism" of the paper's loader, host-side).

Batch layouts:
  causal LM  : tokens (B, S)      labels = tokens shifted left, last = -1
  MLM (paper): tokens (B, S) with [MASK]=4 swaps; labels = original at masked
               positions, -1 elsewhere (15%, 80/10/10 — BERT recipe)
  musicgen   : tokens (B, K, S) with the delay pattern; labels shifted left
  phi-3-vision: causal LM + image patch embeddings and positions
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from repro.common.config import ModelConfig

MASK_ID = 4
IGNORE = -1


def synthetic_tokens(rng: np.random.Generator, batch: int, seq: int,
                     vocab: int, *, ngram: int = 8) -> np.ndarray:
    """Zipf unigrams + repeated n-grams (learnable local structure)."""
    zipf = rng.zipf(1.3, size=(batch, seq)).astype(np.int64)
    toks = (zipf % (vocab - 8)) + 8           # reserve low ids for specials
    # overwrite ~50% of positions with repeats of the previous n-gram
    ngram = min(ngram, max(seq // 4, 1))
    n_rep = seq // (2 * ngram)
    if n_rep and seq - ngram > ngram:
        for b in range(batch):
            starts = rng.integers(ngram, seq - ngram, size=n_rep)
            for s in starts:
                toks[b, s:s + ngram] = toks[b, s - ngram:s]
    return toks.astype(np.int32)


def mlm_mask(rng: np.random.Generator, tokens: np.ndarray, vocab: int,
             prob: float = 0.15):
    """BERT-style masking: 15% positions; 80% [MASK] / 10% random / 10% keep."""
    mask = rng.random(tokens.shape) < prob
    labels = np.where(mask, tokens, IGNORE).astype(np.int32)
    r = rng.random(tokens.shape)
    corrupted = tokens.copy()
    corrupted[mask & (r < 0.8)] = MASK_ID
    rand_sel = mask & (r >= 0.8) & (r < 0.9)
    corrupted[rand_sel] = rng.integers(8, vocab, size=int(rand_sel.sum()))
    return corrupted.astype(np.int32), labels


def _delay_pattern(tokens: np.ndarray) -> np.ndarray:
    """MusicGen delay interleave: codebook k is shifted right by k steps."""
    B, K, S = tokens.shape
    out = np.zeros_like(tokens)
    for k in range(K):
        out[:, k, k:] = tokens[:, k, :S - k]
    return out


def make_batch(cfg: ModelConfig, batch: int, seq: int, seed: int,
               step: int, mlm_prob: float = 0.15) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    if cfg.num_codebooks > 1:
        toks = np.stack([synthetic_tokens(rng, batch, seq, cfg.vocab_size)
                         for _ in range(cfg.num_codebooks)], axis=1)
        toks = _delay_pattern(toks)
        labels = np.full_like(toks, IGNORE)
        labels[..., :-1] = toks[..., 1:]
        return {"tokens": toks, "labels": labels}
    toks = synthetic_tokens(rng, batch, seq, cfg.vocab_size)
    if not cfg.causal:                      # MLM (the paper's task)
        corrupted, labels = mlm_mask(rng, toks, cfg.vocab_size, mlm_prob)
        return {"tokens": corrupted, "labels": labels}
    labels = np.full_like(toks, IGNORE)
    labels[:, :-1] = toks[:, 1:]
    out = {"tokens": toks, "labels": labels}
    if cfg.vision_tokens:
        P = cfg.vision_tokens
        out["image_embeds"] = rng.standard_normal(
            (batch, P, cfg.vision_embed_dim)).astype(np.float32)
        out["image_pos"] = np.tile(np.arange(1, P + 1, dtype=np.int32),
                                   (batch, 1))
        out["labels"][:, :P + 1] = IGNORE   # don't train on image positions
    return out


class DataPipeline:
    """Background-prefetching batch iterator (deterministic in seed+step)."""

    def __init__(self, cfg: ModelConfig, batch: int, seq: int, seed: int = 0,
                 mlm_prob: float = 0.15, prefetch: int = 2):
        self.cfg, self.batch, self.seq = cfg, batch, seq
        self.seed, self.mlm_prob = seed, mlm_prob
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._step = 0
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = 0
        while not self._stop.is_set():
            b = make_batch(self.cfg, self.batch, self.seq, self.seed, step,
                           self.mlm_prob)
            try:
                self._q.put(b, timeout=1.0)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        return self._q.get()

    def close(self):
        self._stop.set()

from repro.data.pipeline import (
    DataPipeline,
    make_batch,
    mlm_mask,
    synthetic_tokens,
)

__all__ = ["DataPipeline", "make_batch", "mlm_mask", "synthetic_tokens"]

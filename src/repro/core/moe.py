"""Mixture-of-Experts layers with one-hop (Switch) and bi-level (SMILE) routing.

This module is the paper's contribution.  Two collective schedules are
implemented behind the same layer interface — and, as of the hop-pipeline
refactor, as two *thin definitions over one shared executor*:

* ``router="switch"`` — one-hop routing: a single flat All2All over the whole
  expert grid ``(n x m slots)``, exactly the Switch-Transformer baseline the
  paper measures against (paper §3.1, Fig. 2/3).

* ``router="smile"`` — bi-level routing (paper §3.2): an inter-node router
  ``p(x) in R^n`` dispatches tokens across the *inter* mesh axes only, then an
  intra-node router ``q(x) in R^{E/n}`` dispatches within the node across the
  *intra* mesh axes. Combine weight is ``p_i * q_j`` (Eq. 3). Four All2Alls
  per layer (two forward, two reversed — paper Fig. 5), each confined to one
  level of the network hierarchy.

**Hop-pipeline architecture** (:mod:`repro.core.pipeline`).  SMILE's thesis
is that routing is compositional — Switch is one dispatch hop, SMILE is two
nested ones — so the layer bodies here only *declare* that composition:

* :func:`switch_moe` builds ONE :class:`~repro.core.pipeline.ExpertHop`
  whose router maps each token's top-k experts onto the flat virtual-group
  grid and whose :class:`~repro.core.pipeline.HopSpec` spans the joint
  ``(inter x intra)`` mesh axes;
* :func:`smile_moe` builds TWO hops — an inter-node hop (groups = nodes,
  axes = ``plan.ep_inter``) whose inner compute is an intra-node hop
  (groups = per-node virtual experts, axes = ``plan.ep_intra``) — the
  level-2 router running on *arrived* tokens exactly as the paper draws it;

and both hand their hop list to the same
:func:`~repro.core.pipeline.execute_pipeline`, which owns every mechanism
the old monolithic bodies duplicated: dispatch backend selection
(``MoEConfig.dispatch_backend``: ``"sort"`` / ``"dense"`` capacity buffers
vs ``"dropless"`` tile-aligned ragged layouts), the exchange kind per hop
(``local`` | ``padded`` fixed-shape All2All | ``ragged`` exact-segment
All2All, ``MoEConfig.ragged_a2a``), the group sort implementation
(``MoEConfig.sort_impl``: XLA argsort vs the one-pass Pallas counting
sort), the routing-stage implementation (``MoEConfig.router_impl``:
separate XLA ops vs the fused Pallas routing megakernel, consumed by the
shared :func:`router_topk` prologue every hop router calls),
rank-major group relabeling so every wire format sees contiguous
per-rank segments, the ragged receive-bound factor
(``MoEConfig.recv_bound_factor`` — bounded receive slabs with clamp-drops
echoed on the reverse path), the expert-FFN flavor (padded / ragged /
compact, Pallas kernels via ``use_kernel``), and one
:class:`~repro.core.pipeline.MoEStats` accumulation path with per-hop
``drop_frac``.  A backend, wire, or kernel improvement lands in the
executor once and every schedule — Switch's flat hop and both SMILE levels
— inherits it; see the pipeline module docstring for how each existing
backend maps onto the IR.

The expert grid is *logical* ``(n, m)`` (from config) and is folded onto the
physical mesh axes, so the identical code runs on a single device (pure-jnp
oracle for tests), on small fake-device test meshes, and on the 256/512-chip
production meshes.

Capacity semantics follow the paper: per-group capacity
``C = ceil(k * T * capacity_factor / groups)``; overflow tokens are dropped
(contribute zeros through the residual connection).  The ``"dropless"``
backend replaces capacity buffers with exact ragged layouts — zero padding
into the FFN and zero drops end-to-end (unless a receive bound is
configured, which trades bounded worst-case clamp drops for a ~P-fold
smaller post-hop FFN bound).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.common.config import MoEConfig
from repro.core import pipeline as PL
from repro.core.dispatch import (combine_gather, dispatch_scatter,
                                 positions_in_group, scatter_flags)
from repro.core.layout import ExpertLayout, make_layout
# re-exported for backward compatibility (tests, benchmarks and downstream
# code import the loss/FFN/stats machinery from here)
from repro.core.pipeline import (MoEStats, execute_pipeline, experts_ffn,
                                 experts_ffn_compact,
                                 experts_ffn_compact_rows, experts_ffn_ragged,
                                 lb_loss_terms, scaled_lb_loss, z_loss,
                                 zero_stats)
from repro.sharding import comm
from repro.sharding.plan import MeshPlan

__all__ = [
    "MoEStats", "zero_stats", "router_probs", "topk_gates", "router_topk",
    "capacity",
    "lb_loss_terms", "scaled_lb_loss", "z_loss", "experts_ffn",
    "experts_ffn_ragged", "experts_ffn_compact", "experts_ffn_compact_rows",
    "switch_moe", "smile_moe", "moe_layer", "init_moe_params",
    "combine_gather", "dispatch_scatter", "positions_in_group",
    "scatter_flags",
]


# =============================================================================
# Routing math (pure, per-device)
# =============================================================================

def router_probs(x: jax.Array, w: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Eq. 1: softmax router probabilities, computed in fp32.

    Returns ``(probs, logits)`` — both (t, E); logits feed the z-loss.
    """
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32),
                        w.astype(jnp.float32))
    return jax.nn.softmax(logits, axis=-1), logits


def topk_gates(probs: jax.Array, k: int, renorm: bool) -> Tuple[jax.Array, jax.Array]:
    """Top-k expert selection. Returns (gates (t,k), idx (t,k))."""
    gates, idx = lax.top_k(probs, k)
    if renorm and k > 1:
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, idx


def router_topk(x: jax.Array, w: jax.Array, k: int, renorm: bool,
                impl: str = "unfused"
                ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """The routing prologue every hop shares: GEMM -> softmax -> top-k.

    Returns ``(gates (t,k), idx (t,k), probs (t,E), logits (t,E))``.
    ``impl`` is ``MoEConfig.router_impl``: ``"unfused"`` runs the separate
    XLA ops above; ``"fused"`` runs the single-pass Pallas routing
    megakernel (:func:`repro.kernels.ops.router_fused` — which also emits
    the counting-sort dispatch positions over the chosen ids without a
    separate sort pass), with bit-compatible outputs either way.  All three
    hop routers — switch's flat hop and both SMILE levels — route through
    here, so the impl switch needs zero per-caller code.
    """
    if impl == "fused":
        from repro.kernels import ops as kops
        gates, idx, probs, logits, _, _ = kops.router_fused(
            x, w, k, renorm=renorm)
        return gates, idx, probs, logits
    if impl != "unfused":
        raise ValueError(f"unknown router_impl {impl!r}; "
                         f"expected \"unfused\" or \"fused\"")
    probs, logits = router_probs(x, w)
    gates, idx = topk_gates(probs, k, renorm)
    return gates, idx, probs, logits


def capacity(tokens: int, k: int, factor: float, groups: int) -> int:
    return max(1, math.ceil(tokens * k * factor / groups))


# =============================================================================
# Mesh/layout helpers shared by the hop builders
# =============================================================================

def _sync_axes(plan: MeshPlan) -> Tuple[str, ...]:
    """All mesh axes across which this step's tokens are distinct (dedup'd)."""
    return tuple(dict.fromkeys(
        tuple(plan.dp_axes) + tuple(plan.ep_axes) + tuple(plan.tp_axes())))


def _grid(cfg: MoEConfig, plan: MeshPlan) -> Tuple[int, int]:
    n, m = cfg.grid
    if n == 0 or m == 0:
        n, m = max(plan.n_inter, 1), max(plan.n_intra, 1)
    if n % max(plan.n_inter, 1) or m % max(plan.n_intra, 1):
        raise ValueError(f"logical grid {(n, m)} must fold onto mesh grid "
                         f"({plan.n_inter}, {plan.n_intra})")
    return n, m


def _my_expert_weights(w: Dict[str, jax.Array], layout: ExpertLayout,
                       plan: MeshPlan, b_n: int, b_m: int):
    """Select this device's expert weights as (b_n * owned, d, f) groups.

    Weights are stored (n_g, E_pn, d, f) sharded (inter, intra?) so the local
    leaf is (b_n, E_pn_local, d, f). For replicated layouts (r > 1) the leaf
    holds all per-node experts and we gather the ones backing our slots.
    """
    out = {}
    if layout.shard_intra:
        # leaf dim1 already == b_m * h experts owned by this device
        for k, v in w.items():
            if v is None:
                continue
            out[k] = v.reshape((-1,) + v.shape[2:])
        return out, b_n * w["w1"].shape[1]
    # replicated layout: slots j_lo..j_lo+b_m map to experts slot // r
    j = comm.axis_index(plan.ep_intra) * b_m
    slot_ids = j + jnp.arange(b_m)
    expert_ids = slot_ids // layout.r                     # (b_m,)
    for k, v in w.items():
        if v is None:
            continue
        sel = jnp.take(v, expert_ids, axis=1)             # (b_n, b_m, d, f)
        out[k] = sel.reshape((-1,) + v.shape[2:])
    return out, b_n * b_m


def _rank_major_perm(V: int, vpn: int, b_n: int, b_mh: int,
                     m_mesh: int) -> Optional[jax.Array]:
    """Canonical (node-major) virtual-group id -> rank-major id.

    Canonical ``g = node * vpn + v_in_node``; joint rank over
    ``(inter, intra)`` owns nodes ``[rank_n*b_n, ...)`` and per-node slots
    ``[rank_m*b_mh, ...)``.  Identity (None) when the hop's mesh is 1x1 —
    and a pure *label* permutation otherwise: per-group contents, positions
    and capacity decisions are label-invariant (see pipeline docstring).
    """
    g = np.arange(V)
    node, vin = g // vpn, g % vpn
    rank = (node // b_n) * m_mesh + vin // b_mh
    local = (node % b_n) * b_mh + vin % b_mh
    perm = rank * (b_n * b_mh) + local
    if np.array_equal(perm, g):
        return None
    return jnp.asarray(perm, jnp.int32)


def _exchange_kind(cfg: MoEConfig, n_ranks: int, innermost: bool) -> str:
    """Map MoEConfig onto a HopSpec exchange kind (one place, all hops)."""
    if cfg.dispatch_backend != "dropless":
        return "padded"
    if innermost and n_ranks == 1:
        return "local"                    # capacity- and exchange-free
    return "ragged" if cfg.ragged_a2a else "padded"


# =============================================================================
# One-hop (Switch) schedule — the baseline, as a 1-hop pipeline
# =============================================================================

def switch_moe(params: Dict, x: jax.Array, cfg: MoEConfig, plan: MeshPlan,
               *, act: str = "gelu", renorm: bool = False,
               use_kernel: bool = False,
               token_valid=None) -> Tuple[jax.Array, MoEStats]:
    """One-hop MoE layer over local tokens ``x``: (t, d) -> (t, d).

    A single :class:`~repro.core.pipeline.ExpertHop` spanning the whole
    (inter x intra) expert grid; all mechanics live in the executor.
    ``token_valid`` (t,) bool masks dead rows (decode ticks); ``None``
    means all valid.
    """
    t, d = x.shape
    n_g, m_g = _grid(cfg, plan)
    layout = make_layout(cfg.num_experts, n_g, m_g)
    E, k = cfg.num_experts, cfg.top_k
    e_pn = layout.experts_per_node
    vpn = layout.virtual_per_node
    n_mesh, m_mesh = max(plan.n_inter, 1), max(plan.n_intra, 1)
    nm_mesh = plan.ep
    b_n, b_m = n_g // n_mesh, m_g // m_mesh
    b_mh = vpn // m_mesh
    V = layout.virtual_total

    def route(xx, token_valid, outer_gid):
        gates, eidx, probs, logits = router_topk(
            xx, params["router"]["w"], k, renorm, cfg.router_impl)   # (t, E)
        # map expert -> (node, slot-in-node, expert-in-slot) -> virtual group
        e_flat = eidx.reshape(-1)                                   # (A,)
        A = e_flat.shape[0]
        node = e_flat // e_pn
        e_local = e_flat % e_pn
        if layout.r > 1:
            # spread token assignments round-robin over the r replicas
            rr = (jnp.arange(A) // k + jnp.arange(A) % k) % layout.r
            v_in_node = e_local * layout.r + rr
        else:
            v_in_node = e_local                     # == slot * h + in-slot
        v = node * vpn + v_in_node                                  # (A,)
        valid = jnp.repeat(token_valid, k) if k > 1 else token_valid
        return PL.RouteDecision(gates.reshape(-1), v, valid, token_valid,
                                probs, logits, eidx[:, 0], k)

    spec = PL.HopSpec(
        name="flat", axes=plan.ep_axes, n_ranks=nm_mesh, num_groups=V,
        exchange=_exchange_kind(cfg, nm_mesh, innermost=True),
        capacity=capacity(t, k, cfg.capacity_factor, V),
        perm=_rank_major_perm(V, vpn, b_n, b_mh, m_mesh),
        recv_bound_factor=cfg.recv_bound_factor,
        lb_coef=cfg.lb_alpha, loss_groups=E,
        wire_integrity=cfg.wire_integrity)

    wsel, n_groups = _my_expert_weights(params["experts"], layout, plan,
                                        b_n, b_m)
    assert n_groups == spec.groups_per_rank, (n_groups, spec)
    return execute_pipeline(x, [PL.ExpertHop(route, spec)], wsel, cfg,
                            act=act, use_kernel=use_kernel,
                            sync=_sync_axes(plan), token_valid=token_valid)


# =============================================================================
# Bi-level (SMILE) schedule — the paper's contribution, as a 2-hop pipeline
# =============================================================================

def smile_moe(params: Dict, x: jax.Array, cfg: MoEConfig, plan: MeshPlan,
              *, act: str = "gelu", renorm: bool = False, top_g: int = 1,
              use_kernel: bool = False,
              token_valid=None) -> Tuple[jax.Array, MoEStats]:
    """Bi-level MoE layer over local tokens ``x``: (t, d) -> (t, d).

    Hop 1: inter-node router p (t, n) over ``plan.ep_inter``.  Hop 2
    (hop 1's inner compute): intra-node router q on *arrived* tokens over
    ``plan.ep_intra``.  The executor mirrors both reverse hops (4 All2Alls
    total); combine weight = p_i * q_j (Eq. 3) falls out of the nested
    gate-weighted combines.  Routers are shared across devices (same
    parameters everywhere), as in the paper.
    """
    t, d = x.shape
    n_g, m_g = _grid(cfg, plan)
    layout = make_layout(cfg.num_experts, n_g, m_g)
    e_pn = layout.experts_per_node
    vpn = layout.virtual_per_node
    k_local = max(1, cfg.top_k // top_g)
    n_mesh, m_mesh = max(plan.n_inter, 1), max(plan.n_intra, 1)
    b_n, b_m = n_g // n_mesh, m_g // m_mesh
    b_mh = vpn // m_mesh
    V2 = b_n * vpn                          # per-device virtual groups, hop 2

    # ---------------- hop 1: route to node -----------------------------------
    def route_inter(xx, token_valid, outer_gid):
        gates, nidx, probs, logits = router_topk(
            xx, params["router_inter"]["w"], top_g, renorm,
            cfg.router_impl)                                           # (t,n)
        valid = (jnp.repeat(token_valid, top_g) if top_g > 1
                 else token_valid)
        return PL.RouteDecision(gates.reshape(-1), nidx.reshape(-1), valid,
                                token_valid, probs, logits, nidx[:, 0],
                                top_g)

    cap1 = capacity(t, top_g, cfg.capacity_factor, n_g)
    spec1 = PL.HopSpec(
        name="inter", axes=plan.ep_inter, n_ranks=n_mesh, num_groups=n_g,
        exchange=_exchange_kind(cfg, n_mesh, innermost=False),
        capacity=cap1, perm=None,           # node ids are already rank-major
        recv_bound_factor=cfg.recv_bound_factor,
        lb_coef=cfg.lb_alpha, loss_groups=n_g,
        wire_integrity=cfg.wire_integrity)

    # ---------------- hop 2: route within node -------------------------------
    def route_intra(x1, valid1, node_row):
        gates, qidx, probs, logits = router_topk(
            x1, params["router_intra"]["w"], k_local, renorm,
            cfg.router_impl)
        q1 = qidx.reshape(-1)                                       # (A2,)
        A2 = q1.shape[0]
        validA = jnp.repeat(valid1, k_local) if k_local > 1 else valid1
        if layout.r > 1:
            rr = jnp.arange(A2) % layout.r
            v_in_node = q1 * layout.r + rr
        else:
            v_in_node = q1
        # per-node virtual groups, node-major (canonical)
        node_of = (jnp.repeat(node_row, k_local) if k_local > 1
                   else node_row)
        v2 = node_of * vpn + v_in_node
        return PL.RouteDecision(gates.reshape(-1), v2, validA, valid1,
                                probs, logits, qidx[:, 0], k_local)

    if cfg.tight_level2_capacity:
        # beyond-paper: the level-1 buffer is ~cap-factor x larger than the
        # tokens it actually carries; sizing level-2 capacity from EXPECTED
        # valid arrivals (t * g / n per node, x cap headroom) instead of the
        # padded buffer removes the capacity compounding that doubles the
        # intra-node All2All payload (EXPERIMENTS.md §Perf-2).
        expected = max(1, math.ceil(t * top_g / n_g))
        cap2 = capacity(expected, k_local, cfg.capacity_factor, vpn)
    else:
        cap2 = capacity(n_mesh * cap1, k_local, cfg.capacity_factor, vpn)
    spec2 = PL.HopSpec(
        name="intra", axes=plan.ep_intra, n_ranks=m_mesh, num_groups=V2,
        exchange=_exchange_kind(cfg, m_mesh, innermost=True),
        capacity=cap2, perm=_rank_major_perm(V2, vpn, b_n, b_mh, m_mesh),
        recv_bound_factor=cfg.recv_bound_factor,
        lb_coef=cfg.lb_beta, loss_groups=e_pn,
        wire_integrity=cfg.wire_integrity)

    wsel, n_groups = _my_expert_weights(params["experts"], layout, plan,
                                        b_n, b_m)
    assert n_groups == spec2.groups_per_rank, (n_groups, spec2)
    return execute_pipeline(
        x, [PL.ExpertHop(route_inter, spec1), PL.ExpertHop(route_intra, spec2)],
        wsel, cfg, act=act, use_kernel=use_kernel, sync=_sync_axes(plan),
        token_valid=token_valid)


# =============================================================================
# Parameter init
# =============================================================================

def init_moe_params(key: jax.Array, cfg: MoEConfig, d_model: int,
                    plan: MeshPlan, *, glu: bool = False,
                    param_dtype=jnp.float32) -> Dict:
    """Init MoE layer params. Expert tensors are stored (n_g, E_pn, d, f)."""
    n_g, m_g = _grid(cfg, plan)
    layout = make_layout(cfg.num_experts, n_g, m_g)
    e_pn = layout.experts_per_node
    f = cfg.d_ff_expert
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    scale_in = 1.0 / math.sqrt(d_model)
    scale_out = 1.0 / math.sqrt(f)
    experts = {
        "w1": (jax.random.normal(k1, (n_g, e_pn, d_model, f)) * scale_in
               ).astype(param_dtype),
        "w2": (jax.random.normal(k2, (n_g, e_pn, f, d_model)) * scale_out
               ).astype(param_dtype),
    }
    if glu:
        experts["w3"] = (jax.random.normal(k3, (n_g, e_pn, d_model, f))
                         * scale_in).astype(param_dtype)
    p: Dict = {"experts": experts}
    if cfg.router == "smile":
        p["router_inter"] = {"w": (jax.random.normal(k4, (d_model, n_g))
                                   * scale_in).astype(param_dtype)}
        p["router_intra"] = {"w": (jax.random.normal(k5, (d_model, e_pn))
                                   * scale_in).astype(param_dtype)}
    else:
        p["router"] = {"w": (jax.random.normal(k4, (d_model, cfg.num_experts))
                             * scale_in).astype(param_dtype)}
    return p


def moe_layer(params: Dict, x: jax.Array, cfg: MoEConfig, plan: MeshPlan,
              *, act: str = "gelu", use_kernel: bool = False,
              token_valid=None) -> Tuple[jax.Array, MoEStats]:
    """Dispatch to the configured routing schedule. ``x``: (t, d) local tokens.

    ``token_valid`` (t,) bool, optional: live-token mask for decode-shaped
    calls (continuous-batching ticks where some slots are dead).  Invalid
    rows route nowhere — zero ragged segments on the wire, excluded from
    LB/z losses — and combine to exactly zero.
    """
    if cfg.router == "smile":
        return smile_moe(params, x, cfg, plan, act=act, renorm=cfg.renorm_gates,
                         top_g=cfg.top_g, use_kernel=use_kernel,
                         token_valid=token_valid)
    return switch_moe(params, x, cfg, plan, act=act, renorm=cfg.renorm_gates,
                      use_kernel=use_kernel, token_valid=token_valid)

"""Mixture-of-Experts layers with one-hop (Switch) and bi-level (SMILE) routing.

This module is the paper's contribution. Two collective schedules are
implemented behind the same layer interface:

* ``router="switch"`` — one-hop routing: a single flat All2All over the whole
  expert grid ``(n x m slots)``, exactly the Switch-Transformer baseline the
  paper measures against (paper §3.1, Fig. 2/3).

* ``router="smile"`` — bi-level routing (paper §3.2): an inter-node router
  ``p(x) in R^n`` dispatches tokens across the *inter* mesh axes only, then an
  intra-node router ``q(x) in R^{E/n}`` dispatches within the node across the
  *intra* mesh axes. Combine weight is ``p_i * q_j`` (Eq. 3). Four All2Alls
  per layer (two forward, two reversed — paper Fig. 5), each confined to one
  level of the network hierarchy.

The expert grid is *logical* ``(n, m)`` (from config) and is folded onto the
physical mesh axes, so the identical code runs on a single device (pure-jnp
oracle for tests), on small fake-device test meshes, and on the 256/512-chip
production meshes.

Capacity semantics follow the paper: per-group capacity
``C = ceil(k * T * capacity_factor / groups)``; overflow tokens are dropped
(contribute zeros through the residual connection).

**Dispatch-backend architecture.** The local dispatch/combine math — placing
token assignments into per-group capacity buffers before each All2All and
reading them back gate-weighted after — is delegated to the pluggable
subsystem in :mod:`repro.core.dispatch`, selected by
``MoEConfig.dispatch_backend``:

* ``"sort"`` (default) — stable argsort by destination group +
  sorted-segment position arithmetic; the buffer is built by *gathering*
  rows straight from the token array (no k-fold token copy), optionally
  through the fused Pallas gather/gather-reduce kernels in
  :mod:`repro.kernels.moe_dispatch` (``use_kernel=True``).
* ``"dense"`` — the O(tokens x groups) one-hot/cumsum oracle, kept for
  verification and as the equivalence reference in tests.
* ``"dropless"`` — capacity-free expert compute AND capacity-free wire:
  tokens are compacted into the tile-aligned ragged layout of
  :func:`repro.core.dispatch.dispatch_ragged` and the expert FFN runs over
  *exact* per-group segment lengths through the ragged grouped-matmul
  kernel (:mod:`repro.kernels.grouped_ffn`).  On a meshed expert grid every
  dispatch hop — switch's one flat All2All and both SMILE levels — moves
  exact tile-aligned token segments through
  :func:`repro.sharding.comm.ragged_all_to_all` (a tiny count All2All, then
  segment movement; ``cfg.ragged_a2a``, on by default): the layout's groups
  are relabeled *rank-major* so each destination rank's wire segment is one
  contiguous row range, the receiver rebuilds per-row (group, validity)
  structure from the exchanged count grid alone, re-compacts, and the
  reverse hop returns exact segments to their origin offsets.  Zero
  capacity padding anywhere — wire or MXU — and **zero token drops
  end-to-end** (``drop_frac`` is the exact constant 0.0; the static
  receive bound absorbs any routing skew — note that bound is the worst
  case ``n_ranks * R`` and inflates post-hop row counts accordingly, see
  :func:`_ragged_hop`).  ``ragged_a2a=False`` restores the fixed-shape
  capacity hop + on-arrival re-compaction for A/B comparison
  (EXPERIMENTS.md §Perf-4 quantifies the wire-byte reduction).

Both routing schedules run every dispatch hop (one for switch, two per
direction for SMILE) through the same interface, so a backend improvement
lands on all of them at once.

Every hop's stable group sort — the sort backend's position assignment,
the dropless sender layout, AND the ragged receiver re-compaction — runs
through :func:`repro.kernels.ops.group_sort`, selected by
``MoEConfig.sort_impl``: ``"argsort"`` (XLA's generic O(A log A) sort, the
default here) vs ``"radix"`` (the one-pass O(A) Pallas counting sort of
:mod:`repro.kernels.radix_sort` — the TPU fast path, bit-identical by
construction; EXPERIMENTS.md §Perf-5).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.common.config import MoEConfig
from repro.core import dispatch as D
# re-exported for backward compatibility (tests and downstream code import
# the dispatch primitives from here)
from repro.core.dispatch import (combine_gather, dispatch_scatter,
                                 positions_in_group, scatter_flags)
from repro.core.layout import ExpertLayout, make_layout
from repro.sharding import comm
from repro.sharding.plan import MeshPlan


# =============================================================================
# Routing math (pure, per-device)
# =============================================================================

def router_probs(x: jax.Array, w: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Eq. 1: softmax router probabilities, computed in fp32.

    Returns ``(probs, logits)`` — both (t, E); logits feed the z-loss.
    """
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32),
                        w.astype(jnp.float32))
    return jax.nn.softmax(logits, axis=-1), logits


def topk_gates(probs: jax.Array, k: int, renorm: bool) -> Tuple[jax.Array, jax.Array]:
    """Top-k expert selection. Returns (gates (t,k), idx (t,k))."""
    gates, idx = lax.top_k(probs, k)
    if renorm and k > 1:
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, idx


def capacity(tokens: int, k: int, factor: float, groups: int) -> int:
    return max(1, math.ceil(tokens * k * factor / groups))


# =============================================================================
# Load-balancing losses
# =============================================================================

def lb_loss_terms(probs: jax.Array, top1: jax.Array, valid: jax.Array,
                  num_groups: int, sync_axes) -> Tuple[jax.Array, jax.Array]:
    """Return globally-averaged (f, P) vectors for one router (paper Eq. 4).

    ``f_i`` — fraction of tokens whose argmax picked group i;
    ``P_i`` — mean router probability mass on group i.
    Both are psum'd over ``sync_axes`` so every device sees global stats.
    """
    v = valid.astype(jnp.float32)
    cnt = comm.psum(v.sum(), sync_axes)
    one = jax.nn.one_hot(top1, num_groups, dtype=jnp.float32) * v[:, None]
    f = comm.psum(one.sum(0), sync_axes) / jnp.maximum(cnt, 1.0)
    p = comm.psum((probs * v[:, None]).sum(0), sync_axes) / jnp.maximum(cnt, 1.0)
    return f, p


def scaled_lb_loss(f: jax.Array, p: jax.Array, coef: float) -> jax.Array:
    """``coef * groups * sum_i f_i P_i`` — min = coef at uniform routing."""
    n = f.shape[0]
    return coef * n * jnp.sum(f * p)


def z_loss(logits: jax.Array, valid: jax.Array, coef: float, sync_axes):
    if coef == 0.0:
        return jnp.float32(0.0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    v = valid.astype(jnp.float32)
    s = comm.psum((jnp.square(lse) * v).sum(), sync_axes)
    cnt = comm.psum(v.sum(), sync_axes)
    return coef * s / jnp.maximum(cnt, 1.0)


# =============================================================================
# Expert FFN (grouped) — Pallas kernel plugs in here via kernels.ops
# =============================================================================

def experts_ffn(w: Dict[str, jax.Array], x: jax.Array, act: str,
                use_kernel: bool = False) -> jax.Array:
    """Apply per-group expert FFN. ``x``: (G, T, d); weights (G, d, f)/(G, f, d)."""
    if use_kernel:
        from repro.kernels import ops as kops
        return kops.grouped_ffn(x, w["w1"], w.get("w3"), w["w2"], act=act)
    actf = jax.nn.silu if act == "silu" else jax.nn.gelu
    h = jnp.einsum("gtd,gdf->gtf", x, w["w1"].astype(x.dtype))
    h = actf(h)
    if "w3" in w and w["w3"] is not None:
        h = h * jnp.einsum("gtd,gdf->gtf", x, w["w3"].astype(x.dtype))
    return jnp.einsum("gtf,gfd->gtd", h, w["w2"].astype(x.dtype))


def experts_ffn_ragged(w: Dict[str, jax.Array], rows: jax.Array,
                       group_starts: jax.Array, act: str, *,
                       block: int, use_kernel: bool = False) -> jax.Array:
    """Expert FFN over the dropless tile-aligned ragged layout.

    ``rows``: (R, d) flat row array from :func:`repro.core.dispatch.
    dispatch_ragged`; ``group_starts``: (G+1,) aligned segment offsets;
    ``block``: the layout's row-tile size.  The non-kernel path runs one
    batched matmul over the row tiles with per-tile weight selection —
    every tile belongs to exactly one group, so this is the jnp shadow of
    the Pallas kernel's scalar-prefetched weight indirection.
    """
    if use_kernel:
        from repro.kernels import ops as kops
        return kops.grouped_ffn_ragged(rows, group_starts, w["w1"],
                                       w.get("w3"), w["w2"], block=block,
                                       act=act)
    R, d = rows.shape
    tile_gid = D.ragged_tile_gids(group_starts, R // block, block)
    xt = rows.reshape(R // block, block, d)
    actf = jax.nn.silu if act == "silu" else jax.nn.gelu
    h = actf(jnp.einsum("tbd,tdf->tbf", xt,
                        jnp.take(w["w1"].astype(rows.dtype), tile_gid, axis=0)))
    if "w3" in w and w["w3"] is not None:
        h = h * jnp.einsum("tbd,tdf->tbf", xt,
                           jnp.take(w["w3"].astype(rows.dtype), tile_gid,
                                    axis=0))
    y = jnp.einsum("tbf,tfd->tbd", h,
                   jnp.take(w["w2"].astype(rows.dtype), tile_gid, axis=0))
    return y.reshape(R, d)


def experts_ffn_compact_rows(w: Dict[str, jax.Array], rows: jax.Array,
                             gid: jax.Array, valid: jax.Array,
                             num_groups: int, act: str,
                             use_kernel: bool = False,
                             sort_impl: str = "argsort") -> jax.Array:
    """Dropless expert compute over *received* rows with per-row group ids.

    ``rows``: (S, d) arrived slab (any layout); ``gid``/``valid``: (S,) local
    group id and real-row flag per slab row.  Compacts the valid rows into
    the tile-aligned ragged layout, runs the FFN over exact segment lengths,
    and scatters results back to the slab layout (invalid rows stay zero) —
    the MXU never touches padding regardless of how the slab arrived.
    """
    ones = jnp.ones((rows.shape[0],), jnp.float32)
    r2, starts, st = D.dispatch_ragged(rows, gid, ones, num_groups, k=1,
                                       valid=valid, use_kernel=use_kernel,
                                       sort_impl=sort_impl)
    out = experts_ffn_ragged(w, r2, starts, act, block=st.cap,
                             use_kernel=use_kernel)
    return D.combine(out, st)


def experts_ffn_compact(w: Dict[str, jax.Array], recv: jax.Array,
                        valid: jax.Array, act: str,
                        use_kernel: bool = False,
                        sort_impl: str = "argsort") -> jax.Array:
    """Dropless expert compute over a *received* capacity buffer.

    When a fixed-shape All2All hop is kept (``ragged_a2a=False``), the
    received ``(G, S, d)`` buffer still carries ``(cf - 1)/cf`` padding rows.
    This compacts the valid rows (``valid``: (G, S) bool) into the ragged
    layout, runs the FFN over exact segment lengths, and scatters results
    back to the fixed slot layout (empty slots stay zero, matching what the
    padded FFN would have produced) — the MegaScale-MoE "no padding into the
    FFN" hot-path fix with the collective left untouched.
    """
    G, S, d = recv.shape
    rgid = jnp.repeat(jnp.arange(G, dtype=jnp.int32), S)
    out = experts_ffn_compact_rows(w, recv.reshape(G * S, d), rgid,
                                   valid.reshape(-1), G, act,
                                   use_kernel=use_kernel,
                                   sort_impl=sort_impl)
    return out.reshape(G, S, d)


# =============================================================================
# Mesh folding helpers
# =============================================================================

def _fold_a2a(buf: jax.Array, groups: int, mesh_axes, mesh_size: int) -> jax.Array:
    """All2All a (groups, ...) buffer over mesh axes of total size ``s | groups``.

    Logical groups are block-assigned to mesh ranks. After the exchange the
    leading dims are (src_rank, my_local_groups, ...), flattened back to
    (mesh_size * groups//mesh_size, ...) in (src, local-group) order.
    """
    if mesh_size == 1:
        return buf
    b = groups // mesh_size
    rest = buf.shape[1:]
    buf = buf.reshape((mesh_size, b) + rest)
    buf = comm.all_to_all(buf, mesh_axes, split_axis=0, concat_axis=0)
    return buf.reshape((mesh_size * b,) + rest)


def _ragged_hop(rows: jax.Array, group_starts: jax.Array,
                seg_lens: jax.Array, n_ranks: int, axes, block: int):
    """Forward ragged All2All of one dispatch hop — zero capacity padding.

    ``rows``: (R, d) *rank-major* ragged layout (groups ordered so that each
    destination rank's groups are contiguous); ``group_starts``: its
    (n_ranks*n_local + 1,) aligned offsets; ``seg_lens``: the raw per-group
    valid counts.  Exchanges exact tile-aligned segments plus the tiny count
    grid, and rebuilds the received slab's per-row structure from the counts
    alone — no intermediate capacity scatter anywhere.

    Returns ``(recv, gid, valid, recv_counts, send_counts)``: ``recv``
    (n_ranks*R, d) source-major received slab; ``gid``/``valid`` per slab
    row (local group id, real-row flag); ``recv_counts`` (n_ranks,) aligned
    per-source rows — exactly the ``send_counts`` of the mirrored reverse
    hop, whose ``recv_counts`` are in turn this hop's ``send_counts`` (so
    the reverse needs no count exchange at all).  Identity when ``axes`` is
    empty.

    The received slab is sized ``n_ranks * R`` — the static worst case
    (every rank routes everything here), which is what guarantees zero
    drops under ANY skew.  That bound is a real cost on every backend,
    native op included: post-hop compute that scans the slab (the level-2
    router on SMILE arrivals, the re-compaction sort, the recompacted FFN's
    row bound) runs over ``~n_ranks/cf x`` more rows than the padded path's
    capacity-bounded buffer, partially offsetting the wire win when those
    stages aren't collective-dominated.  ROADMAP's "ragged receive-bound
    factor" follow-up (bound = factor x expected arrivals, clamp-drops
    reported) is the production-shaped trade.
    """
    n_local = seg_lens.shape[0] // n_ranks
    send_counts = D.ragged_send_counts(group_starts, n_local)
    # one count collective per hop: the (n_ranks, n_local) length grid also
    # determines the aligned per-source segment extents, so the segment
    # exchange skips its own count round trip
    len_grid = comm.all_to_all(seg_lens.reshape(n_ranks, n_local), axes,
                               split_axis=0, concat_axis=0)
    recv_counts = (((len_grid + block - 1) // block) * block).sum(
        axis=1).astype(jnp.int32)
    recv, _ = comm.ragged_all_to_all(
        rows, send_counts, axes, recv_rows=n_ranks * rows.shape[0],
        recv_counts=recv_counts)
    gid, valid = D.ragged_recv_layout(len_grid, block, recv.shape[0])
    return recv, gid, valid, recv_counts, send_counts


# =============================================================================
# Layer state shared by both schedules
# =============================================================================

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class MoEStats:
    """Aux outputs of a MoE layer (losses are fp32 scalars)."""
    lb_loss: jax.Array
    z_loss: jax.Array
    # diagnostic: fraction of token-assignments dropped by capacity
    drop_frac: jax.Array


def _sync_axes(plan: MeshPlan) -> Tuple[str, ...]:
    """All mesh axes across which this step's tokens are distinct (dedup'd)."""
    return tuple(dict.fromkeys(
        tuple(plan.dp_axes) + tuple(plan.ep_axes) + tuple(plan.tp_axes())))


def _grid(cfg: MoEConfig, plan: MeshPlan) -> Tuple[int, int]:
    n, m = cfg.grid
    if n == 0 or m == 0:
        n, m = max(plan.n_inter, 1), max(plan.n_intra, 1)
    if n % max(plan.n_inter, 1) or m % max(plan.n_intra, 1):
        raise ValueError(f"logical grid {(n, m)} must fold onto mesh grid "
                         f"({plan.n_inter}, {plan.n_intra})")
    return n, m


def _my_expert_weights(w: Dict[str, jax.Array], layout: ExpertLayout,
                       plan: MeshPlan, b_n: int, b_m: int):
    """Select this device's expert weights as (b_n * owned, d, f) groups.

    Weights are stored (n_g, E_pn, d, f) sharded (inter, intra?) so the local
    leaf is (b_n, E_pn_local, d, f). For replicated layouts (r > 1) the leaf
    holds all per-node experts and we gather the ones backing our slots.
    """
    out = {}
    if layout.shard_intra:
        # leaf dim1 already == b_m * h experts owned by this device
        for k, v in w.items():
            if v is None:
                continue
            out[k] = v.reshape((-1,) + v.shape[2:])
        return out, b_n * w["w1"].shape[1]
    # replicated layout: slots j_lo..j_lo+b_m map to experts slot // r
    j = comm.axis_index(plan.ep_intra) * b_m
    slot_ids = j + jnp.arange(b_m)
    expert_ids = slot_ids // layout.r                     # (b_m,)
    for k, v in w.items():
        if v is None:
            continue
        sel = jnp.take(v, expert_ids, axis=1)             # (b_n, b_m, d, f)
        out[k] = sel.reshape((-1,) + v.shape[2:])
    return out, b_n * b_m


# =============================================================================
# One-hop (Switch) schedule — the baseline
# =============================================================================

def switch_moe(params: Dict, x: jax.Array, cfg: MoEConfig, plan: MeshPlan,
               *, act: str = "gelu", renorm: bool = False,
               use_kernel: bool = False) -> Tuple[jax.Array, MoEStats]:
    """One-hop MoE layer over local tokens ``x``: (t, d) -> (t, d).

    Single flat All2All across the whole (inter x intra) expert grid.
    """
    t, d = x.shape
    n_g, m_g = _grid(cfg, plan)
    layout = make_layout(cfg.num_experts, n_g, m_g)
    E, k = cfg.num_experts, cfg.top_k
    e_pn = layout.experts_per_node
    sync = _sync_axes(plan)

    probs, logits = router_probs(x, params["router"]["w"])     # (t, E)
    gates, eidx = topk_gates(probs, k, renorm)

    # map expert -> (node, slot-in-node, expert-in-slot) -> virtual group
    e_flat = eidx.reshape(-1)                                   # (A,)
    A = e_flat.shape[0]
    node = e_flat // e_pn
    e_local = e_flat % e_pn
    if layout.r > 1:
        rr = (jnp.arange(A) // k + jnp.arange(A) % k) % layout.r
        slot = e_local * layout.r + rr
        v_in_node = slot                                        # h == 1
    else:
        slot = e_local // layout.h
        v_in_node = e_local                                     # slot*h + in-slot
    v = node * layout.virtual_per_node + v_in_node              # (A,)

    V = layout.virtual_total
    nm_mesh = plan.ep
    b_n = n_g // max(plan.n_inter, 1)
    b_m = m_g // max(plan.n_intra, 1)
    dropless = cfg.dispatch_backend == "dropless"
    simpl = cfg.sort_impl

    if dropless and nm_mesh == 1:
        # ---- fully capacity-free: the whole expert grid is local ------------
        # no (V, cap, d) buffer, no padding into the FFN, zero token drops
        rows, starts, dstate = D.dispatch_ragged(x, v, gates.reshape(-1), V,
                                                 k=k, use_kernel=use_kernel,
                                                 sort_impl=simpl)
        keep = dstate.keep
        wsel, n_groups = _my_expert_weights(params["experts"], layout, plan,
                                            b_n, b_m)
        out_rows = experts_ffn_ragged(wsel, rows, starts, act,
                                      block=dstate.cap, use_kernel=use_kernel)
        y = D.combine(out_rows, dstate)
    elif dropless and cfg.ragged_a2a:
        # ---- meshed + capacity-free: ragged All2All on the wire -------------
        # relabel groups rank-major (joint rank over plan.ep_axes is
        # inter-major, matching the capacity fold) so each rank's wire
        # segment is one contiguous tile-aligned row range
        m_mesh = max(plan.n_intra, 1)
        b_mh = layout.virtual_per_node // m_mesh
        rank = (node // b_n) * m_mesh + v_in_node // b_mh
        local_g = (node % b_n) * b_mh + v_in_node % b_mh
        g_sorted = rank * (b_n * b_mh) + local_g
        rows, starts, dstate = D.dispatch_ragged(x, g_sorted,
                                                 gates.reshape(-1), V, k=k,
                                                 use_kernel=use_kernel,
                                                 sort_impl=simpl)
        keep = dstate.keep                                  # == all True
        seg_lens = D.ragged_seg_lens(g_sorted, keep, V)
        recv, rgid, rvalid, rcounts, scounts = _ragged_hop(
            rows, starts, seg_lens, nm_mesh, plan.ep_axes, dstate.cap)
        wsel, n_groups = _my_expert_weights(params["experts"], layout, plan,
                                            b_n, b_m)
        out_slab = experts_ffn_compact_rows(wsel, recv, rgid, rvalid,
                                            n_groups, act, use_kernel,
                                            sort_impl=simpl)
        back, _ = comm.ragged_all_to_all(out_slab, rcounts, plan.ep_axes,
                                         recv_rows=rows.shape[0],
                                         seg_rows=rows.shape[0],
                                         recv_counts=scounts)
        y = D.combine(back, dstate)
    else:
        # capacity buffers only where the fixed-shape All2All payload needs
        # them; dropless runs the hop on the sort backend's mechanics
        hop_backend = "sort" if dropless else cfg.dispatch_backend
        cap = capacity(t, k, cfg.capacity_factor, V)
        buf, dstate = D.dispatch(x, v, gates.reshape(-1), V, cap, k=k,
                                 backend=hop_backend,
                                 use_kernel=use_kernel,
                                 sort_impl=simpl)                # (V, cap, d)
        keep = dstate.keep

        # ---- single flat All2All over the combined grid --------------------
        def fold(z):
            # (V, cap, ...) -> mesh-major -> (groups, src*cap, ...)
            rest = z.shape[1:]
            z = z.reshape((max(plan.n_inter, 1), b_n, max(plan.n_intra, 1),
                           b_m * layout.h) + rest)
            z = jnp.moveaxis(z, 2, 1)                   # mesh dims first
            z = z.reshape((nm_mesh, b_n * b_m * layout.h) + rest)
            z = _fold_a2a(z, nm_mesh, plan.ep_axes, nm_mesh)    # src-major
            z = z.reshape((nm_mesh, n_groups) + rest)
            return jnp.moveaxis(z, 1, 0).reshape(
                (n_groups, nm_mesh * rest[0]) + rest[1:])

        wsel, n_groups = _my_expert_weights(params["experts"], layout,
                                            plan, b_n, b_m)
        recv = fold(buf)                                # (groups, src*cap, d)

        # ---- expert compute -------------------------------------------------
        if dropless:
            # ragged re-compaction: the A2A keeps its fixed shape, but the
            # FFN only sees the valid rows of the received buffer
            slot_valid = D.dispatch_flags(keep.astype(jnp.float32), dstate)
            rvalid = fold(slot_valid) > 0               # (groups, src*cap)
            out = experts_ffn_compact(wsel, recv, rvalid, act, use_kernel,
                                      sort_impl=simpl)
        else:
            out = experts_ffn(wsel, recv, act, use_kernel)

        # ---- reverse All2All ------------------------------------------------
        out = out.reshape(n_groups, nm_mesh, cap, d).transpose(1, 0, 2, 3)
        out = out.reshape(nm_mesh, n_groups * cap * d)
        back = _fold_a2a(out, nm_mesh, plan.ep_axes, nm_mesh)
        back = back.reshape(nm_mesh, n_groups, cap, d)
        # undo the mesh-major transpose: -> (n_g, m_g*h, cap, d)
        back = back.reshape(max(plan.n_inter, 1), max(plan.n_intra, 1), b_n,
                            b_m * layout.h, cap, d)
        back = back.transpose(0, 2, 1, 3, 4, 5).reshape(V, cap, d)

        y = D.combine(back, dstate)

    # ---- losses -------------------------------------------------------------
    top1 = eidx[:, 0]
    f, p = lb_loss_terms(probs, top1, jnp.ones((t,), bool), E, sync)
    lb = scaled_lb_loss(f, p, cfg.lb_alpha)
    zl = z_loss(logits, jnp.ones((t,), bool), cfg.router_z_coef, sync)
    if dropless and (nm_mesh == 1 or cfg.ragged_a2a):
        # no capacity buffer anywhere on this path: nothing CAN drop, so the
        # diagnostic is the exact constant 0.0 (not a psum over keep masks)
        drop_frac = jnp.float32(0.0)
    else:
        dropped = comm.psum((~keep).sum().astype(jnp.float32), sync)
        total = comm.psum(jnp.float32(A), sync)
        drop_frac = dropped / jnp.maximum(total, 1)
    return y, MoEStats(lb, zl, drop_frac)


# =============================================================================
# Bi-level (SMILE) schedule — the paper's contribution
# =============================================================================

def smile_moe(params: Dict, x: jax.Array, cfg: MoEConfig, plan: MeshPlan,
              *, act: str = "gelu", renorm: bool = False, top_g: int = 1,
              use_kernel: bool = False) -> Tuple[jax.Array, MoEStats]:
    """Bi-level MoE layer over local tokens ``x``: (t, d) -> (t, d).

    Level 1: inter-node router p (t, n) -> All2All over ``plan.ep_inter``.
    Level 2: intra-node router q on *arrived* tokens -> All2All over
    ``plan.ep_intra``. Reverse path mirrors both hops (4 All2Alls total).
    Combine weight = p_i * q_j (Eq. 3). Routers are shared across devices
    (same parameters everywhere), as in the paper.
    """
    t, d = x.shape
    n_g, m_g = _grid(cfg, plan)
    layout = make_layout(cfg.num_experts, n_g, m_g)
    e_pn = layout.experts_per_node
    k_local = max(1, cfg.top_k // top_g)
    sync = _sync_axes(plan)
    dropless = cfg.dispatch_backend == "dropless"
    ragged = dropless and cfg.ragged_a2a
    # without ragged A2A, dropless keeps a capacity buffer for each
    # fixed-shape hop (on the sort backend's mechanics) and goes
    # capacity-free only at the expert compute
    hop_backend = "sort" if dropless else cfg.dispatch_backend
    simpl = cfg.sort_impl
    n_mesh = max(plan.n_inter, 1)
    b_n = n_g // n_mesh

    # ---------------- level 1: route to node --------------------------------
    p_probs, p_logits = router_probs(x, params["router_inter"]["w"])  # (t, n)
    p_gates, nidx = topk_gates(p_probs, top_g, renorm)
    n1 = nidx.reshape(-1)                                             # (A1,)
    A1 = n1.shape[0]
    if ragged:
        # ragged inter-node hop: node ids are already rank-major (rank =
        # node // b_n), so the layout's segments map straight onto the wire
        rows1, starts1, st1 = D.dispatch_ragged(x, n1, p_gates.reshape(-1),
                                                n_g, k=top_g,
                                                use_kernel=use_kernel,
                                                sort_impl=simpl)
        keep1 = st1.keep                                    # == all True
        lens1 = D.ragged_seg_lens(n1, keep1, n_g)
        recv1, node_row, valid1, rc1, sc1 = _ragged_hop(
            rows1, starts1, lens1, n_mesh, plan.ep_inter, st1.cap)
        x1 = recv1                                          # (t1, d) slab
        t1 = x1.shape[0]
    else:
        cap1 = capacity(t, top_g, cfg.capacity_factor, n_g)
        buf1, st1 = D.dispatch(x, n1, p_gates.reshape(-1), n_g, cap1,
                               k=top_g, backend=hop_backend,
                               use_kernel=use_kernel,
                               sort_impl=simpl)                       # (n_g,C1,d)
        keep1 = st1.keep
        vflag = D.dispatch_flags(jnp.ones((A1,), jnp.float32), st1)   # (n_g,C1)

        recv1 = _fold_a2a(buf1, n_g, plan.ep_inter, n_mesh)
        rflag = _fold_a2a(vflag, n_g, plan.ep_inter, n_mesh)
        # received order: (src_rank, my_local_node, C1) -> group by my node
        recv1 = recv1.reshape(n_mesh, b_n, cap1, d).transpose(1, 0, 2, 3)
        recv1 = recv1.reshape(b_n, n_mesh * cap1, d)
        rflag = rflag.reshape(n_mesh, b_n, cap1).transpose(1, 0, 2)
        rflag = rflag.reshape(b_n, n_mesh * cap1)

        t1 = b_n * n_mesh * cap1                              # arrived tokens
        x1 = recv1.reshape(t1, d)
        valid1 = rflag.reshape(t1) > 0
        node_row = jnp.repeat(jnp.arange(b_n, dtype=jnp.int32),
                              n_mesh * cap1)

    # ---------------- level 2: route within node ----------------------------
    q_probs, q_logits = router_probs(x1, params["router_intra"]["w"])  # (t1,e_pn)
    q_gates, qidx = topk_gates(q_probs, k_local, renorm)
    q1 = qidx.reshape(-1)                                             # (A2,)
    A2 = q1.shape[0]
    validA = jnp.repeat(valid1, k_local) if k_local > 1 else valid1

    if layout.r > 1:
        rr = (jnp.arange(A2)) % layout.r
        v_in_node = q1 * layout.r + rr
    else:
        v_in_node = q1
    # per-node virtual groups, node-major so the intra A2A folds per node
    node_of = (jnp.repeat(node_row, k_local) if k_local > 1 else node_row)
    v2 = node_of * layout.virtual_per_node + v_in_node
    V2 = b_n * layout.virtual_per_node
    m_mesh = max(plan.n_intra, 1)
    b_mh = layout.virtual_per_node // m_mesh                  # groups per rank
    b_m = m_g // m_mesh
    wsel, n_groups = _my_expert_weights(params["experts"], layout, plan,
                                        b_n, b_m)
    assert n_groups == b_n * b_mh, (n_groups, b_n, b_mh)

    if dropless and m_mesh == 1:
        # ---------------- level 2, capacity-free ------------------------------
        # the intra-node expert grid is local: no (V2, C2, d) buffer, no
        # level-2 capacity drops, FFN over exact per-group segment lengths
        rows2, starts2, st2 = D.dispatch_ragged(x1, v2, q_gates.reshape(-1),
                                                V2, k=k_local, valid=validA,
                                                use_kernel=use_kernel,
                                                sort_impl=simpl)
        keep2 = st2.keep
        out_rows = experts_ffn_ragged(wsel, rows2, starts2, act,
                                      block=st2.cap, use_kernel=use_kernel)
        y1 = D.combine(out_rows, st2)                          # (t1, d)
    elif ragged:
        # ---------------- level 2, meshed + ragged hop ------------------------
        # relabel the per-node virtual groups intra-rank-major so each intra
        # rank's wire segment is contiguous; no (V2, C2, d) buffer anywhere
        g2 = ((v_in_node // b_mh) * (b_n * b_mh)
              + node_of * b_mh + v_in_node % b_mh)
        rows2, starts2, st2 = D.dispatch_ragged(x1, g2, q_gates.reshape(-1),
                                                V2, k=k_local, valid=validA,
                                                use_kernel=use_kernel,
                                                sort_impl=simpl)
        keep2 = st2.keep                                    # == validA
        lens2 = D.ragged_seg_lens(g2, validA, V2)
        recv2, gid2, rvalid2, rc2, sc2 = _ragged_hop(
            rows2, starts2, lens2, m_mesh, plan.ep_intra, st2.cap)
        out_slab = experts_ffn_compact_rows(wsel, recv2, gid2, rvalid2,
                                            n_groups, act, use_kernel,
                                            sort_impl=simpl)
        back2, _ = comm.ragged_all_to_all(out_slab, rc2, plan.ep_intra,
                                          recv_rows=rows2.shape[0],
                                          seg_rows=rows2.shape[0],
                                          recv_counts=sc2)
        y1 = D.combine(back2, st2)                             # (t1, d)
    else:
        if cfg.tight_level2_capacity:
            # beyond-paper: the level-1 buffer is ~cap-factor x larger than
            # the tokens it actually carries; sizing level-2 capacity from
            # EXPECTED valid arrivals (t * g / n per node, x cap headroom)
            # instead of the padded buffer removes the capacity compounding
            # that doubles the intra-node All2All payload. Drop stats confirm
            # no extra drops at uniform routing (EXPERIMENTS.md §Perf-2).
            expected = max(1, math.ceil(t * top_g / n_g))
            cap2 = capacity(expected, k_local, cfg.capacity_factor,
                            layout.virtual_per_node)
        else:
            cap2 = capacity(n_mesh * cap1, k_local, cfg.capacity_factor,
                            layout.virtual_per_node)
        buf2, st2 = D.dispatch(x1, v2, q_gates.reshape(-1), V2, cap2,
                               k=k_local, valid=validA,
                               backend=hop_backend,
                               use_kernel=use_kernel,
                               sort_impl=simpl)               # (V2, C2, d)
        keep2 = st2.keep

        def fold2(z):
            # (V2, C2, ...) -> intra A2A per node block -> (groups, m*C2, ...)
            rest = z.shape[1:]
            z = z.reshape((b_n, m_mesh, b_mh) + rest)
            z = jnp.moveaxis(z, 1, 0).reshape((m_mesh, b_n * b_mh) + rest)
            z = _fold_a2a(z, m_mesh, plan.ep_intra, m_mesh)   # (m*.., C2, ..)
            z = z.reshape((m_mesh, n_groups) + rest)
            return jnp.moveaxis(z, 1, 0).reshape(
                (n_groups, m_mesh * rest[0]) + rest[1:])

        recv2 = fold2(buf2)                                   # (groups, S, d)

        # ---------------- expert compute -------------------------------------
        if dropless:
            # fixed-shape intra A2A retained; FFN only sees valid rows
            slot_valid2 = D.dispatch_flags(keep2.astype(jnp.float32), st2)
            rvalid2 = fold2(slot_valid2) > 0                  # (groups, S)
            out = experts_ffn_compact(wsel, recv2, rvalid2, act, use_kernel,
                                      sort_impl=simpl)
        else:
            out = experts_ffn(wsel, recv2, act, use_kernel)

        # ---------------- reverse level 2 ------------------------------------
        out = out.reshape(n_groups, m_mesh, cap2, d).transpose(1, 0, 2, 3)
        out = out.reshape(m_mesh, n_groups * cap2 * d)
        back2 = _fold_a2a(out, m_mesh, plan.ep_intra, m_mesh)
        back2 = back2.reshape(m_mesh, b_n, b_mh, cap2, d
                              ).transpose(1, 0, 2, 3, 4)
        back2 = back2.reshape(V2, cap2, d)
        # apply intra gates where q is known (the intermediate hop)
        y1 = D.combine(back2, st2)                             # (t1, d)

    # ---------------- reverse level 1 ----------------------------------------
    if ragged:
        back1, _ = comm.ragged_all_to_all(y1, rc1, plan.ep_inter,
                                          recv_rows=rows1.shape[0],
                                          seg_rows=rows1.shape[0],
                                          recv_counts=sc1)
        y = D.combine(back1, st1)
    else:
        y1 = y1.reshape(b_n, n_mesh, cap1, d).transpose(1, 0, 2, 3)
        y1 = y1.reshape(n_g, cap1, d)
        back1 = _fold_a2a(y1, n_g, plan.ep_inter, n_mesh)      # (n_g, C1, d)
        y = D.combine(back1, st1)

    # ---------------- additive LB loss (Eq. 4) -------------------------------
    f_i, P_i = lb_loss_terms(p_probs, nidx[:, 0], jnp.ones((t,), bool),
                             n_g, sync)
    lb_inter = scaled_lb_loss(f_i, P_i, cfg.lb_alpha)
    sync2 = sync
    f_j, Q_j = lb_loss_terms(q_probs, qidx[:, 0], valid1, e_pn, sync2)
    lb_intra = scaled_lb_loss(f_j, Q_j, cfg.lb_beta)
    zl = (z_loss(p_logits, jnp.ones((t,), bool), cfg.router_z_coef, sync)
          + z_loss(q_logits, valid1, cfg.router_z_coef, sync2))
    # drop_frac: each level normalized by ITS OWN valid-assignment count,
    # then summed (levels compound).  Normalizing level-2 drops by the
    # level-1 count (the old math) mis-scaled the stat whenever the counts
    # differ — e.g. top_k > top_g makes A2's valid count ~k_local x A1, so
    # level-2 drops were over-weighted by that factor.  A level that ran
    # capacity-free reports the exact constant 0.0 — there is no capacity
    # buffer on it, so nothing CAN drop and no keep-mask psum is issued.
    zero = jnp.float32(0.0)
    if ragged:
        df1 = zero
    else:
        dropped1 = comm.psum((~keep1).sum().astype(jnp.float32), sync)
        total1 = comm.psum(jnp.float32(A1), sync)
        df1 = dropped1 / jnp.maximum(total1, 1)
    if ragged or (dropless and m_mesh == 1):
        df2 = zero
    else:
        dropped2 = comm.psum((validA & ~keep2).sum().astype(jnp.float32),
                             sync2)
        total2 = comm.psum(validA.sum().astype(jnp.float32), sync2)
        df2 = dropped2 / jnp.maximum(total2, 1)
    return y, MoEStats(lb_inter + lb_intra, zl, df1 + df2)


# =============================================================================
# Parameter init
# =============================================================================

def init_moe_params(key: jax.Array, cfg: MoEConfig, d_model: int,
                    plan: MeshPlan, *, glu: bool = False,
                    param_dtype=jnp.float32) -> Dict:
    """Init MoE layer params. Expert tensors are stored (n_g, E_pn, d, f)."""
    n_g, m_g = _grid(cfg, plan)
    layout = make_layout(cfg.num_experts, n_g, m_g)
    e_pn = layout.experts_per_node
    f = cfg.d_ff_expert
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    scale_in = 1.0 / math.sqrt(d_model)
    scale_out = 1.0 / math.sqrt(f)
    experts = {
        "w1": (jax.random.normal(k1, (n_g, e_pn, d_model, f)) * scale_in
               ).astype(param_dtype),
        "w2": (jax.random.normal(k2, (n_g, e_pn, f, d_model)) * scale_out
               ).astype(param_dtype),
    }
    if glu:
        experts["w3"] = (jax.random.normal(k3, (n_g, e_pn, d_model, f))
                         * scale_in).astype(param_dtype)
    p: Dict = {"experts": experts}
    if cfg.router == "smile":
        p["router_inter"] = {"w": (jax.random.normal(k4, (d_model, n_g))
                                   * scale_in).astype(param_dtype)}
        p["router_intra"] = {"w": (jax.random.normal(k5, (d_model, e_pn))
                                   * scale_in).astype(param_dtype)}
    else:
        p["router"] = {"w": (jax.random.normal(k4, (d_model, cfg.num_experts))
                             * scale_in).astype(param_dtype)}
    return p


def moe_layer(params: Dict, x: jax.Array, cfg: MoEConfig, plan: MeshPlan,
              *, act: str = "gelu",
              use_kernel: bool = False) -> Tuple[jax.Array, MoEStats]:
    """Dispatch to the configured routing schedule. ``x``: (t, d) local tokens."""
    if cfg.router == "smile":
        return smile_moe(params, x, cfg, plan, act=act, renorm=cfg.renorm_gates,
                         top_g=cfg.top_g, use_kernel=use_kernel)
    return switch_moe(params, x, cfg, plan, act=act, renorm=cfg.renorm_gates,
                      use_kernel=use_kernel)

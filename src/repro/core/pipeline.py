"""Composable hop-pipeline IR for MoE routing schedules.

SMILE's core claim is that routing is *compositional*: Switch is ONE flat
dispatch hop over the whole expert grid; SMILE is TWO nested hops over
heterogeneous links (inter-node, then intra-node on the arrived tokens).
This module makes that composition a first-class object instead of two
parallel monoliths:

* :class:`RouteDecision` — what a router decided for one hop: per-assignment
  destination groups, gates and validity, plus the router's probs/logits for
  the load-balancing and z losses.  Produced by a hop's ``route`` callable,
  consumed by the executor.

* :class:`HopSpec` — the *static* schedule of one hop: which mesh axes its
  exchange spans, how many virtual groups it dispatches into, the exchange
  kind (``"local"`` | ``"padded"`` | ``"ragged"``), the capacity / receive
  bound policy, and the canonical→rank-major group relabeling permutation
  that makes every wire format see contiguous per-rank segments.

* :class:`ExpertHop` — one pipeline stage: a ``route`` callable bound to its
  :class:`HopSpec`.

* :func:`execute_pipeline` — the single executor both schedules share.  It
  walks the hop list recursively: route → dispatch (capacity buffer or
  tile-aligned ragged layout, per ``MoEConfig.dispatch_backend``) → exchange
  (identity / fixed-shape All2All / ragged All2All) → inner compute (the
  next hop, or the expert FFN at the innermost hop) → reverse exchange →
  gate-weighted combine; accumulating one :class:`MoEStats` with *per-hop*
  drop fractions along the way.  A backend or wire improvement lands here
  once and every schedule — Switch's flat hop and both SMILE levels —
  inherits it.

**Group relabeling.**  Every hop's virtual groups are relabeled rank-major
(``spec.perm``) *before* dispatch, so that rank ``p``'s groups occupy the
contiguous id range ``[p*gpr, (p+1)*gpr)``.  This collapses what used to be
three hand-maintained fold/transpose dances (switch's mesh-major fold,
SMILE's per-node fold2, the ragged relabels) into one generic
:func:`_fold` / :func:`_unfold` pair and one ragged wire layout.  The
relabel is a pure permutation of group *labels*: per-group contents,
positions and capacity decisions are label-invariant, so outputs are
bit-identical to the node-major formulation (pinned by
``tests/test_pipeline_golden.py``).

**Receive-bound factor** (ROADMAP follow-up, implemented here once for all
hops).  A ragged hop's receive slab is statically sized for worst-case skew
— ``P x R`` rows, the price of zero drops when every rank routes everything
to one place — and the post-hop compute bound (receiver re-compaction, the
recompacted FFN, SMILE's level-2 router) scales with it.
``HopSpec.recv_bound_factor`` bounds the slab at roughly
``factor x expected arrivals`` instead (tile-aligned, never above ``P x
R``): arrivals beyond the bound are clamp-dropped on the receiver, the
reverse hop echoes each receiver's clamped counts back through its own
count exchange so every sender learns exactly which of its rows returned,
and the executor reports the clamp drops in the hop's ``drop_frac``.
``factor=None`` (the default) keeps the bit-identical zero-drop worst-case
bound.  The payoff is a ~``P/factor``-fold smaller post-hop FFN bound —
what a production deployment runs with the LB loss keeping skew near 1.

**Wire integrity** (robustness follow-up, implemented here once for all
hops).  ``HopSpec.wire_integrity`` arms per-segment payload checksums on
every ragged exchange, both directions: the parity-row wire format lives
in :mod:`repro.sharding.comm` (one integrity word per (src, dst, group)
segment, riding the slab as an extra row — fold + length + identity tag);
verification, quarantine and the exact per-(hop, src rank) accounting
(``MoEStats.fault_events`` / ``wire_faults``) live in
:func:`_ragged_forward` / :func:`_ragged_reverse` below.  ``"detect"``
flags and passes payloads through (the A/B observability mode);
``"quarantine"`` zero-fills flagged segments and charges their assignments
to the hop's drop accounting via the echoed reverse — a corrupting peer
costs its own tokens, not the whole step.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.common import faultinject as FI
from repro.core import dispatch as D
from repro.sharding import comm

# number of hop slots in the fixed-shape per-hop drop vector (switch uses 1,
# SMILE 2; the vector is zero-padded so stats trees from different routers
# and dense layers always add)
MAX_HOPS = 2

EXCHANGES = ("local", "padded", "ragged")


# =============================================================================
# Layer stats (accumulated by the executor; one path for every schedule)
# =============================================================================

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class MoEStats:
    """Aux outputs of a MoE layer (fp32 scalars / fixed-shape vectors).

    ``drop_frac`` is the summed-over-hops diagnostic every consumer already
    reads; ``hop_drop_frac`` is the per-hop breakdown — slot 0 is the
    outermost hop (switch's flat hop / SMILE level 1), slot 1 SMILE level 2,
    unused slots exactly 0.0 — with one accumulation shape for both routers
    (the executor owns it; the old per-schedule ad-hoc folding is gone).

    Robustness fields (fault-containment PR): ``fault_events`` counts, per
    hop, the count-grid entries the sanitizer rejected plus the wire
    segments the checksum layer flagged (psum'd over the sync axes —
    global totals, summed across layers); ``hop_max_load`` /
    ``hop_load_entropy`` feed the router-collapse watchdog — the global
    max-load fraction (f-vector max) and normalized load entropy (in
    [0, 1], 1 = uniform) per hop, accumulated worst-case across layers
    (max / min respectively; unused hop slots stay at the neutral 0 / 1).

    ``wire_faults`` (wire-integrity PR) localizes checksum verdicts: entry
    ``[hop, s]`` is the global number of (receiver, direction) checks that
    flagged source rank ``s`` (ranks folded mod :data:`WIRE_SRC_BINS`) on
    that hop — the "which rank is corrupting the wire" dashboard row.
    All-zero whenever ``wire_integrity="off"`` or the wire is healthy.
    """
    lb_loss: jax.Array
    z_loss: jax.Array
    # diagnostic: fraction of token-assignments dropped (capacity overflow
    # on padded hops, receive-bound clamping on bounded ragged hops,
    # quarantined/suppressed segments under count faults)
    drop_frac: jax.Array
    hop_drop_frac: jax.Array        # (MAX_HOPS,) per-hop breakdown
    fault_events: jax.Array         # (MAX_HOPS,) sanitizer + wire rejections
    hop_max_load: jax.Array         # (MAX_HOPS,) max f-vector entry
    hop_load_entropy: jax.Array     # (MAX_HOPS,) normalized load entropy
    wire_faults: jax.Array          # (MAX_HOPS, WIRE_SRC_BINS) per-src-rank


# source-rank bins of MoEStats.wire_faults (ranks folded mod this; fixed so
# stats trees from different mesh shapes always add)
WIRE_SRC_BINS = 16

WIRE_POLICIES = ("off", "detect", "quarantine")


def zero_stats() -> MoEStats:
    z = jnp.float32(0.0)
    zv = jnp.zeros((MAX_HOPS,), jnp.float32)
    return MoEStats(z, z, z, zv, zv,
                    zv, jnp.ones((MAX_HOPS,), jnp.float32),
                    jnp.zeros((MAX_HOPS, WIRE_SRC_BINS), jnp.float32))


# =============================================================================
# Routing losses (pure; shared by every hop)
# =============================================================================

def lb_loss_terms(probs: jax.Array, top1: jax.Array, valid: jax.Array,
                  num_groups: int, sync_axes) -> Tuple[jax.Array, jax.Array]:
    """Return globally-averaged (f, P) vectors for one router (paper Eq. 4).

    ``f_i`` — fraction of tokens whose argmax picked group i;
    ``P_i`` — mean router probability mass on group i.
    Both are psum'd over ``sync_axes`` so every device sees global stats.
    """
    v = valid.astype(jnp.float32)
    cnt = comm.psum(v.sum(), sync_axes)
    one = jax.nn.one_hot(top1, num_groups, dtype=jnp.float32) * v[:, None]
    f = comm.psum(one.sum(0), sync_axes) / jnp.maximum(cnt, 1.0)
    p = comm.psum((probs * v[:, None]).sum(0), sync_axes) / jnp.maximum(cnt, 1.0)
    return f, p


def scaled_lb_loss(f: jax.Array, p: jax.Array, coef: float) -> jax.Array:
    """``coef * groups * sum_i f_i P_i`` — min = coef at uniform routing."""
    n = f.shape[0]
    return coef * n * jnp.sum(f * p)


def z_loss(logits: jax.Array, valid: jax.Array, coef: float, sync_axes):
    if coef == 0.0:
        return jnp.float32(0.0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    v = valid.astype(jnp.float32)
    s = comm.psum((jnp.square(lse) * v).sum(), sync_axes)
    cnt = comm.psum(v.sum(), sync_axes)
    return coef * s / jnp.maximum(cnt, 1.0)


# =============================================================================
# Expert FFN flavors (padded / ragged / compact) — Pallas kernels plug in
# via kernels.ops
# =============================================================================

def experts_ffn(w: Dict[str, jax.Array], x: jax.Array, act: str,
                use_kernel: bool = False) -> jax.Array:
    """Apply per-group expert FFN. ``x``: (G, T, d); weights (G, d, f)/(G, f, d)."""
    if use_kernel:
        from repro.kernels import ops as kops
        return kops.grouped_ffn(x, w["w1"], w.get("w3"), w["w2"], act=act)
    actf = jax.nn.silu if act == "silu" else jax.nn.gelu
    h = jnp.einsum("gtd,gdf->gtf", x, w["w1"].astype(x.dtype))
    h = actf(h)
    if "w3" in w and w["w3"] is not None:
        h = h * jnp.einsum("gtd,gdf->gtf", x, w["w3"].astype(x.dtype))
    return jnp.einsum("gtf,gfd->gtd", h, w["w2"].astype(x.dtype))


def experts_ffn_ragged(w: Dict[str, jax.Array], rows: jax.Array,
                       group_starts: jax.Array, act: str, *,
                       block: int, use_kernel: bool = False) -> jax.Array:
    """Expert FFN over the dropless tile-aligned ragged layout.

    ``rows``: (R, d) flat row array from :func:`repro.core.dispatch.
    dispatch_ragged`; ``group_starts``: (G+1,) aligned segment offsets;
    ``block``: the layout's row-tile size.  The non-kernel path runs one
    batched matmul over the row tiles with per-tile weight selection —
    every tile belongs to exactly one group, so this is the jnp shadow of
    the Pallas kernel's scalar-prefetched weight indirection.
    """
    if use_kernel:
        from repro.kernels import ops as kops
        return kops.grouped_ffn_ragged(rows, group_starts, w["w1"],
                                       w.get("w3"), w["w2"], block=block,
                                       act=act)
    R, d = rows.shape
    tile_gid = D.ragged_tile_gids(group_starts, R // block, block)
    xt = rows.reshape(R // block, block, d)
    actf = jax.nn.silu if act == "silu" else jax.nn.gelu
    h = actf(jnp.einsum("tbd,tdf->tbf", xt,
                        jnp.take(w["w1"].astype(rows.dtype), tile_gid, axis=0)))
    if "w3" in w and w["w3"] is not None:
        h = h * jnp.einsum("tbd,tdf->tbf", xt,
                           jnp.take(w["w3"].astype(rows.dtype), tile_gid,
                                    axis=0))
    y = jnp.einsum("tbf,tfd->tbd", h,
                   jnp.take(w["w2"].astype(rows.dtype), tile_gid, axis=0))
    return y.reshape(R, d)


def experts_ffn_compact_rows(w: Dict[str, jax.Array], rows: jax.Array,
                             gid: jax.Array, valid: jax.Array,
                             num_groups: int, act: str,
                             use_kernel: bool = False,
                             sort_impl: str = "argsort") -> jax.Array:
    """Dropless expert compute over *received* rows with per-row group ids.

    ``rows``: (S, d) arrived slab (any layout); ``gid``/``valid``: (S,) local
    group id and real-row flag per slab row.  Compacts the valid rows into
    the tile-aligned ragged layout, runs the FFN over exact segment lengths,
    and scatters results back to the slab layout (invalid rows stay zero) —
    the MXU never touches padding regardless of how the slab arrived.
    """
    ones = jnp.ones((rows.shape[0],), jnp.float32)
    r2, starts, st = D.dispatch_ragged(rows, gid, ones, num_groups, k=1,
                                       valid=valid, use_kernel=use_kernel,
                                       sort_impl=sort_impl)
    out = experts_ffn_ragged(w, r2, starts, act, block=st.cap,
                             use_kernel=use_kernel)
    return D.combine(out, st)


def experts_ffn_compact(w: Dict[str, jax.Array], recv: jax.Array,
                        valid: jax.Array, act: str,
                        use_kernel: bool = False,
                        sort_impl: str = "argsort") -> jax.Array:
    """Dropless expert compute over a *received* capacity buffer.

    When a fixed-shape All2All hop is kept (``ragged_a2a=False``), the
    received ``(G, S, d)`` buffer still carries ``(cf - 1)/cf`` padding rows.
    This compacts the valid rows (``valid``: (G, S) bool) into the ragged
    layout, runs the FFN over exact segment lengths, and scatters results
    back to the fixed slot layout (empty slots stay zero, matching what the
    padded FFN would have produced) — the MegaScale-MoE "no padding into the
    FFN" hot-path fix with the collective left untouched.
    """
    G, S, d = recv.shape
    rgid = jnp.repeat(jnp.arange(G, dtype=jnp.int32), S)
    out = experts_ffn_compact_rows(w, recv.reshape(G * S, d), rgid,
                                   valid.reshape(-1), G, act,
                                   use_kernel=use_kernel,
                                   sort_impl=sort_impl)
    return out.reshape(G, S, d)


# =============================================================================
# The IR
# =============================================================================

@dataclasses.dataclass
class RouteDecision:
    """One router's verdict for one hop, in the executor's vocabulary.

    Per-assignment arrays are flat ``(A = t * k,)``; assignment ``a`` belongs
    to token ``a // k``.  ``group_ids`` are *canonical* virtual-group ids in
    ``[0, spec.num_groups)`` — the executor applies ``spec.perm`` itself, so
    route callables never deal in wire layouts.  ``probs``/``logits``/
    ``top1`` are over the router's own domain (``spec.loss_groups`` wide)
    and feed the LB / z losses; ``token_valid`` masks tokens that are
    padding on arrival slabs (SMILE level 2).
    """
    gates: jax.Array          # (A,) combine weights
    group_ids: jax.Array      # (A,) canonical virtual destination groups
    valid: jax.Array          # (A,) assignment validity
    token_valid: jax.Array    # (t,) token validity (losses)
    probs: jax.Array          # (t, loss_groups)
    logits: jax.Array         # (t, loss_groups)
    top1: jax.Array           # (t,) router argmax (LB loss f-vector)
    k: int                    # assignments per token


@dataclasses.dataclass
class HopSpec:
    """Static schedule of one dispatch hop.

    ``exchange`` picks the wire format:

    * ``"local"``  — the hop's mesh is size 1 *and* it is the innermost hop
      with the dropless backend: no exchange, no slab — the expert FFN runs
      straight over the tile-aligned ragged layout.
    * ``"padded"`` — fixed-shape capacity buffer (``capacity`` rows/group)
      through a regular All2All (identity when ``n_ranks == 1``).  Used by
      the capacity backends everywhere and by dropless when
      ``ragged_a2a=False`` (re-compacted on arrival).
    * ``"ragged"`` — exact tile-aligned segments through
      :func:`repro.sharding.comm.ragged_all_to_all`; ``recv_bound_factor``
      optionally clamps the receive slab (see module docstring).

    ``perm`` (``(num_groups,)`` int32 or None) relabels canonical group ids
    rank-major so rank ``p`` owns ids ``[p*gpr, (p+1)*gpr)``; None means the
    canonical order already is rank-major (identity).

    ``wire_integrity`` arms the parity-row checksum layer on this hop's
    ragged exchanges, both directions (see the module docstring): ``"off"``
    traces the exact production wire, ``"detect"`` verifies and accounts
    but passes payloads through, ``"quarantine"`` additionally drops every
    flagged segment.  Ignored on local/padded exchanges and size-1 meshes
    (nothing crosses a wire).
    """
    name: str                         # "flat" | "inter" | "intra" (display)
    axes: Tuple[str, ...]             # mesh axes the exchange spans
    n_ranks: int                      # P = product of axis sizes
    num_groups: int                   # V: virtual groups dispatched into
    exchange: str                     # "local" | "padded" | "ragged"
    capacity: int = 0                 # rows/group (padded exchange only)
    perm: Optional[jax.Array] = None  # canonical -> rank-major relabel
    recv_bound_factor: Optional[float] = None   # ragged exchange only
    lb_coef: float = 0.0              # LB loss coefficient for this hop
    loss_groups: int = 0              # router prob domain (LB/z losses)
    wire_integrity: str = "off"       # "off" | "detect" | "quarantine"

    def __post_init__(self):
        if self.exchange not in EXCHANGES:
            raise ValueError(f"unknown exchange {self.exchange!r}; "
                             f"expected one of {EXCHANGES}")
        if self.wire_integrity not in WIRE_POLICIES:
            raise ValueError(f"unknown wire_integrity "
                             f"{self.wire_integrity!r}; expected one of "
                             f"{WIRE_POLICIES}")
        if self.num_groups % max(self.n_ranks, 1):
            raise ValueError(f"num_groups {self.num_groups} must fold onto "
                             f"{self.n_ranks} ranks")

    @property
    def groups_per_rank(self) -> int:
        return self.num_groups // max(self.n_ranks, 1)


@dataclasses.dataclass
class ExpertHop:
    """One pipeline stage: a router bound to its hop schedule.

    ``route(x, token_valid, outer_gid) -> RouteDecision`` where ``x`` is the
    (t, d) tokens this hop sees (original tokens for the outermost hop, the
    previous hop's arrival slab otherwise), ``token_valid`` masks real rows,
    and ``outer_gid`` (or None at the outermost hop) is each row's local
    group under the *previous* hop — what SMILE's level-2 router needs to
    keep tokens inside the node they arrived at.
    """
    route: Callable[[jax.Array, jax.Array, Optional[jax.Array]],
                    RouteDecision]
    spec: HopSpec


# =============================================================================
# Generic rank-major fold/unfold (padded exchange)
# =============================================================================

def _fold_a2a(buf: jax.Array, groups: int, mesh_axes, mesh_size: int
              ) -> jax.Array:
    """All2All a (groups, ...) buffer over mesh axes of total size ``s | groups``.

    Logical groups are block-assigned to mesh ranks. After the exchange the
    leading dims are (src_rank, my_local_groups, ...), flattened back to
    (mesh_size * groups//mesh_size, ...) in (src, local-group) order.
    """
    if mesh_size == 1:
        return buf
    b = groups // mesh_size
    rest = buf.shape[1:]
    buf = buf.reshape((mesh_size, b) + rest)
    buf = comm.all_to_all(buf, mesh_axes, split_axis=0, concat_axis=0)
    return buf.reshape((mesh_size * b,) + rest)


def _fold(z: jax.Array, spec: HopSpec) -> jax.Array:
    """Forward exchange of a rank-major capacity buffer.

    ``z``: (V, cap, ...) with groups rank-major -> (gpr, P*cap, ...): each of
    my ``gpr`` local groups holds the ``cap`` arrivals from every source
    rank, source-major — the layout the grouped FFN consumes directly.
    """
    P, gpr = spec.n_ranks, spec.groups_per_rank
    rest = z.shape[1:]
    z = _fold_a2a(z, spec.num_groups, spec.axes, P)         # src-major
    z = z.reshape((P, gpr) + rest)
    z = jnp.moveaxis(z, 1, 0)                               # groups first
    return z.reshape((gpr, P * rest[0]) + rest[1:])


def _unfold(y: jax.Array, spec: HopSpec, cap: int) -> jax.Array:
    """Reverse exchange: (gpr, P*cap, ...) back to the (V, cap, ...)
    rank-major buffer at the origin ranks — the exact mirror of :func:`_fold`."""
    P, gpr = spec.n_ranks, spec.groups_per_rank
    rest = y.shape[2:]
    y = y.reshape((gpr, P, cap) + rest)
    y = jnp.moveaxis(y, 1, 0)                               # dest rank first
    y = y.reshape((spec.num_groups, cap) + rest)
    return _fold_a2a(y, spec.num_groups, spec.axes, P)


# =============================================================================
# Ragged exchange (with the optional receive bound)
# =============================================================================

def recv_bound_rows(factor: float, rows: int, n_ranks: int,
                    groups_per_rank: int, block: int) -> int:
    """Static bounded receive-slab size for a clamped ragged hop.

    ``factor x`` the sender-layout row count (== expected arrivals at
    uniform routing) plus one tile of alignment slack per (source, local
    group) — so ``factor >= 1`` never clamp-drops a perfectly uniform
    routing — rounded up to the row tile and never above the worst-case
    ``P x R`` bound.
    """
    slack = n_ranks * groups_per_rank * block
    b = int(math.ceil(factor * rows)) + slack
    b = ((b + block - 1) // block) * block
    return min(b, n_ranks * rows)


def sanitize_len_grid(len_grid: jax.Array, block: int, src_rows: int
                      ) -> Tuple[jax.Array, jax.Array]:
    """Validate an exchanged ``(P, nl)`` count grid; quarantine bad sources.

    The grid arrives over the wire, so the receiver must not trust it: a
    negative entry or a source whose tile-aligned row total exceeds its
    ``src_rows`` staging bound would drive the slab layout math (and the
    fused-emulation compaction gather) out of bounds.  Entries violating
    either invariant mark their *source row* untrustworthy, and the whole
    row is zeroed — segment-granularity quarantine, because a partially
    believed row would shift the group sub-offsets of every later group
    from that source and silently hand tokens to the wrong expert.  The
    quarantined source's rows simply never materialize; the echoed reverse
    hop reports them dropped with exact accounting.

    Returns ``(grid, events, src_bad)``: the sanitized grid, the number of
    *violating* entries (a float32 scalar — the hop's ``fault_events``
    contribution; quarantine collateral, i.e. valid entries zeroed because
    a sibling violated, is intentionally not counted so injected faults
    have exact expected counts), and the (P,) bool per-source quarantine
    mask.  The mask lets the wire-integrity verifier *deduplicate*: a
    source zeroed here necessarily fails its payload checksum too (the
    receiver now believes zero-length segments the sender checksummed at
    full length), and re-flagging it would double-count the one injected
    fault in ``fault_events``/``wire_faults``.  On a healthy grid this is
    the identity with ``events == 0`` and an all-false mask — pure integer
    math, bit-identical outputs (pinned by the golden matrix).

    Known limitation, by construction: an *in-bounds inflated* count — a
    source claiming more rows than it actually staged, within its bound —
    is indistinguishable from a real count at grid level; the sanitizer
    only guarantees no OOB/crash/hang.  That gap is what the wire-integrity
    layer closes: with ``HopSpec.wire_integrity`` on, the per-segment
    parity word's length term exposes the inflation (and its fold/tag terms
    expose payload corruption and segment replay) with exact per-(hop, src
    rank) localization; with it off, the step sentinel still catches the
    downstream loss anomaly globally.
    """
    aligned = ((len_grid + block - 1) // block) * block
    neg = len_grid < 0
    over = jnp.cumsum(jnp.where(neg, 0, aligned), axis=1) > src_rows
    bad = neg | over
    events = bad.sum().astype(jnp.float32)
    src_bad = bad.any(axis=1)
    return jnp.where(src_bad[:, None], 0, len_grid), events, src_bad


@dataclasses.dataclass
class _RaggedHopState:
    """Everything the reverse of one ragged hop needs."""
    recv: jax.Array           # (B, d) received slab
    gid: jax.Array            # (B,) local group per slab row
    valid: jax.Array          # (B,) real-row flag per slab row
    recv_counts: jax.Array    # (P,) aligned rows per source (unclamped)
    send_counts: jax.Array    # (P,) aligned rows sent per destination
    kept: Optional[jax.Array]  # (P,) rows kept per source after the clamp
    rows_out: int             # R: sender layout rows (reverse recv bound)


def _wire_tags(me: jax.Array, P: int, nl: int, incoming: bool) -> jax.Array:
    """(P*nl,) int32 identity tags of a wire's segments, flat-ordered.

    ``tag = (src * P + dst) * nl + g`` — outgoing tags fix ``src = me``,
    incoming tags fix ``dst = me``; a replayed segment carries the wrong
    ``src`` and the tag term of its parity word gives it away.
    """
    other = jnp.repeat(jnp.arange(P, dtype=jnp.int32), nl)
    g = jnp.tile(jnp.arange(nl, dtype=jnp.int32), P)
    src, dst = (other, me) if incoming else (me, other)
    return (src * P + dst) * nl + g


def _ragged_forward(rows: jax.Array, group_starts: jax.Array,
                    seg_lens: jax.Array, spec: HopSpec, block: int,
                    fp: Optional[FI.FaultPlan] = None, level: int = 0
                    ) -> Tuple[_RaggedHopState, jax.Array,
                               Optional[jax.Array]]:
    """Forward ragged All2All of one dispatch hop — zero capacity padding.

    ``rows``: (R, d) *rank-major* ragged layout; ``group_starts``: its
    (V + 1,) aligned offsets; ``seg_lens``: the raw per-group valid counts.
    Exchanges exact tile-aligned segments plus the tiny count grid, and
    rebuilds the received slab's per-row structure from the counts alone —
    no intermediate capacity scatter anywhere.  Identity when the hop's
    mesh is size 1.

    Unclamped, the received slab is sized ``P * R`` — the static worst case
    (every rank routes everything here), which is what guarantees zero
    drops under ANY skew, and what makes every post-hop stage scan
    ``~P/cf x`` more rows than a capacity bound would.  With
    ``spec.recv_bound_factor`` set the slab is :func:`recv_bound_rows`
    instead: sources land at their aligned offsets and whatever falls past
    the bound is clamp-dropped (a tile-aligned *prefix* of the slab
    survives, so surviving segments keep their offsets).  The reverse hop
    (:func:`_ragged_reverse`) echoes the clamped counts back to the
    senders.

    The exchanged count grid is never trusted: :func:`sanitize_len_grid`
    quarantines sources with invalid counts before any layout math (the
    identity, and bit-identical, on healthy grids).  ``fp`` optionally
    injects faults for this ``level`` — grid corruption (``counts`` /
    ``dropseg`` / ``inflate`` / ``dupseg``) before sanitation, wire-slab
    corruption (``bitflip`` / wire-mode ``nanrows`` / ``dupseg``'s region
    replay) onto the received checksummed slab — and because a
    count-targeting plan can legitimately shrink ``rc`` below what the
    senders shipped, it also forces the clamp-style ``kept`` bookkeeping so
    the reverse hop echoes the surviving counts instead of assuming
    everything returns (``fp=None`` keeps the collective-identical
    zero-echo fast path).

    With ``spec.wire_integrity`` armed (and a real wire, ``P > 1``) the
    exchange rides :func:`repro.sharding.comm.checksummed_ragged_all_to_all`
    instead: each source's segment carries ``nl`` parity rows, the receiver
    recomputes every (src, group) integrity word from the payload and
    counts it believes, and a mismatching *source* is flagged —
    ``"quarantine"`` zero-fills its rows, drops their validity (combine
    skips them) and echoes ``kept = 0`` so the origin accounts every lost
    assignment; ``"detect"`` only flags.  Returns ``(state, sanitizer
    events, per-source wire verdicts | None)``.
    """
    P, nl = spec.n_ranks, spec.groups_per_rank
    R = rows.shape[0]
    send_counts = D.ragged_send_counts(group_starts, nl)
    # one count collective per hop: the (P, nl) length grid also determines
    # the aligned per-source segment extents, so the segment exchange skips
    # its own count round trip.  This boundary rides the generic payload
    # all_to_all (which comm cannot dtype-gate), so the count contract is
    # asserted here.
    comm.assert_count_i32(seg_lens, "_ragged_forward(seg_lens)")
    len_grid = comm.all_to_all(seg_lens.reshape(P, nl), spec.axes,
                               split_axis=0, concat_axis=0)
    inject = fp is not None and fp.targets(level)
    if inject and fp.kind == "counts":
        len_grid = FI.corrupt_len_grid(fp, level, len_grid)
    if inject and fp.kind == "dropseg":
        len_grid = FI.drop_segment(fp, level, len_grid)
    if inject and fp.kind == "inflate":
        len_grid = FI.inflate_grid(fp, level, len_grid)
    if inject and fp.kind == "dupseg":
        len_grid = FI.dup_grid(fp, level, len_grid)
    len_grid, events, san_bad = sanitize_len_grid(len_grid, block, R)
    rc = (((len_grid + block - 1) // block) * block).sum(
        axis=1).astype(jnp.int32)
    force_echo = fp is not None and fp.wants_echo
    factor = spec.recv_bound_factor
    clamped = (factor is not None and P > 1
               and recv_bound_rows(factor, R, P, nl, block) < P * R)
    B = recv_bound_rows(factor, R, P, nl, block) if clamped else P * R
    wire = spec.wire_integrity != "off" and P > 1
    if not wire:
        if not clamped:
            # no factor, single-rank hop, or a bound that doesn't reduce the
            # worst case: keep the exact zero-drop path (native-op eligible,
            # no echo exchange) so a non-reducing factor stays bit-identical
            # AND collective-identical to factor=None
            recv, _ = comm.ragged_all_to_all(rows, send_counts, spec.axes,
                                             recv_rows=B, recv_counts=rc)
            gid, valid = D.ragged_recv_layout(len_grid, block, B)
            if inject and fp.kind == "nanrows":
                recv = FI.nan_rows(fp, level, recv, valid)
            # under a count-targeting plan, rc can shrink below what peers
            # shipped: echo the surviving counts (== rc, sum(rc) <= P*R) so
            # senders learn exactly which rows died instead of reading stale
            # slab rows back — the quarantine's drop accounting
            kept = rc if force_echo else None
            return _RaggedHopState(recv, gid, valid, rc, send_counts,
                                   kept, R), events, None
        # bounded slab: segments past B rows are truncated on arrival (the
        # emulations do this natively; allow_truncate keeps the jax-native
        # op off this path, whose paired offset/size contract cannot
        # truncate)
        recv, _ = comm.ragged_all_to_all(rows, send_counts, spec.axes,
                                         recv_rows=B, recv_counts=rc,
                                         allow_truncate=True)
        gid, valid = D.ragged_recv_layout(len_grid, block, B)
        if inject and fp.kind == "nanrows":
            recv = FI.nan_rows(fp, level, recv, valid)
        kept = jnp.clip(B - comm.excl_cumsum(rc), 0, rc)
        return _RaggedHopState(recv, gid, valid, rc, send_counts,
                               kept, R), events, None

    # ---- checksummed wire: parity rows ride the slab ------------------------
    me = comm.axis_index(spec.axes)
    words = comm.segment_parity_words(
        rows, group_starts, seg_lens, _wire_tags(me, P, nl, incoming=False))
    parity = comm.words_to_rows(words, rows.dtype)
    rcw = rc + jnp.int32(nl)
    slab, _ = comm.checksummed_ragged_all_to_all(
        rows, parity, send_counts, spec.axes, recv_rows=B + P * nl,
        recv_counts=rc, nl=nl, allow_truncate=clamped)
    woff = comm.excl_cumsum(rcw)
    if inject and fp.kind == "bitflip":
        slab = FI.flip_wire(fp, level, slab, woff, rc, nl)
    if inject and fp.kind == "nanrows":
        slab = FI.nan_wire(fp, level, slab, woff, rcw)
    if inject and fp.kind == "dupseg":
        slab = FI.copy_wire_region(fp, level, slab, woff, rcw)
    recv, par = comm.split_checksummed_recv(slab, rc, nl, B)
    gid, valid = D.ragged_recv_layout(len_grid, block, B)
    doff = comm.excl_cumsum(rc)
    sseg, swithin, sval = D.ragged_row_membership(
        jnp.concatenate([doff, doff[-1:] + rc[-1:]]), rc, B)
    if clamped:
        kept_wire = jnp.clip((B + P * nl) - woff, 0, rcw)
        full = kept_wire == rcw          # region fully arrived (incl parity)
        data_kept = jnp.minimum(kept_wire, rc)
        # a truncated source's missing rows read clamped garbage off the
        # slab edge: zero them and drop their validity — the plain receive
        # gets this for free (its truncated rows simply never materialize)
        alive = sval & (swithin < jnp.take(data_kept, sseg))
        recv = jnp.where(alive[:, None], recv, 0)
        valid = valid & alive
    else:
        full = jnp.ones((P,), bool)
        data_kept = rc
    aligned = (((len_grid + block - 1) // block) * block).reshape(-1)
    rbounds = jnp.concatenate(
        [comm.excl_cumsum(aligned),
         aligned.sum().reshape(1).astype(jnp.int32)])
    expect = comm.segment_parity_words(
        recv, rbounds, len_grid.reshape(-1),
        _wire_tags(me, P, nl, incoming=True))
    bad_cell = jnp.any(
        comm.int_lane_view(par.reshape(P * nl, -1))
        != comm.stored_words(expect, recv.dtype), axis=-1).reshape(P, nl)
    # source-granular verdict: one corrupt (src, group) cell condemns the
    # whole source segment — a partially believed region would shift every
    # later group's sub-offsets exactly like a half-believed count row.
    # A sanitizer-quarantined source is excluded: its count row was zeroed
    # above, so its parity words trivially mismatch the (now zero-length)
    # segments the receiver believes — re-flagging it here would charge the
    # one injected fault twice in fault_events/wire_faults (and its rows
    # are already zeroed/dropped via the sanitized grid)
    src_bad = bad_cell.any(axis=1) & full & ~san_bad
    if spec.wire_integrity == "quarantine":
        rowbad = jnp.take(src_bad, sseg) & sval
        recv = jnp.where(rowbad[:, None], 0, recv)
        valid = valid & ~rowbad
        kept = jnp.where(src_bad, 0, data_kept)
    else:
        kept = data_kept if (clamped or force_echo) else None
    return (_RaggedHopState(recv, gid, valid, rc, send_counts, kept, R),
            events, src_bad.astype(jnp.float32))


def _ragged_reverse(y_slab: jax.Array, hs: _RaggedHopState, spec: HopSpec
                    ) -> Tuple[jax.Array, Optional[jax.Array],
                               Optional[jax.Array]]:
    """Reverse ragged All2All: route each source's slab segment back to its
    origin rank at the origin offsets.

    Returns ``(back, survived, wire_bad)``: ``back`` (R, d) aligned with
    the sender's original layout rows; ``survived`` (R,) marks the rows
    whose results actually returned — None on the unclamped path
    (everything returns, no extra collective: the mirrored counts are
    already known).  On the clamped path the reverse runs its own tiny
    count exchange, which is exactly the "clamped counts echoed on the
    reverse path": every sender learns how many of its rows each receiver
    kept, reconstructs which layout rows those were (each receiver keeps a
    contiguous *prefix* of each sender's segment), and zero-fills the
    clamp-dropped rows.

    With ``spec.wire_integrity`` armed the returning slab is checksummed
    too (``nl = 1``: one parity row per peer — the reverse wire's segments
    are per-source, not per-group): the origin verifies each returning
    segment's word and, under ``"quarantine"``, zero-fills and un-survives
    rows from flagged peers.  ``wire_bad`` is the (P,) per-peer verdict
    (None with the layer off).  Because quarantine can zero ``kept``
    *mid-slab*, the wire path first compacts the surviving segments to the
    echoed offsets — the off-path's prefix-survival shortcut (send from
    unclamped offsets) no longer holds.
    """
    R = hs.rows_out
    P = spec.n_ranks
    wire = spec.wire_integrity != "off" and P > 1
    if not wire:
        if hs.kept is None:
            back, _ = comm.ragged_all_to_all(y_slab, hs.recv_counts,
                                             spec.axes, recv_rows=R,
                                             seg_rows=R,
                                             recv_counts=hs.send_counts)
            return back, None, None
        # clamped: each surviving forward segment is a prefix of the slab,
        # so sending `kept` rows from the unclamped offsets is
        # self-consistent.  The reverse can never truncate (sum(rb) <=
        # sum(send_counts) <= R), so it stays native-op eligible — only the
        # forward needs allow_truncate
        back_c, rb = comm.ragged_all_to_all(y_slab, hs.kept, spec.axes,
                                            recv_rows=R, seg_rows=R)
        # rb[p] = rows peer p kept of MY segment (the echo). Returning
        # segments arrive compacted at cumsum(rb); remap each to its
        # original offset.
        send_starts = jnp.concatenate(
            [comm.excl_cumsum(hs.send_counts),
             hs.send_counts.sum().reshape(1).astype(jnp.int32)])
        seg, within, ok = D.ragged_row_membership(send_starts, rb, R)
        rboff = comm.excl_cumsum(rb)
        src = jnp.where(ok, jnp.take(rboff, seg) + within, 0)
        back = jnp.where(ok[:, None], jnp.take(back_c, src, axis=0), 0)
        return back, ok, None

    # ---- checksummed reverse wire -------------------------------------------
    me = comm.axis_index(spec.axes)
    if hs.kept is None:
        # mirror-counts path: segments already sit at the believed offsets
        sc, y_send, rb = hs.recv_counts, y_slab, hs.send_counts
    else:
        # compact surviving segments to the echoed cumsum offsets (a
        # quarantined source leaves a hole mid-slab, so the data no longer
        # sits where excl_cumsum(kept) says)
        sc = hs.kept
        doff = comm.excl_cumsum(hs.recv_counts)
        koff = comm.excl_cumsum(sc)
        kb = jnp.concatenate([koff, koff[-1:] + sc[-1:]])
        seg, within, ok = D.ragged_row_membership(kb, sc, y_slab.shape[0])
        idx = jnp.where(ok, jnp.take(doff, seg) + within, 0)
        y_send = jnp.where(ok[:, None], jnp.take(y_slab, idx, axis=0), 0)
        rb = comm.exchange_counts(sc, spec.axes)
    soff = comm.excl_cumsum(sc)
    words = comm.segment_parity_words(
        y_send, jnp.concatenate([soff, soff[-1:] + sc[-1:]]), sc,
        _wire_tags(me, P, 1, incoming=False))
    wire_back, _ = comm.checksummed_ragged_all_to_all(
        y_send, comm.words_to_rows(words, y_send.dtype), sc, spec.axes,
        recv_rows=R + P, recv_counts=rb, nl=1)
    back_c, par = comm.split_checksummed_recv(wire_back, rb, 1, R)
    rboff = comm.excl_cumsum(rb)
    expect = comm.segment_parity_words(
        back_c, jnp.concatenate([rboff, rboff[-1:] + rb[-1:]]), rb,
        _wire_tags(me, P, 1, incoming=True))
    bad = jnp.any(comm.int_lane_view(par.reshape(P, -1))
                  != comm.stored_words(expect, back_c.dtype), axis=-1)
    if hs.kept is None:
        # mirror path: rb == send_counts, arrivals already at origin offsets
        send_starts = jnp.concatenate(
            [comm.excl_cumsum(hs.send_counts),
             hs.send_counts.sum().reshape(1).astype(jnp.int32)])
        seg, _, ok = D.ragged_row_membership(send_starts, rb, R)
        back = back_c
    else:
        send_starts = jnp.concatenate(
            [comm.excl_cumsum(hs.send_counts),
             hs.send_counts.sum().reshape(1).astype(jnp.int32)])
        seg, within, ok = D.ragged_row_membership(send_starts, rb, R)
        src = jnp.where(ok, jnp.take(rboff, seg) + within, 0)
        back = jnp.where(ok[:, None], jnp.take(back_c, src, axis=0), 0)
    if spec.wire_integrity == "quarantine":
        rowbad = jnp.take(bad, seg) & ok
        back = jnp.where(rowbad[:, None], 0, back)
        ok = ok & ~rowbad
        survived = ok
    else:
        survived = None if hs.kept is None else ok
    return back, survived, bad.astype(jnp.float32)


# =============================================================================
# The executor
# =============================================================================

def _occupancy(st: D.CombineState, A: int) -> jax.Array:
    """Per-slot occupancy flags mirroring the token dispatch."""
    return D.dispatch_flags(jnp.ones((A,), jnp.float32), st)


def execute_pipeline(x: jax.Array, hops: Sequence[ExpertHop],
                     wsel: Dict[str, jax.Array], cfg, *, act: str,
                     use_kernel: bool, sync,
                     token_valid: Optional[jax.Array] = None
                     ) -> Tuple[jax.Array, MoEStats]:
    """Run a routing schedule expressed as a hop pipeline.

    ``x``: (t, d) local tokens; ``hops``: outermost-first; ``wsel``: this
    device's expert weights, (gpr_innermost, d, f) groups in local order;
    ``cfg``: :class:`repro.common.config.MoEConfig` (dispatch backend, sort
    impl, z coefficient); ``sync``: mesh axes for globally-averaged stats.

    ``token_valid`` (t,) bool masks the *top-level* tokens: invalid rows are
    excluded from every hop's LB/z losses, contribute zero dispatch
    assignments (so ragged hops put zero segments for them on the wire and
    the ``recv_bound_factor`` receive bound sizes itself over live tokens
    only), and combine to exactly zero output.  ``None`` (the default) is
    the all-valid training/prefill path, bit-identical to the pre-serving
    pipeline.  This is the decode-tick contract: a continuous-batching
    engine passes its live-slot mask here so dead slots cost nothing on
    the expert wire.

    Returns ``(y, stats)`` with ``y`` (t, d) gate-weighted combined outputs
    and one :class:`MoEStats` accumulated across all hops (lb and z losses
    summed, ``drop_frac`` summed with the per-hop breakdown preserved).

    **Fault containment.**  ``cfg.fault_plan`` (parsed once here) injects
    deterministic faults at the hop boundaries — count-grid corruption and
    segment suppression inside :func:`_ragged_forward`, NaN rows into every
    exchange flavor's post-dispatch buffer, routing-skew storms onto the
    route decision — while the *always-on* containment machinery
    (:func:`sanitize_len_grid`, the echoed reverse hop, the occupancy-masked
    compact FFNs) keeps every faulted step inside a defined state.  The
    per-hop sanitizer event counts are psum'd into ``stats.fault_events``,
    and the psum'd LB ``f``-vector feeds the router-collapse watchdog
    fields ``hop_max_load`` / ``hop_load_entropy`` at zero extra collective
    cost.  ``fault_plan=None`` is the production path: no injection code
    traces at all, bit-identical to the golden matrix.

    **Wire integrity.**  ``cfg.wire_integrity`` (threaded onto every
    :class:`HopSpec` by the schedule builders) arms per-segment payload
    checksums on both directions of every ragged exchange
    (:func:`_ragged_forward` / :func:`_ragged_reverse`): each flagged
    source adds one event to that hop's ``fault_events`` and one count to
    ``wire_faults[hop, src]`` — exact (hop, source rank) localization —
    and under ``"quarantine"`` the corrupt segment is zero-filled and
    dropped with the same exact accounting the count sanitizer uses, so a
    value-corrupting peer costs its own tokens instead of the whole step.
    """
    if len(hops) > MAX_HOPS:
        raise ValueError(f"pipeline has {len(hops)} hops; MAX_HOPS is "
                         f"{MAX_HOPS} (bump it alongside MoEStats)")
    dropless = cfg.dispatch_backend == "dropless"
    simpl = cfg.sort_impl
    fp = FI.parse_fault_plan(getattr(cfg, "fault_plan", None))
    zero = jnp.float32(0.0)
    lb_terms, z_terms = [], []
    hop_drops = [zero] * MAX_HOPS
    hop_faults = [zero] * MAX_HOPS
    hop_maxload = [zero] * MAX_HOPS
    hop_entropy = [jnp.float32(1.0)] * MAX_HOPS
    hop_wire = [jnp.zeros((WIRE_SRC_BINS,), jnp.float32)] * MAX_HOPS
    wire_used = False

    def run_hop(level: int, x: jax.Array, token_valid: jax.Array,
                outer_gid: Optional[jax.Array]) -> jax.Array:
        hop = hops[level]
        spec = hop.spec
        innermost = level == len(hops) - 1
        dec = hop.route(x, token_valid, outer_gid)
        if fp is not None and fp.kind == "skew" and fp.targets(level):
            dec = FI.apply_skew(fp, level, dec, spec.num_groups,
                                spec.loss_groups)
        A, k = dec.group_ids.shape[0], dec.k
        gid = (dec.group_ids if spec.perm is None
               else jnp.take(spec.perm, dec.group_ids))
        nanrows_here = (fp is not None and fp.kind == "nanrows"
                        and fp.targets(level))

        # ---- losses (one path per hop) --------------------------------------
        f, p = lb_loss_terms(dec.probs, dec.top1, dec.token_valid,
                             spec.loss_groups, sync)
        lb_terms.append(scaled_lb_loss(f, p, spec.lb_coef))
        z_terms.append(z_loss(dec.logits, dec.token_valid,
                              cfg.router_z_coef, sync))
        # router-collapse watchdog inputs, from the already-global f-vector:
        # max-load fraction and normalized load entropy (1 = uniform)
        hop_maxload[level] = jnp.max(f)
        if spec.loss_groups > 1:
            fr = f / jnp.maximum(f.sum(), 1e-9)
            ent = -jnp.sum(fr * jnp.log(jnp.maximum(fr, 1e-20)))
            hop_entropy[level] = ent / math.log(spec.loss_groups)

        # ---- dispatch + exchange + inner compute + reverse + combine --------
        if spec.exchange == "local":
            # capacity-free and exchange-free: the expert grid backing this
            # hop is local — FFN straight over exact ragged segment lengths
            rows, starts, st = D.dispatch_ragged(
                x, gid, dec.gates, spec.num_groups, k=k, valid=dec.valid,
                use_kernel=use_kernel, sort_impl=simpl)
            if nanrows_here:
                rows = FI.nan_rows(fp, level, rows, _occupancy(st, A) > 0)
            out = experts_ffn_ragged(wsel, rows, starts, act, block=st.cap,
                                     use_kernel=use_kernel)
            return D.combine(out, st)               # nothing CAN drop: 0.0

        if spec.exchange == "ragged":
            rows, starts, st = D.dispatch_ragged(
                x, gid, dec.gates, spec.num_groups, k=k, valid=dec.valid,
                use_kernel=use_kernel, sort_impl=simpl)
            seg_lens = D.ragged_seg_lens(gid, st.keep, spec.num_groups)
            hs, ev, wbad = _ragged_forward(rows, starts, seg_lens, spec,
                                           st.cap, fp=fp, level=level)
            if innermost:
                y_slab = experts_ffn_compact_rows(
                    wsel, hs.recv, hs.gid, hs.valid, spec.groups_per_rank,
                    act, use_kernel, sort_impl=simpl)
            else:
                y_slab = run_hop(level + 1, hs.recv, hs.valid, hs.gid)
            back, survived, rbad = _ragged_reverse(y_slab, hs, spec)
            # wire verdicts: every flagged source is one fault event and one
            # per-src-rank localization count (forward + reverse directions)
            for verdict in (wbad, rbad):
                if verdict is not None:
                    nonlocal wire_used
                    wire_used = True
                    ev = ev + verdict.sum()
                    hop_wire[level] = hop_wire[level].at[
                        jnp.arange(spec.n_ranks, dtype=jnp.int32)
                        % WIRE_SRC_BINS].add(verdict)
            hop_faults[level] = ev
            if survived is None:
                # capacity-free end-to-end: exact-constant 0.0, no psum
                return D.combine(back, st)
            keep = st.keep & jnp.take(survived, jnp.maximum(st.pos, 0))
            dropped = comm.psum((st.keep & ~keep).sum().astype(jnp.float32),
                                sync)
            total = comm.psum(st.keep.sum().astype(jnp.float32), sync)
            hop_drops[level] = dropped / jnp.maximum(total, 1)
            return D.combine(back, dataclasses.replace(st, keep=keep))

        # ---- padded: fixed-shape capacity buffer on the wire ----------------
        hop_backend = "sort" if dropless else cfg.dispatch_backend
        buf, st = D.dispatch(x, gid, dec.gates, spec.num_groups,
                             spec.capacity, k=k, valid=dec.valid,
                             backend=hop_backend, use_kernel=use_kernel,
                             sort_impl=simpl)
        recv = _fold(buf, spec)                     # (gpr, P*cap, d)
        if nanrows_here:
            occ = _fold(_occupancy(st, A), spec) > 0
            recv = FI.nan_rows(fp, level, recv.reshape(-1, recv.shape[-1]),
                               occ.reshape(-1)).reshape(recv.shape)
        if innermost:
            if dropless:
                # fixed-shape A2A retained; FFN only sees valid rows
                rvalid = _fold(_occupancy(st, A), spec) > 0
                out = experts_ffn_compact(wsel, recv, rvalid, act,
                                          use_kernel, sort_impl=simpl)
            else:
                out = experts_ffn(wsel, recv, act, use_kernel)
        else:
            gpr, S, d = recv.shape
            x1 = recv.reshape(gpr * S, d)
            valid1 = _fold(_occupancy(st, A), spec).reshape(gpr * S) > 0
            gid1 = jnp.repeat(jnp.arange(gpr, dtype=jnp.int32), S)
            out = run_hop(level + 1, x1, valid1, gid1).reshape(gpr, S, d)
        back = _unfold(out, spec, spec.capacity)
        dropped = comm.psum((dec.valid & ~st.keep).sum().astype(jnp.float32),
                            sync)
        total = comm.psum(dec.valid.sum().astype(jnp.float32), sync)
        hop_drops[level] = dropped / jnp.maximum(total, 1)
        return D.combine(back, st)

    t = x.shape[0]
    if token_valid is None:
        token_valid = jnp.ones((t,), bool)
    y = run_hop(0, x, token_valid, None)
    hop_vec = jnp.stack(hop_drops)
    # sanitizer events are per-device local counts -> one stacked psum per
    # layer makes them global (f-vector stats are already psum'd upstream)
    fault_vec = comm.psum(jnp.stack(hop_faults), sync)
    # only a wire-armed trace pays the localization psum; the off policy
    # keeps the production collective profile exactly
    wire_vec = (comm.psum(jnp.stack(hop_wire), sync) if wire_used
                else jnp.stack(hop_wire))
    stats = MoEStats(sum(lb_terms[1:], lb_terms[0]),
                     sum(z_terms[1:], z_terms[0]),
                     hop_vec.sum(), hop_vec, fault_vec,
                     jnp.stack(hop_maxload), jnp.stack(hop_entropy),
                     wire_vec)
    return y, stats

"""Expert-to-slot layout for the 2-D (n x m) expert grid.

The paper assumes one expert per worker (``E == n*m``).  Real configs break
that assumption in both directions, so we generalize:

* ``E == n*m*h`` with ``h >= 1``: each grid slot hosts ``h`` experts.
* ``E < n*m`` (e.g. qwen3-moe: 128 experts on a 256-slot grid): each expert is
  **replicated** ``r = n*m/E`` times *within its node*; tokens are spread
  round-robin over replicas.  Replication is the TPU-native answer to the
  grid being larger than the expert count, and doubles as hot-expert load
  spreading (beyond-paper).

Slots within a node are indexed ``j in [0, m)``; per-node experts are indexed
``e_local in [0, E_pn)`` with ``E_pn = E / n``.  The *virtual expert* id ``v``
(used for capacity accounting) enumerates ``(slot, expert_in_slot)`` pairs.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ExpertLayout:
    num_experts: int      # E (real experts)
    n_inter: int          # n (nodes)
    n_intra: int          # m (workers per node)
    h: int                # experts per slot (>= 1)
    r: int                # replicas per expert (>= 1); h > 1 implies r == 1
    shard_intra: bool     # True: expert dim0 sharded over intra axes too

    @property
    def experts_per_node(self) -> int:
        return self.num_experts // self.n_inter

    @property
    def slots(self) -> int:
        return self.n_inter * self.n_intra

    @property
    def virtual_per_node(self) -> int:
        """Capacity groups per node = m*h (== E_pn * r when replicated)."""
        return self.n_intra * self.h

    @property
    def virtual_total(self) -> int:
        return self.slots * self.h

    @property
    def local_experts(self) -> int:
        """Experts materialized per device (param leaf dim0 after sharding)."""
        return self.h if self.shard_intra else self.experts_per_node


def make_layout(num_experts: int, n_inter: int, n_intra: int) -> ExpertLayout:
    slots = n_inter * n_intra
    if num_experts % slots == 0:
        return ExpertLayout(num_experts, n_inter, n_intra,
                            h=num_experts // slots, r=1, shard_intra=True)
    if num_experts % n_inter != 0:
        raise ValueError(
            f"num_experts={num_experts} not divisible by n_inter={n_inter}")
    e_pn = num_experts // n_inter
    if n_intra % e_pn != 0:
        raise ValueError(
            f"cannot lay out {e_pn} experts/node on {n_intra} slots/node: "
            f"need E_pn | m or m | E_pn")
    return ExpertLayout(num_experts, n_inter, n_intra,
                        h=1, r=n_intra // e_pn, shard_intra=False)

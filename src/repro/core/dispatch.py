"""Pluggable token dispatch/combine for MoE capacity buffers.

Every routing schedule in :mod:`repro.core.moe` reduces to the same local
primitive: place ``A = t*k`` routing assignments into a per-group capacity
buffer ``(num_groups, cap, d)`` (dispatch), run expert compute, and read the
buffer back to token order with gate weighting (combine).  Two backends
implement that primitive behind one interface:

* ``"dense"`` — the original math, kept as the oracle: a dense
  ``(A, num_groups)`` one-hot matrix, a cumsum over the token axis for
  within-group positions, a k-fold ``jnp.repeat`` of the tokens, and a
  scatter-add into the buffer.  O(A * num_groups) memory and work before a
  single useful byte moves.

* ``"sort"`` — argsort assignments by destination group (stable, so the
  paper's arrival-order drop semantics are preserved), compute within-group
  positions with sorted-segment arithmetic (a boundary mask + running max —
  no dense one-hot, no O(A*V) cumsum), then build the buffer by *gathering*
  source rows directly from ``x`` at ``assignment // k`` (no k-fold token
  copy ever materializes).  Combine is the mirrored gather-reduce.  With
  ``use_kernel=True`` both gathers run through the fused Pallas kernels in
  :mod:`repro.kernels.moe_dispatch`.

Both backends produce bit-identical buffers and keep masks; within-group
positions agree on every *valid* assignment (the position of an assignment
with ``valid=False`` is unspecified — it never lands in the buffer).

The interface::

    buf, state = dispatch(x, group_ids, gates, num_groups, cap, k=k, ...)
    ...                                # A2A + expert FFN on buf
    y = combine(buf_back, state)       # (t, d), gate-weighted

``dispatch_flags`` scatters per-assignment scalars (e.g. validity flags for
SMILE level 1) into a ``(num_groups, cap)`` buffer using the same state.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref

BACKENDS = ("dense", "sort")


# =============================================================================
# Dense backend primitives (the oracle; formerly inlined in core/moe.py)
# =============================================================================

def positions_in_group(group_ids: jax.Array, keep_in: jax.Array,
                       num_groups: int, cap: int
                       ) -> Tuple[jax.Array, jax.Array]:
    """Assign each (flat) routing decision a slot within its group.

    ``group_ids``: (A,) int32; ``keep_in``: (A,) bool validity. Returns
    ``pos`` (A,) position within group and ``keep`` (A,) bool (valid and
    under capacity). Overflow = dropped, in arrival order (paper semantics).
    """
    onehot = jax.nn.one_hot(group_ids, num_groups, dtype=jnp.int32)
    onehot = onehot * keep_in[:, None].astype(jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - onehot       # exclusive prefix count
    pos = jnp.take_along_axis(pos, group_ids[:, None], axis=1)[:, 0]
    keep = keep_in & (pos < cap)
    return pos, keep


def dispatch_scatter(x: jax.Array, group_ids: jax.Array, pos: jax.Array,
                     keep: jax.Array, num_groups: int, cap: int) -> jax.Array:
    """Scatter tokens (A, d) into a capacity buffer (num_groups, cap, d)."""
    d = x.shape[-1]
    buf = jnp.zeros((num_groups, cap, d), dtype=x.dtype)
    safe_pos = jnp.where(keep, pos, cap)            # OOB -> dropped
    return buf.at[group_ids, safe_pos].add(
        x * keep[:, None].astype(x.dtype), mode="drop")


def scatter_flags(vals: jax.Array, group_ids: jax.Array, pos: jax.Array,
                  keep: jax.Array, num_groups: int, cap: int) -> jax.Array:
    """Scatter per-assignment scalars into (num_groups, cap)."""
    buf = jnp.zeros((num_groups, cap), dtype=vals.dtype)
    safe_pos = jnp.where(keep, pos, cap)
    return buf.at[group_ids, safe_pos].add(vals * keep.astype(vals.dtype),
                                           mode="drop")


def combine_gather(buf: jax.Array, group_ids: jax.Array, pos: jax.Array,
                   keep: jax.Array, gates: jax.Array,
                   out_tokens: int, k: int) -> jax.Array:
    """Gather expert outputs back to token order and apply gates.

    ``buf``: (groups, cap, d); ids/pos/keep/gates flat (t*k,). Returns (t, d).
    """
    d = buf.shape[-1]
    got = buf.at[group_ids, pos].get(mode="fill", fill_value=0)   # (A, d)
    got = got * (gates * keep.astype(gates.dtype))[:, None].astype(buf.dtype)
    return got.reshape(out_tokens, k, d).sum(axis=1)


# =============================================================================
# Sort backend primitives
# =============================================================================

def sort_positions(group_ids: jax.Array, valid: jax.Array,
                   num_groups: int, cap: int
                   ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Within-group positions via a stable sort instead of a dense cumsum.

    Returns ``(pos, keep, slot_assign)``: ``pos``/``keep`` as
    :func:`positions_in_group` (positions of invalid assignments are
    unspecified), plus ``slot_assign`` (num_groups*cap,) int32 — the flat
    assignment index occupying each buffer slot, ``-1`` for empty slots.
    ``slot_assign`` turns the dispatch scatter into a gather.
    """
    A = group_ids.shape[0]
    gi = group_ids.astype(jnp.int32)
    # invalid assignments sort after every real group -> never take a slot
    keys = jnp.where(valid, gi, num_groups)
    idx = jnp.arange(A, dtype=jnp.int32)
    if (num_groups + 1) * A < 2**31:
        # pack (key, arrival index) into one int32: a single-operand sort is
        # ~4x faster on CPU than the stable variadic argsort, and the packed
        # low bits make it order-preserving within each key by construction
        sp = jax.lax.sort(keys * A + idx)
        order = sp % A
        skeys = sp // A
    else:                                       # int32 packing would overflow
        order = jnp.argsort(keys, stable=True).astype(jnp.int32)  # (A,)
        skeys = jnp.take(keys, order)
    # position within the sorted group run = idx - (first index of the run);
    # run starts come from a tiny (num_groups+1,) searchsorted, not a scan
    starts = jnp.searchsorted(
        skeys, jnp.arange(num_groups + 1, dtype=jnp.int32)).astype(jnp.int32)
    pos_s = idx - jnp.take(starts, skeys)
    keep_s = (skeys < num_groups) & (pos_s < cap)
    pos = jnp.zeros((A,), jnp.int32).at[order].set(pos_s)
    keep = jnp.zeros((A,), bool).at[order].set(keep_s)
    dst = jnp.where(keep_s, skeys * cap + pos_s, num_groups * cap)
    slot_assign = jnp.full((num_groups * cap,), -1, jnp.int32
                           ).at[dst].set(order, mode="drop")
    return pos, keep, slot_assign


# =============================================================================
# The pluggable interface
# =============================================================================

@dataclasses.dataclass
class CombineState:
    """Everything combine/flags need to invert a dispatch.

    Array fields are flat per-assignment (A = out_tokens * k,) except
    ``slot_assign`` (sort backend only): (num_groups * cap,) assignment
    index per buffer slot, -1 = empty.
    """
    group_ids: jax.Array
    pos: jax.Array
    keep: jax.Array
    gates: jax.Array
    slot_assign: Optional[jax.Array]
    num_groups: int
    cap: int
    k: int
    out_tokens: int
    backend: str
    use_kernel: bool


jax.tree_util.register_dataclass(
    CombineState,
    data_fields=("group_ids", "pos", "keep", "gates", "slot_assign"),
    meta_fields=("num_groups", "cap", "k", "out_tokens", "backend",
                 "use_kernel"),
)


def dispatch(x: jax.Array, group_ids: jax.Array, gates: jax.Array,
             num_groups: int, cap: int, *, k: int = 1,
             valid: Optional[jax.Array] = None, backend: str = "sort",
             use_kernel: bool = False
             ) -> Tuple[jax.Array, CombineState]:
    """Place tokens into a (num_groups, cap, d) capacity buffer.

    ``x``: (t, d) local tokens; ``group_ids``/``gates``: flat (t*k,)
    per-assignment destination group and combine weight (assignment ``a``
    belongs to token ``a // k``); ``valid``: optional (t*k,) bool — invalid
    assignments never consume capacity.  Returns the buffer and the opaque
    state consumed by :func:`combine` / :func:`dispatch_flags`.
    """
    t, d = x.shape
    A = group_ids.shape[0]
    if A != t * k:
        raise ValueError(f"group_ids {A} != tokens {t} * k {k}")
    if valid is None:
        valid = jnp.ones((A,), bool)

    if backend == "dense":
        pos, keep = positions_in_group(group_ids, valid, num_groups, cap)
        xr = jnp.repeat(x, k, axis=0) if k > 1 else x
        buf = dispatch_scatter(xr, group_ids, pos, keep, num_groups, cap)
        state = CombineState(group_ids, pos, keep, gates, None,
                             num_groups, cap, k, t, backend, use_kernel)
        return buf, state

    if backend != "sort":
        raise ValueError(f"unknown dispatch backend {backend!r}; "
                         f"expected one of {BACKENDS}")
    pos, keep, slot_assign = sort_positions(group_ids, valid, num_groups, cap)
    token_src = jnp.where(slot_assign >= 0, slot_assign // k, -1)
    if use_kernel:
        from repro.kernels import ops as kops
        rows = kops.dispatch_gather(x, token_src)
    else:
        rows = ref.dispatch_gather_ref(x, token_src)
    state = CombineState(group_ids, pos, keep, gates, slot_assign,
                         num_groups, cap, k, t, backend, use_kernel)
    return rows.reshape(num_groups, cap, d), state


def combine(buf: jax.Array, state: CombineState) -> jax.Array:
    """Read a (num_groups, cap, d) buffer back to (t, d) token order,
    weighting each surviving assignment by its gate."""
    d = buf.shape[-1]
    if state.backend == "dense":
        return combine_gather(buf, state.group_ids, state.pos, state.keep,
                              state.gates, state.out_tokens, state.k)
    rows = buf.reshape(state.num_groups * state.cap, d)
    src = jnp.where(state.keep,
                    state.group_ids.astype(jnp.int32) * state.cap + state.pos,
                    -1).reshape(state.out_tokens, state.k)
    scale = (state.gates * state.keep.astype(state.gates.dtype)
             ).reshape(state.out_tokens, state.k)
    if state.use_kernel:
        from repro.kernels import ops as kops
        return kops.combine_gather(rows, src, scale)
    return ref.combine_gather_ref(rows, src, scale)


def dispatch_flags(vals: jax.Array, state: CombineState) -> jax.Array:
    """Place per-assignment scalars (A,) into a (num_groups, cap) buffer
    mirroring the token dispatch (zeros in empty slots)."""
    if state.backend == "dense":
        return scatter_flags(vals, state.group_ids, state.pos, state.keep,
                             state.num_groups, state.cap)
    sa = state.slot_assign
    got = jnp.take(vals, jnp.maximum(sa, 0)) * (sa >= 0).astype(vals.dtype)
    return got.reshape(state.num_groups, state.cap)

"""Pluggable token dispatch/combine for MoE capacity buffers.

Every routing schedule reduces to the same local primitive: place
``A = t*k`` routing assignments into a per-group capacity buffer
``(num_groups, cap, d)`` (dispatch), run expert compute, and read the
buffer back to token order with gate weighting (combine).  The hop-pipeline
executor (:mod:`repro.core.pipeline`) is the sole layer-level consumer —
each :class:`~repro.core.pipeline.ExpertHop` runs exactly one
dispatch/combine round trip through this interface, so a backend added
here lands on switch's flat hop and both SMILE levels at once.  Three
backends implement the primitive behind one interface:

* ``"dense"`` — the original math, kept as the oracle: a dense
  ``(A, num_groups)`` one-hot matrix, a cumsum over the token axis for
  within-group positions, a k-fold ``jnp.repeat`` of the tokens, and a
  scatter-add into the buffer.  O(A * num_groups) memory and work before a
  single useful byte moves.

* ``"sort"`` — argsort assignments by destination group (stable, so the
  paper's arrival-order drop semantics are preserved), compute within-group
  positions with sorted-segment arithmetic (a boundary mask + running max —
  no dense one-hot, no O(A*V) cumsum), then build the buffer by *gathering*
  source rows directly from ``x`` at ``assignment // k`` (no k-fold token
  copy ever materializes).  Combine is the mirrored gather-reduce.  With
  ``use_kernel=True`` both gathers run through the fused Pallas kernels in
  :mod:`repro.kernels.moe_dispatch`.

Both capacity backends produce bit-identical buffers and keep masks;
within-group positions agree on every *valid* assignment (the position of an
assignment with ``valid=False`` is unspecified — it never lands in the
buffer).

The interface::

    buf, state = dispatch(x, group_ids, gates, num_groups, cap, k=k, ...)
    ...                                # A2A + expert FFN on buf
    y = combine(buf_back, state)       # (t, d), gate-weighted

``dispatch_flags`` scatters per-assignment scalars (e.g. validity flags for
SMILE level 1) into a ``(num_groups, cap)`` buffer using the same state.

* ``"dropless"`` — no capacity buffer at all: :func:`dispatch_ragged` sorts
  assignments by destination group into a flat *tile-aligned ragged* layout —
  each group's segment starts at a multiple of ``block`` and holds exactly its
  own assignments (MegaBlocks-style), so expert FFN runs over true per-group
  segment lengths with zero capacity padding and **zero token drops**.  The
  total padding is bounded by ``num_groups * (block - 1)`` rows regardless of
  routing skew, vs the unbounded ``(cf - 1) * A`` padding (plus overflow
  drops) of capacity buffers.  Because the layout is data-independent in
  *shape* (only the segment boundaries move), it stays jittable; the ragged
  grouped-matmul kernel (:mod:`repro.kernels.grouped_ffn`) scalar-prefetches
  the per-tile group ids derived from ``group_starts``.  On meshed hops the
  layout goes straight onto the wire: :func:`ragged_send_counts` reads
  per-destination-rank segment extents off ``group_starts`` (rank-major
  group order), :func:`ragged_seg_lens` supplies the raw per-group counts a
  receiver needs, and :func:`ragged_recv_layout` rebuilds a received slab's
  per-row (group, validity) structure from those counts alone — no
  intermediate capacity scatter anywhere (see
  :func:`repro.sharding.comm.ragged_all_to_all` for the exchange itself).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref

BACKENDS = ("dense", "sort", "dropless")
# the two that place tokens into fixed (num_groups, cap, d) buffers and can
# therefore drop overflow; "dropless" routes through dispatch_ragged instead
CAPACITY_BACKENDS = ("dense", "sort")

# row-tile bounds for the tile-aligned ragged layout; the default adapts to
# the mean segment length and the compute path, see _ragged_block()
RAGGED_BLOCK_MIN = 8
RAGGED_BLOCK_MAX_KERNEL = 128      # one MXU tile; keeps the VMEM working set
RAGGED_BLOCK_MAX_JNP = 4096        # XLA batched matmul reaches dense parity


# =============================================================================
# Dense backend primitives (the oracle; formerly inlined in core/moe.py)
# =============================================================================

def positions_in_group(group_ids: jax.Array, keep_in: jax.Array,
                       num_groups: int, cap: int
                       ) -> Tuple[jax.Array, jax.Array]:
    """Assign each (flat) routing decision a slot within its group.

    ``group_ids``: (A,) int32; ``keep_in``: (A,) bool validity. Returns
    ``pos`` (A,) position within group and ``keep`` (A,) bool (valid and
    under capacity). Overflow = dropped, in arrival order (paper semantics).
    """
    onehot = jax.nn.one_hot(group_ids, num_groups, dtype=jnp.int32)
    onehot = onehot * keep_in[:, None].astype(jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - onehot       # exclusive prefix count
    pos = jnp.take_along_axis(pos, group_ids[:, None], axis=1)[:, 0]
    keep = keep_in & (pos < cap)
    return pos, keep


def dispatch_scatter(x: jax.Array, group_ids: jax.Array, pos: jax.Array,
                     keep: jax.Array, num_groups: int, cap: int) -> jax.Array:
    """Scatter tokens (A, d) into a capacity buffer (num_groups, cap, d)."""
    d = x.shape[-1]
    buf = jnp.zeros((num_groups, cap, d), dtype=x.dtype)
    safe_pos = jnp.where(keep, pos, cap)            # OOB -> dropped
    return buf.at[group_ids, safe_pos].add(
        x * keep[:, None].astype(x.dtype), mode="drop")


def scatter_flags(vals: jax.Array, group_ids: jax.Array, pos: jax.Array,
                  keep: jax.Array, num_groups: int, cap: int) -> jax.Array:
    """Scatter per-assignment scalars into (num_groups, cap)."""
    buf = jnp.zeros((num_groups, cap), dtype=vals.dtype)
    safe_pos = jnp.where(keep, pos, cap)
    return buf.at[group_ids, safe_pos].add(vals * keep.astype(vals.dtype),
                                           mode="drop")


def combine_gather(buf: jax.Array, group_ids: jax.Array, pos: jax.Array,
                   keep: jax.Array, gates: jax.Array,
                   out_tokens: int, k: int) -> jax.Array:
    """Gather expert outputs back to token order and apply gates.

    ``buf``: (groups, cap, d); ids/pos/keep/gates flat (t*k,). Returns (t, d).
    """
    d = buf.shape[-1]
    got = buf.at[group_ids, pos].get(mode="fill", fill_value=0)   # (A, d)
    got = got * (gates * keep.astype(gates.dtype))[:, None].astype(buf.dtype)
    return got.reshape(out_tokens, k, d).sum(axis=1)


# =============================================================================
# Sort backend primitives
# =============================================================================

def _group_sort(keys: jax.Array, num_keys: int, sort_impl: str):
    """Stable small-domain sort via :func:`repro.kernels.ops.group_sort`
    (lazy import, matching the other kernel touchpoints in this module)."""
    from repro.kernels import ops as kops
    return kops.group_sort(keys, num_keys, impl=sort_impl)


def sort_positions(group_ids: jax.Array, valid: jax.Array,
                   num_groups: int, cap: int, *, sort_impl: str = "argsort"
                   ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Within-group positions via a stable sort instead of a dense cumsum.

    Returns ``(pos, keep, slot_assign)``: ``pos``/``keep`` as
    :func:`positions_in_group` (positions of invalid assignments are
    unspecified), plus ``slot_assign`` (num_groups*cap,) int32 — the flat
    assignment index occupying each buffer slot, ``-1`` for empty slots.
    ``slot_assign`` turns the dispatch scatter into a gather.

    The sort itself runs through :func:`repro.kernels.ops.group_sort`
    (``sort_impl``: ``"radix"`` = one-pass Pallas counting sort,
    ``"argsort"`` = packed single-operand ``lax.sort``; bit-identical).
    Given the sorted ``ranks`` and the per-group ``starts`` the counting
    sort hands back for free, every quantity is computed element-side —
    ``pos = rank - starts[key]`` — with no scatter back from sorted order.
    """
    if num_groups < 1:
        raise ValueError(f"num_groups must be >= 1, got {num_groups}")
    A = group_ids.shape[0]
    if A == 0:
        # serving can hand us an empty local batch; nothing to sort
        return (jnp.zeros((0,), jnp.int32), jnp.zeros((0,), bool),
                jnp.full((num_groups * cap,), -1, jnp.int32))
    gi = group_ids.astype(jnp.int32)
    # invalid assignments sort after every real group -> never take a slot
    keys = jnp.where(valid, gi, num_groups)
    ranks, starts = _group_sort(keys, num_groups + 1, sort_impl)
    idx = jnp.arange(A, dtype=jnp.int32)
    # position within the group run = sorted rank - first rank of the run
    pos = ranks - jnp.take(starts, keys)
    keep = valid & (pos < cap)
    dst = jnp.where(keep, keys * cap + pos, num_groups * cap)
    slot_assign = jnp.full((num_groups * cap,), -1, jnp.int32
                           ).at[dst].set(idx, mode="drop")
    return pos, keep, slot_assign


# =============================================================================
# Dropless (tile-aligned ragged) backend primitives
# =============================================================================

def _ragged_block(A: int, num_groups: int, block: Optional[int],
                  use_kernel: bool = False) -> int:
    """Pick the row-tile size for the ragged layout.

    Up to ``block`` rows of alignment slack are paid per group, so aim for
    ~8+ tiles per average segment (<= ~6% waste at uniform routing): tile
    ``~ mean/8``, power of two.  The cap depends on the compute path: the
    Pallas kernel wants one MXU tile (bigger blows the VMEM working set at
    large d), while the jnp fallback wants tiles as large as the slack
    budget allows — XLA's batched matmul only reaches the dense grouped
    einsum's per-row throughput at a few thousand rows per batch entry.
    Static in A/num_groups, so jit-safe.
    """
    if block is not None:
        return block
    cap = RAGGED_BLOCK_MAX_KERNEL if use_kernel else RAGGED_BLOCK_MAX_JNP
    mean = max(A // max(num_groups, 1), 1)
    target = mean if mean < 64 else max(mean // 8, 64)
    b = RAGGED_BLOCK_MIN
    while b * 2 <= min(target, cap):
        b *= 2
    return b


def ragged_rows(A: int, num_groups: int, block: int) -> int:
    """Static row count of the ragged layout: worst-case tile-aligned size.

    Each group wastes at most one partial tile, so
    ``ceil(A/block) + num_groups`` tiles always suffice.
    """
    return ((A + block - 1) // block + num_groups) * block


def ragged_positions(group_ids: jax.Array, valid: jax.Array,
                     num_groups: int, block: int, *,
                     sort_impl: str = "argsort"
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Tile-aligned ragged layout: the capacity-free sibling of
    :func:`sort_positions`.

    Assignments are stable-sorted by destination group (through
    :func:`repro.kernels.ops.group_sort` — ``sort_impl`` selects the Pallas
    counting sort vs the argsort oracle, bit-identically); group ``g``'s
    segment is placed starting at ``group_starts[g]`` — always a multiple of
    ``block`` — and holds exactly its own valid assignments, in arrival
    order.  Nothing is ever dropped.

    Returns ``(rank, group_starts, row_src)``:

    * ``rank`` (A,) int32 — row of each assignment in the flat layout
      (``-1`` for invalid assignments);
    * ``group_starts`` (num_groups+1,) int32 — aligned segment starts;
      ``group_starts[g+1] - group_starts[g]`` is group g's *aligned* extent,
      and rows ``[group_starts[g], group_starts[g] + len_g)`` are its real
      assignments (the remainder of the last tile is padding);
    * ``row_src`` (R,) int32 — assignment id occupying each row, ``-1`` for
      alignment padding / unused tail (R = :func:`ragged_rows`, static).
    """
    if num_groups < 1:
        raise ValueError(f"num_groups must be >= 1, got {num_groups}")
    A = group_ids.shape[0]
    G = num_groups
    R = ragged_rows(A, G, block)
    if A == 0:
        return (jnp.zeros((0,), jnp.int32), jnp.zeros((G + 1,), jnp.int32),
                jnp.full((R,), -1, jnp.int32))
    keys = jnp.where(valid, group_ids.astype(jnp.int32), G)
    ranks, starts = _group_sort(keys, G + 1, sort_impl)
    idx = jnp.arange(A, dtype=jnp.int32)
    # raw segment bounds: counts of keys < g; bounds[G] == number of valid
    # rows (the counting sort's prefix array IS the searchsorted result)
    bounds = starts[:G + 1]
    lens = bounds[1:] - bounds[:-1]                               # (G,)
    aligned = ((lens + block - 1) // block) * block
    group_starts = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(aligned).astype(jnp.int32)])
    # element-side: within-segment position = sorted rank - first rank of
    # the segment; invalid keys (== G) index bounds[G]/group_starts[G] and
    # are masked to the sentinels below — no scatter back from sorted order
    pos_e = ranks - jnp.take(bounds, keys)
    arow = jnp.take(group_starts, keys) + pos_e
    arow = jnp.where(valid, arow, R)               # sentinel: off the layout
    rank = jnp.where(valid, arow, -1)
    row_src = jnp.full((R,), -1, jnp.int32).at[arow].set(idx, mode="drop")
    return rank, group_starts, row_src


def ragged_seg_lens(group_ids: jax.Array, valid: jax.Array,
                    num_groups: int) -> jax.Array:
    """Exact per-group valid-assignment counts: (num_groups,) int32.

    The raw (un-aligned) segment lengths of the ragged layout — the numbers a
    ragged All2All hop exchanges so the receiver can tell real rows from
    tile-alignment padding (no intermediate capacity scatter needed).
    """
    if group_ids.shape[0] == 0:
        return jnp.zeros((num_groups,), jnp.int32)
    return jnp.zeros((num_groups,), jnp.int32).at[group_ids].add(
        valid.astype(jnp.int32), mode="drop")


def ragged_send_counts(group_starts: jax.Array,
                       groups_per_rank: int) -> jax.Array:
    """Per-destination-rank aligned row counts of a rank-major ragged layout.

    When the layout's groups are ordered rank-major (all of rank 0's groups,
    then rank 1's, ...), rank ``p``'s wire segment is the contiguous row range
    ``[group_starts[p*gpr], group_starts[(p+1)*gpr])`` — tile-aligned, so the
    only padding on the wire is the bounded alignment slack.  Returns (P,)
    int32 counts straight off the (P*gpr + 1,) offsets.
    """
    b = group_starts[::groups_per_rank]                       # (P + 1,)
    return (b[1:] - b[:-1]).astype(jnp.int32)


def ragged_row_membership(starts: jax.Array, counts: jax.Array,
                          n_rows: int
                          ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Map each row of a concatenated-segments layout to its segment.

    ``starts``: (S+1,) ascending segment start offsets — segment ``s`` spans
    rows ``[starts[s], starts[s+1])`` and its first ``counts[s]`` rows are
    occupied (``counts[s] <= starts[s+1] - starts[s]``).  Returns
    ``(seg, within, valid)`` over ``(n_rows,)``: the owning segment (clamped
    on the tail), the offset within it, and whether the row is occupied.
    The single source of truth for counts-to-row reconstruction — used both
    by :func:`ragged_recv_layout` (per-group segments) and the emulated
    compaction inside :func:`repro.sharding.comm.ragged_all_to_all`
    (per-source segments).
    """
    S = counts.shape[0]
    ar = jnp.arange(n_rows, dtype=jnp.int32)
    seg = jnp.clip(jnp.searchsorted(starts, ar, side="right")
                   .astype(jnp.int32) - 1, 0, S - 1)
    within = ar - jnp.take(starts, seg)
    valid = within < jnp.take(counts, seg)
    return seg, within, valid


def ragged_recv_layout(len_grid: jax.Array, block: int, recv_rows: int
                       ) -> Tuple[jax.Array, jax.Array]:
    """Rebuild the structure of a received ragged slab from exchanged counts.

    ``len_grid``: (P, n_local) int32 — raw (valid-row) segment length per
    (source rank, my local group); the received slab concatenates, source-
    major, each source's ``n_local`` tile-aligned segments exactly as its
    ``ragged_positions`` laid them out (``block`` must match the sender's row
    tile).  Returns ``(gid, valid)`` over the (recv_rows,) slab: the local
    group id owning each row (clamped on the unused tail) and whether the row
    is a real assignment (False on alignment padding and the tail) — enough
    to re-compact with :func:`dispatch_ragged` without any capacity buffer.
    """
    P, nl = len_grid.shape
    aligned = ((len_grid + block - 1) // block) * block
    starts = jnp.concatenate([
        jnp.zeros((1,), jnp.int32),
        jnp.cumsum(aligned.reshape(-1)).astype(jnp.int32)])   # (P*nl + 1,)
    seg, _, valid = ragged_row_membership(starts, len_grid.reshape(-1),
                                          recv_rows)
    return seg % nl, valid


def ragged_tile_gids(group_starts: jax.Array, n_tiles: int,
                     block: int) -> jax.Array:
    """Group id owning each row tile of the ragged layout.

    Segment starts are tile-aligned, so every tile belongs to exactly one
    group; tiles past the last segment clamp to the final group (their rows
    are zero, so they contribute nothing through the FFN).
    """
    t0 = jnp.arange(n_tiles, dtype=jnp.int32) * block
    gid = jnp.searchsorted(group_starts, t0, side="right").astype(jnp.int32) - 1
    return jnp.clip(gid, 0, group_starts.shape[0] - 2)


# =============================================================================
# The pluggable interface
# =============================================================================

@dataclasses.dataclass
class CombineState:
    """Everything combine/flags need to invert a dispatch.

    Array fields are flat per-assignment (A = out_tokens * k,) except
    ``slot_assign`` (sort backend only): (num_groups * cap,) assignment
    index per buffer slot, -1 = empty.

    The ``"dropless"`` backend reuses the fields for its flat ragged layout:
    ``pos`` holds each assignment's *row* in the (R,) layout (-1 invalid),
    ``slot_assign`` the (R,) row -> assignment map (-1 padding), and ``cap``
    the row-tile size ``block`` (there is no capacity).
    """
    group_ids: jax.Array
    pos: jax.Array
    keep: jax.Array
    gates: jax.Array
    slot_assign: Optional[jax.Array]
    num_groups: int
    cap: int
    k: int
    out_tokens: int
    backend: str
    use_kernel: bool


jax.tree_util.register_dataclass(
    CombineState,
    data_fields=("group_ids", "pos", "keep", "gates", "slot_assign"),
    meta_fields=("num_groups", "cap", "k", "out_tokens", "backend",
                 "use_kernel"),
)


def dispatch(x: jax.Array, group_ids: jax.Array, gates: jax.Array,
             num_groups: int, cap: int, *, k: int = 1,
             valid: Optional[jax.Array] = None, backend: str = "sort",
             use_kernel: bool = False, sort_impl: str = "argsort"
             ) -> Tuple[jax.Array, CombineState]:
    """Place tokens into a (num_groups, cap, d) capacity buffer.

    ``x``: (t, d) local tokens; ``group_ids``/``gates``: flat (t*k,)
    per-assignment destination group and combine weight (assignment ``a``
    belongs to token ``a // k``); ``valid``: optional (t*k,) bool — invalid
    assignments never consume capacity.  ``sort_impl`` selects the group
    sort of the sort backend (``MoEConfig.sort_impl``; ignored by dense).
    Returns the buffer and the opaque state consumed by :func:`combine` /
    :func:`dispatch_flags`.
    """
    if num_groups < 1:
        # hoisted above the backend split so the dense path fails loudly
        # too instead of producing a shape-0 buffer
        raise ValueError(f"num_groups must be >= 1, got {num_groups}")
    t, d = x.shape
    A = group_ids.shape[0]
    if A != t * k:
        raise ValueError(f"group_ids {A} != tokens {t} * k {k}")
    if valid is None:
        valid = jnp.ones((A,), bool)

    if backend == "dense":
        pos, keep = positions_in_group(group_ids, valid, num_groups, cap)
        xr = jnp.repeat(x, k, axis=0) if k > 1 else x
        buf = dispatch_scatter(xr, group_ids, pos, keep, num_groups, cap)
        state = CombineState(group_ids, pos, keep, gates, None,
                             num_groups, cap, k, t, backend, use_kernel)
        return buf, state

    if backend != "sort":
        raise ValueError(f"unknown dispatch backend {backend!r}; "
                         f"expected \"dense\" or \"sort\" (capacity-buffer "
                         f"backends; for \"dropless\" use dispatch_ragged)")
    pos, keep, slot_assign = sort_positions(group_ids, valid, num_groups, cap,
                                            sort_impl=sort_impl)
    state = CombineState(group_ids, pos, keep, gates, slot_assign,
                         num_groups, cap, k, t, backend, use_kernel)
    if t == 0:
        # empty local batch (serving): nothing to gather from
        return jnp.zeros((num_groups, cap, d), x.dtype), state
    token_src = jnp.where(slot_assign >= 0, slot_assign // k, -1)
    if use_kernel:
        from repro.kernels import ops as kops
        rows = kops.dispatch_gather(x, token_src)
    else:
        rows = ref.dispatch_gather_ref(x, token_src)
    return rows.reshape(num_groups, cap, d), state


def dispatch_ragged(x: jax.Array, group_ids: jax.Array, gates: jax.Array,
                    num_groups: int, *, k: int = 1,
                    valid: Optional[jax.Array] = None,
                    block: Optional[int] = None, use_kernel: bool = False,
                    sort_impl: str = "argsort"
                    ) -> Tuple[jax.Array, jax.Array, CombineState]:
    """Capacity-free dispatch into the tile-aligned ragged layout.

    Same contract as :func:`dispatch` (including ``sort_impl``) but with no
    capacity buffer: returns ``(rows, group_starts, state)`` where ``rows``
    is the flat ``(R, d)`` gathered array (R static, see
    :func:`ragged_rows`), ``group_starts`` the ``(num_groups+1,)`` aligned
    segment offsets consumed by the ragged grouped FFN, and ``state`` feeds
    :func:`combine` / :func:`dispatch_flags` as usual.  No assignment is
    ever dropped (``state.keep == valid``).
    """
    t, d = x.shape
    A = group_ids.shape[0]
    if A != t * k:
        raise ValueError(f"group_ids {A} != tokens {t} * k {k}")
    if valid is None:
        valid = jnp.ones((A,), bool)
    blk = _ragged_block(A, num_groups, block, use_kernel)
    rank, group_starts, row_src = ragged_positions(group_ids, valid,
                                                   num_groups, blk,
                                                   sort_impl=sort_impl)
    state = CombineState(group_ids, rank, valid, gates, row_src,
                         num_groups, blk, k, t, "dropless", use_kernel)
    R = row_src.shape[0]
    if t == 0:
        return jnp.zeros((R, d), x.dtype), group_starts, state
    token_src = jnp.where(row_src >= 0, row_src // k, -1)
    if use_kernel:
        from repro.kernels import ops as kops
        rows = kops.dispatch_gather(x, token_src)
    else:
        rows = ref.dispatch_gather_ref(x, token_src)
    return rows, group_starts, state


def combine(buf: jax.Array, state: CombineState) -> jax.Array:
    """Read expert outputs back to (t, d) token order, weighting each
    surviving assignment by its gate.  ``buf`` is the (num_groups, cap, d)
    capacity buffer for the dense/sort backends, or the flat (R, d) ragged
    row array for the dropless backend."""
    d = buf.shape[-1]
    if state.backend == "dense":
        return combine_gather(buf, state.group_ids, state.pos, state.keep,
                              state.gates, state.out_tokens, state.k)
    if state.backend == "dropless":
        rows = buf                                       # already flat (R, d)
        src = jnp.where(state.keep, state.pos, -1
                        ).reshape(state.out_tokens, state.k)
    else:
        rows = buf.reshape(state.num_groups * state.cap, d)
        src = jnp.where(
            state.keep,
            state.group_ids.astype(jnp.int32) * state.cap + state.pos,
            -1).reshape(state.out_tokens, state.k)
    scale = (state.gates * state.keep.astype(state.gates.dtype)
             ).reshape(state.out_tokens, state.k)
    if state.out_tokens == 0:
        return jnp.zeros((0, d), buf.dtype)
    if state.use_kernel:
        from repro.kernels import ops as kops
        return kops.combine_gather(rows, src, scale)
    return ref.combine_gather_ref(rows, src, scale)


def dispatch_flags(vals: jax.Array, state: CombineState) -> jax.Array:
    """Place per-assignment scalars (A,) into a buffer mirroring the token
    dispatch (zeros in empty slots): (num_groups, cap) for the capacity
    backends, flat (R,) for the dropless ragged layout."""
    if state.backend == "dense":
        return scatter_flags(vals, state.group_ids, state.pos, state.keep,
                             state.num_groups, state.cap)
    sa = state.slot_assign
    if vals.shape[0] == 0:                       # empty local batch
        got = jnp.zeros(sa.shape, vals.dtype)
    else:
        got = jnp.take(vals, jnp.maximum(sa, 0)) * (sa >= 0).astype(vals.dtype)
    if state.backend == "dropless":
        return got                                       # flat (R,)
    return got.reshape(state.num_groups, state.cap)

"""Batched serving example: prefill a batch of prompts through a MoE decoder
(bi-level routing active in every MoE layer) and greedily decode.

    PYTHONPATH=src python examples/serve_decode.py [--arch qwen3-moe-30b-a3b]
"""
import argparse

from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-moe-30b-a3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()
    serve(args.arch, reduced=True, batch=args.batch,
          prompt_len=args.prompt_len, new_tokens=args.new_tokens)


if __name__ == "__main__":
    main()

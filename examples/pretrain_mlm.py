"""End-to-end driver (paper setting): pretrain a ~100M-param SMILE MLM for a
few hundred steps on the synthetic C4-like stream, with checkpointing and a
Switch baseline for the convergence-parity check (Fig. 6).

    PYTHONPATH=src python examples/pretrain_mlm.py [--steps 200] [--full]

``--full`` uses the real bert-base backbone (12L/768, ~110M active params);
default is the reduced config so the example finishes quickly on CPU.
"""
import argparse
import json

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full", action="store_true",
                    help="12L/768 backbone (~110M active params)")
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--with-switch-baseline", action="store_true")
    args = ap.parse_args()

    reduced = not args.full
    print(f"== SMILE (bi-level routing), {'full' if args.full else 'reduced'}")
    _, hist_smile = train("smile-3.7b", reduced=reduced, steps=args.steps,
                          batch=args.batch, seq=args.seq, lr=1e-3,
                          optimizer="lamb",
                          ckpt="experiments/ckpt/smile_mlm.npz")
    if args.with_switch_baseline:
        print("== Switch baseline (one-hop routing)")
        _, hist_sw = train("switch-3.7b", reduced=reduced, steps=args.steps,
                           batch=args.batch, seq=args.seq, lr=1e-3,
                           optimizer="lamb")
        print(f"final CE: smile {hist_smile[-1]['ce']:.4f} "
              f"vs switch {hist_sw[-1]['ce']:.4f} "
              f"(paper Fig. 6: curves overlap)")
    with open("experiments/pretrain_mlm_history.json", "w") as f:
        json.dump(hist_smile, f, indent=1)


if __name__ == "__main__":
    import os
    os.makedirs("experiments/ckpt", exist_ok=True)
    main()

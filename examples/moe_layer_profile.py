"""The paper's §4.4 microbenchmark: a tiny model with a single MoE layer,
profiled under both routing schedules.

On CPU this measures the arithmetic path and *counts* the collectives each
schedule would issue (1 flat All2All x 2 hops vs 2+2 level-local All2Alls);
on a real mesh the same code exercises the actual ICI/DCN paths.

    PYTHONPATH=src python examples/moe_layer_profile.py
"""
import time

import jax
import jax.numpy as jnp

from repro.common.config import MoEConfig
from repro.core.moe import init_moe_params, moe_layer
from repro.sharding.plan import single_device_plan

plan = single_device_plan()
d, tokens = 256, 4096

for router, alpha_beta in (("switch", (0.01, 0.0)), ("smile", (0.005, 0.005))):
    cfg = MoEConfig(num_experts=64, top_k=1, d_ff_expert=1024,
                    capacity_factor=2.0, router=router, grid=(8, 8),
                    lb_alpha=alpha_beta[0], lb_beta=alpha_beta[1])
    params = init_moe_params(jax.random.PRNGKey(0), cfg, d, plan)
    x = jax.random.normal(jax.random.PRNGKey(1), (tokens, d))

    fn = jax.jit(lambda p, x: moe_layer(p, x, cfg, plan)[0])
    fn(params, x).block_until_ready()          # compile
    t0 = time.time()
    for _ in range(5):
        fn(params, x).block_until_ready()
    dt = (time.time() - t0) / 5

    n_a2a = 2 if router == "switch" else 4
    groups = "1 group of 64" if router == "switch" else "8-way + 8-way"
    print(f"{router:7s}: {dt*1e3:7.1f} ms/layer (CPU math path) | "
          f"{n_a2a} All2Alls per layer over {groups} workers")
print("\nSee benchmarks/bench_moe_layer.py for the Table-3 cluster-time "
      "reproduction and experiments/dryrun for the compiled-mesh bytes.")

"""Quickstart: build a SMILE bi-level MoE layer, route tokens, inspect stats.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.common.config import MoEConfig
from repro.core.moe import init_moe_params, moe_layer
from repro.sharding.plan import single_device_plan

plan = single_device_plan()          # same code runs on the 256-chip mesh
d_model = 128

# a 4x4 logical expert grid (the paper's n x m = nodes x workers-per-node)
cfg = MoEConfig(
    num_experts=16,
    top_k=1,                         # Switch-style top-1 (the paper)
    d_ff_expert=512,
    capacity_factor=2.0,
    router="smile",                  # bi-level routing
    lb_alpha=0.005, lb_beta=0.005,   # additive LB loss (Eq. 4)
    grid=(4, 4),
)

params = init_moe_params(jax.random.PRNGKey(0), cfg, d_model, plan)
tokens = jax.random.normal(jax.random.PRNGKey(1), (256, d_model))

out, stats = moe_layer(params, tokens, cfg, plan)

print(f"output shape        : {out.shape}")
print(f"additive LB loss    : {float(stats.lb_loss):.4f} "
      f"(floor = alpha + beta = {cfg.lb_alpha + cfg.lb_beta})")
print(f"capacity drop frac  : {float(stats.drop_frac):.4f}")

# compare with the one-hop Switch baseline (same experts, different schedule)
cfg_switch = MoEConfig(num_experts=16, top_k=1, d_ff_expert=512,
                       capacity_factor=2.0, router="switch",
                       lb_alpha=0.01, grid=(4, 4))
params_sw = init_moe_params(jax.random.PRNGKey(0), cfg_switch, d_model, plan)
out_sw, stats_sw = moe_layer(params_sw, tokens, cfg_switch, plan)
print(f"switch LB loss      : {float(stats_sw.lb_loss):.4f} (floor = alpha)")
print("\nOn a real mesh, `router='smile'` turns the single flat All2All into"
      "\ntwo per-level All2Alls (inter over 'data', intra over 'model').")

"""Paged KV cache tests: allocator invariants, paged-vs-ring equivalence
(page-boundary crossings, dirty-page reuse) and recompile determinism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models.transformer import init_caches, init_model
from repro.serve.engine import Engine
from repro.serve.kvcache import PageAllocator, pages_needed
from repro.sharding.plan import single_device_plan

PLAN = single_device_plan()


# =============================================================================
# PageAllocator
# =============================================================================

def test_allocator_reservation_and_free():
    a = PageAllocator(pool_pages=8, page_size=4)
    assert a.n_free == 8 and a.occupancy == 0.0
    assert pages_needed(1, 4) == 1 and pages_needed(4, 4) == 1
    assert pages_needed(5, 4) == 2 and pages_needed(0, 4) == 1

    p1 = a.alloc(13)                      # ceil(13/4) = 4 pages
    assert p1 is not None and len(p1) == 4 and a.n_free == 4
    p2 = a.alloc(16)                      # exactly the remaining 4
    assert p2 is not None and len(p2) == 4 and a.n_free == 0
    assert a.occupancy == 1.0
    assert a.alloc(1) is None             # pool exhausted -> refuse, not raise
    assert not a.can_fit(1)

    a.free(p1)
    assert a.n_free == 4 and a.can_fit(16) and not a.can_fit(17)
    a.free(p2)
    assert a.n_free == 8
    assert sorted(p1 + p2) == list(range(8))   # every page handed out once


def test_allocator_lifo_reuse_and_double_free():
    a = PageAllocator(pool_pages=4, page_size=2)
    p1 = a.alloc(4)
    a.free(p1)
    p2 = a.alloc(4)
    assert p2 == p1[::-1]                 # freed pages are reused first
    a.free(p2)
    with pytest.raises(AssertionError):
        a.free(p2)                        # double free
    with pytest.raises(AssertionError):
        a.free([99])                      # out-of-range page id


# =============================================================================
# Paged cache == ring-buffer cache
# =============================================================================

def _ring_reference(cfg, params, prompt, new_tokens, cache_len):
    """Plain fixed-batch prefill + ring-buffer decode (the oracle path)."""
    from repro.serve.decode import build_decode_step, build_prefill
    caches = init_caches(cfg, 1, cache_len, PLAN)
    pf = build_prefill(cfg, PLAN, params, jnp.asarray(prompt)[None], caches)
    tok, caches = pf(params, jnp.asarray(prompt)[None], caches)
    dc = build_decode_step(cfg, PLAN, params, tok, caches)
    out = [int(np.asarray(tok)[0])]
    for i in range(new_tokens - 1):
        tok, caches = dc(params, tok, caches, jnp.int32(len(prompt) + i))
        out.append(int(np.asarray(tok)[0]))
    return out


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "qwen3-moe-30b-a3b"])
def test_paged_matches_ring_across_page_boundaries(arch):
    """Greedy tokens through the paged engine == ring-buffer oracle.

    page_size=3 with prompt_len=8 puts page boundaries at 3/6/9/12 — the
    prefill chunk, the prefill->decode handoff and several decode steps all
    cross a page edge, and the last page is only partially filled.
    """
    cfg = get_reduced(arch)
    params = init_model(jax.random.PRNGKey(0), cfg, PLAN)
    rng = np.random.default_rng(2)
    prompt = rng.integers(8, cfg.vocab_size, 8).astype(np.int32)
    new = 6

    eng = Engine(params, cfg, PLAN, cache_len=16, page_size=3, n_slots=2)
    uid = eng.submit(prompt, max_new_tokens=new)
    got = eng.run()[uid]
    want = _ring_reference(cfg, params, prompt, new, cache_len=16)
    assert got == want


def test_dirty_page_reuse_after_evict():
    """Freed pages are reused WITHOUT zeroing: a request admitted onto pages
    a finished request just released must decode the same tokens as on a
    fresh engine (the read mask, not memset, hides the stale KV rows)."""
    cfg = get_reduced("qwen1.5-0.5b")
    params = init_model(jax.random.PRNGKey(0), cfg, PLAN)
    rng = np.random.default_rng(3)
    prompt_a = rng.integers(8, 500, 9).astype(np.int32)
    prompt_b = rng.integers(8, 500, 7).astype(np.int32)

    # pool sized so B can only run on pages A has dirtied and freed
    eng = Engine(params, cfg, PLAN, cache_len=16, page_size=4, n_slots=1,
                 pool_pages=4)
    uid_a = eng.submit(prompt_a, max_new_tokens=5)
    eng.run()
    assert eng.alloc.n_free == 4           # A held the whole pool, now freed
    uid_b = eng.submit(prompt_b, max_new_tokens=6)
    out = eng.run()

    fresh = Engine(params, cfg, PLAN, cache_len=16, page_size=4, n_slots=1,
                   pool_pages=4)
    uid_f = fresh.submit(prompt_b, max_new_tokens=6)
    assert out[uid_b] == fresh.run()[uid_f]
    assert uid_a in eng.finished


def test_pool_exhaustion_queues_instead_of_failing():
    """With slots free but no pages, admission waits; everything completes."""
    cfg = get_reduced("qwen1.5-0.5b")
    params = init_model(jax.random.PRNGKey(0), cfg, PLAN)
    rng = np.random.default_rng(4)
    # 4 slots but pages for ~one request at a time
    eng = Engine(params, cfg, PLAN, cache_len=16, page_size=4, n_slots=4,
                 pool_pages=5)
    uids = [eng.submit(rng.integers(8, 500, 8).astype(np.int32), 4)
            for _ in range(3)]
    out = eng.run()
    assert sorted(out) == sorted(uids)
    assert eng.alloc.n_free == 5


def test_oversized_request_rejected():
    cfg = get_reduced("qwen1.5-0.5b")
    params = init_model(jax.random.PRNGKey(0), cfg, PLAN)
    eng = Engine(params, cfg, PLAN, cache_len=16, page_size=4, n_slots=2,
                 pool_pages=2)
    with pytest.raises(ValueError):
        eng.submit(np.arange(12, dtype=np.int32), max_new_tokens=8)  # > cache
    with pytest.raises(ValueError):                  # fits cache, never pool
        eng.submit(np.arange(8, dtype=np.int32), max_new_tokens=4)


def test_recompile_determinism():
    """The fused decode step compiles exactly once, and each prefill bucket
    exactly once, across ragged prompt lengths and many admit/evict cycles."""
    cfg = get_reduced("qwen1.5-0.5b")
    params = init_model(jax.random.PRNGKey(0), cfg, PLAN)
    rng = np.random.default_rng(5)
    eng = Engine(params, cfg, PLAN, cache_len=64, page_size=8, n_slots=2,
                 prefill_buckets="8,16,32")
    for plen in [3, 8, 11, 16, 20, 5]:    # hits buckets 8, 16 and 32
        eng.submit(rng.integers(8, 500, plen).astype(np.int32),
                   max_new_tokens=3)
    eng.run()
    n = eng.compile_counts()
    assert n["decode"] == 1, n
    assert set(n["prefill"]) <= {8, 16, 32}
    assert all(v == 1 for v in n["prefill"].values()), n

"""Model-level correctness: decode==full-forward, SSD chunking, sliding
window, MLA cache, vocab-parallel CE."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import layers as L
from repro.models.transformer import forward, init_caches, init_model
from repro.sharding.plan import single_device_plan

PLAN = single_device_plan()
B, S = 2, 16


def _decode_vs_full(name, tol):
    cfg = get_reduced(name)
    params = init_model(jax.random.PRNGKey(0), cfg, PLAN)
    shape = (B, cfg.num_codebooks, S) if cfg.num_codebooks > 1 else (B, S)
    toks = jax.random.randint(jax.random.PRNGKey(1), shape, 0, cfg.vocab_size)
    _, ref_logits, _, _ = forward(params, toks, cfg, PLAN,
                                  positions=jnp.arange(S))
    ref = np.asarray(ref_logits[:, -1])
    caches = init_caches(cfg, B, 2 * S, PLAN)
    out = None
    for t in range(S):
        _, lg, _, caches = forward(params, toks[..., t:t + 1], cfg, PLAN,
                                   positions=jnp.array([t]), caches=caches)
        out = np.asarray(lg[:, -1])
    err = np.abs(out - ref).max() / (np.abs(ref).max() + 1e-9)
    assert err < tol, (name, err)


@pytest.mark.parametrize("name,tol", [
    ("llama3-405b", 1e-4),
    # absorbed-MLA decode reorders the score einsums (q@W_UK)@ckv vs
    # q@(ckv@W_UK): ~1% bf16 noise, the standard trade-off of latent-space
    # decoding (see layers.mla_forward)
    ("deepseek-v3-671b", 3e-2),
    ("rwkv6-1.6b", 1e-4), ("zamba2-2.7b", 3e-2),   # bf16 chunked-vs-seq SSD
    ("musicgen-large", 1e-4), ("qwen3-moe-30b-a3b", 1e-4),
    ("qwen1.5-0.5b", 1e-4), ("phi-3-vision-4.2b", 1e-4),
])
def test_decode_matches_full_forward(name, tol):
    _decode_vs_full(name, tol)


def test_sliding_window_equals_full_for_short_seq():
    """window >= seq -> sliding attention must equal full attention."""
    cfg = get_reduced("llama3-405b")
    params = init_model(jax.random.PRNGKey(0), cfg, PLAN)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    _, full, _, _ = forward(params, toks, cfg, PLAN, positions=jnp.arange(S))
    cfg_w = cfg.replace(attention="sliding", window=S + 4)
    _, slid, _, _ = forward(params, toks, cfg_w, PLAN,
                            positions=jnp.arange(S))
    np.testing.assert_allclose(np.asarray(full), np.asarray(slid),
                               rtol=1e-5, atol=1e-5)


def test_sliding_window_restricts_context():
    """With a tiny window, distant-token perturbations must not leak in."""
    cfg = get_reduced("llama3-405b").replace(attention="sliding", window=4)
    params = init_model(jax.random.PRNGKey(0), cfg, PLAN)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, S), 0,
                              cfg.vocab_size)
    toks2 = toks.at[0, 0].set((toks[0, 0] + 1) % cfg.vocab_size)
    _, a, _, _ = forward(params, toks, cfg, PLAN, positions=jnp.arange(S))
    _, b, _, _ = forward(params, toks2, cfg, PLAN, positions=jnp.arange(S))
    # last position is > window away from position 0
    np.testing.assert_allclose(np.asarray(a[0, -1]), np.asarray(b[0, -1]),
                               rtol=1e-5, atol=1e-5)
    assert np.abs(np.asarray(a[0, 0]) - np.asarray(b[0, 0])).max() > 1e-3


def test_mla_cache_is_compressed():
    cfg = get_reduced("deepseek-v3-671b")
    caches = init_caches(cfg, B, 64, PLAN)
    moe_stage = caches[-1]
    leaf_names = set()
    for path, leaf in jax.tree_util.tree_flatten_with_path(moe_stage)[0]:
        leaf_names.add(str(path[-1]))
    assert any("ckv" in n for n in leaf_names)     # latent, not full K/V
    assert not any(n == "'k'" for n in leaf_names)


def test_vocab_parallel_xent_single_device_matches_dense():
    logits = jax.random.normal(jax.random.PRNGKey(0), (8, 32))
    labels = jax.random.randint(jax.random.PRNGKey(1), (8,), 0, 32)
    ce = L.vocab_parallel_xent(logits, labels, PLAN)
    dense = -jax.nn.log_softmax(logits)[jnp.arange(8), labels]
    np.testing.assert_allclose(np.asarray(ce), np.asarray(dense), rtol=1e-5)


def test_chunked_attention_matches_exact():
    Bq, T, H, hd = 2, 100, 3, 16
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (Bq, T, H, hd))
    k = jax.random.normal(ks[1], (Bq, T, H, hd))
    v = jax.random.normal(ks[2], (Bq, T, H, hd))
    pos = jnp.arange(T)
    got = L.chunked_attention(q, k, v, pos, pos, causal=True, chunk=32)
    from repro.kernels.ref import flash_attention_ref
    want = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_bidirectional_attention_for_mlm():
    """MLM configs attend bidirectionally: last token influences first."""
    cfg = get_reduced("smile-3.7b")
    assert not cfg.causal
    params = init_model(jax.random.PRNGKey(0), cfg, PLAN)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, S), 8,
                              cfg.vocab_size)
    toks2 = toks.at[0, -1].set((toks[0, -1] + 3) % cfg.vocab_size)
    _, a, _, _ = forward(params, toks, cfg, PLAN, positions=jnp.arange(S))
    _, b, _, _ = forward(params, toks2, cfg, PLAN, positions=jnp.arange(S))
    assert np.abs(np.asarray(a[0, 0]) - np.asarray(b[0, 0])).max() > 1e-4

"""Pipeline refactor regression suite.

* **Golden equivalence**: the hop-pipeline switch/SMILE layers
  (``repro.core.pipeline.execute_pipeline``) reproduce the pre-refactor
  monolithic implementations BIT for BIT across the full ``dispatch_backend
  x ragged_a2a x sort_impl`` matrix, at ample capacity AND under
  starved-capacity drops.  The fixture (``tests/golden/moe_layer_golden.npz``,
  regenerate with ``tests/golden/gen_golden.py``) was captured from the PR-4
  tree; bit-level float reproducibility only holds within one (platform,
  jax version) pair, so the comparison degrades to tight allclose when the
  recorded environment differs from the running one.

* **Unified stats**: the executor's single accumulation path reports
  per-hop ``drop_frac`` (``MoEStats.hop_drop_frac``) consistently for both
  routers — the old switch/smile stat-shape asymmetry is pinned away.

* **Options registry**: ``MoEConfig.with_options`` validates against
  ``MOE_OPTIONS`` (the same registry the launchers derive their flags
  from), and the deprecated ``configs.with_dispatch_backend`` shim warns
  but still works.
"""
import dataclasses
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import (MOE_DRYRUN_OPTS, MOE_OPTION_FIELDS,
                                 MOE_OPTIONS, MoEConfig)
from repro.core import moe as M
from repro.core import pipeline as PL
from repro.sharding.plan import single_device_plan

PLAN = single_device_plan()
GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "moe_layer_golden.npz")

BACKENDS = ("sort", "dense", "dropless")
RAGGED = (True, False)
SORT_IMPLS = ("argsort", "radix")
CASES = {"ample": 8.0, "starved": 1.0}
MATRIX = [(router, case, b, r, s)
          for router in ("switch", "smile") for case in CASES
          for b in BACKENDS for r in RAGGED for s in SORT_IMPLS]


def _layer_cfg(router, backend, ragged, sort_impl, cf):
    return MoEConfig(num_experts=16, top_k=2, top_g=2, d_ff_expert=32,
                     capacity_factor=cf, router=router, grid=(4, 4),
                     renorm_gates=True, dispatch_backend=backend,
                     ragged_a2a=ragged, sort_impl=sort_impl)


@pytest.fixture(scope="module")
def golden():
    return np.load(GOLDEN, allow_pickle=False)


@pytest.fixture(scope="module")
def golden_env(golden):
    ver, platform = (str(v) for v in golden["__meta__"])
    return ver == jax.__version__ and platform == jax.default_backend()


@pytest.fixture(scope="module")
def golden_params(golden):
    key = jax.random.PRNGKey(0)
    params = {}
    for router in ("switch", "smile"):
        cfg = _layer_cfg(router, "dense", True, "argsort", 8.0)
        params[router] = M.init_moe_params(key, cfg, 32, PLAN, glu=False)
    return params, jnp.asarray(golden["x"])


@pytest.mark.parametrize("router,case,backend,ragged,sort_impl", MATRIX)
def test_golden_equivalence(router, case, backend, ragged, sort_impl,
                            golden, golden_env, golden_params):
    """Every matrix cell of the pipeline-built layer reproduces the
    pre-refactor monolith's output and stats — bit-identically when run in
    the fixture's recorded environment."""
    params, x = golden_params
    cfg = _layer_cfg(router, backend, ragged, sort_impl, CASES[case])
    y, st = M.moe_layer(params[router], x, cfg, PLAN, act="gelu")
    tag = f"{router}|{case}|{backend}|r{int(ragged)}|{sort_impl}"
    y_g, s_g = golden[f"y|{tag}"], golden[f"s|{tag}"]
    s = np.asarray([float(st.lb_loss), float(st.z_loss),
                    float(st.drop_frac)], np.float64)
    if golden_env:
        np.testing.assert_array_equal(np.asarray(y), y_g)
        np.testing.assert_array_equal(s, s_g)
    else:                   # cross-platform: compilation-order float drift
        np.testing.assert_allclose(np.asarray(y), y_g, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(s, s_g, rtol=1e-5, atol=1e-7)


@pytest.mark.parametrize("router,case,backend,ragged,sort_impl", MATRIX)
def test_golden_equivalence_fused_router(router, case, backend, ragged,
                                         sort_impl, golden, golden_env,
                                         golden_params, monkeypatch):
    """Every golden cell again under ``router_impl="fused"`` (the real
    Pallas megakernel, forced): the fused routing prologue must reproduce
    the recorded pre-refactor outputs under the same per-environment
    policy as the unfused path — bit-identically in the fixture's recorded
    environment, tight allclose elsewhere."""
    from repro.kernels import ops as kops
    monkeypatch.setattr(kops, "ROUTER_FUSED_MIN_ROWS", 0)
    params, x = golden_params
    cfg = _layer_cfg(router, backend, ragged, sort_impl, CASES[case]
                     ).with_options(router_impl="fused")
    y, st = M.moe_layer(params[router], x, cfg, PLAN, act="gelu")
    tag = f"{router}|{case}|{backend}|r{int(ragged)}|{sort_impl}"
    y_g, s_g = golden[f"y|{tag}"], golden[f"s|{tag}"]
    s = np.asarray([float(st.lb_loss), float(st.z_loss),
                    float(st.drop_frac)], np.float64)
    if golden_env:
        np.testing.assert_array_equal(np.asarray(y), y_g)
        np.testing.assert_array_equal(s, s_g)
    else:
        np.testing.assert_allclose(np.asarray(y), y_g, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(s, s_g, rtol=1e-5, atol=1e-7)


def test_fused_route_decision_deterministic_across_recompiles(monkeypatch):
    """Two independent jit compilations of the fused routing prologue on
    identical inputs produce bit-identical RouteDecision inputs — gates,
    expert ids, loss probs/logits, and the dispatch positions (the
    histogram scratch carries across grid steps sequentially, so no
    compilation-order freedom may leak into the counts)."""
    from repro.kernels import ops as kops
    monkeypatch.setattr(kops, "ROUTER_FUSED_MIN_ROWS", 0)
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal((192, 32)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)

    def make_jit():
        # a fresh lambda defeats jax's function-identity jit cache, forcing
        # an independent trace + compile
        return jax.jit(lambda a, b: kops.router_fused(a, b, 2, renorm=True))

    out1 = make_jit()(x, w)
    out2 = make_jit()(x, w)
    for a, b in zip(out1, out2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def make_topk_jit():
        return jax.jit(lambda a, b: M.router_topk(a, b, 2, True, "fused"))

    dec1 = make_topk_jit()(x, w)
    dec2 = make_topk_jit()(x, w)
    for a, b in zip(dec1, dec2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ----------------------------------------------------------- unified stats
def test_per_hop_drop_frac_switch(golden_params):
    """Switch is a 1-hop pipeline: slot 0 carries its (only) drop stat,
    slot 1 is exactly zero, and the summed drop_frac equals the vector sum."""
    params, x = golden_params
    cfg = _layer_cfg("switch", "sort", True, "argsort", 1.0)
    _, st = M.moe_layer(params["switch"], x, cfg, PLAN, act="gelu")
    hdf = np.asarray(st.hop_drop_frac)
    assert hdf.shape == (PL.MAX_HOPS,)
    assert float(st.drop_frac) == hdf.sum()
    assert hdf[0] > 0.0 and hdf[1] == 0.0


def test_per_hop_drop_frac_smile(golden_params):
    """SMILE reports each level's drop fraction separately; the scalar is
    their sum (levels compound) — one accumulation path, no ad-hoc fold."""
    params, x = golden_params
    cfg = _layer_cfg("smile", "sort", True, "argsort", 1.0)
    _, st = M.moe_layer(params["smile"], x, cfg, PLAN, act="gelu")
    hdf = np.asarray(st.hop_drop_frac)
    assert float(st.drop_frac) == pytest.approx(hdf.sum(), abs=0)
    assert hdf[0] > 0.0                     # starved level-1 capacity drops
    # capacity-free hops report the EXACT constant 0.0 per hop
    cfg_d = dataclasses.replace(cfg, dispatch_backend="dropless")
    _, st_d = M.moe_layer(params["smile"], x, cfg_d, PLAN, act="gelu")
    assert not np.asarray(st_d.hop_drop_frac).any()
    assert float(st_d.drop_frac) == 0.0


def test_stats_tree_add_shapes():
    """zero_stats() trees add across routers/dense blocks (the transformer
    layer scan requirement)."""
    z = PL.zero_stats()
    assert z.hop_drop_frac.shape == (PL.MAX_HOPS,)
    tot = jax.tree_util.tree_map(lambda a, b: a + b, z, z)
    assert tot.hop_drop_frac.shape == (PL.MAX_HOPS,)


# ------------------------------------------------------ options registry
def test_with_options_validates():
    cfg = MoEConfig(num_experts=8, d_ff_expert=16)
    with pytest.raises(ValueError, match="unknown MoE option"):
        cfg.with_options(nonexistent_knob=1)
    with pytest.raises(ValueError, match="expected one of"):
        cfg.with_options(dispatch_backend="bogus")
    with pytest.raises(ValueError, match="expected a bool"):
        cfg.with_options(ragged_a2a="yes")
    with pytest.raises(ValueError, match="positive"):
        cfg.with_options(dispatch_backend="dropless",
                         recv_bound_factor=-1.0)
    # cross-option constraint: the factor only exists on ragged hops
    with pytest.raises(ValueError, match="recv_bound_factor.*requires"):
        cfg.with_options(recv_bound_factor=2.0)
    with pytest.raises(ValueError, match="recv_bound_factor.*requires"):
        cfg.with_options(dispatch_backend="dropless", ragged_a2a=False,
                         recv_bound_factor=2.0)
    with pytest.raises(ValueError, match="positive"):
        cfg.with_options(dispatch_backend="dropless",
                         recv_bound_factor=True)   # bool is not a factor
    out = cfg.with_options(dispatch_backend="dropless",
                           recv_bound_factor=2.0, sort_impl="radix")
    assert out.dispatch_backend == "dropless"
    assert out.recv_bound_factor == 2.0 and out.sort_impl == "radix"


def test_registry_choices_match_canonical_tuples():
    """The registry's enum choices must track the canonical definitions
    (dispatch.BACKENDS, kernels.ops.SORT_IMPLS) — config.py cannot import
    them (it stays jax-free), so this pin turns silent drift into a
    failure when a new backend/sort impl is added."""
    from repro.core.dispatch import BACKENDS
    from repro.kernels.ops import SORT_IMPLS
    assert set(MOE_OPTION_FIELDS["dispatch_backend"].choices) == set(BACKENDS)
    assert set(MOE_OPTION_FIELDS["sort_impl"].choices) == set(SORT_IMPLS)


def test_registry_covers_config_fields():
    """Every registered option is a real MoEConfig field, and every dryrun
    token — prerequisites included — applies cleanly on its own (the
    dryrun contract: ``--opt recv_bound`` alone must not crash)."""
    fields = {f.name for f in dataclasses.fields(MoEConfig)}
    for opt in MOE_OPTIONS:
        assert opt.field in fields, opt.field
        for req_field, _ in opt.requires:
            assert req_field in fields, (opt.field, req_field)
    base = MoEConfig(num_experts=8, d_ff_expert=16,
                     dispatch_backend="dropless")
    for tok, kw in MOE_DRYRUN_OPTS.items():
        assert set(kw) <= set(MOE_OPTION_FIELDS), tok
        base.with_options(**kw)
        # standalone application from the DEFAULT config too (what dryrun
        # does when the token is the only one passed)
        MoEConfig(num_experts=8, d_ff_expert=16).with_options(**kw)


def test_registry_derives_train_flags():
    """train.py's CLI flags come from the registry — a knob registered
    there parses end-to-end without touching the launcher."""
    import argparse

    from repro.launch.train import add_moe_option_flags, parse_moe_option_flags
    ap = argparse.ArgumentParser()
    add_moe_option_flags(ap)
    args = ap.parse_args(["--dispatch-backend", "dropless",
                          "--ragged-a2a", "on", "--sort-impl", "radix",
                          "--recv-bound-factor", "1.5"])
    opts = parse_moe_option_flags(args)
    assert opts == {"dispatch_backend": "dropless", "ragged_a2a": True,
                    "sort_impl": "radix", "recv_bound_factor": 1.5}
    MoEConfig(num_experts=8, d_ff_expert=16).with_options(**opts)
    # empty flags -> no overrides
    assert parse_moe_option_flags(ap.parse_args([])) == {}


def test_with_dispatch_backend_shim_warns():
    """The deprecated entry point still works — with a DeprecationWarning —
    and lands on exactly what with_options produces."""
    from repro.configs import get_reduced, with_dispatch_backend, with_options
    cfg = get_reduced("smile-3.7b")
    with pytest.warns(DeprecationWarning, match="with_options"):
        old = with_dispatch_backend(cfg, "dropless", ragged_a2a=False,
                                    sort_impl="radix")
    new = with_options(cfg, dispatch_backend="dropless", ragged_a2a=False,
                       sort_impl="radix")
    assert old == new
    # still validates through the registry
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError):
            with_dispatch_backend(cfg, "bogus")
    # dense archs: no-op, but arguments still validated
    dense = get_reduced("qwen1.5-0.5b")
    with pytest.warns(DeprecationWarning):
        assert with_dispatch_backend(dense, "sort") == dense


def test_recv_bound_rows_properties():
    """Static bound: tile-aligned, monotone in factor, never above the
    worst case, and >= expected arrivals + alignment slack at factor 1."""
    R, P, nl, block = 1024, 8, 4, 64
    worst = P * R
    prev = 0
    for f in (0.5, 1.0, 2.0, 4.0, 16.0):
        b = PL.recv_bound_rows(f, R, P, nl, block)
        assert b % block == 0
        assert b <= worst
        assert b >= prev
        prev = b
    assert PL.recv_bound_rows(1.0, R, P, nl, block) >= R + P * nl * block
    assert PL.recv_bound_rows(100.0, R, P, nl, block) == worst

"""Fallback for the ``hypothesis`` dependency (absent in this container).

When hypothesis is installed, re-exports the real ``given``/``settings``/
``st``.  Otherwise provides minimal stand-ins that replay a fixed number of
deterministic pseudo-random examples, so the property tests still execute
(with reduced rigor) instead of breaking collection of the whole module.

Only the strategy constructors the test suite actually uses are implemented
(``st.integers``, ``st.floats``); extend as needed.
"""
try:
    from hypothesis import given, settings
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # offline container
    HAVE_HYPOTHESIS = False
    import numpy as _np

    _FALLBACK_EXAMPLES = 10

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

    st = _Strategies()

    def settings(**_kwargs):
        return lambda fn: fn

    def given(**strategies):
        def deco(fn):
            def wrapper(*args, **kwargs):
                rng = _np.random.default_rng(0)
                for _ in range(_FALLBACK_EXAMPLES):
                    drawn = {name: s.draw(rng)
                             for name, s in strategies.items()}
                    fn(*args, **drawn, **kwargs)
            # NOT functools.wraps: the wrapper must hide the original
            # signature, or pytest would look for fixtures named after the
            # strategy-drawn parameters
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

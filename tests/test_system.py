"""End-to-end behaviour: training reduces loss; SMILE == Switch convergence
(the paper's central claim, Fig. 6, at toy scale); serving generates."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.train import train


def test_training_reduces_loss_smile():
    _, hist = train("smile-3.7b", reduced=True, steps=30, batch=16, seq=128,
                    lr=1e-3, optimizer="lamb", seed=0)
    first, last = hist[0]["ce"], hist[-1]["ce"]
    assert last < first - 0.1, (first, last)


def test_smile_matches_switch_convergence():
    """Paper Fig. 6: bi-level routing does not change convergence behavior.

    Toy-scale proxy: after the same number of steps on identical data, the
    CE of smile and switch variants must agree within a small margin."""
    _, h_smile = train("smile-3.7b", reduced=True, steps=25, batch=16,
                       seq=128, lr=1e-3, seed=0)
    _, h_switch = train("switch-3.7b", reduced=True, steps=25, batch=16,
                        seq=128, lr=1e-3, seed=0)
    ce_s, ce_o = h_smile[-1]["ce"], h_switch[-1]["ce"]
    assert abs(ce_s - ce_o) < 0.25, (ce_s, ce_o)
    # both must actually be learning
    assert h_smile[-1]["ce"] < h_smile[0]["ce"]
    assert h_switch[-1]["ce"] < h_switch[0]["ce"]


def test_serve_generates():
    from repro.launch.serve import serve
    gen = serve("qwen1.5-0.5b", reduced=True, batch=2, prompt_len=16,
                new_tokens=6)
    assert gen.shape == (2, 6)
    assert (gen >= 0).all() and (gen < 512).all()


def test_lb_loss_near_minimum_after_training():
    """The additive LB loss should sit near its alpha+beta floor during
    healthy training (uniform-ish routing)."""
    _, hist = train("smile-3.7b", reduced=True, steps=10, batch=8, seq=64,
                    lr=1e-3, seed=1)
    lb = hist[-1]["lb"]
    floor = 0.005 + 0.005
    assert lb < 3.0 * floor, lb

"""The fused routing megakernel vs the unfused routing chain.

``repro.kernels.router_fused.router_fused_pallas`` (and its pure-jnp
oracle ``ref.router_fused_ref``) fuse the per-hop routing prologue —
router GEMM, softmax, top-k, histogram and dispatch positions — into one
pass.  The contract is BIT-compatibility with the unfused chain the
executor otherwise runs (``core.moe.router_probs`` + ``topk_gates`` +
``ops.group_sort``):

* property tests over adversarial distributions — including DELIBERATE
  logit ties (duplicated expert columns, all-tied logits) and bf16 inputs,
  where an unpinned tie-break would silently diverge — assert the fused
  expert ids equal the unfused ``lax.top_k`` ids bit for bit, and gates /
  probs / logits / positions likewise;
* the kernel (interpret mode) and the oracle agree on every output across
  awkward token-tile splits;
* the ``ops.router_fused`` wrapper routes small inputs to the oracle and
  large ones to the kernel, both bit-identical.

Degenerate expert counts (E <= 2) are excluded from the property domain:
there the padded kernel GEMM and the unfused mat-vec associate the
contraction differently (1-ulp logit drift — measured, not hypothesized);
the wrapper's ``ROUTER_FUSED_MIN_EXPERTS`` gate pins those widths to the
oracle at any token count (asserted below), and ``ROUTER_FUSED_MIN_ROWS``
keeps tiny inputs on the oracle regardless.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import moe as M
from repro.kernels import ops as kops
from repro.kernels import ref
from repro.kernels.router_fused import router_fused_pallas

# named adversarial input families, indexed by a drawn integer so the
# offline hypothesis fallback (integers/floats only) can select them too
_DISTRIBUTIONS = ("normal", "bf16", "dup_experts", "all_tied", "bf16_dup")


def _make_case(rng, dist: str, t: int, d: int, E: int):
    x = rng.standard_normal((t, d)).astype(np.float32)
    w = rng.standard_normal((d, E)).astype(np.float32)
    if dist in ("dup_experts", "bf16_dup"):
        # duplicated expert columns: EXACT logit ties between expert pairs,
        # the case where an unpinned tie-break order silently diverges
        w[:, 1::2] = w[:, 0::2][:, :E // 2]
    if dist == "all_tied":
        x[:] = 0.0                       # every logit 0: the full-tie storm
    if dist in ("bf16", "bf16_dup"):
        return jnp.asarray(x, jnp.bfloat16), jnp.asarray(w, jnp.bfloat16)
    return jnp.asarray(x), jnp.asarray(w)


def _check_against_unfused(x, w, k, renorm, outs):
    """Assert one impl's 6-tuple against the unfused chain, bit for bit."""
    gates, idx, probs, logits, ranks, starts = outs
    probs_u, logits_u = M.router_probs(x, w)
    gates_u, idx_u = M.topk_gates(probs_u, k, renorm)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(idx_u))
    np.testing.assert_array_equal(np.asarray(gates), np.asarray(gates_u))
    np.testing.assert_array_equal(np.asarray(probs), np.asarray(probs_u))
    np.testing.assert_array_equal(np.asarray(logits), np.asarray(logits_u))
    r_u, s_u = ref.group_sort_ref(jnp.asarray(idx_u).reshape(-1), w.shape[1])
    np.testing.assert_array_equal(np.asarray(ranks), np.asarray(r_u))
    np.testing.assert_array_equal(np.asarray(starts), np.asarray(s_u))


@settings(deadline=None, max_examples=25)
@given(t=st.integers(1, 300), d=st.integers(4, 96), e=st.integers(4, 64),
       k=st.integers(1, 4), dist_i=st.integers(0, len(_DISTRIBUTIONS) - 1),
       block_i=st.integers(0, 2), renorm_i=st.integers(0, 1),
       seed=st.integers(0, 2**31 - 1))
def test_router_fused_property(t, d, e, k, dist_i, block_i, renorm_i, seed):
    """Kernel == oracle == unfused chain, bit for bit, on adversarial
    distributions (deliberate ties, bf16) and awkward tile splits."""
    k = min(k, e)
    renorm = bool(renorm_i)
    rng = np.random.default_rng(seed)
    x, w = _make_case(rng, _DISTRIBUTIONS[dist_i], t, d, e)
    block = (8, 32, 128)[block_i]               # incl. many-tile splits
    out_k = router_fused_pallas(x, w, k, renorm=renorm, block=block,
                                interpret=True)
    out_r = ref.router_fused_ref(x, w, k, renorm=renorm)
    for a, b in zip(out_k, out_r):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    _check_against_unfused(x, w, k, renorm, out_k)


def test_router_fused_deliberate_bf16_full_tie():
    """The headline tie case pinned explicitly (not just drawn): bf16
    inputs, every logit identical, k = 3 — the fused ids must be exactly
    the first k expert indices per token (lowest-index tie-break), equal
    to ``lax.top_k``'s order bit for bit."""
    t, d, E, k = 96, 16, 12, 3
    x = jnp.zeros((t, d), jnp.bfloat16)
    w = jnp.asarray(np.random.default_rng(0).standard_normal((d, E)),
                    jnp.bfloat16)
    out = router_fused_pallas(x, w, k, renorm=True, block=32, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(out[1]), np.tile(np.arange(k, dtype=np.int32), (t, 1)))
    _check_against_unfused(x, w, k, True, out)
    for a, b in zip(out, ref.router_fused_ref(x, w, k, renorm=True)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("t,e,k", [
    (1, 4, 1),       # single token
    (128, 8, 8),     # k == E: full selection, ids a permutation per token
    (256, 16, 2),    # exact tile multiple
    (257, 16, 2),    # one past a tile boundary
    (48, 130, 4),    # E past one lane width (domain padding)
])
def test_router_fused_edge_shapes(t, e, k):
    rng = np.random.default_rng(t * 31 + e + k)
    x, w = _make_case(rng, "normal", t, 16, e)
    out_k = router_fused_pallas(x, w, k, block=128, interpret=True)
    out_r = ref.router_fused_ref(x, w, k)
    for a, b in zip(out_k, out_r):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    _check_against_unfused(x, w, k, False, out_k)
    if k == e:
        idx = np.sort(np.asarray(out_k[1]), axis=1)
        np.testing.assert_array_equal(idx, np.tile(np.arange(e), (t, 1)))


def test_router_fused_empty_and_invalid():
    x = jnp.zeros((0, 8), jnp.float32)
    w = jnp.zeros((8, 4), jnp.float32)
    gates, idx, probs, logits, ranks, starts = router_fused_pallas(
        x, w, 2, interpret=True)
    assert gates.shape == (0, 2) and probs.shape == (0, 4)
    assert ranks.shape == (0,)
    np.testing.assert_array_equal(np.asarray(starts), np.zeros(5, np.int32))
    for fn in (lambda: router_fused_pallas(jnp.zeros((4, 8)), w, 0,
                                           interpret=True),
               lambda: router_fused_pallas(jnp.zeros((4, 8)), w, 5,
                                           interpret=True),
               lambda: ref.router_fused_ref(jnp.zeros((4, 8)), w, 0),
               lambda: ref.router_fused_ref(jnp.zeros((4, 8)), w, 5)):
        with pytest.raises(ValueError, match="top-k"):
            fn()


def test_ops_wrapper_threshold_switch(monkeypatch):
    """ops.router_fused: the oracle below ROUTER_FUSED_MIN_ROWS, the Pallas
    kernel at/above it (forced via the override) — bit-identical routes."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((64, 16)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)
    small = kops.router_fused(x, w, 2, renorm=True)      # oracle route
    monkeypatch.setattr(kops, "ROUTER_FUSED_MIN_ROWS", 0)
    forced = kops.router_fused(x, w, 2, renorm=True)     # kernel route
    for a, b in zip(small, forced):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ops_wrapper_degenerate_experts_stay_on_oracle(monkeypatch):
    """E <= 2 routes to the oracle even above ROUTER_FUSED_MIN_ROWS (the
    padded kernel GEMM has measured 1-ulp logit drift there — module
    docstring), preserving the bit-compat contract for e.g. SMILE
    inter-node routing on a 2-node mesh.  E = ROUTER_FUSED_MIN_EXPERTS is
    the first kernel-eligible width."""
    monkeypatch.setattr(kops, "ROUTER_FUSED_MIN_ROWS", 0)
    monkeypatch.setattr(kops, "router_fused_pallas",
                        lambda *a, **kw: pytest.fail(
                            "kernel must not run for E <= 2"))
    rng = np.random.default_rng(11)
    for e, k in [(1, 1), (2, 1), (2, 2)]:
        x = jnp.asarray(rng.standard_normal((64, 16)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((16, e)), jnp.float32)
        out = kops.router_fused(x, w, k, renorm=True)    # oracle route
        _check_against_unfused(x, w, k, True, out)
    x = jnp.asarray(rng.standard_normal((64, 16)), jnp.float32)
    w = jnp.asarray(rng.standard_normal(
        (16, kops.ROUTER_FUSED_MIN_EXPERTS)), jnp.float32)
    with pytest.raises(pytest.fail.Exception, match="must not run"):
        kops.router_fused(x, w, 2)                       # kernel route


def test_router_fused_gradients_match_unfused(monkeypatch):
    """Router-weight gradients through the fused route (custom_vjp backward
    = the oracle chain's VJP) match the unfused chain — including under
    ``jax.checkpoint``, the combination that (a) has no Pallas autodiff
    rule and (b) materializes float0 tangents on the integer outputs,
    which the combine path's ``group_ids * cap`` multiply then rejects.
    The loss consumes gates/probs/logits AND multiplies the int ids."""
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal((256, 16)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)
    monkeypatch.setattr(kops, "ROUTER_FUSED_MIN_ROWS", 0)    # kernel route

    def fused_loss(ww):
        gates, idx, probs, logits, _r, _s = kops.router_fused(
            x, ww, 2, renorm=True)
        src = idx.astype(jnp.int32) * 4 + 1      # the float0-tangent trap
        return (gates * (src >= 0)).sum() + (probs * logits).mean()

    def unfused_loss(ww):
        probs, logits = M.router_probs(x, ww)
        gates, idx = M.topk_gates(probs, 2, True)
        src = idx.astype(jnp.int32) * 4 + 1
        return (gates * (src >= 0)).sum() + (probs * logits).mean()

    g_f = jax.grad(fused_loss)(w)
    g_u = jax.grad(unfused_loss)(w)
    np.testing.assert_allclose(np.asarray(g_f), np.asarray(g_u),
                               rtol=1e-6, atol=1e-7)
    g_r = jax.grad(jax.checkpoint(fused_loss))(w)
    np.testing.assert_allclose(np.asarray(g_r), np.asarray(g_f),
                               rtol=1e-6, atol=1e-7)


def test_router_fused_large_jitted():
    """A dispatch-sized jitted call through the wrapper's real kernel path
    (t >= ROUTER_FUSED_MIN_ROWS), against the oracle."""
    rng = np.random.default_rng(3)
    t = max(kops.ROUTER_FUSED_MIN_ROWS, 1024)
    x = jnp.asarray(rng.standard_normal((t, 32)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)
    fused = jax.jit(lambda a, b: kops.router_fused(a, b, 2, renorm=True))
    out_k = fused(x, w)
    out_r = ref.router_fused_ref(x, w, 2, renorm=True)
    for a, b in zip(out_k, out_r):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

"""Sharding rules: PartitionSpecs, grad-sync axis derivation, token split."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, get_reduced
from repro.models.transformer import init_model
from repro.sharding import comm
from repro.sharding.plan import (MeshPlan, plan_from_mesh, single_device_plan,
                                 test_plan)
from repro.sharding.specs import (batch_dim_spec, param_specs, shard_axes,
                                  sharded_axes_only)

PLAN = test_plan(n_inter=2, n_intra=2)


def _leaf_specs(name):
    cfg = get_reduced(name)
    params = jax.eval_shape(
        lambda k: init_model(k, cfg, PLAN), jax.random.PRNGKey(0))
    return cfg, params, param_specs(params, cfg, PLAN)


@pytest.mark.parametrize("arch", ["llama3-405b", "deepseek-v3-671b",
                                  "rwkv6-1.6b", "zamba2-2.7b",
                                  "qwen3-moe-30b-a3b", "musicgen-large"])
def test_specs_divide_shapes(arch):
    """Every sharded dim must be divisible by its mesh-axis product."""
    sizes = dict(PLAN.axis_sizes)
    cfg, params, specs = _leaf_specs(arch)
    flat_p = jax.tree_util.tree_leaves(params)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    for leaf, spec in zip(flat_p, flat_s):
        for dim, entry in zip(leaf.shape, tuple(spec)):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            prod = int(np.prod([sizes[a] for a in axes]))
            assert dim % prod == 0, (arch, leaf.shape, spec)


def _axis_leaves(tree):
    def is_axes(x):
        return isinstance(x, tuple) and all(isinstance(e, str) for e in x)
    return [l for l in jax.tree_util.tree_flatten(tree, is_leaf=is_axes)[0]
            if isinstance(l, tuple)]


def test_shard_axes_partition():
    """shard_axes + sharded_axes_only partition the mesh axes per leaf."""
    cfg, params, specs = _leaf_specs("llama3-405b")
    rep = _axis_leaves(shard_axes(specs, PLAN))
    shd = _axis_leaves(sharded_axes_only(specs, PLAN))
    assert len(rep) == len(shd) and rep
    for r, s in zip(rep, shd):
        assert set(r) | set(s) == {"data", "model"}
        assert not set(r) & set(s)


def test_expert_specs_shard_expert_grid():
    cfg, params, specs = _leaf_specs("deepseek-v3-671b")
    # find an expert leaf spec
    found = []
    def visit(path, spec):
        if "experts" in str(path):
            found.append(spec)
    flat, _ = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))
    for path, spec in flat:
        if any(getattr(k, "key", None) == "experts" for k in path):
            found.append(spec)
    assert found
    for spec in found:
        flat_axes = [a for e in spec if e for a in
                     (e if isinstance(e, tuple) else (e,))]
        assert "data" in flat_axes          # inter level sharded


def test_batch_dim_spec():
    plan = test_plan(4, 4)
    assert batch_dim_spec(16, plan) == "data"
    assert batch_dim_spec(1, plan) is None       # replicate tiny batches
    assert batch_dim_spec(6, plan) is None       # non-divisible -> replicate


def test_split_unsplit_roundtrip():
    x = jnp.arange(40, dtype=jnp.float32).reshape(10, 4)
    # single-device path: split pads, unsplit removes
    loc, pad = comm.split_tokens(x, None, 4)
    assert loc.shape[0] == 12 and pad == 2
    back = comm.unsplit_tokens(loc, None, 10)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


def test_plan_from_mesh_roles():
    import os
    # plan derivation is pure given axis names/sizes
    plan = MeshPlan(dp_axes=("pod", "data"), tp_axis="model",
                    ep_inter=("data",), ep_intra=("model",),
                    axis_sizes=(("pod", 2), ("data", 16), ("model", 16)))
    assert plan.dp == 32 and plan.tp == 16
    assert plan.n_inter == 16 and plan.n_intra == 16 and plan.ep == 256

"""Shared fixtures. NOTE: no XLA_FLAGS here — unit tests see ONE device;
multi-device coverage runs via subprocess scripts in tests/distributed/."""
import os
import sys

import jax
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)

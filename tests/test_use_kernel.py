"""End-to-end forward with Pallas kernels enabled (interpret mode on CPU):
the kernel path must match the jnp path within bf16 tolerance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models.transformer import forward, init_model
from repro.sharding.plan import single_device_plan

PLAN = single_device_plan()


@pytest.mark.parametrize("arch", ["rwkv6-1.6b", "qwen3-moe-30b-a3b"])
def test_forward_with_kernels_matches(arch):
    cfg = get_reduced(arch)
    params = init_model(jax.random.PRNGKey(0), cfg, PLAN)
    B, S = 2, 64
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    pos = jnp.arange(S)
    _, ref, _, _ = forward(params, toks, cfg, PLAN, positions=pos,
                           use_kernel=False)
    _, got, _, _ = forward(params, toks, cfg, PLAN, positions=pos,
                           use_kernel=True)
    a, b = np.asarray(ref, np.float32), np.asarray(got, np.float32)
    if cfg.moe is None or not cfg.moe.num_experts:
        rel = np.abs(a - b).max() / (np.abs(a).max() + 1e-9)
        assert rel < 3e-2, rel
        return
    # MoE archs: a handful of near-tied top-k router decisions legitimately
    # flip under bf16 kernel-vs-jnp differences, giving those tokens
    # discretely different (but individually valid) outputs — so assert the
    # bulk per-token error plus a cap on flipped tokens instead of a global
    # max (which is 0/1 on a single flip).
    per_tok = (np.abs(a - b).max(axis=-1).reshape(-1)
               / (np.abs(a).max() + 1e-9))
    p90 = np.percentile(per_tok, 90)
    flipped = (per_tok > 3e-2).mean()
    assert p90 < 3e-2, p90
    assert flipped < 0.05, flipped

"""Cross-backend dispatch conformance matrix.

One parametrized matrix over ``dispatch_backend`` x ``ragged_a2a`` x
``sort_impl`` — every cell a future backend or sort implementation will
land in — asserting:

* ``combine(dispatch(x))`` equivalence against the dense one-hot oracle,
  at the primitive level and through full switch/SMILE layers;
* the radix path bit-identical to the stable-argsort path on every cell
  (a stable integer sort is unique, so everything downstream must agree
  bit for bit — radix cells force the real Pallas kernel via the
  ``RADIX_MIN_ROWS`` override, not the small-input fallback);
* seeded determinism: two independent jit compilations of the same
  dispatch produce bit-identical position arrays for both sort impls;
* the edge cases only partially guarded before this suite existed —
  ``num_groups == 1`` and all-assignments-dropped inputs — on every
  backend and sort impl;
* the ``router_impl`` axis: the full matrix again with the fused Pallas
  routing megakernel (forced through the real kernel via the
  ``ROUTER_FUSED_MIN_ROWS`` override), every cell matching the dense
  oracle AND its unfused sibling bit for bit — both routers.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import MoEConfig
from repro.core import dispatch as D
from repro.core import moe as M
from repro.kernels import ops as kops
from repro.sharding.plan import single_device_plan

PLAN = single_device_plan()

BACKENDS = ("sort", "dense", "dropless")
RAGGED = (True, False)
SORT_IMPLS = ("radix", "argsort")
MATRIX = [(b, r, s) for b in BACKENDS for r in RAGGED for s in SORT_IMPLS]


@pytest.fixture
def force_radix_kernel(monkeypatch):
    """Route every radix-impl group sort through the real Pallas kernel
    (interpret mode on CPU) regardless of input size, so "radix" cells
    exercise the kernel rather than the small-input argsort fallback."""
    monkeypatch.setattr(kops, "RADIX_MIN_ROWS", 0)


def _case(t=64, k=2, groups=8, d=16, seed=0, invalid_frac=0.25):
    rng = np.random.default_rng(seed)
    A = t * k
    x = jnp.asarray(rng.standard_normal((t, d)), jnp.float32)
    gids = jnp.asarray(rng.integers(0, groups, A), jnp.int32)
    gates = jnp.asarray(rng.uniform(0.0, 1.0, A), jnp.float32)
    valid = jnp.asarray(rng.uniform(size=A) >= invalid_frac)
    return x, gids, gates, valid


def _roundtrip(backend, sort_impl, x, gids, gates, valid, groups, cap, k):
    """combine(dispatch(x)) for one matrix cell (identity expert FFN)."""
    if backend == "dropless":
        rows, _, state = D.dispatch_ragged(x, gids, gates, groups, k=k,
                                           valid=valid, sort_impl=sort_impl)
        return D.combine(rows, state), state
    buf, state = D.dispatch(x, gids, gates, groups, cap, k=k, valid=valid,
                            backend=backend, sort_impl=sort_impl)
    return D.combine(buf, state), state


# --------------------------------------------------- primitive-level matrix
@pytest.mark.parametrize("backend,sort_impl",
                         [(b, s) for b in BACKENDS for s in SORT_IMPLS])
def test_primitive_conformance(backend, sort_impl, force_radix_kernel):
    """combine(dispatch(x)) against the dense oracle at ample capacity
    (nothing drops, so every backend must reproduce the oracle), plus
    bit-identical keep masks."""
    t, k, groups = 64, 2, 8
    x, gids, gates, valid = _case(t=t, k=k, groups=groups)
    cap = t * k                                  # ample: nothing drops
    y_oracle, st_oracle = _roundtrip("dense", "argsort", x, gids, gates,
                                     valid, groups, cap, k)
    y, state = _roundtrip(backend, sort_impl, x, gids, gates, valid,
                          groups, cap, k)
    np.testing.assert_array_equal(np.asarray(st_oracle.keep),
                                  np.asarray(state.keep))
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_oracle),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("overflow", [False, True])
def test_primitive_radix_bitidentical(backend, overflow, force_radix_kernel):
    """The acceptance bar: on every cell — including capacity overflow —
    the radix path's buffers, positions, keep masks, and combined outputs
    equal the argsort path's BIT FOR BIT."""
    t, k, groups = 64, 2, 8
    x, gids, gates, valid = _case(t=t, k=k, groups=groups, seed=1)
    cap = 5 if overflow else t * k
    outs = {}
    for impl in SORT_IMPLS:
        y, state = _roundtrip(backend, impl, x, gids, gates, valid,
                              groups, cap, k)
        outs[impl] = (y, state)
    y_r, st_r = outs["radix"]
    y_a, st_a = outs["argsort"]
    np.testing.assert_array_equal(np.asarray(y_r), np.asarray(y_a))
    np.testing.assert_array_equal(np.asarray(st_r.pos), np.asarray(st_a.pos))
    np.testing.assert_array_equal(np.asarray(st_r.keep),
                                  np.asarray(st_a.keep))
    if st_r.slot_assign is not None:
        np.testing.assert_array_equal(np.asarray(st_r.slot_assign),
                                      np.asarray(st_a.slot_assign))


# ------------------------------------------------------- full-layer matrix
def _layer_cfg(router, backend, ragged, sort_impl):
    return MoEConfig(num_experts=16, top_k=2, top_g=2, d_ff_expert=32,
                     capacity_factor=8.0, router=router, grid=(4, 4),
                     renorm_gates=True, dispatch_backend=backend,
                     ragged_a2a=ragged, sort_impl=sort_impl)


@pytest.fixture(scope="module")
def layer_inputs():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(jax.random.PRNGKey(1), (48, 32))
    params = {}
    for router in ("switch", "smile"):
        cfg = _layer_cfg(router, "dense", True, "argsort")
        params[router] = M.init_moe_params(key, cfg, 32, PLAN, glu=False)
    return params, x


@pytest.fixture(scope="module")
def layer_oracle(layer_inputs):
    params, x = layer_inputs
    out = {}
    for router in ("switch", "smile"):
        cfg = _layer_cfg(router, "dense", True, "argsort")
        y, stats = M.moe_layer(params[router], x, cfg, PLAN, act="gelu")
        out[router] = (np.asarray(y), float(stats.lb_loss))
    return out


@pytest.mark.parametrize("router", ["switch", "smile"])
@pytest.mark.parametrize("backend,ragged,sort_impl", MATRIX)
def test_layer_conformance(router, backend, ragged, sort_impl,
                           layer_inputs, layer_oracle, force_radix_kernel):
    """Every (backend x ragged_a2a x sort_impl) cell of a full MoE layer —
    both routers — matches the dense oracle at ample capacity, and the
    radix cells match their argsort sibling bit for bit."""
    params, x = layer_inputs
    cfg = _layer_cfg(router, backend, ragged, sort_impl)
    y, stats = M.moe_layer(params[router], x, cfg, PLAN, act="gelu")
    y_oracle, lb_oracle = layer_oracle[router]
    np.testing.assert_allclose(np.asarray(y), y_oracle,
                               rtol=1e-5, atol=1e-6)
    assert float(stats.lb_loss) == pytest.approx(lb_oracle, rel=1e-6)
    assert float(stats.drop_frac) == 0.0
    if sort_impl == "radix":
        cfg_a = dataclasses.replace(cfg, sort_impl="argsort")
        y_a, _ = M.moe_layer(params[router], x, cfg_a, PLAN, act="gelu")
        np.testing.assert_array_equal(np.asarray(y), np.asarray(y_a))


@pytest.fixture
def force_router_fused_kernel(monkeypatch):
    """Route every fused-impl routing prologue through the real Pallas
    megakernel (interpret mode on CPU) regardless of token count, so
    "fused" cells exercise the kernel rather than the small-input oracle."""
    monkeypatch.setattr(kops, "ROUTER_FUSED_MIN_ROWS", 0)


@pytest.mark.parametrize("router", ["switch", "smile"])
@pytest.mark.parametrize("backend,ragged,sort_impl", MATRIX)
def test_layer_conformance_fused_router(router, backend, ragged, sort_impl,
                                        layer_inputs, layer_oracle,
                                        force_radix_kernel,
                                        force_router_fused_kernel):
    """The full conformance matrix again under ``router_impl="fused"``:
    every cell — both routers, all three hops between them — must match
    its unfused sibling BIT for BIT (the megakernel acceptance bar) and
    the dense oracle at ample capacity."""
    params, x = layer_inputs
    cfg = _layer_cfg(router, backend, ragged, sort_impl)
    y_u, _ = M.moe_layer(params[router], x, cfg, PLAN, act="gelu")
    y, stats = M.moe_layer(params[router], x,
                           cfg.with_options(router_impl="fused"),
                           PLAN, act="gelu")
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_u))
    y_oracle, lb_oracle = layer_oracle[router]
    np.testing.assert_allclose(np.asarray(y), y_oracle, rtol=1e-5, atol=1e-6)
    assert float(stats.lb_loss) == pytest.approx(lb_oracle, rel=1e-6)
    assert float(stats.drop_frac) == 0.0


# ------------------------------------------------------ seeded determinism
@pytest.mark.parametrize("sort_impl", SORT_IMPLS)
def test_dispatch_determinism_across_recompiles(sort_impl,
                                                force_radix_kernel):
    """Two independent jit compilations of the same dispatch on identical
    inputs produce bit-identical position arrays (the scatter targets have
    no compilation-order freedom: every index is unique)."""
    t, k, groups, cap = 128, 2, 16, 12
    x, gids, gates, valid = _case(t=t, k=k, groups=groups, seed=7)

    def make_jit():
        # a fresh lambda defeats jax's function-identity jit cache, forcing
        # an independent trace + compile
        return jax.jit(lambda xx, gg, ww, vv: D.dispatch(
            xx, gg, ww, groups, cap, k=k, valid=vv, backend="sort",
            sort_impl=sort_impl))

    buf1, st1 = make_jit()(x, gids, gates, valid)
    buf2, st2 = make_jit()(x, gids, gates, valid)
    np.testing.assert_array_equal(np.asarray(st1.pos), np.asarray(st2.pos))
    np.testing.assert_array_equal(np.asarray(st1.keep), np.asarray(st2.keep))
    np.testing.assert_array_equal(np.asarray(st1.slot_assign),
                                  np.asarray(st2.slot_assign))
    np.testing.assert_array_equal(np.asarray(buf1), np.asarray(buf2))

    def make_ragged_jit():
        return jax.jit(lambda xx, gg, ww, vv: D.dispatch_ragged(
            xx, gg, ww, groups, k=k, valid=vv, sort_impl=sort_impl))

    r1, s1, rst1 = make_ragged_jit()(x, gids, gates, valid)
    r2, s2, rst2 = make_ragged_jit()(x, gids, gates, valid)
    np.testing.assert_array_equal(np.asarray(rst1.pos), np.asarray(rst2.pos))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))


# ------------------------------------------------- edge-case regressions
@pytest.mark.parametrize("sort_impl", SORT_IMPLS)
@pytest.mark.parametrize("backend", BACKENDS)
def test_single_group_roundtrip(backend, sort_impl, force_radix_kernel):
    """num_groups == 1: the degenerate domain every key maps to."""
    t, k, d = 24, 2, 8
    x, gids, gates, valid = _case(t=t, k=k, groups=1, d=d, seed=11)
    cap = t * k
    y_oracle, _ = _roundtrip("dense", "argsort", x, gids, gates, valid,
                             1, cap, k)
    y, state = _roundtrip(backend, sort_impl, x, gids, gates, valid,
                          1, cap, k)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_oracle),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(state.keep), np.asarray(valid))


@pytest.mark.parametrize("sort_impl", SORT_IMPLS)
@pytest.mark.parametrize("backend", BACKENDS)
def test_all_assignments_dropped(backend, sort_impl, force_radix_kernel):
    """valid == all-False (every assignment dropped before dispatch): the
    buffer/layout must be empty, combine must return exact zeros, and
    flags must be zero everywhere — previously only A == 0 was covered."""
    t, k, groups, d = 16, 2, 4, 8
    x, gids, gates, _ = _case(t=t, k=k, groups=groups, d=d, seed=13)
    valid = jnp.zeros((t * k,), bool)
    y, state = _roundtrip(backend, sort_impl, x, gids, gates, valid,
                          groups, 8, k)
    assert not np.asarray(state.keep).any()
    assert not np.asarray(y).any()
    assert y.shape == (t, d)
    flags = D.dispatch_flags(jnp.ones((t * k,), jnp.float32), state)
    assert not np.asarray(flags).any()
    if backend == "dropless":
        assert not np.asarray(state.slot_assign >= 0).any()


def test_dispatch_rejects_empty_group_domain():
    """num_groups < 1 must fail loudly, not produce shape-0 garbage."""
    x = jnp.ones((4, 8))
    gids = jnp.zeros((4,), jnp.int32)
    gates = jnp.ones((4,))
    for backend in BACKENDS:
        if backend == "dropless":
            continue
        with pytest.raises(ValueError, match="num_groups"):
            D.dispatch(x, gids, gates, 0, 2, backend=backend)
    with pytest.raises(ValueError, match="num_groups"):
        D.dispatch_ragged(x, gids, gates, 0)


@pytest.mark.parametrize("sort_impl", SORT_IMPLS)
def test_compact_rows_all_invalid(sort_impl, force_radix_kernel):
    """Receiver re-compaction (the post-A2A group sort) with an all-invalid
    slab: the FFN output must be exact zeros in every slab row."""
    rng = np.random.default_rng(17)
    S, d, f, G = 32, 8, 16, 4
    rows = jnp.asarray(rng.standard_normal((S, d)), jnp.float32)
    gid = jnp.asarray(rng.integers(0, G, S), jnp.int32)
    w = {"w1": jnp.asarray(rng.standard_normal((G, d, f)), jnp.float32),
         "w2": jnp.asarray(rng.standard_normal((G, f, d)), jnp.float32)}
    out = M.experts_ffn_compact_rows(w, rows, gid, jnp.zeros((S,), bool),
                                     G, "gelu", sort_impl=sort_impl)
    assert out.shape == (S, d)
    assert not np.asarray(out).any()

"""Multi-device coverage via subprocesses (8 fake CPU devices each).

The unit-test process itself must keep ONE device (Pallas interpret-mode
kernels and smoke tests rely on it), so every shard_map test runs in a
subprocess with its own XLA_FLAGS.
"""
import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(__file__)


def _run(script: str, timeout: int = 900):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    p = subprocess.run([sys.executable, os.path.join(HERE, "distributed",
                                                     script)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    if p.returncode != 0:
        raise AssertionError(
            f"{script} failed:\nSTDOUT:\n{p.stdout[-3000:]}\n"
            f"STDERR:\n{p.stderr[-3000:]}")
    return p.stdout


def test_ragged_all_to_all_oracle():
    out = _run("_ragged_a2a.py")
    assert "ALL RAGGED A2A OK" in out


def test_moe_layer_equivalence():
    out = _run("_moe_equiv.py")
    assert "ALL MOE EQUIV OK" in out


def test_recv_bound_factor():
    out = _run("_recv_bound.py")
    assert "ALL RECV BOUND OK" in out


def test_train_step_equivalence():
    out = _run("_train_equiv.py", timeout=1800)
    assert "ALL TRAIN EQUIV OK" in out


def test_decode_equivalence():
    out = _run("_decode_equiv.py", timeout=1800)
    assert "ALL DECODE EQUIV OK" in out


def test_zero1_equivalence():
    out = _run("_zero1_equiv.py", timeout=1800)
    assert "ZERO1 EQUIV OK" in out


def test_fault_containment():
    out = _run("_faults.py", timeout=1800)
    assert "ALL FAULT CONTAINMENT OK" in out

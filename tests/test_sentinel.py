"""Step-sentinel tests: verdicts, the guarded apply, and end-to-end
containment — a NaN-poisoned MoE layer (fault_plan=nanrows) must leave
params and optimizer state BIT-unchanged through a sentinel step, while
the sentinel-off and healthy-sentinel paths keep training normally."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import sentinel as S

# ---------------------------------------------------------------- unit level


def test_step_verdict_flags():
    sent = S.init_sentinel_state()
    g = {"w": jnp.ones((4,)), "b": jnp.zeros((2,))}
    ok, nf, sp = S.step_verdict(jnp.float32(1.0), g, sent, ())
    assert bool(ok) and not bool(nf) and not bool(sp)
    ok, nf, _ = S.step_verdict(jnp.float32(np.nan), g, sent, ())
    assert not bool(ok) and bool(nf)
    bad = {"w": jnp.ones((4,)).at[2].set(np.inf), "b": jnp.zeros((2,))}
    ok, nf, _ = S.step_verdict(jnp.float32(1.0), bad, sent, ())
    assert not bool(ok) and bool(nf)
    # int leaves (e.g. step counters riding a tree) never trip the check
    ok, _, _ = S.step_verdict(jnp.float32(1.0),
                              {"n": jnp.int32(7)}, sent, ())
    assert bool(ok)


def test_spike_detector_arms_after_warmup():
    sent = S.init_sentinel_state()
    g = {"w": jnp.ones((2,))}
    # before warmup: a huge loss is NOT a spike (no baseline yet)
    ok, _, sp = S.step_verdict(jnp.float32(1e9), g, sent, ())
    assert bool(ok) and not bool(sp)
    for _ in range(S.WARMUP_STEPS):
        ok, nf, sp = S.step_verdict(jnp.float32(2.0), g, sent, ())
        sent = S.update_sentinel(sent, jnp.float32(2.0), ok, nf, sp,
                                 jnp.bool_(False))
    assert float(sent.loss_ema) == pytest.approx(2.0)
    ok, nf, sp = S.step_verdict(jnp.float32(2.0 * S.SPIKE_FACTOR + 1.0),
                                g, sent, ())
    assert not bool(ok) and bool(sp) and not bool(nf)
    # the rejected spike must not raise its own baseline
    sent2 = S.update_sentinel(sent, jnp.float32(1e6), ok, nf, sp,
                              jnp.bool_(False))
    assert float(sent2.loss_ema) == float(sent.loss_ema)
    assert float(sent2.skipped) == 1.0 and float(sent2.spikes) == 1.0


def test_router_alarm_thresholds():
    t = jnp.float32
    assert bool(S.router_alarm(t(0.95), t(0.8)))     # load concentration
    assert bool(S.router_alarm(t(0.3), t(0.01)))     # entropy collapse
    assert not bool(S.router_alarm(t(0.3), t(0.9)))  # healthy


def test_gated_update_identity_on_bad_step():
    params = {"w": jnp.arange(4.0)}
    opt_state = {"m": jnp.ones((4,))}
    grads = {"w": jnp.full((4,), 2.0)}
    upd = lambda g, o, p: ({"w": p["w"] - g["w"]}, {"m": o["m"] + 1})
    p1, o1 = S.gated_update(jnp.bool_(True), upd, grads, opt_state, params)
    np.testing.assert_array_equal(np.asarray(p1["w"]),
                                  np.arange(4.0) - 2.0)
    p0, o0 = S.gated_update(jnp.bool_(False), upd, grads, opt_state, params)
    np.testing.assert_array_equal(np.asarray(p0["w"]), np.arange(4.0))
    np.testing.assert_array_equal(np.asarray(o0["m"]), np.ones((4,)))


# ------------------------------------------------------------- end to end

@pytest.fixture(scope="module")
def tiny_setup():
    from repro.configs import get_reduced
    from repro.data.pipeline import DataPipeline
    from repro.models.transformer import init_model
    from repro.optim import make_optimizer, make_schedule
    from repro.sharding.plan import single_device_plan
    cfg = get_reduced("smile-3.7b")
    plan = single_device_plan()
    params = init_model(jax.random.PRNGKey(0), cfg, plan)
    pipe = DataPipeline(cfg, 2, 16, seed=0)
    batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
    pipe.close()
    opt = make_optimizer("lamb")
    sched = make_schedule("cosine", 3e-4, 2, 10)
    return cfg, plan, params, batch, opt, sched


def _build(cfg, plan, params, batch, opt, sched, sentinel):
    from repro.common.config import TrainConfig
    from repro.train.step import build_train_step
    tcfg = TrainConfig(global_batch_size=2, seq_len=16, steps=10,
                       optimizer="lamb", sentinel=sentinel)
    fn, _ = build_train_step(cfg, tcfg, plan, opt, sched, params, batch,
                             mesh=None, sentinel=sentinel)
    return fn


def _tree_equal(a, b):
    return all(bool((np.asarray(x) == np.asarray(y)).all())
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _fresh(tree):
    # the jitted step donates params/opt_state — every call needs its own
    return jax.tree.map(lambda x: jnp.array(np.asarray(x)), tree)


def test_sentinel_step_healthy_and_poisoned(tiny_setup):
    """Healthy step: identical params to the sentinel-off step, skip=0.
    NaN-poisoned MoE (fault_plan=nanrows): loss goes NaN, the update is
    skipped, params AND opt state are bit-unchanged, counters bump."""
    cfg, plan, params, batch, opt, sched = tiny_setup
    opt_state = opt.init(params)
    p0 = jax.tree.map(np.asarray, params)        # pre-donation snapshots
    o0 = jax.tree.map(np.asarray, opt_state)
    step_off = _build(cfg, plan, params, batch, opt, sched, sentinel=False)
    step_on = _build(cfg, plan, params, batch, opt, sched, sentinel=True)
    sent = S.init_sentinel_state()

    p_off, o_off, m_off = step_off(_fresh(p0), _fresh(o0), batch,
                                   jnp.int32(1))
    p_on, o_on, m_on, sent1 = step_on(_fresh(p0), _fresh(o0), batch,
                                      jnp.int32(1), sent)
    assert float(m_on["skip"]) == 0.0
    assert _tree_equal(p_off, p_on) and _tree_equal(o_off, o_on)
    assert float(sent1.steps) == 1.0 and float(sent1.skipped) == 0.0
    assert "fault_events" in m_off and float(m_off["fault_events"]) == 0.0

    # poison every MoE layer's receive slab -> NaN loss -> skipped update
    cfg_bad = cfg.replace(moe=cfg.moe.with_options(fault_plan="nanrows"))
    step_bad = _build(cfg_bad, plan, params, batch, opt, sched,
                      sentinel=True)
    p_b, o_b, m_b, sent2 = step_bad(_fresh(p0), _fresh(o0), batch,
                                    jnp.int32(1), sent)
    assert not np.isfinite(float(m_b["loss"]))
    assert float(m_b["skip"]) == 1.0
    assert _tree_equal(p_b, p0) and _tree_equal(o_b, o0)
    assert float(sent2.nonfinite) == 1.0 and float(sent2.skipped) == 1.0
    # the EMA ignored the poisoned step
    assert float(sent2.ema_steps) == 0.0


def test_sentinel_zero1_poisoned(tiny_setup):
    """Sentinel under ZeRO-1 (the split zero1_reduce_and_clip/zero1_apply):
    a healthy sentinel step matches the sentinel-off ZeRO-1 step exactly;
    a NaN-poisoned step leaves params AND the ZeRO-1 optimizer state
    (moments + step clock) bit-unchanged."""
    from repro.common.config import TrainConfig
    from repro.train.step import build_train_step, zero1_state
    cfg, plan, params, batch, opt, sched = tiny_setup
    tcfg = TrainConfig(global_batch_size=2, seq_len=16, steps=10,
                       optimizer="lamb", sentinel=True)
    ostate = zero1_state(params, cfg, plan)
    p0 = jax.tree.map(np.asarray, params)
    o0 = jax.tree.map(np.asarray, ostate)
    step_off, _ = build_train_step(cfg, tcfg, plan, opt, sched, params,
                                   batch, mesh=None, zero1=True)
    step_on, _ = build_train_step(cfg, tcfg, plan, opt, sched, params,
                                  batch, mesh=None, zero1=True,
                                  sentinel=True)
    sent = S.init_sentinel_state()

    p_off, o_off, m_off = step_off(_fresh(p0), _fresh(o0), batch,
                                   jnp.int32(1))
    p_on, o_on, m_on, sent1 = step_on(_fresh(p0), _fresh(o0), batch,
                                      jnp.int32(1), sent)
    assert float(m_on["skip"]) == 0.0
    assert _tree_equal(p_off, p_on) and _tree_equal(o_off, o_on)
    assert float(sent1.steps) == 1.0 and float(sent1.skipped) == 0.0

    # NaN-poisoned MoE -> NaN loss -> the gated zero1_apply never runs
    cfg_bad = cfg.replace(moe=cfg.moe.with_options(fault_plan="nanrows"))
    step_bad, _ = build_train_step(cfg_bad, tcfg, plan, opt, sched, params,
                                   batch, mesh=None, zero1=True,
                                   sentinel=True)
    p_b, o_b, m_b, sent2 = step_bad(_fresh(p0), _fresh(o0), batch,
                                    jnp.int32(1), sent)
    assert not np.isfinite(float(m_b["loss"]))
    assert float(m_b["skip"]) == 1.0
    assert _tree_equal(p_b, p0) and _tree_equal(o_b, o0)
    assert float(np.asarray(o_b.step)) == float(np.asarray(o0.step))
    assert float(sent2.nonfinite) == 1.0 and float(sent2.skipped) == 1.0

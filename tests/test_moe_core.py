"""MoE core invariants: routing, capacity, dispatch/combine, LB losses.

Includes property tests on the dispatch machinery (hypothesis when
available, deterministic replay otherwise — see _hypothesis_compat) and the
paper's Eq. 4 minimum (loss_lb -> alpha + beta at uniform routing).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.common.config import MoEConfig
from repro.core import moe as M
from repro.core.layout import make_layout
from repro.sharding.plan import single_device_plan

PLAN = single_device_plan()


# ---------------------------------------------------------------- layout
def test_layout_exact():
    l = make_layout(256, 16, 16)
    assert l.h == 1 and l.r == 1 and l.shard_intra


def test_layout_replicated():
    l = make_layout(128, 16, 16)
    assert l.r == 2 and l.h == 1 and not l.shard_intra
    assert l.experts_per_node == 8


def test_layout_multi_expert_slot():
    l = make_layout(64, 4, 4)
    assert l.h == 4 and l.r == 1


def test_layout_invalid():
    with pytest.raises(ValueError):
        make_layout(100, 16, 16)   # 100 not divisible by 16


# ------------------------------------------------------- dispatch invariants
@settings(deadline=None, max_examples=25)
@given(t=st.integers(4, 64), groups=st.integers(1, 8),
       cap=st.integers(1, 16), seed=st.integers(0, 2**31 - 1))
def test_positions_under_capacity(t, groups, cap, seed):
    rng = np.random.default_rng(seed)
    gids = jnp.asarray(rng.integers(0, groups, t))
    pos, keep = M.positions_in_group(gids, jnp.ones(t, bool), groups, cap)
    pos, keep, gids = map(np.asarray, (pos, keep, gids))
    # kept slots are unique per group and < capacity
    for g in range(groups):
        sel = keep & (gids == g)
        assert (pos[sel] < cap).all()
        assert len(np.unique(pos[sel])) == sel.sum()
    # arrival-order drop semantics: within a group the first `cap` survive
    for g in range(groups):
        idx = np.where(gids == g)[0]
        assert keep[idx[:cap]].all()
        assert not keep[idx[cap:]].any()


@settings(deadline=None, max_examples=20)
@given(t=st.integers(4, 32), groups=st.integers(1, 4),
       cap=st.integers(4, 8), d=st.integers(4, 16),
       seed=st.integers(0, 2**31 - 1))
def test_scatter_gather_roundtrip(t, groups, cap, d, seed):
    """With ample capacity, combine(dispatch(x)) with gate 1 returns x."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((t, d)), jnp.float32)
    gids = jnp.asarray(rng.integers(0, groups, t))
    cap = max(cap, t)                                 # no drops
    pos, keep = M.positions_in_group(gids, jnp.ones(t, bool), groups, cap)
    buf = M.dispatch_scatter(x, gids, pos, keep, groups, cap)
    y = M.combine_gather(buf, gids, pos, keep, jnp.ones(t), t, 1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), rtol=1e-6)


def test_token_conservation():
    """Every surviving token appears in the buffer exactly once."""
    t, groups, cap, d = 32, 4, 4, 8
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((t, d)), jnp.float32)
    gids = jnp.asarray(rng.integers(0, groups, t))
    pos, keep = M.positions_in_group(gids, jnp.ones(t, bool), groups, cap)
    buf = M.dispatch_scatter(x, gids, pos, keep, groups, cap)
    # sum of buffer equals sum of kept tokens
    kept_sum = np.asarray((x * np.asarray(keep)[:, None]).sum(0))
    np.testing.assert_allclose(np.asarray(buf.sum((0, 1))), kept_sum,
                               rtol=1e-5)


# ------------------------------------------------------------- LB losses
def test_lb_loss_minimum_uniform():
    """Paper: min loss_lb = alpha + beta at uniform routing (Eq. 4)."""
    n = 8
    f = jnp.full((n,), 1.0 / n)
    p = jnp.full((n,), 1.0 / n)
    assert abs(float(M.scaled_lb_loss(f, p, 0.005)) - 0.005) < 1e-7


def test_lb_loss_penalizes_imbalance():
    n = 8
    f = jnp.zeros((n,)).at[0].set(1.0)
    p = jnp.zeros((n,)).at[0].set(1.0)
    skew = float(M.scaled_lb_loss(f, p, 0.005))
    assert skew > 0.005 * (n - 1)


# --------------------------------------------------- full layers (oracle)
@pytest.mark.parametrize("router", ["switch", "smile"])
@pytest.mark.parametrize("grid,E,k,g", [
    ((4, 4), 16, 1, 1),      # one expert per slot, top-1 (the paper)
    ((4, 4), 8, 2, 1),       # replication r=2
    ((4, 4), 32, 8, 4),      # h=2 experts per slot, bi-level top-(4x2)
    ((2, 2), 4, 4, 2),
])
def test_moe_layer_shapes_and_finiteness(router, grid, E, k, g, rng_key):
    cfg = MoEConfig(num_experts=E, top_k=k, top_g=g, d_ff_expert=64,
                    capacity_factor=8.0, router=router, grid=grid,
                    renorm_gates=(k > 1))
    params = M.init_moe_params(rng_key, cfg, 32, PLAN, glu=True)
    x = jax.random.normal(jax.random.PRNGKey(1), (96, 32))
    y, stats = M.moe_layer(params, x, cfg, PLAN, act="silu")
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(stats.drop_frac) < 0.5


@pytest.mark.parametrize("router", ["switch", "smile"])
def test_capacity_drops_under_tiny_capacity(router, rng_key):
    cfg = MoEConfig(num_experts=4, top_k=1, d_ff_expert=32,
                    capacity_factor=0.25, router=router, grid=(2, 2))
    params = M.init_moe_params(rng_key, cfg, 16, PLAN)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
    y, stats = M.moe_layer(params, x, cfg, PLAN, act="gelu")
    assert float(stats.drop_frac) > 0.0          # must drop something
    # dropped tokens produce zero rows (residual passthrough upstream)
    assert np.isfinite(np.asarray(y)).all()


def test_smile_router_param_reduction():
    """Paper §3.2.1: router params O(mn) -> O(m+n)."""
    d, n, m = 64, 8, 8
    cfg_s = MoEConfig(num_experts=n * m, top_k=1, d_ff_expert=16,
                      router="smile", grid=(n, m))
    cfg_o = MoEConfig(num_experts=n * m, top_k=1, d_ff_expert=16,
                      router="switch", grid=(n, m))
    key = jax.random.PRNGKey(0)
    p_s = M.init_moe_params(key, cfg_s, d, PLAN)
    p_o = M.init_moe_params(key, cfg_o, d, PLAN)
    n_smile = p_s["router_inter"]["w"].size + p_s["router_intra"]["w"].size
    n_switch = p_o["router"]["w"].size
    assert n_smile == d * (n + m)
    assert n_switch == d * n * m
    assert n_smile < n_switch


def test_smile_equals_switch_experts_param_count(rng_key):
    """Expert storage is identical across routers (only routing differs)."""
    cfg_s = MoEConfig(num_experts=16, top_k=1, d_ff_expert=32,
                      router="smile", grid=(4, 4))
    cfg_o = MoEConfig(num_experts=16, top_k=1, d_ff_expert=32,
                      router="switch", grid=(4, 4))
    p_s = M.init_moe_params(rng_key, cfg_s, 32, PLAN)
    p_o = M.init_moe_params(rng_key, cfg_o, 32, PLAN)
    assert p_s["experts"]["w1"].shape == p_o["experts"]["w1"].shape

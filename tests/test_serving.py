"""Continuous-batching engine + eval harness tests."""
import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models.transformer import init_model
from repro.serve.batcher import Batcher
from repro.sharding.plan import single_device_plan

PLAN = single_device_plan()


def test_batcher_completes_ragged_requests():
    cfg = get_reduced("qwen1.5-0.5b")
    params = init_model(jax.random.PRNGKey(0), cfg, PLAN)
    b = Batcher(params, cfg, PLAN, n_slots=2, cache_len=64, prompt_len=8)
    rng = np.random.default_rng(0)
    uids = []
    lens = [3, 7, 2, 5, 4]
    for n in lens:                       # 5 requests, 2 slots, ragged lengths
        uids.append(b.submit(rng.integers(8, 500, 8).astype(np.int32),
                             max_new_tokens=n))
    out = b.run()
    assert sorted(out) == sorted(uids)
    for uid, n in zip(uids, lens):
        assert len(out[uid]) == n
        assert all(0 <= t < cfg.vocab_size for t in out[uid])
    # continuous batching: total ticks far below run-to-completion batching
    assert b.ticks <= sum(lens)


def test_batcher_matches_plain_decode():
    """A single request through the batcher == direct prefill+decode."""
    from repro.models.transformer import init_caches
    from repro.serve.decode import build_decode_step, build_prefill
    import jax.numpy as jnp

    cfg = get_reduced("qwen1.5-0.5b")
    params = init_model(jax.random.PRNGKey(0), cfg, PLAN)
    rng = np.random.default_rng(1)
    prompt = rng.integers(8, 500, 8).astype(np.int32)

    b = Batcher(params, cfg, PLAN, n_slots=2, cache_len=64, prompt_len=8)
    uid = b.submit(prompt, max_new_tokens=5)
    got = b.run()[uid]

    caches = init_caches(cfg, 1, 64, PLAN)
    pf = build_prefill(cfg, PLAN, params, jnp.asarray(prompt)[None], caches)
    tok, caches = pf(params, jnp.asarray(prompt)[None], caches)
    dc = build_decode_step(cfg, PLAN, params, tok, caches)
    want = [int(np.asarray(tok)[0])]
    for i in range(4):
        tok, caches = dc(params, tok, caches, jnp.int32(8 + i))
        want.append(int(np.asarray(tok)[0]))
    assert got == want


def test_engine_no_starvation_and_pages_freed():
    """Many more requests than slots: every request completes (FCFS head-of-
    line admission cannot starve), and every page returns to the pool."""
    from repro.serve.engine import Engine
    cfg = get_reduced("qwen1.5-0.5b")
    params = init_model(jax.random.PRNGKey(0), cfg, PLAN)
    rng = np.random.default_rng(6)
    eng = Engine(params, cfg, PLAN, cache_len=32, page_size=4, n_slots=2)
    uids = [eng.submit(rng.integers(8, 500, int(rng.integers(2, 10)))
                       .astype(np.int32), int(rng.integers(1, 5)))
            for _ in range(9)]
    out = eng.run()
    assert sorted(out) == sorted(uids)
    assert eng.alloc.n_free == eng.alloc.pool_pages
    assert not eng.busy and all(r is None for r in eng.slot_req)
    m = eng.metrics()
    assert m["completed"] == 9 and 0.0 < m["page_occupancy_max"] <= 1.0


def test_engine_deterministic_seeded_trace():
    """Two engines fed the identical request trace produce identical tokens
    in the identical number of ticks (the scheduler has no hidden state)."""
    from repro.serve.engine import Engine
    cfg = get_reduced("qwen1.5-0.5b")
    params = init_model(jax.random.PRNGKey(0), cfg, PLAN)

    def trace(eng):
        rng = np.random.default_rng(7)
        for _ in range(5):
            eng.submit(rng.integers(8, 500, int(rng.integers(3, 9)))
                       .astype(np.int32), int(rng.integers(2, 6)))
        return eng.run(), eng.ticks

    a = Engine(params, cfg, PLAN, cache_len=32, page_size=4, n_slots=2)
    b = Engine(params, cfg, PLAN, cache_len=32, page_size=4, n_slots=2)
    out_a, ticks_a = trace(a)
    out_b, ticks_b = trace(b)
    assert out_a == out_b and ticks_a == ticks_b


def test_engine_sjf_admits_shortest_first():
    from repro.serve.engine import Engine
    cfg = get_reduced("qwen1.5-0.5b")
    params = init_model(jax.random.PRNGKey(0), cfg, PLAN)
    rng = np.random.default_rng(8)
    eng = Engine(params, cfg, PLAN, cache_len=32, page_size=4, n_slots=1,
                 admit_policy="sjf")
    long = eng.submit(rng.integers(8, 500, 12).astype(np.int32), 2)
    short = eng.submit(rng.integers(8, 500, 3).astype(np.int32), 2)
    first_done = None
    while eng.busy:
        eng.step()
        if eng.finished and first_done is None:
            first_done = next(iter(eng.finished))
    assert first_done == short and long in eng.finished


def test_engine_rejects_recurrent_state_archs():
    from repro.serve.engine import Engine
    cfg = get_reduced("rwkv6-1.6b")
    params = init_model(jax.random.PRNGKey(0), cfg, PLAN)
    with pytest.raises(ValueError, match="ROADMAP"):
        Engine(params, cfg, PLAN)


def test_batcher_shim_deprecation():
    import warnings
    cfg = get_reduced("qwen1.5-0.5b")
    params = init_model(jax.random.PRNGKey(0), cfg, PLAN)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        Batcher(params, cfg, PLAN, n_slots=2, cache_len=64, prompt_len=8)
    assert any(issubclass(x.category, DeprecationWarning) for x in w)


def test_evaluate_harness():
    from repro.train.evaluate import evaluate
    cfg = get_reduced("qwen1.5-0.5b")
    params = init_model(jax.random.PRNGKey(0), cfg, PLAN)
    ev = evaluate(params, cfg, PLAN, batch=4, seq=32, n_batches=2)
    assert ev["eval_ce"] > 0 and np.isfinite(ev["eval_ce"])
    assert ev["eval_tokens"] > 0

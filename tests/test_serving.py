"""Continuous-batching engine + eval harness tests."""
import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models.transformer import init_model
from repro.serve.batcher import Batcher
from repro.sharding.plan import single_device_plan

PLAN = single_device_plan()


def test_batcher_completes_ragged_requests():
    cfg = get_reduced("qwen1.5-0.5b")
    params = init_model(jax.random.PRNGKey(0), cfg, PLAN)
    b = Batcher(params, cfg, PLAN, n_slots=2, cache_len=64, prompt_len=8)
    rng = np.random.default_rng(0)
    uids = []
    lens = [3, 7, 2, 5, 4]
    for n in lens:                       # 5 requests, 2 slots, ragged lengths
        uids.append(b.submit(rng.integers(8, 500, 8).astype(np.int32),
                             max_new_tokens=n))
    out = b.run()
    assert sorted(out) == sorted(uids)
    for uid, n in zip(uids, lens):
        assert len(out[uid]) == n
        assert all(0 <= t < cfg.vocab_size for t in out[uid])
    # continuous batching: total ticks far below run-to-completion batching
    assert b.ticks <= sum(lens)


def test_batcher_matches_plain_decode():
    """A single request through the batcher == direct prefill+decode."""
    from repro.models.transformer import init_caches
    from repro.serve.decode import build_decode_step, build_prefill
    import jax.numpy as jnp

    cfg = get_reduced("qwen1.5-0.5b")
    params = init_model(jax.random.PRNGKey(0), cfg, PLAN)
    rng = np.random.default_rng(1)
    prompt = rng.integers(8, 500, 8).astype(np.int32)

    b = Batcher(params, cfg, PLAN, n_slots=2, cache_len=64, prompt_len=8)
    uid = b.submit(prompt, max_new_tokens=5)
    got = b.run()[uid]

    caches = init_caches(cfg, 1, 64, PLAN)
    pf = build_prefill(cfg, PLAN, params, jnp.asarray(prompt)[None], caches)
    tok, caches = pf(params, jnp.asarray(prompt)[None], caches)
    dc = build_decode_step(cfg, PLAN, params, tok, caches)
    want = [int(np.asarray(tok)[0])]
    for i in range(4):
        tok, caches = dc(params, tok, caches, jnp.int32(8 + i))
        want.append(int(np.asarray(tok)[0]))
    assert got == want


def test_evaluate_harness():
    from repro.train.evaluate import evaluate
    cfg = get_reduced("qwen1.5-0.5b")
    params = init_model(jax.random.PRNGKey(0), cfg, PLAN)
    ev = evaluate(params, cfg, PLAN, batch=4, seq=32, n_batches=2)
    assert ev["eval_ce"] > 0 and np.isfinite(ev["eval_ce"])
    assert ev["eval_tokens"] > 0

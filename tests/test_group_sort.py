"""The group-sort primitive under every dispatch hop.

``repro.kernels.ops.group_sort`` (and its two implementations — the
one-pass Pallas counting sort ``group_sort_pallas`` and the packed-argsort
oracle ``ref.group_sort_ref``) must be a *stable* sort: property tests
assert permutation validity, stability (equal keys preserve arrival
order), bit-identical agreement with ``jnp.argsort(..., stable=True)``,
and exact prefix counts, across adversarial key distributions —
all-one-group, empty groups, A == 0, E == 1, non-power-of-two A, and
pathological tile boundaries.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops as kops
from repro.kernels import ref
from repro.kernels.radix_sort import group_sort_pallas

# named adversarial key distributions, indexed by a drawn integer so the
# offline hypothesis fallback (integers/floats only) can select them too
_DISTRIBUTIONS = ("uniform", "one_group", "two_ends", "sorted", "reversed",
                  "skewed")


def _make_keys(rng, dist: str, A: int, D: int) -> np.ndarray:
    if dist == "uniform":
        return rng.integers(0, D, A)
    if dist == "one_group":                     # all keys equal: pure stability
        return np.full(A, int(rng.integers(0, D)))
    if dist == "two_ends":                      # empty groups in the middle
        return np.where(rng.uniform(size=A) < 0.5, 0, D - 1)
    if dist == "sorted":
        return np.sort(rng.integers(0, D, A))
    if dist == "reversed":
        return np.sort(rng.integers(0, D, A))[::-1].copy()
    # "skewed": one hot group plus a sprinkle everywhere
    hot = int(rng.integers(0, D))
    keys = rng.integers(0, D, A)
    keys[rng.uniform(size=A) < 0.8] = hot
    return keys


def _check_group_sort(keys: np.ndarray, D: int, ranks, starts):
    """Assert the full (ranks, starts) contract against numpy oracles."""
    A = keys.shape[0]
    ranks = np.asarray(ranks)
    starts = np.asarray(starts)
    # permutation validity
    assert sorted(ranks.tolist()) == list(range(A))
    # stability + bit-identical agreement with the stable argsort: a stable
    # integer sort is unique, so the rank array is fully determined
    order = np.argsort(keys, kind="stable")
    want = np.empty(A, np.int64)
    want[order] = np.arange(A)
    np.testing.assert_array_equal(ranks, want)
    # equal keys preserve arrival order (implied by the above, asserted
    # directly so a future contract change can't silently weaken it)
    for d in np.unique(keys):
        np.testing.assert_array_equal(np.sort(ranks[keys == d]),
                                      ranks[keys == d])
    # exclusive prefix counts over the whole domain
    np.testing.assert_array_equal(
        starts, np.searchsorted(keys[order], np.arange(D + 1)))


@settings(deadline=None, max_examples=25)
@given(a=st.integers(0, 500), d=st.integers(1, 12),
       dist_i=st.integers(0, len(_DISTRIBUTIONS) - 1),
       block_i=st.integers(0, 2), seed=st.integers(0, 2**31 - 1))
def test_group_sort_property(a, d, dist_i, block_i, seed):
    """Pallas counting sort == argsort oracle == numpy stable argsort,
    bit for bit, on adversarial distributions and awkward tile splits."""
    rng = np.random.default_rng(seed)
    keys = _make_keys(rng, _DISTRIBUTIONS[dist_i], a, d)
    kj = jnp.asarray(keys, jnp.int32)
    block = (8, 32, 256)[block_i]               # incl. many-tile splits
    r_p, s_p = group_sort_pallas(kj, d, block=block, interpret=True)
    r_r, s_r = ref.group_sort_ref(kj, d)
    _check_group_sort(keys, d, r_p, s_p)
    np.testing.assert_array_equal(np.asarray(r_p), np.asarray(r_r))
    np.testing.assert_array_equal(np.asarray(s_p), np.asarray(s_r))


@pytest.mark.parametrize("a,d", [
    (0, 5),          # empty input
    (7, 1),          # single-group domain (E == 1)
    (1, 1),          # single element, single group
    (333, 4),        # non-power-of-two A spanning several tiles
    (256, 3),        # exact tile multiple
    (257, 3),        # one past a tile boundary
])
def test_group_sort_edge_shapes(a, d):
    rng = np.random.default_rng(a * 31 + d)
    keys = rng.integers(0, d, a)
    kj = jnp.asarray(keys, jnp.int32)
    for impl_out in (group_sort_pallas(kj, d, block=128, interpret=True),
                     ref.group_sort_ref(kj, d)):
        _check_group_sort(keys, d, *impl_out)


def test_group_sort_empty_groups():
    """Groups with zero keys must still get well-formed prefix entries."""
    keys = jnp.asarray([5, 5, 0, 5, 0], jnp.int32)        # groups 1-4, 6+ empty
    for ranks, starts in (group_sort_pallas(keys, 8, block=8, interpret=True),
                          ref.group_sort_ref(keys, 8)):
        _check_group_sort(np.asarray(keys), 8, ranks, starts)
        np.testing.assert_array_equal(np.asarray(starts),
                                      [0, 2, 2, 2, 2, 2, 5, 5, 5])


def test_ops_wrapper_impl_switch(monkeypatch):
    """ops.group_sort: "argsort" -> oracle; "radix" -> the Pallas kernel at
    or above RADIX_MIN_ROWS, oracle fallback below; unknown impl raises;
    both routes bit-identical."""
    rng = np.random.default_rng(0)
    keys = jnp.asarray(rng.integers(0, 9, 64), jnp.int32)
    with pytest.raises(ValueError, match="unknown sort_impl"):
        kops.group_sort(keys, 9, impl="quantum")
    r_a, s_a = kops.group_sort(keys, 9, impl="argsort")
    # below the threshold radix falls back to the oracle
    r_f, s_f = kops.group_sort(keys, 9, impl="radix")
    np.testing.assert_array_equal(np.asarray(r_a), np.asarray(r_f))
    # force the kernel on the same small input: still bit-identical
    monkeypatch.setattr(kops, "RADIX_MIN_ROWS", 0)
    r_k, s_k = kops.group_sort(keys, 9, impl="radix")
    np.testing.assert_array_equal(np.asarray(r_a), np.asarray(r_k))
    np.testing.assert_array_equal(np.asarray(s_a), np.asarray(s_k))


def test_group_sort_rejects_empty_domain():
    keys = jnp.zeros((4,), jnp.int32)
    for fn in (lambda: ref.group_sort_ref(keys, 0),
               lambda: group_sort_pallas(keys, 0, interpret=True)):
        with pytest.raises(ValueError, match="num_keys"):
            fn()


def test_group_sort_large_jitted():
    """A dispatch-sized jitted cell through the real kernel path of the ops
    wrapper (A >= RADIX_MIN_ROWS), against the oracle."""
    rng = np.random.default_rng(3)
    A, D = max(kops.RADIX_MIN_ROWS, 1024), 65
    keys = jnp.asarray(rng.integers(0, D, A), jnp.int32)
    radix = jax.jit(lambda k: kops.group_sort(k, D, impl="radix"))
    r_k, s_k = radix(keys)
    r_a, s_a = ref.group_sort_ref(keys, D)
    np.testing.assert_array_equal(np.asarray(r_k), np.asarray(r_a))
    np.testing.assert_array_equal(np.asarray(s_k), np.asarray(s_a))

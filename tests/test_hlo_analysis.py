"""Loop-aware HLO walker: hand-checked counts on synthetic modules."""
import textwrap

from repro.launch.hlo_analysis import (analyze_hlo, collective_summary,
                                       split_computations)

SYNTHETIC = textwrap.dedent("""\
    HloModule test

    %body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
      %p = (s32[], f32[8,16]{1,0}) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
      %w = f32[16,16]{1,0} constant({...})
      %d = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[8,16]{1,0} all-reduce(%d), replica_groups={{0,1,2,3}}, to_apply=%sum
      %one = s32[] constant(1)
      %i2 = s32[] add(%i, %one)
      ROOT %t = (s32[], f32[8,16]{1,0}) tuple(%i2, %ar)
    }

    %cond (p2: (s32[], f32[8,16])) -> pred[] {
      %p2 = (s32[], f32[8,16]{1,0}) parameter(0)
      %i3 = s32[] get-tuple-element(%p2), index=0
      %n = s32[] constant(12)
      ROOT %lt = pred[] compare(%i3, %n), direction=LT
    }

    ENTRY %main (a: f32[8,16]) -> f32[8,16] {
      %a = f32[8,16]{1,0} parameter(0)
      %z = s32[] constant(0)
      %tup = (s32[], f32[8,16]{1,0}) tuple(%z, %a)
      %wh = (s32[], f32[8,16]{1,0}) while(%tup), condition=%cond, body=%body
      ROOT %out = f32[8,16]{1,0} get-tuple-element(%wh), index=1
    }
""")


def test_walker_counts_loop_iterations():
    costs = analyze_hlo(SYNTHETIC, total_devices=4, multi_pod=False)
    # dot: 2*8*16*16 flops x 12 trips
    assert costs.dot_flops == 12 * 2 * 8 * 16 * 16
    cs = collective_summary(costs)
    assert cs["n_collectives"] == 12
    # all-reduce bytes: 8*16*4 per trip
    assert cs["bytes_per_op"]["all-reduce"] == 12 * 8 * 16 * 4


def test_walker_group_and_bw_model():
    costs = analyze_hlo(SYNTHETIC, total_devices=4, multi_pod=False)
    c = costs.collectives[0]
    assert c["group"] == 4 and not c["dcn"]
    cs = collective_summary(costs, ici_bw=50e9)
    want = 12 * 2 * (8 * 16 * 4) * (3 / 4) / 50e9
    assert abs(cs["ici_seconds"] - want) / want < 1e-9


def test_split_computations():
    comps = split_computations(SYNTHETIC)
    assert "__entry__" in comps and "%body" in comps and "%cond" in comps


RAGGED = textwrap.dedent("""\
    HloModule ragged

    ENTRY %main (rows: f32[512,32]) -> f32[512,32] {
      %rows = f32[512,32]{1,0} parameter(0)
      %out = f32[512,32]{1,0} broadcast(), dimensions={}
      %so = s32[8]{0} constant({0,0,0,0,0,0,0,0})
      %ss = s32[8]{0} constant({64,64,64,64,64,64,64,64})
      ROOT %r = f32[512,32]{1,0} ragged-all-to-all(%rows, %out, %so, %ss, %so, %ss), replica_groups={{0,1,2,3,4,5,6,7}}
    }
""")


def test_ragged_all_to_all_classified():
    """The native ragged A2A op must count as a collective, not free ops.

    Before the fix, ``ragged-all-to-all`` was absent from COLLECTIVE_OPS,
    so native-op runs under-reported collective bytes/wire-seconds.
    """
    costs = analyze_hlo(RAGGED, total_devices=8, multi_pod=False)
    cs = collective_summary(costs)
    assert cs["n_collectives"] == 1
    assert cs["bytes_per_op"]["ragged-all-to-all"] == 512 * 32 * 4
    # group of 8 -> (g-1)/g factor, same class as all-to-all
    want = (512 * 32 * 4) * (7 / 8) / 50e9
    assert abs(cs["seconds_per_op"]["ragged-all-to-all"] - want) / want < 1e-9
    assert cs["total_seconds"] > 0


def test_real_module_nonzero():
    """A tiny real jit'd scan must produce loop-multiplied dot flops."""
    import jax
    import jax.numpy as jnp

    def body(c, _):
        return c @ c, None

    def f(x):
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y.sum()

    hlo = jax.jit(f).lower(
        jax.ShapeDtypeStruct((32, 32), jnp.float32)).compile().as_text()
    costs = analyze_hlo(hlo, 1, False)
    assert costs.dot_flops == 7 * 2 * 32 * 32 * 32

"""Per-architecture smoke tests (deliverable f).

For each assigned architecture: instantiate the REDUCED variant (2 layers,
d_model <= 512, <= 4 experts) and run one forward + one real train step on
CPU, asserting output shapes and finiteness.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import TrainConfig
from repro.configs import ASSIGNED, get_reduced
from repro.data.pipeline import make_batch
from repro.models.transformer import forward, init_model
from repro.optim import make_optimizer, make_schedule
from repro.sharding.plan import single_device_plan
from repro.train.step import build_train_step

PLAN = single_device_plan()
B, S = 2, 64


def _batch(cfg):
    b = make_batch(cfg, B, S, seed=0, step=0)
    return {k: jnp.asarray(v) for k, v in b.items()}


@pytest.mark.parametrize("arch", ASSIGNED + ["smile-3.7b", "switch-3.7b",
                                             "bert-110m"])
def test_forward_smoke(arch, rng_key):
    cfg = get_reduced(arch)
    assert cfg.num_layers <= 4 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    params = init_model(rng_key, cfg, PLAN)
    batch = _batch(cfg)
    extra = {k: batch[k] for k in ("image_embeds", "image_pos") if k in batch}
    _, logits, stats, _ = forward(params, batch["tokens"], cfg, PLAN,
                                  positions=jnp.arange(S), extra=extra or None)
    if cfg.num_codebooks > 1:
        assert logits.shape == (B, S, cfg.num_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(float(stats.lb_loss))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_train_step_smoke(arch, rng_key):
    cfg = get_reduced(arch).replace(remat=False)
    params = init_model(rng_key, cfg, PLAN)
    batch = _batch(cfg)
    tcfg = TrainConfig(global_batch_size=B, seq_len=S, optimizer="adamw",
                       lr=1e-3, warmup_steps=1)
    opt = make_optimizer("adamw")
    sched = make_schedule("constant", 1e-3, 1, 10)
    step, _ = build_train_step(cfg, tcfg, PLAN, opt, sched, params, batch)
    p2, s2, m = step(params, opt.init(params), batch, jnp.int32(1))
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"]))
    # params must actually change
    l0 = jax.tree.leaves(p2)[0]
    assert l0.dtype == jnp.float32

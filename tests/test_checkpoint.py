"""Hardened checkpoint/resume tests.

Unit level: real :class:`CheckpointError` diagnostics (missing keys with
near-match hints, shape mismatch, unreadable files), the ``x/`` extras
namespace, keep-last-K rotation with a checksummed manifest, and the
corrupt-newest -> previous-good fallback walk.

End to end (via ``launch.train.train``): a run halted at step N and
resumed must be BIT-identical to the uninterrupted run — including when
the newest snapshot is corrupted and resume falls back one snapshot
(the deterministic data stream replays the lost step exactly).
"""
import os
import shutil

import jax
import numpy as np
import pytest

from repro.train.checkpoint import (CheckpointError, CheckpointManager,
                                    load_checkpoint, save_checkpoint)

# ---------------------------------------------------------------- unit level


def _params():
    return {"layer": {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
                      "b": np.zeros(3, np.float32)},
            "head": np.full((4,), 2.5, np.float32)}


def test_roundtrip_with_extras(tmp_path):
    path = str(tmp_path / "c.npz")
    params = _params()
    opt = {"m": np.ones((2, 3), np.float32)}
    extra = {"ema": np.float32(1.5), "n": np.float32(3.0)}
    save_checkpoint(path, params, opt, step=7, extra=extra)
    p, o, step, x = load_checkpoint(path, params, opt, extra_like=extra)
    assert step == 7
    for got, want in ((p, params), (o, opt), (x, extra)):
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # 3-tuple form without extras
    p, o, step = load_checkpoint(path, params, opt)
    assert step == 7 and o is not None


def test_missing_key_reports_near_match(tmp_path):
    path = str(tmp_path / "c.npz")
    save_checkpoint(path, _params())
    like = {"layer": {"w_new": np.zeros((2, 3), np.float32)}}
    with pytest.raises(CheckpointError, match="nearest stored keys"):
        load_checkpoint(path, like)
    with pytest.raises(CheckpointError, match="p/layer/w_new"):
        load_checkpoint(path, like)


def test_shape_mismatch(tmp_path):
    path = str(tmp_path / "c.npz")
    save_checkpoint(path, _params())
    like = _params()
    like["head"] = np.zeros((5,), np.float32)
    with pytest.raises(CheckpointError, match=r"stored shape \(4,\)"):
        load_checkpoint(path, like)


def test_unreadable_and_foreign_files(tmp_path):
    junk = tmp_path / "junk.npz"
    junk.write_bytes(b"this is not a zip archive")
    with pytest.raises(CheckpointError, match="cannot read"):
        load_checkpoint(str(junk), _params())
    # a valid npz that save_checkpoint did not produce
    foreign = str(tmp_path / "foreign.npz")
    np.savez(foreign, a=np.zeros(3))
    with pytest.raises(CheckpointError, match="__step__"):
        load_checkpoint(foreign, _params())


def test_manager_rotation_and_manifest(tmp_path):
    d = str(tmp_path / "run")
    mgr = CheckpointManager(d, keep=3)
    params = _params()
    for step in (1, 2, 3, 4, 5):
        p = dict(params, head=params["head"] + step)
        mgr.save(step, p)
    files = sorted(f for f in os.listdir(d) if f.endswith(".npz"))
    assert files == ["ckpt_00000003.npz", "ckpt_00000004.npz",
                     "ckpt_00000005.npz"]
    entries = mgr._read_manifest()
    assert [e["step"] for e in entries] == [3, 4, 5]
    assert all(e["sha256"] and e["bytes"] > 0 for e in entries)
    got = mgr.restore_latest(params)
    assert got is not None
    p, _, step = got
    assert step == 5
    np.testing.assert_array_equal(p["head"], params["head"] + 5)


def test_manager_corrupt_newest_falls_back(tmp_path):
    d = str(tmp_path / "run")
    mgr = CheckpointManager(d, keep=3)
    params = _params()
    for step in (1, 2, 3):
        mgr.save(step, dict(params, head=params["head"] + step))
    # truncate the newest snapshot: manifest checksum must reject it
    newest = mgr.path_for(3)
    data = open(newest, "rb").read()
    with open(newest, "wb") as f:
        f.write(data[: len(data) // 2])
    msgs = []
    got = mgr.restore_latest(params, log=msgs.append)
    assert got is not None
    p, _, step = got
    assert step == 2
    np.testing.assert_array_equal(p["head"], params["head"] + 2)
    assert any("checksum" in m for m in msgs)
    # corrupt everything -> None, not an exception
    for step in (1, 2):
        with open(mgr.path_for(step), "wb") as f:
            f.write(b"gone")
    assert mgr.restore_latest(params, log=msgs.append) is None


def test_manager_stray_without_manifest(tmp_path):
    d = str(tmp_path / "run")
    mgr = CheckpointManager(d, keep=3)
    params = _params()
    mgr.save(4, dict(params, head=params["head"] + 4))
    os.remove(mgr.manifest_path)        # hand-copied dir, no manifest
    got = CheckpointManager(d).restore_latest(params)
    assert got is not None and got[2] == 4


# ------------------------------------------------------- end to end (train)

_KW = dict(reduced=True, steps=4, batch=2, seq=16, lr=1e-3, seed=0,
           log_every=10, sentinel=True)


@pytest.fixture(scope="module")
def train_runs(tmp_path_factory):
    from repro.launch.train import train
    root = tmp_path_factory.mktemp("resume")
    p_full, _ = train("smile-3.7b", **_KW)
    halted = str(root / "halted")
    train("smile-3.7b", ckpt_dir=halted, ckpt_every=1, ckpt_keep=3,
          halt_after=2, **_KW)
    snaps = sorted(f for f in os.listdir(halted) if f.endswith(".npz"))
    assert snaps == ["ckpt_00000001.npz", "ckpt_00000002.npz"]
    return jax.tree.map(np.asarray, p_full), halted, root


def _assert_bit_identical(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_resume_is_bit_identical(train_runs, capsys):
    from repro.launch.train import train
    p_full, halted, root = train_runs
    d = str(root / "clean")
    shutil.copytree(halted, d)
    p_res, _ = train("smile-3.7b", ckpt_dir=d, ckpt_every=1, ckpt_keep=3,
                     resume=True, **_KW)
    assert "resumed from step 2" in capsys.readouterr().out
    _assert_bit_identical(p_res, p_full)


def test_resume_falls_back_past_corrupt_snapshot(train_runs, capsys):
    """Corrupt the newest snapshot: resume restores step 1 instead, the
    deterministic data stream replays step 2, and the final params are
    STILL bit-identical to the uninterrupted run."""
    from repro.launch.train import train
    p_full, halted, root = train_runs
    d = str(root / "corrupt")
    shutil.copytree(halted, d)
    victim = os.path.join(d, "ckpt_00000002.npz")
    data = open(victim, "rb").read()
    with open(victim, "wb") as f:
        f.write(data[: len(data) // 2])
    p_res, _ = train("smile-3.7b", ckpt_dir=d, ckpt_every=1, ckpt_keep=3,
                     resume=True, **_KW)
    out = capsys.readouterr().out
    assert "checksum" in out and "resumed from step 1" in out
    _assert_bit_identical(p_res, p_full)


def test_resume_requires_ckpt_dir():
    from repro.launch.train import train
    with pytest.raises(ValueError, match="ckpt-dir"):
        train("smile-3.7b", resume=True, **_KW)

"""Regenerate the MoE-layer golden fixture (``moe_layer_golden.npz``).

The fixture pins the *exact* (bit-level) outputs of the switch/SMILE layers
across the full ``dispatch_backend x ragged_a2a x sort_impl`` conformance
matrix, plus a low-capacity case that exercises the drop path.  It was first
captured from the pre-pipeline monolithic ``switch_moe``/``smile_moe``
implementations (PR 4 tree), so the pipeline refactor's golden-equivalence
test (``tests/test_pipeline_golden.py``) proves the rewrite is a pure
refactor: bit-identical outputs on every cell.

Bit-level float reproducibility only holds within one (platform, jax
version) pair — both are recorded in the fixture and the test falls back to
tight allclose when they differ from the running environment.

    PYTHONPATH=src python tests/golden/gen_golden.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import MoEConfig
from repro.core import moe as M
from repro.sharding.plan import single_device_plan

PLAN = single_device_plan()
BACKENDS = ("sort", "dense", "dropless")
RAGGED = (True, False)
SORT_IMPLS = ("argsort", "radix")

# the conformance-suite layer shape (ample capacity, nothing drops) plus a
# starved-capacity variant that pins the drop bookkeeping bit-exactly
CASES = {
    "ample": dict(capacity_factor=8.0),
    "starved": dict(capacity_factor=1.0),
}


def layer_cfg(router, backend, ragged, sort_impl, capacity_factor):
    return MoEConfig(num_experts=16, top_k=2, top_g=2, d_ff_expert=32,
                     capacity_factor=capacity_factor, router=router,
                     grid=(4, 4), renorm_gates=True,
                     dispatch_backend=backend, ragged_a2a=ragged,
                     sort_impl=sort_impl)


def main(out_path):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(jax.random.PRNGKey(1), (48, 32))
    out = {"x": np.asarray(x)}
    meta = {"jax_version": jax.__version__,
            "platform": jax.default_backend()}
    params = {}
    for router in ("switch", "smile"):
        cfg0 = layer_cfg(router, "dense", True, "argsort", 8.0)
        params[router] = M.init_moe_params(key, cfg0, 32, PLAN, glu=False)
    for router in ("switch", "smile"):
        for case, kw in CASES.items():
            for backend in BACKENDS:
                for ragged in RAGGED:
                    for simpl in SORT_IMPLS:
                        cfg = layer_cfg(router, backend, ragged, simpl, **kw)
                        y, st = M.moe_layer(params[router], x, cfg, PLAN,
                                            act="gelu")
                        tag = f"{router}|{case}|{backend}|r{int(ragged)}|{simpl}"
                        out[f"y|{tag}"] = np.asarray(y)
                        out[f"s|{tag}"] = np.asarray(
                            [float(st.lb_loss), float(st.z_loss),
                             float(st.drop_frac)], np.float64)
    np.savez_compressed(out_path, __meta__=np.asarray(
        [meta["jax_version"], meta["platform"]]), **out)
    print(f"wrote {out_path} ({len(out) - 1} arrays, "
          f"jax {meta['jax_version']} on {meta['platform']})")


if __name__ == "__main__":
    # optional argv[1]: write elsewhere (e.g. to diff a regeneration against
    # the checked-in fixture without clobbering it)
    main(sys.argv[1] if len(sys.argv) > 1 else
         os.path.join(os.path.dirname(os.path.abspath(__file__)),
                      "moe_layer_golden.npz"))

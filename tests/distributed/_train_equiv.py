"""Subprocess test: one distributed LAMB train step == single-device oracle.

Validates the manual-collective gradient assembly (partition loss + per-leaf
psum over replicated axes) across all six architecture families on a
(2 x 2) fake-device mesh. Asserts loss, grad-norm and updated-parameter
agreement. Exits non-zero on mismatch.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import TrainConfig
from repro.configs import get_reduced
from repro.data.pipeline import make_batch
from repro.models.transformer import init_model
from repro.optim import make_optimizer, make_schedule
from repro.sharding.compat import make_mesh, shard_map
from repro.sharding.plan import single_device_plan, test_plan
from repro.train.step import build_train_step

mesh = make_mesh((2, 2), ("data", "model"))
plan = test_plan(n_inter=2, n_intra=2)
oracle = single_device_plan()

ARCHS = ["smile-3.7b", "switch-3.7b", "qwen3-moe-30b-a3b", "llama3-405b",
         "rwkv6-1.6b", "zamba2-2.7b", "deepseek-v3-671b", "musicgen-large"]

# The rwkv6 KNOWN_BAD waiver is gone: the "distributed" divergence was not a
# sharding bug at all — the per-head group norm's eps=1e-5 amplified
# shape-dependent last-ulp compilation differences by ~316x wherever the
# near-empty WKV state made var ~ 0 (reproducible with NO mesh, purely by
# batch slicing).  Fixed by the head-size-scaled GN_EPS in models/rwkv6.py;
# all eight archs now assert the same thresholds.

for name in ARCHS:
    cfg = get_reduced(name).replace(remat=False)
    tcfg = TrainConfig(global_batch_size=8, seq_len=32, optimizer="lamb",
                       lr=1e-3, warmup_steps=2, grad_clip=1.0)
    params = init_model(jax.random.PRNGKey(0), cfg, oracle)
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, 8, 32, 0, 0).items()}
    opt = make_optimizer("lamb")
    sched = make_schedule("cosine", 1e-3, 2, 100)

    step1, _ = build_train_step(cfg, tcfg, oracle, opt, sched, params, batch)
    p_in = jax.tree.map(jnp.copy, params)
    p_ref, _, m_ref = step1(p_in, opt.init(params), batch, jnp.int32(1))

    step2, _ = build_train_step(cfg, tcfg, plan, opt, sched, params, batch,
                                mesh=mesh)
    p_dist, _, m_dist = step2(params, opt.init(params), batch, jnp.int32(1))

    dl = abs(float(m_ref["loss"]) - float(m_dist["loss"]))
    dg = abs(float(m_ref["grad_norm"]) - float(m_dist["grad_norm"]))
    rel_g = dg / max(float(m_ref["grad_norm"]), 1e-6)
    errs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        p_ref, p_dist)
    maxerr = max(jax.tree.leaves(errs))
    print(f"{name:20s} dloss={dl:.2e} dgnorm_rel={rel_g:.2e} "
          f"dparam={maxerr:.2e}")
    assert dl < 2e-2, (name, dl)
    assert rel_g < 6e-2, (name, rel_g)
    assert maxerr < 5e-3, (name, maxerr)
print("ALL TRAIN EQUIV OK")

"""Subprocess test: distributed ZeRO-1 LAMB step == standard LAMB oracle."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import TrainConfig
from repro.configs import get_reduced
from repro.data.pipeline import make_batch
from repro.models.transformer import init_model
from repro.optim import make_optimizer, make_schedule
from repro.sharding.compat import make_mesh, shard_map
from repro.sharding.plan import single_device_plan, test_plan
from repro.train.step import build_train_step, zero1_state

mesh = make_mesh((2, 2), ("data", "model"))
plan = test_plan(2, 2)
oracle = single_device_plan()

for name in ["llama3-405b", "qwen3-moe-30b-a3b", "deepseek-v3-671b"]:
    cfg = get_reduced(name).replace(remat=False)
    tcfg = TrainConfig(global_batch_size=8, seq_len=32, optimizer="lamb",
                       lr=1e-3, warmup_steps=2, grad_clip=1.0)
    params = init_model(jax.random.PRNGKey(0), cfg, oracle)
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, 8, 32, 0, 0).items()}
    opt = make_optimizer("lamb")
    sched = make_schedule("cosine", 1e-3, 2, 100)

    step_ref, _ = build_train_step(cfg, tcfg, oracle, opt, sched, params,
                                   batch)
    p_ref, _, m_ref = step_ref(jax.tree.map(jnp.copy, params),
                               opt.init(params), batch, jnp.int32(1))

    step_z, _ = build_train_step(cfg, tcfg, plan, opt, sched, params, batch,
                                 mesh=mesh, zero1=True)
    ostate = zero1_state(params, cfg, plan)
    p_z, _, m_z = step_z(params, ostate, batch, jnp.int32(1))

    dl = abs(float(m_ref["loss"]) - float(m_z["loss"]))
    rel_g = abs(float(m_ref["grad_norm"]) - float(m_z["grad_norm"])) / \
        max(float(m_ref["grad_norm"]), 1e-6)
    errs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        p_ref, p_z)
    maxerr = max(jax.tree.leaves(errs))
    print(f"{name:20s} dloss={dl:.2e} dgnorm_rel={rel_g:.2e} "
          f"dparam={maxerr:.2e}")
    assert dl < 2e-2 and rel_g < 6e-2 and maxerr < 5e-3, name

# --------------- sentinel under ZeRO-1 (ROADMAP follow-up, retired) ----------
# The split zero1_reduce_and_clip/zero1_apply lets sentinel.gated_update
# gate the owned-chunk apply: a healthy sentinel step is bit-identical to
# the plain ZeRO-1 step; a NaN-poisoned step leaves params and the SHARDED
# optimizer state (moment chunks + step clock) bit-unchanged.
from repro.train import sentinel as SEN

cfg = get_reduced("llama3-405b").replace(remat=False)
tcfg = TrainConfig(global_batch_size=8, seq_len=32, optimizer="lamb",
                   lr=1e-3, warmup_steps=2, grad_clip=1.0, sentinel=True)
params = init_model(jax.random.PRNGKey(0), cfg, oracle)
batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, 8, 32, 0, 0).items()}
opt = make_optimizer("lamb")
sched = make_schedule("cosine", 1e-3, 2, 100)
fresh = lambda t: jax.tree.map(lambda x: jnp.array(np.asarray(x)), t)
teq = lambda a, b: all(bool((np.asarray(x) == np.asarray(y)).all())
                       for x, y in zip(jax.tree.leaves(a),
                                       jax.tree.leaves(b)))

step_z, _ = build_train_step(cfg, tcfg, plan, opt, sched, params, batch,
                             mesh=mesh, zero1=True)
step_s, _ = build_train_step(cfg, tcfg, plan, opt, sched, params, batch,
                             mesh=mesh, zero1=True, sentinel=True)
ostate = zero1_state(params, cfg, plan)
p0 = jax.tree.map(np.asarray, params)
o0 = jax.tree.map(np.asarray, ostate)
sent = SEN.init_sentinel_state()

p_z, o_z, _ = step_z(fresh(p0), fresh(o0), batch, jnp.int32(1))
p_s, o_s, m_s, sent1 = step_s(fresh(p0), fresh(o0), batch, jnp.int32(1),
                              sent)
assert float(m_s["skip"]) == 0.0
assert teq(p_z, p_s) and teq(o_z, o_s)
print("OK zero1 sentinel healthy step bit-identical to plain zero1")

# poison the params with NaN -> NaN loss + NaN grads survive the
# reduce-scatter; the verdict is global; the gated apply never runs
def poison(x):
    x = np.asarray(x).copy()
    if np.issubdtype(x.dtype, np.floating):
        x[...] = np.nan
    return x

def beq(a, b):           # bitwise tree equality (NaN == NaN by bit pattern)
    ok = True
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        x = np.ascontiguousarray(np.asarray(x))
        y = np.ascontiguousarray(np.asarray(y))
        ok = ok and x.shape == y.shape and x.dtype == y.dtype and bool(
            (x.view(np.uint8) == y.view(np.uint8)).all())
    return ok

pb0 = jax.tree.map(poison, p0)
p_b, o_b, m_b, sent2 = step_s(fresh(pb0), fresh(o0), batch, jnp.int32(1),
                              sent)
assert not np.isfinite(float(m_b["loss"]))
assert float(m_b["skip"]) == 1.0
assert beq(p_b, pb0), "poisoned step must leave params bit-unchanged"
assert beq(o_b, o0), "poisoned step must leave sharded opt state unchanged"
assert float(np.asarray(o_b.step)) == 0.0       # step clock did not advance
assert float(sent2.nonfinite) == 1.0 and float(sent2.skipped) == 1.0
print("OK zero1 sentinel poisoned step skipped, sharded state bit-unchanged")
print("ZERO1 EQUIV OK")

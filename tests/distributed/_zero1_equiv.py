"""Subprocess test: distributed ZeRO-1 LAMB step == standard LAMB oracle."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import TrainConfig
from repro.configs import get_reduced
from repro.data.pipeline import make_batch
from repro.models.transformer import init_model
from repro.optim import make_optimizer, make_schedule
from repro.sharding.compat import make_mesh, shard_map
from repro.sharding.plan import single_device_plan, test_plan
from repro.train.step import build_train_step, zero1_state

mesh = make_mesh((2, 2), ("data", "model"))
plan = test_plan(2, 2)
oracle = single_device_plan()

for name in ["llama3-405b", "qwen3-moe-30b-a3b", "deepseek-v3-671b"]:
    cfg = get_reduced(name).replace(remat=False)
    tcfg = TrainConfig(global_batch_size=8, seq_len=32, optimizer="lamb",
                       lr=1e-3, warmup_steps=2, grad_clip=1.0)
    params = init_model(jax.random.PRNGKey(0), cfg, oracle)
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, 8, 32, 0, 0).items()}
    opt = make_optimizer("lamb")
    sched = make_schedule("cosine", 1e-3, 2, 100)

    step_ref, _ = build_train_step(cfg, tcfg, oracle, opt, sched, params,
                                   batch)
    p_ref, _, m_ref = step_ref(jax.tree.map(jnp.copy, params),
                               opt.init(params), batch, jnp.int32(1))

    step_z, _ = build_train_step(cfg, tcfg, plan, opt, sched, params, batch,
                                 mesh=mesh, zero1=True)
    ostate = zero1_state(params, cfg, plan)
    p_z, _, m_z = step_z(params, ostate, batch, jnp.int32(1))

    dl = abs(float(m_ref["loss"]) - float(m_z["loss"]))
    rel_g = abs(float(m_ref["grad_norm"]) - float(m_z["grad_norm"])) / \
        max(float(m_ref["grad_norm"]), 1e-6)
    errs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        p_ref, p_z)
    maxerr = max(jax.tree.leaves(errs))
    print(f"{name:20s} dloss={dl:.2e} dgnorm_rel={rel_g:.2e} "
          f"dparam={maxerr:.2e}")
    assert dl < 2e-2 and rel_g < 6e-2 and maxerr < 5e-3, name
print("ZERO1 EQUIV OK")

"""Subprocess test: distributed MoE layer == single-device oracle.

Covers both routers x {exact grid, h>1 slots, replication r>1, bi-level
top-(g x k_local)} on an 8-fake-device (4 x 2) mesh.

Dropless cases run BOTH wire strategies — ragged All2All (exact tile-aligned
segments over comm.ragged_all_to_all, the default) and the padded capacity
hop (ragged_a2a=False) — and assert, on non-overflowing inputs (cf=16):

* each matches the single-device oracle within the shared thresholds;
* they match each other (the ragged exchange is a pure wire-format change);
* the ragged run reports drop_frac == 0.0 exactly — no capacity buffer
  exists anywhere, at either SMILE level, so nothing can drop.

Exits non-zero on any mismatch.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.common.config import MoEConfig
from repro.core.moe import init_moe_params, moe_layer
from repro.sharding.compat import make_mesh, shard_map
from repro.sharding.plan import single_device_plan, test_plan

mesh = make_mesh((4, 2), ("data", "model"))
plan = test_plan(n_inter=4, n_intra=2)
oracle = single_device_plan()
d = 32

CASES = [((4, 2), 8, 1, 1, "sort"), ((4, 4), 16, 2, 1, "sort"),
         ((4, 4), 8, 4, 2, "sort"), ((4, 8), 8, 2, 2, "sort"),
         ((8, 4), 32, 1, 1, "sort"),
         # dropless on a real mesh: ragged A2A hops by default, padded
         # capacity hops + on-arrival re-compaction as the A/B variant
         ((4, 4), 16, 2, 1, "dropless"), ((4, 4), 8, 4, 2, "dropless"),
         ((4, 2), 8, 1, 1, "dropless"), ((4, 8), 8, 2, 2, "dropless")]


def run_dist(cfg, params, x):
    n_g, m_g = cfg.grid
    e_pn = cfg.num_experts // n_g
    shard_intra = (cfg.num_experts % (n_g * m_g) == 0) and (e_pn % 2 == 0)
    espec = P("data", "model" if shard_intra else None, None, None)
    pspecs = {"experts": {"w1": espec, "w2": espec}}
    if cfg.router == "smile":
        pspecs["router_inter"] = {"w": P(None, None)}
        pspecs["router_intra"] = {"w": P(None, None)}
    else:
        pspecs["router"] = {"w": P(None, None)}

    def f(params, x):
        y, st = moe_layer(params, x, cfg, plan, act="gelu")
        return y, st.lb_loss, st.drop_frac

    fsm = jax.jit(shard_map(
        f, mesh=mesh, in_specs=(pspecs, P(("data", "model"), None)),
        out_specs=(P(("data", "model"), None), P(), P())))
    return fsm(params, x)


for router in ["switch", "smile"]:
    for grid, E, k, g, backend in CASES:
        cfg = MoEConfig(num_experts=E, top_k=k, top_g=g, d_ff_expert=64,
                        capacity_factor=16.0, router=router, grid=grid,
                        renorm_gates=(k > 1), dispatch_backend=backend)
        params = init_moe_params(jax.random.PRNGKey(0), cfg, d, plan,
                                 glu=False)
        x = jax.random.normal(jax.random.PRNGKey(1), (64, d))
        y_ref, st_ref = moe_layer(params, x, cfg, oracle, act="gelu")

        y_dist, lb_dist, df_dist = run_dist(cfg, params, x)
        np.testing.assert_allclose(np.asarray(y_dist), np.asarray(y_ref),
                                   rtol=2e-4, atol=2e-5)
        np.testing.assert_allclose(float(lb_dist), float(st_ref.lb_loss),
                                   rtol=1e-4)
        if backend == "dropless":
            # ragged A2A: capacity-free end-to-end -> exact-zero drop stat
            # on the mesh (both SMILE levels) and on the oracle
            assert float(df_dist) == 0.0, (router, grid, float(df_dist))
            assert float(st_ref.drop_frac) == 0.0
            # padded-hop variant agrees with the ragged exchange (and the
            # oracle) on non-overflowing inputs
            cfg_p = dataclasses.replace(cfg, ragged_a2a=False)
            y_pad, _, df_pad = run_dist(cfg_p, params, x)
            np.testing.assert_allclose(np.asarray(y_pad), np.asarray(y_ref),
                                       rtol=2e-4, atol=2e-5)
            np.testing.assert_allclose(np.asarray(y_dist),
                                       np.asarray(y_pad),
                                       rtol=2e-4, atol=2e-5)
            assert float(df_pad) == 0.0, (router, grid, float(df_pad))
            # bounded receive slab at a non-clamping factor: BIT-identical
            # to the unbounded ragged run, still exactly zero drops
            # (skew-adversarial clamping is covered in _recv_bound.py)
            cfg_b = dataclasses.replace(cfg, recv_bound_factor=8.0)
            y_bnd, _, df_bnd = run_dist(cfg_b, params, x)
            np.testing.assert_array_equal(np.asarray(y_bnd),
                                          np.asarray(y_dist))
            assert float(df_bnd) == 0.0, (router, grid, float(df_bnd))
        print(f"OK {router} grid={grid} E={E} k={k} g={g} [{backend}]")
print("ALL MOE EQUIV OK")

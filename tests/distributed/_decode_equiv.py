"""Subprocess test: distributed decode step == single-device oracle decode.

Runs prefill + a few decode steps for attention / MLA / SSM / MoE archs on a
(2 x 2) mesh and compares sampled tokens with the oracle run.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.data.pipeline import synthetic_tokens
from repro.models.transformer import init_caches, init_model
from repro.serve.decode import build_decode_step, build_prefill
from repro.sharding.compat import make_mesh, shard_map
from repro.sharding.plan import single_device_plan, test_plan

mesh = make_mesh((2, 2), ("data", "model"))
plan = test_plan(n_inter=2, n_intra=2)
oracle = single_device_plan()
B, PROMPT, NEW = 4, 16, 6

for name in ["llama3-405b", "rwkv6-1.6b", "qwen3-moe-30b-a3b"]:
    cfg = get_reduced(name)
    params = init_model(jax.random.PRNGKey(0), cfg, oracle)
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(synthetic_tokens(rng, B, PROMPT, cfg.vocab_size))

    def run(pl, msh):
        caches = init_caches(cfg, B, PROMPT + NEW, pl)
        pf = build_prefill(cfg, pl, params, prompts, caches, mesh=msh)
        tok, caches = pf(params, prompts, caches)
        dc = build_decode_step(cfg, pl, params, tok, caches, mesh=msh)
        outs = [np.asarray(tok)]
        for i in range(NEW - 1):
            tok, caches = dc(params, tok, caches, jnp.int32(PROMPT + i))
            outs.append(np.asarray(tok))
        return np.stack(outs, -1)

    ref = run(oracle, None)
    dist = run(plan, mesh)
    match = (ref == dist).mean()
    print(f"{name:20s} token agreement {match:.3f}")
    assert match >= 0.85, (name, ref, dist)   # bf16 ties may flip rarely

# zamba2 (psum'd gated norm + chunked SSD) and deepseek-v3 (absorbed-MLA
# decode) reorder bf16 reductions, giving ~1-2% logit noise; near-tie argmax
# flips cascade autoregressively, so compare LOGITS of the prefill forward
# instead of sampled token ids.
from repro.models.transformer import forward  # noqa: E402
from repro.sharding.specs import param_specs  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

for noisy in ["zamba2-2.7b", "deepseek-v3-671b"]:
    cfg = get_reduced(noisy)
    params = init_model(jax.random.PRNGKey(0), cfg, oracle)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(synthetic_tokens(rng, B, PROMPT, cfg.vocab_size))
    _, ref_lg, _, _ = forward(params, toks, cfg, oracle,
                              positions=jnp.arange(PROMPT))
    pspec = param_specs(params, cfg, plan)

    def f(p, t):
        _, lg, _, _ = forward(p, t, cfg, plan, positions=jnp.arange(PROMPT))
        return lg

    fsm = jax.jit(shard_map(f, mesh=mesh,
                            in_specs=(pspec, P("data", None)),
                            out_specs=P("data", None, "model")))
    dist_lg = fsm(params, toks)
    a, b = np.asarray(ref_lg, np.float32), np.asarray(dist_lg, np.float32)
    rel = np.abs(a - b).max() / np.abs(a).max()
    print(f"{noisy:20s} logits rel err {rel:.4f}")
    assert rel < 0.05, (noisy, rel)
print("ALL DECODE EQUIV OK")

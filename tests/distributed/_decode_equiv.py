"""Subprocess test: distributed decode step == single-device oracle decode.

Runs prefill + a few decode steps for attention / MLA / SSM / MoE archs on a
(2 x 2) mesh and compares sampled tokens with the oracle run.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.data.pipeline import synthetic_tokens
from repro.models.transformer import init_caches, init_model
from repro.serve.decode import build_decode_step, build_prefill
from repro.sharding.compat import make_mesh, shard_map
from repro.sharding.plan import single_device_plan, test_plan

mesh = make_mesh((2, 2), ("data", "model"))
plan = test_plan(n_inter=2, n_intra=2)
oracle = single_device_plan()
B, PROMPT, NEW = 4, 16, 6

for name in ["llama3-405b", "rwkv6-1.6b", "qwen3-moe-30b-a3b"]:
    cfg = get_reduced(name)
    params = init_model(jax.random.PRNGKey(0), cfg, oracle)
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(synthetic_tokens(rng, B, PROMPT, cfg.vocab_size))

    def run(pl, msh):
        caches = init_caches(cfg, B, PROMPT + NEW, pl)
        pf = build_prefill(cfg, pl, params, prompts, caches, mesh=msh)
        tok, caches = pf(params, prompts, caches)
        dc = build_decode_step(cfg, pl, params, tok, caches, mesh=msh)
        outs = [np.asarray(tok)]
        for i in range(NEW - 1):
            tok, caches = dc(params, tok, caches, jnp.int32(PROMPT + i))
            outs.append(np.asarray(tok))
        return np.stack(outs, -1)

    ref = run(oracle, None)
    dist = run(plan, mesh)
    match = (ref == dist).mean()
    print(f"{name:20s} token agreement {match:.3f}")
    assert match >= 0.85, (name, ref, dist)   # bf16 ties may flip rarely

# zamba2 (psum'd gated norm + chunked SSD) and deepseek-v3 (absorbed-MLA
# decode) reorder bf16 reductions, giving ~1-2% logit noise; near-tie argmax
# flips cascade autoregressively, so compare LOGITS of the prefill forward
# instead of sampled token ids.
from repro.models.transformer import forward  # noqa: E402
from repro.sharding.specs import param_specs  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

for noisy in ["zamba2-2.7b", "deepseek-v3-671b"]:
    cfg = get_reduced(noisy)
    params = init_model(jax.random.PRNGKey(0), cfg, oracle)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(synthetic_tokens(rng, B, PROMPT, cfg.vocab_size))
    _, ref_lg, _, _ = forward(params, toks, cfg, oracle,
                              positions=jnp.arange(PROMPT))
    pspec = param_specs(params, cfg, plan)

    def f(p, t):
        _, lg, _, _ = forward(p, t, cfg, plan, positions=jnp.arange(PROMPT))
        return lg

    fsm = jax.jit(shard_map(f, mesh=mesh,
                            in_specs=(pspec, P("data", None)),
                            out_specs=P("data", None, "model")))
    dist_lg = fsm(params, toks)
    a, b = np.asarray(ref_lg, np.float32), np.asarray(dist_lg, np.float32)
    rel = np.abs(a - b).max() / np.abs(a).max()
    print(f"{noisy:20s} logits rel err {rel:.4f}")
    assert rel < 0.05, (noisy, rel)

# decode-tick MoE cell: a continuous-batching tick presents the MoE layer
# with a live-slot mask (dead slots = invalid tokens). The distributed
# masked RAGGED dispatch must match the single-device DENSE oracle given the
# same mask: dead rows combine to exactly zero everywhere, live rows agree.
from repro.core.moe import init_moe_params, moe_layer  # noqa: E402

moe_cfg = get_reduced("qwen3-moe-30b-a3b").moe.with_options(
    dispatch_backend="dropless", ragged_a2a=True)
D, TT = 32, 16
mp_params = init_moe_params(jax.random.PRNGKey(3), moe_cfg, D, plan)
xx = jnp.asarray(np.random.default_rng(4).normal(size=(TT, D)), jnp.float32)
live = jnp.asarray(np.random.default_rng(5).random(TT) < 0.6)   # dead slots

dense_cfg = moe_cfg.with_options(dispatch_backend="dense", ragged_a2a=False)
y_ref, _ = moe_layer(mp_params, xx, dense_cfg, oracle, token_valid=live)

# qwen3-moe reduced: E=4 on grid (2, 4) -> experts replicate across the
# intra axis (4 % 8 != 0), so only the inter dim is sharded
n_g, m_g = moe_cfg.grid
shard_intra = (moe_cfg.num_experts % (n_g * m_g) == 0
               and (moe_cfg.num_experts // n_g) % 2 == 0)
espec = P("data", "model" if shard_intra else None, None, None)
mspecs = {"experts": {k: espec for k in mp_params["experts"]},
          "router_inter": {"w": P(None, None)},
          "router_intra": {"w": P(None, None)}}

def moe_tick(p, x, valid):
    y, _ = moe_layer(p, x, moe_cfg, plan, token_valid=valid)
    return y

tick = jax.jit(shard_map(
    moe_tick, mesh=mesh,
    in_specs=(mspecs, P(("data", "model"), None), P(("data", "model"))),
    out_specs=P(("data", "model"), None)))
y_dist = tick(mp_params, xx, live)
a, b = np.asarray(y_ref, np.float32), np.asarray(y_dist, np.float32)
dead = ~np.asarray(live)
assert np.all(a[dead] == 0.0) and np.all(b[dead] == 0.0), \
    "dead slots must combine to exactly zero"
rel = np.abs(a - b).max() / max(np.abs(a).max(), 1e-9)
print(f"{'moe decode tick':20s} masked ragged vs dense rel err {rel:.5f}")
assert rel < 1e-4, rel
print("ALL DECODE EQUIV OK")

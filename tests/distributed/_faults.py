"""Subprocess test: the fault-injection containment matrix.

Every fault class of ``repro.common.faultinject`` x {switch, smile} on the
8-fake-device (4 x 2) mesh, dropless + ragged hops (the wire where count
grids actually travel).  For each cell the layer must end in a DEFINED
state with EXACT accounting — no crash, no hang, no wrong-expert output:

* ``counts``  — sanitizer quarantines the poisoned sources: global
  ``fault_events[hop] == n_devices * expected_count_events(...)`` exactly,
  the quarantined segments are dropped (``drop_frac > 0``) and the output
  stays finite.
* ``dropseg`` — a valid-but-silent grid: ZERO fault events, and the drop
  accounting is exact — ``hop_drop_frac[hop] == 1/P`` of that hop's ranks
  (every assignment from the victim rank, nothing else).
* ``nanrows`` — NO hop-level detection at ``wire_integrity=off`` by design
  (payloads are not checksummed): NaN reaches the layer output, zero
  events, zero drops — containment is the step sentinel's job
  (tests/test_sentinel.py).
* ``skew``   — routing collapse onto one group: the unbounded ragged hops
  absorb it with exactly zero drops while the router watchdog fields alarm
  (``hop_max_load == 1``, ``hop_load_entropy ~ 0``).
* inert plan (``counts`` aimed at a hop that doesn't exist) — the forced
  echo-reverse path on healthy counts is BIT-identical to ``fault_plan=
  None``, which itself is the golden-pinned production path.

Wire-integrity matrix (``wire_integrity = detect | quarantine``, the
per-segment parity rows of ``comm.checksummed_ragged_all_to_all``):

* healthy runs at EVERY policy are bit-identical to the production path —
  the parity rows ride the slab and are stripped before compute;
* ``nanrows``/``bitflip``/``inflate``/``dupseg`` under ``quarantine`` are
  each localized to the exact (hop, source rank): ``fault_events[hop] ==
  n_devices`` (one flagged source per receiver), ``wire_faults[hop,
  victim] == n_devices``, ``hop_drop_frac[hop] == 1/P`` (exactly the
  victim's segment at every receiver, nothing else), and the output stays
  finite — no sentinel burn;
* ``detect`` counts and localizes the same events but passes payloads
  through with exactly zero drops (the A/B policy);
* ``off`` is provably blind to ``inflate``/``dupseg``: the PR-6 sanitizer
  accepts the corrupted-but-structurally-valid grid with zero events.

Exits non-zero on any violation.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.common import faultinject as FI
from repro.common.config import MoEConfig
from repro.core.moe import init_moe_params, moe_layer
from repro.sharding.compat import make_mesh, shard_map
from repro.sharding.plan import test_plan

mesh = make_mesh((4, 2), ("data", "model"))
plan = test_plan(n_inter=4, n_intra=2)
NDEV = 8
d = 32

# hop wire parameters on this mesh for grid=(4,4), E=16 (see core/moe.py):
# switch: one flat hop over both axes; smile: inter over "data", intra
# over "model" with V2 = 4 local virtual groups
HOPS = {"switch": {0: (8, 2)},              # level -> (P, groups_per_rank)
        "smile": {0: (4, 1), 1: (2, 2)}}


def base_cfg(router):
    return MoEConfig(num_experts=16, top_k=2, top_g=2, d_ff_expert=64,
                     capacity_factor=16.0, router=router, grid=(4, 4),
                     renorm_gates=True, dispatch_backend="dropless",
                     ragged_a2a=True)


def run_dist(cfg, params, x):
    espec = P("data", "model", None, None)
    pspecs = {"experts": {"w1": espec, "w2": espec}}
    if cfg.router == "smile":
        pspecs["router_inter"] = {"w": P(None, None)}
        pspecs["router_intra"] = {"w": P(None, None)}
    else:
        pspecs["router"] = {"w": P(None, None)}

    def f(params, x):
        y, st = moe_layer(params, x, cfg, plan, act="gelu")
        return (y, st.drop_frac, st.hop_drop_frac, st.fault_events,
                st.hop_max_load, st.hop_load_entropy, st.wire_faults)

    fsm = jax.jit(shard_map(
        f, mesh=mesh, in_specs=(pspecs, P(("data", "model"), None)),
        out_specs=(P(("data", "model"), None),) + (P(),) * 6))
    return fsm(params, x)


for router in ("switch", "smile"):
    cfg = base_cfg(router)
    params = init_moe_params(jax.random.PRNGKey(0), cfg, d, plan, glu=False)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, d))
    y0, df0, hdf0, ev0, ml0, le0, wf0 = run_dist(cfg, params, x)
    assert float(df0) == 0.0 and not np.asarray(ev0).any()
    assert not np.asarray(wf0).any()
    assert not np.isnan(np.asarray(y0)).any()

    # ---- inert plan: echo-reverse machinery on healthy counts is the
    # identity, bit for bit (and zero events / zero drops)
    y_i, df_i, _, ev_i, _, _, _ = run_dist(
        cfg.with_options(fault_plan="counts@0:7"), params, x)
    np.testing.assert_array_equal(np.asarray(y_i), np.asarray(y0))
    assert float(df_i) == 0.0 and not np.asarray(ev_i).any()
    print(f"OK {router} inert-echo bit-identical")

    # ---- counts: exact sanitizer event accounting, finite output ---------
    fp = FI.parse_fault_plan("counts")
    y, df, hdf, ev, _, _, _ = run_dist(cfg.with_options(fault_plan="counts"),
                                    params, x)
    expect = np.zeros(2, np.float32)
    for lvl, (Pn, nl) in HOPS[router].items():
        expect[lvl] = NDEV * FI.expected_count_events(fp, lvl, Pn, nl)
    np.testing.assert_array_equal(np.asarray(ev), expect)
    assert not np.isnan(np.asarray(y)).any()
    assert float(df) > 0.0                 # quarantined segments dropped
    print(f"OK {router} counts events={np.asarray(ev)} drop={float(df):.3f}")

    # ---- dropseg: zero events, EXACT 1/P drop on the victim's hop --------
    for lvl, (Pn, nl) in HOPS[router].items():
        y, df, hdf, ev, _, _, _ = run_dist(
            cfg.with_options(fault_plan=f"dropseg:{lvl}"), params, x)
        assert not np.asarray(ev).any(), (router, lvl, np.asarray(ev))
        hdf = np.asarray(hdf)
        assert hdf[lvl] == np.float32(1.0 / Pn), (router, lvl, hdf, Pn)
        other = [h for i, h in enumerate(hdf) if i != lvl]
        assert not np.asarray(other).any(), (router, lvl, hdf)
        assert not np.isnan(np.asarray(y)).any()
        print(f"OK {router} dropseg:{lvl} drop={hdf[lvl]:.4f} == 1/{Pn}")

    # ---- nanrows: undetectable at hop level BY DESIGN — NaN must reach
    # the output (sentinel territory), with zero events / zero drops
    y, df, _, ev, _, _, _ = run_dist(cfg.with_options(fault_plan="nanrows"),
                                  params, x)
    assert np.isnan(np.asarray(y)).any()
    assert not np.asarray(ev).any() and float(df) == 0.0
    print(f"OK {router} nanrows propagates to sentinel")

    # ---- skew: storm absorbed with zero drops; watchdog alarms -----------
    y, df, _, ev, ml, le, _ = run_dist(cfg.with_options(fault_plan="skew"),
                                    params, x)
    assert float(df) == 0.0 and not np.asarray(ev).any()
    assert not np.isnan(np.asarray(y)).any()
    ml, le = np.asarray(ml), np.asarray(le)
    for lvl in HOPS[router]:
        assert ml[lvl] == 1.0, (router, lvl, ml)
        assert le[lvl] < 0.05, (router, lvl, le)
    print(f"OK {router} skew absorbed, watchdog max_load={ml} entropy={le}")

    # ================= wire-integrity matrix (parity-row checksums) =======
    # ---- healthy wire at every policy is bit-identical to production -----
    for pol in ("detect", "quarantine"):
        y, df, hdf, ev, _, _, wf = run_dist(
            cfg.with_options(wire_integrity=pol), params, x)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(y0))
        assert float(df) == 0.0 and not np.asarray(ev).any()
        assert not np.asarray(wf).any(), (router, pol, np.asarray(wf))
        print(f"OK {router} healthy {pol} bit-identical, zero events")

    # ---- quarantine: every wire fault class localized to the exact
    # (hop, src rank) with exact event / drop / per-rank accounting --------
    for kind in ("nanrows", "bitflip", "inflate", "dupseg"):
        for lvl, (Pn, nl) in HOPS[router].items():
            fp = FI.parse_fault_plan(f"{kind}:{lvl}")
            victim = FI.wire_fault_victim(fp, lvl, Pn, nl)
            y, df, hdf, ev, _, _, wf = run_dist(
                cfg.with_options(wire_integrity="quarantine",
                                 fault_plan=f"{kind}:{lvl}"), params, x)
            ev, hdf, wf = map(np.asarray, (ev, hdf, wf))
            # one flagged source per receiver, on the faulted hop only
            expect_ev = np.zeros(2, np.float32)
            expect_ev[lvl] = NDEV
            np.testing.assert_array_equal(ev, expect_ev)
            # localized to the EXACT source rank at every receiver
            expect_wf = np.zeros_like(wf)
            expect_wf[lvl, victim] = NDEV
            np.testing.assert_array_equal(wf, expect_wf)
            # exactly the victim's segment dropped everywhere: 1/P
            assert hdf[lvl] == np.float32(1.0 / Pn), (router, kind, lvl, hdf)
            other = [h for i, h in enumerate(hdf) if i != lvl]
            assert not np.asarray(other).any(), (router, kind, lvl, hdf)
            # degraded-mode continue: finite output, nothing for the
            # sentinel to burn the step over
            assert not np.isnan(np.asarray(y)).any(), (router, kind, lvl)
            print(f"OK {router} quarantine {kind}:{lvl} -> "
                  f"(hop {lvl}, rank {victim}) drop=1/{Pn}")

    # ---- counts x quarantine: the sanitizer and the checksum verifier must
    # not DOUBLE-count the same injected fault — a source quarantined by
    # sanitize_len_grid trivially fails its wire parity too (the receiver
    # now believes zero-length segments the sender checksummed full-length),
    # so fault_events must equal the sanitizer's exact entry count alone and
    # wire_faults must stay zero (the PR-8 known-edge, fixed + pinned here)
    fp = FI.parse_fault_plan("counts")
    y, df, hdf, ev, _, _, wf = run_dist(
        cfg.with_options(wire_integrity="quarantine", fault_plan="counts"),
        params, x)
    expect = np.zeros(2, np.float32)
    for lvl, (Pn, nl) in HOPS[router].items():
        expect[lvl] = NDEV * FI.expected_count_events(fp, lvl, Pn, nl)
    np.testing.assert_array_equal(np.asarray(ev), expect)
    assert not np.asarray(wf).any(), (router, np.asarray(wf))
    assert float(df) > 0.0                 # quarantined segments dropped
    assert not np.isnan(np.asarray(y)).any()
    print(f"OK {router} counts x quarantine deduplicated: "
          f"events={np.asarray(ev)} wire_faults all zero")

    # ---- detect: same events + localization, payloads pass through -------
    fp = FI.parse_fault_plan("bitflip:0")
    Pn, nl = HOPS[router][0]
    victim = FI.wire_fault_victim(fp, 0, Pn, nl)
    y, df, hdf, ev, _, _, wf = run_dist(
        cfg.with_options(wire_integrity="detect", fault_plan="bitflip:0"),
        params, x)
    ev, wf = np.asarray(ev), np.asarray(wf)
    assert ev[0] == NDEV and ev[1] == 0.0, (router, ev)
    assert wf[0, victim] == NDEV and wf.sum() == NDEV, (router, wf)
    assert float(df) == 0.0 and not np.asarray(hdf).any()   # A/B: no drops
    y = np.asarray(y)
    assert not np.array_equal(y, np.asarray(y0))    # corruption passes ...
    assert not np.isnan(y).any()                    # ... but stays finite
    print(f"OK {router} detect bitflip counted at (0, rank {victim}), "
          f"payload passed through")

    # ---- off: the sanitizer alone is provably blind to in-bounds grid
    # corruption — structurally valid, zero events, zero drops -------------
    for kind in ("inflate", "dupseg"):
        y, df, hdf, ev, _, _, wf = run_dist(
            cfg.with_options(fault_plan=f"{kind}:0"), params, x)
        assert not np.asarray(ev).any() and not np.asarray(wf).any()
        # inflate is FULLY silent; dupseg's misattributed rows may fail the
        # echo (a drop, never a detection) — blindness is about events
        if kind == "inflate":
            assert float(df) == 0.0, (router, kind, float(df))
        assert not np.isnan(np.asarray(y)).any(), (router, kind)
        print(f"OK {router} off {kind} zero events (sanitizer blind spot)")

print("ALL FAULT CONTAINMENT OK")

"""Subprocess test: the ragged receive-bound factor (HopSpec.recv_bound_factor).

On an 8-fake-device (4 x 2) mesh, asserts the full contract of the bounded
ragged hop implemented once at the pipeline level:

* PRIMITIVE (pipeline._ragged_forward/_ragged_reverse under all-to-one-rank
  skew): the receive slab is statically bounded at ``recv_bound_rows`` (far
  below the worst-case ``P x R``), the receiver's clamped per-source counts
  are echoed back on the reverse path (sender-observed return counts ==
  transpose of receiver-kept counts), returned rows land at their original
  layout offsets with clamp-dropped rows zero-filled, and the survived mask
  matches the echoed counts exactly.

* LAYER (switch + SMILE through the shared executor, zero per-caller code):
  under adversarial all-tokens-to-one-rank routing, every output row is
  either (numerically) identical to the unbounded run's row or exactly
  zero (clamp-dropped), and the reported ``drop_frac`` equals the zero-row
  fraction exactly (k=1: assignments == tokens).

* NO-CLAMP EQUIVALENCE: ``factor`` large enough that nothing clamps is
  BIT-identical to ``factor=None`` — switch and smile, uniform routing —
  with ``drop_frac`` exactly 0.0 (the clamp machinery degenerates to the
  zero-drop path).

Exits non-zero on any mismatch.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.common.config import MoEConfig
from repro.core import dispatch as D
from repro.core import pipeline as PL
from repro.core.moe import init_moe_params, moe_layer
from repro.sharding.compat import make_mesh, shard_map
from repro.sharding.plan import test_plan

mesh = make_mesh((4, 2), ("data", "model"))
plan = test_plan(n_inter=4, n_intra=2)
P_ = 8                                     # joint ranks over (data, model)
d = 16


# =============================================================================
# Part 1: primitive-level skew — bounded slab, echoed counts, origin offsets
# =============================================================================

def primitive_skew():
    nl = 2                                 # local groups per rank
    V = P_ * nl
    t_local = 64
    factor = 1.5
    x = jax.random.normal(jax.random.PRNGKey(0), (P_ * t_local, d))

    def f(xx):
        t = xx.shape[0]
        # adversarial: every token targets rank 0 (alternating its 2 groups)
        gid = (jnp.arange(t, dtype=jnp.int32) % nl)
        rows, starts, st = D.dispatch_ragged(xx, gid, jnp.ones((t,)), V, k=1)
        seg_lens = D.ragged_seg_lens(gid, st.keep, V)
        spec = PL.HopSpec(name="t", axes=plan.ep_axes, n_ranks=P_,
                          num_groups=V, exchange="ragged",
                          recv_bound_factor=factor)
        hs, ev, _ = PL._ragged_forward(rows, starts, seg_lens, spec, st.cap)
        # marker transform so reverse provenance is checkable
        y_slab = hs.recv * 2.0
        back, ok, _ = PL._ragged_reverse(y_slab, hs, spec)
        nz = (jnp.abs(back).sum(-1) > 0)
        return (back[None], ok[None], hs.kept[None], hs.recv_counts[None],
                rows[None], nz[None], st.pos[None],
                jnp.int32(hs.recv.shape[0])[None],
                jnp.int32(rows.shape[0])[None], jnp.int32(st.cap)[None],
                ev[None])

    fm = jax.jit(shard_map(
        f, mesh=mesh, in_specs=P(("data", "model"), None),
        out_specs=tuple(P(("data", "model")) for _ in range(11))))
    (back, ok, kept, rc, rows, nz, pos, b_rows, r_rows, blocks, ev) = map(
        np.asarray, fm(x))
    # the sanitizer must treat these (healthy, merely skewed) grids as clean
    assert not ev.any(), ev
    B, R, block = int(b_rows[0]), int(r_rows[0]), int(blocks[0])

    # static slab bound honored, and genuinely below the worst case
    assert B == PL.recv_bound_rows(1.5, R, P_, nl, block), (B, R, block)
    assert B < P_ * R, (B, P_ * R)

    # receiver-side clamp: kept counts are the prefix-clipped rc
    for r in range(P_):
        roff = np.concatenate([[0], np.cumsum(rc[r])])[:-1]
        np.testing.assert_array_equal(kept[r],
                                      np.clip(B - roff, 0, rc[r]))
    # only rank 0 receives anything (all tokens target its groups)
    assert rc[1:].sum() == 0 and kept[1:].sum() == 0
    assert kept[0].sum() == B                      # clamped slab exactly full

    # echo: sender q's surviving-row count toward receiver r == kept[r][q]
    for q in range(P_):
        srv = ok[q]
        # q's layout is rank-major: segment for rank r at send offsets
        sc = np.array([0] * P_)
        # recompute send_counts from the local layout: all rows go to rank 0
        sc[0] = R
        off = np.concatenate([[0], np.cumsum(sc)])[:-1]
        for r in range(P_):
            got_back = srv[off[r]:off[r] + sc[r]].sum()
            assert got_back == kept[r][q], (q, r, got_back, kept[r][q])

    # returned rows at origin offsets: back == 2 * rows where ok, else 0
    for q in range(P_):
        np.testing.assert_allclose(back[q][ok[q]], 2.0 * rows[q][ok[q]],
                                   rtol=0, atol=0)
        assert not np.abs(back[q][~ok[q]]).any()
    print(f"OK primitive skew: slab {B} rows vs worst-case {P_ * R} "
          f"({P_ * R / B:.1f}x smaller), echo verified")


# =============================================================================
# Part 2: full layers under skew — drop accounting through the executor
# =============================================================================

def run_layer(cfg, params, x):
    n_g, m_g = cfg.grid
    espec = P("data", "model", None, None)
    pspecs = {"experts": {"w1": espec, "w2": espec}}
    if cfg.router == "smile":
        pspecs["router_inter"] = {"w": P(None, None)}
        pspecs["router_intra"] = {"w": P(None, None)}
    else:
        pspecs["router"] = {"w": P(None, None)}

    def f(params, x):
        y, st = moe_layer(params, x, cfg, plan, act="gelu")
        return y, st.drop_frac, st.hop_drop_frac

    fsm = jax.jit(shard_map(
        f, mesh=mesh, in_specs=(pspecs, P(("data", "model"), None)),
        out_specs=(P(("data", "model"), None), P(), P())))
    y, df, hdf = fsm(params, x)
    return np.asarray(y), float(df), np.asarray(hdf)


def layer_skew(router):
    cfg = MoEConfig(num_experts=16, top_k=1, top_g=1, d_ff_expert=32,
                    router=router, grid=(4, 2), dispatch_backend="dropless",
                    ragged_a2a=True)
    params = init_moe_params(jax.random.PRNGKey(0), cfg, d, plan, glu=False)
    # adversarial router: all-positive tokens + a one-column router weight
    # make EVERY token pick expert/node 0 deterministically -> rank 0
    if router == "smile":
        w = params["router_inter"]["w"]
        params["router_inter"]["w"] = jnp.zeros_like(w).at[:, 0].set(8.0)
    else:
        w = params["router"]["w"]
        params["router"]["w"] = jnp.zeros_like(w).at[:, 0].set(8.0)
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (8 * 64, d))) + 0.1

    y_u, df_u, _ = run_layer(cfg, params, x)              # unbounded
    assert df_u == 0.0
    # at these toy sizes the ragged layout carries ~2x tile-alignment
    # headroom (R >> A), so the bound needs a tighter factor on SMILE's
    # 4-rank level-1 hop than on switch's 8-rank flat hop to actually clamp
    factor = 1.5 if router == "switch" else 0.75
    cfg_b = dataclasses.replace(cfg, recv_bound_factor=factor)
    y_b, df_b, hdf_b = run_layer(cfg_b, params, x)

    assert df_b > 0.0, (router, df_b)
    assert np.isclose(df_b, hdf_b.sum()), (df_b, hdf_b)
    # every row: clamp-dropped (exact zero) or the unbounded row
    zero = ~np.abs(y_b).sum(-1).astype(bool)
    np.testing.assert_allclose(y_b[~zero], y_u[~zero], rtol=1e-5, atol=1e-6)
    assert np.abs(y_u[zero]).sum() > 0        # they weren't zero unbounded
    # k=1, top_g=1: dropped assignments == zero-rows, so drop_frac is the
    # exact zero-row fraction (switch: one hop; smile: levels compound but
    # a level-1 drop removes the token from level 2's valid set)
    if router == "switch":
        assert np.isclose(df_b, zero.mean()), (df_b, zero.mean())
    else:
        assert hdf_b[0] > 0.0                 # level 1 clamps under this skew
    print(f"OK layer skew [{router}]: drop_frac {df_b:.3f} "
          f"({int(zero.sum())}/{len(zero)} rows clamp-dropped)")


def layer_noclamp_bitidentical(router):
    cfg = MoEConfig(num_experts=16, top_k=2, top_g=2, d_ff_expert=32,
                    capacity_factor=8.0, router=router, grid=(4, 2),
                    renorm_gates=True, dispatch_backend="dropless",
                    ragged_a2a=True)
    params = init_moe_params(jax.random.PRNGKey(0), cfg, d, plan, glu=False)
    x = jax.random.normal(jax.random.PRNGKey(1), (8 * 32, d))
    y_u, df_u, hdf_u = run_layer(cfg, params, x)
    # factor = P guarantees bound == worst case: the executor must detect
    # the non-reducing bound and take the exact factor=None path (no echo
    # exchange, native-op eligible) — bit-identical by construction
    cfg_b = dataclasses.replace(cfg, recv_bound_factor=float(P_))
    y_b, df_b, hdf_b = run_layer(cfg_b, params, x)
    np.testing.assert_array_equal(y_b, y_u)
    assert df_b == 0.0 and df_u == 0.0
    assert not hdf_b.any() and not hdf_u.any()
    print(f"OK no-clamp bit-identical [{router}]")


primitive_skew()
for router in ("switch", "smile"):
    layer_skew(router)
    layer_noclamp_bitidentical(router)
print("ALL RECV BOUND OK")

"""Subprocess test: comm.ragged_all_to_all == numpy segment-exchange oracle.

Edge-case matrix on an 8-fake-device (4 x 2) mesh, joint-axes (8-rank) and
single-axis (4-rank per model column) exchanges:

* balanced random counts;
* zero rows to some ranks (including a rank that sends nothing at all);
* ALL rows to one rank (the worst-case skew the static bound must absorb);
* reverse exchange (send_counts = forward recv_counts) restores every
  original segment at its original offset;
* truncation (``allow_truncate=True`` with a ``recv_rows`` bound below the
  worst case): both emulations prefix-truncate at the unclamped offsets
  against a numpy truncation oracle, and ``comm.clamped_segment_counts``
  — the paired clamped sizes the native ``lax.ragged_all_to_all`` path
  uses — reproduces exactly the kept-row matrix the emulations realize,
  and every rank's full ``comm.native_truncation_plan`` argument triple
  satisfies the op's cross-rank paired contract (sender ``s``'s
  ``send_sizes[d]`` == receiver ``d``'s ``recv_sizes[s]``; live segments
  at the unclamped offsets; ``out_off + send_sizes <= bound``).  The
  emulations are the semantic oracle: the installed jax predates the
  native op, so these checks are what keep the native path honest.

Exits non-zero on any mismatch.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.sharding import comm
from repro.sharding.compat import make_mesh, shard_map

mesh = make_mesh((4, 2), ("data", "model"))
R, d = 24, 5
rng = np.random.default_rng(0)


def oracle(rows, counts):
    """numpy reference: rows (P, R, d) per-rank staging, counts (P, P)
    [src, dst] -> (recv (P, P*R, d), recv_counts (P, P))."""
    P_, = {rows.shape[0], counts.shape[0], counts.shape[1]}
    recv = np.zeros((P_, P_ * R, d), rows.dtype)
    rc = counts.T.copy()                       # [dst, src]
    for dst in range(P_):
        off = 0
        for src in range(P_):
            s0 = counts[src, :dst].sum()
            n = counts[src, dst]
            recv[dst, off:off + n] = rows[src, s0:s0 + n]
            off += n
    return recv, rc


def run_exchange(rows, counts, axes, p, emulation="auto"):
    """Run the exchange under shard_map; rows (P, R, d), counts (P, p)."""
    def f(r, c):
        out, rc = comm.ragged_all_to_all(r[0], c[0], axes, recv_rows=p * R,
                                         emulation=emulation)
        return out[None], rc[None]

    fsm = jax.jit(shard_map(
        f, mesh=mesh, in_specs=(P(("data", "model")), P(("data", "model"))),
        out_specs=(P(("data", "model")), P(("data", "model")))))
    return fsm(jnp.asarray(rows), jnp.asarray(counts))


def check_joint(counts, label, emulation="auto"):
    """Joint (4x2 = 8-rank) exchange vs oracle + reverse round trip."""
    Pn = 8
    rows = np.zeros((Pn, R, d), np.float32)
    for src in range(Pn):
        n = counts[src].sum()
        assert n <= R, (label, n)
        # distinctive payload: encodes (src, position) so any misrouting
        # or mis-offset shows up as a value mismatch, not just a count one
        rows[src, :n] = (src * 1000
                         + np.arange(n)[:, None] * 10
                         + np.arange(d)[None, :])
    got, got_rc = run_exchange(rows, counts, ("data", "model"), Pn,
                               emulation)
    want, want_rc = oracle(rows, counts)
    np.testing.assert_array_equal(np.asarray(got_rc), want_rc, err_msg=label)
    np.testing.assert_array_equal(np.asarray(got), want, err_msg=label)

    # reverse hop: exchanging back with send_counts = recv_counts must land
    # every segment at its origin offsets (zero elsewhere)
    def rev(r, c):
        fwd, rc = comm.ragged_all_to_all(r[0], c[0], ("data", "model"),
                                         recv_rows=Pn * R,
                                         emulation=emulation)
        back, back_c = comm.ragged_all_to_all(fwd, rc, ("data", "model"),
                                              recv_rows=R,
                                              emulation=emulation)
        return back[None], back_c[None]

    fsm = jax.jit(shard_map(
        rev, mesh=mesh, in_specs=(P(("data", "model")), P(("data", "model"))),
        out_specs=(P(("data", "model")), P(("data", "model")))))
    back, back_c = fsm(jnp.asarray(rows), jnp.asarray(counts))
    np.testing.assert_array_equal(np.asarray(back_c), counts, err_msg=label)
    masked = rows.copy()
    for src in range(Pn):
        masked[src, counts[src].sum():] = 0.0  # staging slack returns as 0
    np.testing.assert_array_equal(np.asarray(back), masked, err_msg=label)
    print(f"OK joint {label} [{emulation}]")


# both emulation strategies must agree with the oracle: the fused
# all_to_all slab (the fast default under jax<0.4.38) and the explicit
# ppermute rotation rounds (the ring-fabric schedule)
for emu in ["a2a", "ppermute"]:
    # ---- balanced random counts ---------------------------------------------
    c = rng.integers(0, R // 8, (8, 8)).astype(np.int32)
    check_joint(c, "balanced", emu)

    # ---- zero rows to some ranks (one rank sends nothing, one starves) -----
    c = rng.integers(0, R // 8, (8, 8)).astype(np.int32)
    c[:, 3] = 0          # nobody sends to rank 3
    c[5, :] = 0          # rank 5 sends nothing
    check_joint(c, "zero-to-some", emu)

    # ---- ALL rows to one rank (worst-case skew; fills the static bound) ----
    c = np.zeros((8, 8), np.int32)
    c[:, 2] = R          # every rank ships its whole staging buffer to rank 2
    check_joint(c, "all-to-one", emu)

# ---- truncation: bounded recv_rows prefix-truncates at unclamped offsets ---
def trunc_oracle(rows, counts, bound):
    """numpy truncation reference: segments land at their UNCLAMPED
    source-major offsets; rows past ``bound`` never materialize.  Returns
    ``(recv (P, bound, d), kept (P, P) [dst, src])``."""
    P_ = rows.shape[0]
    recv = np.zeros((P_, bound, d), rows.dtype)
    kept = np.zeros((P_, P_), np.int32)
    for dst in range(P_):
        off = 0
        for src in range(P_):
            s0 = counts[src, :dst].sum()
            n = counts[src, dst]
            nk = max(0, min(n, bound - off))
            recv[dst, off:off + nk] = rows[src, s0:s0 + nk]
            kept[dst, src] = nk
            off += n
    return recv, kept


def check_truncated(counts, bound, label, emulation):
    Pn = 8
    rows = np.zeros((Pn, R, d), np.float32)
    for src in range(Pn):
        n = counts[src].sum()
        rows[src, :n] = (src * 1000 + np.arange(n)[:, None] * 10
                         + np.arange(d)[None, :])

    def f(r, c):
        out, rc = comm.ragged_all_to_all(r[0], c[0], ("data", "model"),
                                         recv_rows=bound, emulation=emulation,
                                         allow_truncate=True)
        return out[None], rc[None]

    fsm = jax.jit(shard_map(
        f, mesh=mesh, in_specs=(P(("data", "model")), P(("data", "model"))),
        out_specs=(P(("data", "model")), P(("data", "model")))))
    got, _ = fsm(jnp.asarray(rows), jnp.asarray(counts))
    want, kept = trunc_oracle(rows, counts, bound)
    np.testing.assert_array_equal(np.asarray(got), want, err_msg=label)
    # the paired clamped sizes the native lax.ragged_all_to_all path uses
    # must describe EXACTLY this truncation: kept[s, d] with row me a
    # rank's clamped send sizes and column me its clamped recv sizes
    kept_helper = np.asarray(
        comm.clamped_segment_counts(jnp.asarray(counts), bound))
    np.testing.assert_array_equal(kept_helper, kept.T, err_msg=label)
    # the full per-rank argument triples of the native path: every rank's
    # plan must satisfy lax.ragged_all_to_all's cross-rank paired contract
    # (sender s's send_sizes[d] == receiver d's recv_sizes[s]) and stay in
    # bounds — exercised numerically because no CI jax has the native op
    plans = [tuple(np.asarray(a) for a in
                   comm.native_truncation_plan(jnp.asarray(counts), r, bound))
             for r in range(Pn)]
    for s in range(Pn):
        send_sizes, out_off, recv_sizes = plans[s]
        np.testing.assert_array_equal(send_sizes, kept[:, s], err_msg=label)
        np.testing.assert_array_equal(recv_sizes, kept[s], err_msg=label)
        for dst in range(Pn):
            assert send_sizes[dst] == plans[dst][2][s], (label, s, dst)
            assert 0 <= out_off[dst], (label, s, dst)
            assert out_off[dst] + send_sizes[dst] <= bound, (label, s, dst)
            if send_sizes[dst]:     # live segments land at unclamped offsets
                assert out_off[dst] == counts[:s, dst].sum(), (label, s, dst)
    print(f"OK truncated {label} [{emulation}]")


for emu in ["a2a", "ppermute"]:
    c = rng.integers(0, R // 8, (8, 8)).astype(np.int32)
    check_truncated(c, 8, "balanced-tight", emu)      # bound below arrivals
    c = np.zeros((8, 8), np.int32)
    c[:, 2] = R                                       # rank 2 overflows hard
    check_truncated(c, 40, "all-to-one-trunc", emu)
    c = rng.integers(0, R // 8, (8, 8)).astype(np.int32)
    check_truncated(c, 8 * R, "bound-no-op", emu)     # bound == worst case

# ---- single-axis exchange: 4 ranks over "data", per model column -----------
# model column is part of the joint sharding but NOT of the exchange: the
# two columns run independent 4-rank exchanges.
Pn = 4
counts = rng.integers(0, R // 4, (8, Pn)).astype(np.int32)
rows = np.zeros((8, R, d), np.float32)
for dev in range(8):
    n = counts[dev].sum()
    rows[dev, :n] = (dev * 1000 + np.arange(n)[:, None] * 10
                     + np.arange(d)[None, :])
got, got_rc = run_exchange(rows, counts, ("data",), Pn)
# oracle per model column: device (i, j) has joint rank i*2+j, data rank i
for col in range(2):
    devs = [i * 2 + col for i in range(Pn)]
    want, want_rc = oracle(rows[devs][:, :R], counts[devs])
    np.testing.assert_array_equal(np.asarray(got_rc)[devs], want_rc)
    np.testing.assert_array_equal(np.asarray(got)[devs], want)
print("OK single-axis")

print("ALL RAGGED A2A OK")

"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret=True executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attn import flash_attention_pallas
from repro.kernels.grouped_ffn import grouped_ffn_pallas
from repro.kernels.rwkv6_scan import rwkv6_scan_pallas


@pytest.mark.parametrize("G,T,d,f", [(1, 128, 64, 128), (4, 64, 128, 256),
                                     (2, 200, 64, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("glu", [True, False])
def test_grouped_ffn_sweep(G, T, d, f, dtype, glu):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = (jax.random.normal(ks[0], (G, T, d)) * 0.5).astype(dtype)
    w1 = (jax.random.normal(ks[1], (G, d, f)) * 0.05).astype(dtype)
    w3 = (jax.random.normal(ks[2], (G, d, f)) * 0.05).astype(dtype) if glu else None
    w2 = (jax.random.normal(ks[3], (G, f, d)) * 0.05).astype(dtype)
    got = grouped_ffn_pallas(x, w1, w3, w2, act="silu", block_t=64,
                             block_f=128, interpret=True)
    want = ref.grouped_ffn_ref(x, w1, w3, w2, act="silu")
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("f,block_f", [(768, 512), (192, 128), (96, 512)])
def test_grouped_ffn_f_not_multiple_of_block(f, block_f):
    """Regression: f % block_f != 0 used to silently truncate the f axis
    (grid = f // bf dropped the tail columns entirely)."""
    G, T, d = 2, 64, 64
    ks = jax.random.split(jax.random.PRNGKey(5), 4)
    x = jax.random.normal(ks[0], (G, T, d)) * 0.5
    w1 = jax.random.normal(ks[1], (G, d, f)) * 0.05
    w3 = jax.random.normal(ks[2], (G, d, f)) * 0.05
    w2 = jax.random.normal(ks[3], (G, f, d)) * 0.05
    got = grouped_ffn_pallas(x, w1, w3, w2, act="gelu", block_t=64,
                             block_f=block_f, interpret=True)
    want = ref.grouped_ffn_ref(x, w1, w3, w2, act="gelu")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("B,T,H,hd", [(1, 128, 2, 64), (2, 256, 4, 32),
                                      (1, 512, 1, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, T, H, hd, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, T, H, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (B, T, H, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (B, T, H, hd)).astype(dtype)
    got = flash_attention_pallas(q, k, v, block_q=64, block_k=64,
                                 interpret=True)
    want = ref.flash_attention_ref(q, k, v)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("B,T,nh,hd", [(1, 64, 1, 16), (2, 32, 2, 64),
                                       (1, 128, 4, 32)])
def test_rwkv6_scan_sweep(B, T, nh, hd):
    ks = jax.random.split(jax.random.PRNGKey(2), 6)
    r = jax.random.normal(ks[0], (B, T, nh, hd)) * 0.3
    k = jax.random.normal(ks[1], (B, T, nh, hd)) * 0.3
    v = jax.random.normal(ks[2], (B, T, nh, hd)) * 0.3
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, nh, hd)))
    u = jax.random.normal(ks[4], (nh, hd)) * 0.1
    s0 = jax.random.normal(ks[5], (B, nh, hd, hd)) * 0.1
    y1, s1 = rwkv6_scan_pallas(r, k, v, w, u, s0, interpret=True)
    y2, s2 = ref.rwkv6_scan_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-5, atol=1e-5)


def test_rwkv6_state_carry_composes():
    """Scanning two halves with the carried state == one full scan."""
    B, T, nh, hd = 1, 64, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 6)
    r = jax.random.normal(ks[0], (B, T, nh, hd)) * 0.3
    k = jax.random.normal(ks[1], (B, T, nh, hd)) * 0.3
    v = jax.random.normal(ks[2], (B, T, nh, hd)) * 0.3
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, nh, hd)))
    u = jax.random.normal(ks[4], (nh, hd)) * 0.1
    s0 = jnp.zeros((B, nh, hd, hd))
    y_full, s_full = ref.rwkv6_scan_ref(r, k, v, w, u, s0)
    h = T // 2
    y1, s_mid = rwkv6_scan_pallas(r[:, :h], k[:, :h], v[:, :h], w[:, :h],
                                  u, s0, interpret=True)
    y2, s_end = rwkv6_scan_pallas(r[:, h:], k[:, h:], v[:, h:], w[:, h:],
                                  u, s_mid, interpret=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_end), np.asarray(s_full),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("B,nc,Q,nh,hd,ds", [(1, 2, 32, 2, 16, 8),
                                             (2, 1, 64, 1, 32, 16)])
def test_ssd_chunk_sweep(B, nc, Q, nh, hd, ds):
    from repro.kernels.ssd_chunk import ssd_chunk_pallas
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    xh = jax.random.normal(ks[0], (B, nc, Q, nh, hd))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, nc, Q, nh)))
    loga = -jax.nn.softplus(jax.random.normal(ks[2], (B, nc, Q, nh))) * 0.5
    Bc = jax.random.normal(ks[3], (B, nc, Q, ds))
    Cc = jax.random.normal(ks[4], (B, nc, Q, ds))
    y1, sb1, ac1 = ssd_chunk_pallas(xh, dt, loga, Bc, Cc, interpret=True)
    y2, sb2, ac2 = ref.ssd_chunk_ref(xh, dt, loga, Bc, Cc)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(sb1), np.asarray(sb2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(ac1), np.asarray(ac2),
                               rtol=1e-5, atol=1e-6)

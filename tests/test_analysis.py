"""Static analyzer: seeded-bad fixtures must be flagged, live tree clean.

Each fixture seeds exactly one hazard class from the analyzer's rule set
and asserts the matching rule (and only it) fires; the final test runs the
full CLI against the live codebase in a subprocess (it needs 8 fake
devices, which the unit-test process must not have) and asserts exit 0.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.analysis import Finding, format_findings, jaxpr_lint, pallas_lint, repo_lint
from repro.sharding import comm

HERE = os.path.dirname(__file__)
SRC = os.path.join(HERE, "..", "src")


# ---------------------------------------------------------------- jaxpr pass
def test_cond_one_sided_psum_flagged():
    """A cond whose true branch psums and whose false branch doesn't."""
    def f(x, flag):
        return lax.cond(flag,
                        lambda v: lax.psum(v, "data"),
                        lambda v: v, x)

    closed = jax.make_jaxpr(f, axis_env=[("data", 8)])(
        jnp.ones((4,)), jnp.bool_(True))
    got = jaxpr_lint.check_cond_congruence(closed.jaxpr, entry="fixture")
    assert len(got) == 1 and got[0].rule == "cond-collective-mismatch"
    assert "psum over ('data',)" in got[0].message


def test_uniform_cond_waives_congruence():
    """The same asymmetry through comm.uniform_cond is intentionally waived."""
    def f(x, flag):
        return comm.uniform_cond(flag,
                                 lambda v: lax.psum(v, "data"),
                                 lambda v: v, x)

    closed = jax.make_jaxpr(f, axis_env=[("data", 8)])(
        jnp.ones((4,)), jnp.bool_(True))
    assert jaxpr_lint.check_cond_congruence(closed.jaxpr) == []


def test_unknown_axis_and_int_dtype_rules():
    def f(c):
        return lax.psum(c, "data")

    closed = jax.make_jaxpr(f, axis_env=[("data", 8)])(
        jnp.ones((4,), jnp.int64) if jax.config.jax_enable_x64
        else jnp.arange(4, dtype=jnp.int32))
    sites = jaxpr_lint.collect_collectives(closed.jaxpr)
    assert len(sites) == 1
    # axis rule: the traced axis name is missing from a disjoint mesh spec
    got = jaxpr_lint.check_axis_names(sites, mesh_axes=("model",))
    assert len(got) == 1 and got[0].rule == "unknown-axis-name"
    # dtype rule fires on a synthetic site with an int64 operand
    bad = jaxpr_lint.CollectiveSite(
        prim="all_to_all", axes=("data",), in_types=("int64[8]",),
        path="/shard_map", file=None, line=None)
    got = jaxpr_lint.check_count_dtypes([bad])
    assert len(got) == 1 and got[0].rule == "collective-int-dtype"
    assert jaxpr_lint.check_count_dtypes(sites) == []


# --------------------------------------------------------------- pallas pass
def _trace_pallas(fn, *args):
    closed = jax.make_jaxpr(fn)(*args)
    eqns = list(pallas_lint._pallas_eqns(closed.jaxpr))
    assert len(eqns) == 1
    return eqns[0]


def test_oversized_vmem_block_flagged():
    """One 16 MiB f32 block in + out: 2x double-buffered = 64 MiB >> 16."""
    def f(x):
        def k(x_ref, o_ref):
            o_ref[...] = x_ref[...]
        return pl.pallas_call(
            k, grid=(2,),
            in_specs=[pl.BlockSpec((1, 2048, 2048), lambda i: (i, 0, 0))],
            out_specs=pl.BlockSpec((1, 2048, 2048), lambda i: (i, 0, 0)),
            out_shape=jax.ShapeDtypeStruct((2, 2048, 2048), jnp.float32),
            compiler_params=pltpu.TPUCompilerParams(
                dimension_semantics=("parallel",)),
            interpret=True)(x)

    eqn = _trace_pallas(f, jnp.zeros((2, 2048, 2048), jnp.float32))
    got = pallas_lint.lint_pallas_call(eqn, name="fixture")
    assert [g.rule for g in got] == ["vmem-budget"]
    # a budget large enough clears it
    assert pallas_lint.lint_pallas_call(eqn, name="fixture",
                                        vmem_budget=1 << 30) == []


def test_scratch_across_parallel_axis_flagged():
    """Accumulating output revisited across an axis marked parallel."""
    def f(x):
        def k(x_ref, o_ref):
            o_ref[...] = o_ref[...] + x_ref[...]
        return pl.pallas_call(
            k, grid=(4,),
            in_specs=[pl.BlockSpec((1, 128), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((1, 128), lambda i: (0, 0)),
            out_shape=jax.ShapeDtypeStruct((1, 128), jnp.float32),
            compiler_params=pltpu.TPUCompilerParams(
                dimension_semantics=("parallel",)),
            interpret=True)(x)

    eqn = _trace_pallas(f, jnp.zeros((4, 128), jnp.float32))
    got = pallas_lint.lint_pallas_call(eqn, name="fixture")
    assert [g.rule for g in got] == ["grid-race"]
    assert "axis 0" in got[0].message


def test_missing_semantics_and_oob_flagged():
    def f(x):
        def k(x_ref, o_ref):
            o_ref[...] = x_ref[...]
        return pl.pallas_call(
            k, grid=(4,),
            # off-by-one: block i+1 walks past the final block of x
            in_specs=[pl.BlockSpec((1, 128), lambda i: (i + 1, 0))],
            out_specs=pl.BlockSpec((1, 128), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((4, 128), jnp.float32),
            interpret=True)(x)

    eqn = _trace_pallas(f, jnp.zeros((4, 128), jnp.float32))
    rules = {g.rule for g in pallas_lint.lint_pallas_call(eqn, name="fixture")}
    assert rules == {"index-map-oob", "missing-dimension-semantics"}


# ----------------------------------------------------------------- repo pass
def test_unregistered_config_knob_flagged(tmp_path):
    src = open(os.path.join(SRC, "repro", "common", "config.py")).read()
    anchor = "    num_experts:"
    assert anchor in src
    seeded = src.replace(
        anchor, "    totally_unregistered_knob: int = 0\n" + anchor, 1)
    p = tmp_path / "config.py"
    p.write_text(seeded)
    got = repo_lint.check_config_registry(str(p))
    assert len(got) == 1 and got[0].rule == "unregistered-config-knob"
    assert "totally_unregistered_knob" in got[0].message
    # the pristine file is clean (the live tree's own guarantee)
    clean = tmp_path / "clean_config.py"
    clean.write_text(src)
    assert repo_lint.check_config_registry(str(clean)) == []


def test_rogue_all_to_all_flagged(tmp_path):
    p = tmp_path / "rogue.py"
    p.write_text(
        "from jax import lax\n\n"
        "def leak(x):\n"
        "    return lax.all_to_all(x, 'data', split_axis=0, concat_axis=0)\n")
    got = repo_lint.check_collective_callsites([str(p)])
    assert len(got) == 1 and got[0].rule == "rogue-collective"
    assert got[0].line == 4
    # the same call inside a file named sharding/comm.py is allowed
    d = tmp_path / "sharding"
    d.mkdir()
    (d / "comm.py").write_text(p.read_text())
    assert repo_lint.check_collective_callsites([str(d / "comm.py")]) == []


def test_kernel_twin_rule(tmp_path):
    (tmp_path / "ops.py").write_text("from k import good_pallas\n")
    (tmp_path / "ref.py").write_text("def good_ref(x):\n    return x\n")
    (tmp_path / "k.py").write_text(
        "def good_pallas(x):\n    return x\n\n"
        "def orphan_pallas(x):\n    return x\n")
    got = repo_lint.check_kernel_twins(str(tmp_path))
    rules = sorted(g.rule for g in got)
    assert rules == ["kernel-missing-ref", "kernel-missing-wrapper"]
    assert all("orphan_pallas" in g.message for g in got)


# ------------------------------------- dynamic twin of the int32 boundary rule
def test_comm_count_boundary_dtype_assert():
    good = jnp.zeros((4,), jnp.int32)
    assert comm.exchange_counts(good, None) is good
    with pytest.raises(TypeError, match="int32 at the collective boundary"):
        comm.exchange_counts(good.astype(jnp.int16), None)
    with pytest.raises(TypeError, match="int32 at the collective boundary"):
        comm.ragged_all_to_all(jnp.zeros((8, 4)), good.astype(jnp.float32),
                               None, recv_rows=8)


# ------------------------------------------------------------- driver + live
def test_finding_format():
    f = Finding("pallas", "vmem-budget", "too big", "a/b.py", 7)
    assert f.format() == "[pallas] vmem-budget: too big (a/b.py:7)"
    assert format_findings([]) == "no findings"
    assert format_findings([f]).endswith("1 finding(s)")


def test_cli_exit_code_plumbing(monkeypatch):
    from repro.launch import analyze
    monkeypatch.setattr(repo_lint, "run", lambda log=None: [])
    assert analyze.main(["--pass", "repo", "-q"]) == 0
    monkeypatch.setattr(
        repo_lint, "run",
        lambda log=None: [Finding("repo", "rogue-collective", "seeded")])
    assert analyze.main(["--pass", "repo", "-q"]) == 1


def test_live_codebase_passes_clean():
    """The full analyzer over the real tree: all passes, exit 0."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    p = subprocess.run([sys.executable, "-m", "repro.launch.analyze", "-q"],
                       capture_output=True, text=True, timeout=900, env=env)
    assert p.returncode == 0, (
        f"analyzer flagged the live tree:\nSTDOUT:\n{p.stdout[-3000:]}\n"
        f"STDERR:\n{p.stderr[-3000:]}")
    assert "no findings" in p.stdout

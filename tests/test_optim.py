"""Optimizer + schedule + data-pipeline + checkpoint tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.optim import make_optimizer, make_schedule
from repro.optim.optimizers import clip_by_global_norm


@pytest.mark.parametrize("name", ["lamb", "adamw"])
def test_optimizer_minimizes_quadratic(name):
    opt = make_optimizer(name, weight_decay=0.0)
    target = jnp.asarray(np.random.default_rng(0).standard_normal((4, 4)),
                         jnp.float32)
    params = {"w": jnp.zeros((4, 4))}
    state = opt.init(params)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params, 0.05)
    assert float(loss(params)) < 1e-2


def test_lamb_trust_ratio_scale_invariance():
    """LAMB updates are invariant to gradient rescaling (trust ratio)."""
    opt = make_optimizer("lamb", weight_decay=0.0)
    p = {"w": jnp.ones((8, 8))}
    g = {"w": jnp.full((8, 8), 0.5)}
    p1, _ = opt.update(g, opt.init(p), p, 0.1)
    g2 = {"w": jnp.full((8, 8), 500.0)}
    p2, _ = opt.update(g2, opt.init(p), p, 0.1)
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p2["w"]),
                               rtol=1e-5)


@settings(deadline=None, max_examples=20)
@given(scale=st.floats(0.1, 100.0), max_norm=st.floats(0.1, 10.0))
def test_clip_by_global_norm_property(scale, max_norm):
    g = {"a": jnp.full((4,), scale), "b": jnp.full((2, 2), -scale)}
    clipped, total = clip_by_global_norm(g, max_norm)
    expected = np.sqrt(8) * scale
    np.testing.assert_allclose(float(total), expected, rtol=1e-5)
    out_norm = float(jnp.sqrt(sum(jnp.sum(x ** 2)
                                  for x in jax.tree.leaves(clipped))))
    assert out_norm <= max_norm * 1.001 or out_norm <= expected * 1.001


def test_schedule_shapes():
    s = make_schedule("cosine", 1e-3, warmup=10, total=100)
    assert float(s(0)) == 0.0
    assert abs(float(s(10)) - 1e-3) < 1e-9
    assert float(s(100)) < 1e-4
    lin = make_schedule("linear", 1e-3, warmup=0, total=100)
    assert float(lin(50)) == pytest.approx(5e-4, rel=1e-5)


# -------------------------------------------------------------- data pipeline
def test_data_determinism():
    from repro.configs import get_reduced
    from repro.data.pipeline import make_batch
    cfg = get_reduced("llama3-405b")
    b1 = make_batch(cfg, 4, 64, seed=7, step=3)
    b2 = make_batch(cfg, 4, 64, seed=7, step=3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = make_batch(cfg, 4, 64, seed=7, step=4)
    assert (b1["tokens"] != b3["tokens"]).any()


def test_mlm_masking_fractions():
    from repro.configs import get_reduced
    from repro.data.pipeline import make_batch
    cfg = get_reduced("smile-3.7b")
    b = make_batch(cfg, 16, 256, seed=0, step=0, mlm_prob=0.15)
    frac = (b["labels"] >= 0).mean()
    assert 0.10 < frac < 0.20
    # causal-label check for LM
    cfg2 = get_reduced("llama3-405b")
    b2 = make_batch(cfg2, 2, 64, seed=0, step=0)
    np.testing.assert_array_equal(b2["labels"][:, :-1], b2["tokens"][:, 1:])


def test_musicgen_delay_pattern():
    from repro.configs import get_reduced
    from repro.data.pipeline import make_batch
    cfg = get_reduced("musicgen-large")
    b = make_batch(cfg, 2, 32, seed=0, step=0)
    assert b["tokens"].shape == (2, cfg.num_codebooks, 32)
    # delayed codebooks start with zeros
    assert (b["tokens"][:, 1, 0] == 0).all()
    assert (b["tokens"][:, 3, :3] == 0).all()


def test_checkpoint_roundtrip(tmp_path):
    from repro.configs import get_reduced
    from repro.models.transformer import init_model
    from repro.sharding.plan import single_device_plan
    from repro.train.checkpoint import load_checkpoint, save_checkpoint
    cfg = get_reduced("qwen1.5-0.5b")
    params = init_model(jax.random.PRNGKey(0), cfg, single_device_plan())
    path = str(tmp_path / "ck.npz")
    save_checkpoint(path, params, step=42)
    restored, _, step = load_checkpoint(path, params)
    assert step == 42
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

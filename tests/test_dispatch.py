"""Dispatch subsystem: the sort and dropless backends must match the dense
oracle.

Covers the primitive level (positions / keep masks / buffers / flags, bit
for bit, including overflow-drop arrival ordering; the dropless ragged
layout's segment contiguity and zero-drop guarantee), the fused Pallas
kernels vs their jnp oracles (including the ragged grouped FFN), zero-token
dispatch (serving can hand every backend an empty local batch), and full
switch/smile layers (both SMILE levels) run end-to-end under each backend.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.common.config import MoEConfig
from repro.core import dispatch as D
from repro.core import moe as M
from repro.kernels import ops as kops
from repro.kernels import ref
from repro.kernels.grouped_ffn import grouped_ffn_ragged_pallas
from repro.kernels.moe_dispatch import (combine_gather_pallas,
                                        dispatch_gather_pallas)
from repro.sharding.plan import single_device_plan

PLAN = single_device_plan()


def _random_case(rng, t, k, groups, cap, d, invalid_frac=0.0):
    A = t * k
    x = jnp.asarray(rng.standard_normal((t, d)), jnp.float32)
    gids = jnp.asarray(rng.integers(0, groups, A), jnp.int32)
    gates = jnp.asarray(rng.uniform(0.0, 1.0, A), jnp.float32)
    valid = jnp.asarray(rng.uniform(size=A) >= invalid_frac)
    return x, gids, gates, valid


# ------------------------------------------------------- property equivalence
@settings(deadline=None, max_examples=25)
@given(t=st.integers(4, 64), k=st.integers(1, 3), groups=st.integers(1, 8),
       cap=st.integers(1, 16), seed=st.integers(0, 2**31 - 1))
def test_sort_equals_dense_property(t, k, groups, cap, seed):
    """keep masks and kept positions bit-for-bit; buffers bit-for-bit;
    combined outputs allclose — including capacity overflow and invalid
    assignments."""
    rng = np.random.default_rng(seed)
    x, gids, gates, valid = _random_case(rng, t, k, groups, cap, d=8,
                                         invalid_frac=0.25)
    buf_d, st_d = D.dispatch(x, gids, gates, groups, cap, k=k, valid=valid,
                             backend="dense")
    buf_s, st_s = D.dispatch(x, gids, gates, groups, cap, k=k, valid=valid,
                             backend="sort")
    np.testing.assert_array_equal(np.asarray(st_d.keep), np.asarray(st_s.keep))
    kept = np.asarray(st_d.keep)
    np.testing.assert_array_equal(np.asarray(st_d.pos)[kept],
                                  np.asarray(st_s.pos)[kept])
    np.testing.assert_array_equal(np.asarray(buf_d), np.asarray(buf_s))
    y_d = D.combine(buf_d, st_d)
    y_s = D.combine(buf_s, st_s)
    np.testing.assert_allclose(np.asarray(y_d), np.asarray(y_s),
                               rtol=1e-6, atol=1e-6)
    vals = jnp.asarray(rng.uniform(1.0, 2.0, t * k), jnp.float32)
    np.testing.assert_array_equal(np.asarray(D.dispatch_flags(vals, st_d)),
                                  np.asarray(D.dispatch_flags(vals, st_s)))


def test_overflow_drops_in_arrival_order():
    """Paper semantics: within a group the first `cap` assignments survive,
    later arrivals are dropped — on both backends."""
    t, k, groups, cap, d = 12, 1, 2, 3, 4
    x = jnp.arange(t * d, dtype=jnp.float32).reshape(t, d)
    gids = jnp.asarray([0, 0, 1, 0, 0, 1, 0, 1, 1, 1, 0, 1], jnp.int32)
    gates = jnp.ones((t,), jnp.float32)
    for backend in D.CAPACITY_BACKENDS:
        buf, state = D.dispatch(x, gids, gates, groups, cap, k=1,
                                backend=backend)
        keep = np.asarray(state.keep)
        for g in range(groups):
            idx = np.where(np.asarray(gids) == g)[0]
            assert keep[idx[:cap]].all(), backend
            assert not keep[idx[cap:]].any(), backend
        # surviving slots hold the first `cap` arrivals of each group, in order
        np.testing.assert_array_equal(np.asarray(buf)[0, :, 0],
                                      np.asarray(x)[[0, 1, 3], 0])
        np.testing.assert_array_equal(np.asarray(buf)[1, :, 0],
                                      np.asarray(x)[[2, 5, 7], 0])
        # dropped tokens contribute zero rows on combine
        y = D.combine(buf, state)
        dropped = ~keep
        assert (np.asarray(y)[dropped] == 0).all(), backend


# ------------------------------------------------------- dropless equivalence
@settings(deadline=None, max_examples=25)
@given(t=st.integers(4, 64), k=st.integers(1, 3), groups=st.integers(1, 8),
       seed=st.integers(0, 2**31 - 1))
def test_dropless_equals_dense_property(t, k, groups, seed):
    """Dropless vs the dense oracle at ample capacity (no drops): identical
    keep masks, allclose combined outputs, exactly zero dropped assignments,
    and a well-formed ragged layout (contiguous per-group segments in
    arrival order, tile-aligned starts)."""
    rng = np.random.default_rng(seed)
    x, gids, gates, valid = _random_case(rng, t, k, groups, cap=0, d=8,
                                         invalid_frac=0.25)
    A = t * k
    buf_d, st_d = D.dispatch(x, gids, gates, groups, A, k=k, valid=valid,
                             backend="dense")          # cap=A: nothing drops
    rows, starts, st_r = D.dispatch_ragged(x, gids, gates, groups, k=k,
                                           valid=valid)
    # zero drops: every valid assignment survives, bit-identical keep masks
    np.testing.assert_array_equal(np.asarray(st_r.keep), np.asarray(valid))
    np.testing.assert_array_equal(np.asarray(st_d.keep), np.asarray(st_r.keep))
    # layout: group g's segment holds exactly its valid assignments, in
    # arrival order, starting at a block-aligned offset
    blk = st_r.cap
    sa = np.asarray(starts)
    rs = np.asarray(st_r.slot_assign)
    assert (sa % blk == 0).all()
    for g in range(groups):
        ids = [a for a in range(A) if valid[a] and gids[a] == g]
        assert list(rs[sa[g]:sa[g] + len(ids)]) == ids
        assert (rs[sa[g] + len(ids):sa[g + 1]] == -1).all()
    # combine: rows hold the right tokens -> identity FFN must reproduce the
    # dense-oracle combine exactly
    y_d = D.combine(buf_d, st_d)
    y_r = D.combine(rows, st_r)
    np.testing.assert_allclose(np.asarray(y_d), np.asarray(y_r),
                               rtol=1e-6, atol=1e-6)
    # flags mirror the layout
    vals = jnp.asarray(rng.uniform(1.0, 2.0, A), jnp.float32)
    fl = np.asarray(D.dispatch_flags(vals, st_r))
    want = np.zeros_like(fl)
    rank = np.asarray(st_r.pos)
    for a in range(A):
        if valid[a]:
            want[rank[a]] = vals[a]
    np.testing.assert_array_equal(fl, want)


@pytest.mark.parametrize("backend", ["dense", "sort"])
def test_zero_token_dispatch(backend):
    """Serving can produce empty local batches: every backend must handle
    t == 0 without dividing by the assignment count."""
    d, groups, cap = 8, 4, 3
    x = jnp.zeros((0, d), jnp.float32)
    gids = jnp.zeros((0,), jnp.int32)
    gates = jnp.zeros((0,), jnp.float32)
    buf, state = D.dispatch(x, gids, gates, groups, cap, k=1, backend=backend)
    assert buf.shape == (groups, cap, d)
    assert not np.asarray(buf).any()
    y = D.combine(buf, state)
    assert y.shape == (0, d)


def test_zero_token_dispatch_ragged():
    d, groups = 8, 4
    x = jnp.zeros((0, d), jnp.float32)
    rows, starts, state = D.dispatch_ragged(
        x, jnp.zeros((0,), jnp.int32), jnp.zeros((0,), jnp.float32), groups)
    assert not np.asarray(rows).any()
    np.testing.assert_array_equal(np.asarray(starts), np.zeros(groups + 1))
    assert D.combine(rows, state).shape == (0, d)


# --------------------------------------------- ragged-A2A layout helpers
@settings(deadline=None, max_examples=25)
@given(t=st.integers(1, 64), k=st.integers(1, 3), ranks=st.integers(1, 4),
       n_local=st.integers(1, 4), seed=st.integers(0, 2**31 - 1))
def test_ragged_wire_layout_property(t, k, ranks, n_local, seed):
    """The wire-layout helpers agree with a numpy oracle: seg_lens counts
    exactly the valid assignments per group, send_counts are the contiguous
    aligned extents per destination rank, and ragged_recv_layout run on the
    sender's own count grid reconstructs the layout's row->(group, valid)
    structure bit for bit (the P=1 'exchange')."""
    rng = np.random.default_rng(seed)
    G = ranks * n_local
    x, gids, gates, valid = _random_case(rng, t, k, G, cap=0, d=4,
                                         invalid_frac=0.3)
    A = t * k
    lens = np.asarray(D.ragged_seg_lens(gids, valid, G))
    want_lens = np.bincount(np.asarray(gids)[np.asarray(valid)], minlength=G)
    np.testing.assert_array_equal(lens, want_lens)

    rows, starts, st_r = D.dispatch_ragged(x, gids, gates, G, k=k,
                                           valid=valid)
    blk = st_r.cap
    sc = np.asarray(D.ragged_send_counts(starts, n_local))
    sa = np.asarray(starts)
    want_sc = [sa[(p + 1) * n_local] - sa[p * n_local] for p in range(ranks)]
    np.testing.assert_array_equal(sc, want_sc)
    assert sc.sum() == sa[-1]

    # receiver reconstruction from counts alone == sender's own layout
    gid, rvalid = D.ragged_recv_layout(
        jnp.asarray(lens.reshape(1, G), jnp.int32), blk, rows.shape[0])
    rs = np.asarray(st_r.slot_assign)
    np.testing.assert_array_equal(np.asarray(rvalid), rs >= 0)
    row_gid = np.asarray(gid)
    for g in range(G):
        seg = slice(sa[g], sa[g] + want_lens[g])
        assert (row_gid[seg] == g).all()


def test_ragged_recv_layout_skew():
    """Zero rows to some groups and all rows to one group: validity must
    track the raw lengths exactly and the tail past the last segment is
    invalid."""
    blk = 8
    grid = jnp.asarray([[0, 13], [5, 0]], jnp.int32)    # (P=2, n_local=2)
    gid, valid = D.ragged_recv_layout(grid, blk, 48)
    v = np.asarray(valid)
    g = np.asarray(gid)
    # src0: g0 empty (0 rows), g1 13 valid in a 16-row aligned segment
    assert v[:13].all() and (g[:13] == 1).all()
    assert not v[13:16].any()
    # src1: g0 5 valid in an 8-row segment, g1 empty; tail all invalid
    assert v[16:21].all() and (g[16:21] == 0).all()
    assert not v[21:].any()
    # all-to-one-group grid
    gid1, valid1 = D.ragged_recv_layout(
        jnp.asarray([[0, 24]], jnp.int32), blk, 32)
    assert np.asarray(valid1)[:24].all() and not np.asarray(valid1)[24:].any()
    assert (np.asarray(gid1)[:24] == 1).all()


def test_ragged_all_to_all_identity():
    """Group size 1 (empty axes): the exchange is the identity up to the
    static receive bound — rows zero-padded, counts unchanged."""
    from repro.sharding import comm
    rows = jnp.arange(12.0).reshape(6, 2)
    counts = jnp.asarray([4], jnp.int32)
    out, rc = comm.ragged_all_to_all(rows, counts, None, recv_rows=8)
    assert out.shape == (8, 2)
    np.testing.assert_array_equal(np.asarray(out[:6]), np.asarray(rows))
    assert not np.asarray(out[6:]).any()
    np.testing.assert_array_equal(np.asarray(rc), [4])


@pytest.mark.parametrize("router", ["switch", "smile"])
def test_zero_token_moe_layer(router):
    """A whole MoE layer on an empty local batch returns (0, d) and finite
    stats under every backend."""
    for backend in D.BACKENDS:
        cfg = MoEConfig(num_experts=8, top_k=2, top_g=2, d_ff_expert=32,
                        capacity_factor=2.0, router=router, grid=(4, 2),
                        renorm_gates=True, dispatch_backend=backend)
        params = M.init_moe_params(jax.random.PRNGKey(0), cfg, 16, PLAN,
                                   glu=False)
        y, stats = M.moe_layer(params, jnp.zeros((0, 16)), cfg, PLAN)
        assert y.shape == (0, 16)
        assert np.isfinite(float(stats.lb_loss))
        assert float(stats.drop_frac) == 0.0


def test_sort_backend_no_dense_onehot():
    """The sort path never materializes an (A, num_groups) intermediate."""
    t, groups, cap = 32, 8, 8
    gids = jnp.asarray(np.random.default_rng(0).integers(0, groups, t))
    jaxpr = jax.make_jaxpr(
        lambda g: D.sort_positions(g, jnp.ones((t,), bool), groups, cap))(gids)
    for eqn in jaxpr.jaxpr.eqns:
        for v in eqn.outvars:
            assert getattr(v.aval, "shape", ()) != (t, groups)


# --------------------------------------------------------------- the kernels
@pytest.mark.parametrize("T,d,R", [(32, 128, 64), (40, 64, 48), (8, 256, 96)])
def test_dispatch_gather_kernel_matches_ref(T, d, R):
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((T, d)), jnp.float32)
    src = jnp.asarray(rng.integers(-1, T, R), jnp.int32)
    got = dispatch_gather_pallas(x, src, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.dispatch_gather_ref(x, src)))


@pytest.mark.parametrize("t,k,d,R", [(16, 1, 128, 64), (24, 3, 64, 48)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_combine_gather_kernel_matches_ref(t, k, d, R, dtype):
    rng = np.random.default_rng(2)
    rows = jnp.asarray(rng.standard_normal((R, d)), jnp.float32).astype(dtype)
    src = jnp.asarray(rng.integers(-1, R, (t, k)), jnp.int32)
    scale = jnp.asarray(rng.uniform(0, 1, (t, k)), jnp.float32)
    got = combine_gather_pallas(rows, src, scale, interpret=True)
    want = ref.combine_gather_ref(rows, src, scale)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_ops_wrappers_tiny_shape_fallback():
    """ops.* must route tiny/misaligned shapes to the oracle, not Pallas."""
    x = jnp.ones((4, 7), jnp.float32)            # d % 8 != 0
    src = jnp.asarray([0, -1, 2, 3], jnp.int32)
    np.testing.assert_array_equal(np.asarray(kops.dispatch_gather(x, src)),
                                  np.asarray(ref.dispatch_gather_ref(x, src)))
    rows = jnp.ones((4, 7), jnp.float32)
    src2 = jnp.asarray([[0], [-1], [2], [3]], jnp.int32)
    sc = jnp.full((4, 1), 0.5, jnp.float32)
    np.testing.assert_allclose(
        np.asarray(kops.combine_gather(rows, src2, sc)),
        np.asarray(ref.combine_gather_ref(rows, src2, sc)))


@pytest.mark.parametrize("G,block,d,f,glu", [
    (4, 16, 16, 32, True), (6, 8, 32, 24, False), (3, 32, 64, 128, True)])
def test_grouped_ffn_ragged_kernel_matches_ref(G, block, d, f, glu):
    """The ragged grouped-FFN Pallas kernel (scalar-prefetched per-tile group
    ids) must match the per-row-gather jnp oracle on a real ragged layout."""
    rng = np.random.default_rng(3)
    t, k = 40, 2
    x = jnp.asarray(rng.standard_normal((t, d)), jnp.float32)
    gids = jnp.asarray(rng.integers(0, G, t * k), jnp.int32)
    gates = jnp.ones((t * k,), jnp.float32)
    valid = jnp.asarray(rng.uniform(size=t * k) >= 0.2)
    rows, starts, st = D.dispatch_ragged(x, gids, gates, G, k=k, valid=valid,
                                         block=block)
    w1 = jnp.asarray(rng.standard_normal((G, d, f)), jnp.float32) * 0.1
    w3 = (jnp.asarray(rng.standard_normal((G, d, f)), jnp.float32) * 0.1
          if glu else None)
    w2 = jnp.asarray(rng.standard_normal((G, f, d)), jnp.float32) * 0.1
    want = ref.grouped_ffn_ragged_ref(rows, starts, w1, w3, w2, act="silu")
    tile_gid = D.ragged_tile_gids(starts, rows.shape[0] // block, block)
    got = grouped_ffn_ragged_pallas(rows, tile_gid, w1, w3, w2, act="silu",
                                    interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    # alignment-padding rows stay exactly zero through the FFN
    pad = np.asarray(st.slot_assign) < 0
    assert not np.asarray(got)[pad].any()


# ------------------------------------------------------- full-layer coverage
@pytest.mark.parametrize("router", ["switch", "smile"])
@pytest.mark.parametrize("grid,E,k,g,cf", [
    ((4, 4), 16, 1, 1, 8.0),     # ample capacity, top-1 (the paper)
    ((4, 4), 8, 2, 1, 8.0),      # replication r=2
    ((4, 4), 32, 8, 4, 8.0),     # h=2, bi-level top-(4x2): both levels busy
    ((4, 4), 16, 2, 2, 0.5),     # overflow: drops on BOTH smile levels
])
def test_layer_backend_equivalence(router, grid, E, k, g, cf, rng_key):
    cfg = MoEConfig(num_experts=E, top_k=k, top_g=g, d_ff_expert=64,
                    capacity_factor=cf, router=router, grid=grid,
                    renorm_gates=(k > 1), dispatch_backend="dense")
    params = M.init_moe_params(rng_key, cfg, 32, PLAN, glu=True)
    x = jax.random.normal(jax.random.PRNGKey(1), (96, 32))
    y_d, s_d = M.moe_layer(params, x, cfg, PLAN, act="silu")
    cfg_s = dataclasses.replace(cfg, dispatch_backend="sort")
    y_s, s_s = M.moe_layer(params, x, cfg_s, PLAN, act="silu")
    np.testing.assert_allclose(np.asarray(y_d), np.asarray(y_s),
                               rtol=1e-5, atol=1e-6)
    assert float(s_d.drop_frac) == pytest.approx(float(s_s.drop_frac),
                                                 abs=1e-9)
    assert float(s_d.lb_loss) == pytest.approx(float(s_s.lb_loss), rel=1e-6)
    if cf < 1.0:
        assert float(s_s.drop_frac) > 0.0       # overflow actually exercised
    # dropless + ragged A2A (the default): no capacity buffer on ANY hop,
    # so the reported drop fraction is exactly 0.0 at every cf and every
    # router, and the output matches the dense oracle wherever the oracle
    # itself kept every token.  (At starvation cf SMILE's intra LB stats
    # legitimately differ from the oracle's — more tokens now arrive at
    # level 2 — so lb equality is only asserted where nothing dropped.)
    cfg_r = dataclasses.replace(cfg, dispatch_backend="dropless")
    y_r, s_r = M.moe_layer(params, x, cfg_r, PLAN, act="silu")
    assert float(s_r.drop_frac) == 0.0
    if float(s_d.drop_frac) == 0.0:             # oracle dropped nothing
        assert float(s_d.lb_loss) == pytest.approx(float(s_r.lb_loss),
                                                   rel=1e-6)
        np.testing.assert_allclose(np.asarray(y_d), np.asarray(y_r),
                                   rtol=1e-5, atol=1e-6)
    # dropless + padded hops (ragged_a2a=False) reproduces the pre-ragged
    # semantics: level-1 keeps the paper's capacity buffer, so at
    # starvation cf its drop fraction is the level-1 share only — strictly
    # below the capacity backends' — and the arrival-dependent LB stats
    # match the oracle exactly.
    cfg_p = dataclasses.replace(cfg, dispatch_backend="dropless",
                                ragged_a2a=False)
    y_p, s_p = M.moe_layer(params, x, cfg_p, PLAN, act="silu")
    if router == "switch" or cf >= 1.0:
        assert float(s_p.drop_frac) == 0.0
    else:
        assert float(s_p.drop_frac) < float(s_d.drop_frac)
    assert float(s_d.lb_loss) == pytest.approx(float(s_p.lb_loss), rel=1e-6)
    if float(s_d.drop_frac) == 0.0:
        np.testing.assert_allclose(np.asarray(y_d), np.asarray(y_p),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(y_r), np.asarray(y_p),
                                   rtol=1e-5, atol=1e-6)


def test_dropless_keeps_overflow_tokens(rng_key):
    """At a starvation capacity factor the capacity backends drop most
    assignments; dropless (switch) must keep them all and match a dense
    oracle given unbounded capacity."""
    cfg = MoEConfig(num_experts=16, top_k=2, d_ff_expert=64,
                    capacity_factor=0.25, router="switch", grid=(4, 4),
                    renorm_gates=True, dispatch_backend="sort")
    params = M.init_moe_params(rng_key, cfg, 32, PLAN, glu=False)
    x = jax.random.normal(jax.random.PRNGKey(2), (64, 32))
    _, s_sort = M.moe_layer(params, x, cfg, PLAN, act="gelu")
    assert float(s_sort.drop_frac) > 0.1
    cfg_r = dataclasses.replace(cfg, dispatch_backend="dropless")
    y_r, s_r = M.moe_layer(params, x, cfg_r, PLAN, act="gelu")
    assert float(s_r.drop_frac) == 0.0
    cfg_big = dataclasses.replace(cfg, dispatch_backend="dense",
                                  capacity_factor=64.0)
    y_big, _ = M.moe_layer(params, x, cfg_big, PLAN, act="gelu")
    np.testing.assert_allclose(np.asarray(y_r), np.asarray(y_big),
                               rtol=1e-5, atol=1e-6)


def test_dropless_smile_eliminates_level2_drops(rng_key):
    """SMILE under dropless with padded hops (ragged_a2a=False) keeps the
    paper's level-1 capacity (the fixed-shape inter-node A2A payload) but
    must drop nothing at the level-2 expert compute: its drop fraction is
    strictly below the capacity backend's whenever level 2 was dropping.
    With ragged hops (the default) no capacity buffer exists anywhere and
    the stat is exactly zero even at a starvation capacity factor."""
    cfg = MoEConfig(num_experts=16, top_k=4, top_g=2, d_ff_expert=64,
                    capacity_factor=0.5, router="smile", grid=(4, 4),
                    renorm_gates=True, dispatch_backend="sort")
    params = M.init_moe_params(rng_key, cfg, 32, PLAN, glu=False)
    x = jax.random.normal(jax.random.PRNGKey(2), (64, 32))
    _, s_sort = M.moe_layer(params, x, cfg, PLAN, act="gelu")
    cfg_p = dataclasses.replace(cfg, dispatch_backend="dropless",
                                ragged_a2a=False)
    _, s_p = M.moe_layer(params, x, cfg_p, PLAN, act="gelu")
    assert 0.0 < float(s_p.drop_frac) < float(s_sort.drop_frac)
    cfg_r = dataclasses.replace(cfg, dispatch_backend="dropless")
    _, s_r = M.moe_layer(params, x, cfg_r, PLAN, act="gelu")
    assert float(s_r.drop_frac) == 0.0


def test_smile_drop_frac_per_level_normalization(rng_key):
    """Regression for the drop-fraction stat: each level must be normalized
    by its own valid-assignment count.  Construct a case with zero level-1
    drops (ample inter capacity at top_g=1) and known level-2 drops: the
    reported fraction must equal dropped2 / valid2 — under the old math it
    was dropped2 / A1, overstated by ~k_local when top_k > top_g."""
    t, E, k, g = 64, 16, 4, 1
    cfg = MoEConfig(num_experts=E, top_k=k, top_g=g, d_ff_expert=32,
                    capacity_factor=1.0, router="smile", grid=(1, 4),
                    renorm_gates=True)
    # level 1 has a single node: nothing can drop there (cap1 = t >= t) and
    # every arrival is valid; level 2 routes t*k assignments at cf=1.0
    params = M.init_moe_params(rng_key, cfg, 32, PLAN, glu=False)
    x = jax.random.normal(jax.random.PRNGKey(3), (t, 32))
    _, stats = M.moe_layer(params, x, cfg, PLAN, act="gelu")
    frac = float(stats.drop_frac)
    assert 0.0 < frac < 1.0
    # recompute the ground truth by brute force from the routing decisions
    probs, _ = M.router_probs(x, params["router_intra"]["w"])
    gates, qidx = M.topk_gates(probs, k, renorm=True)
    e_pn = E // 1
    cap2 = M.capacity(t, k, 1.0, cfg.grid[1] * (E // (cfg.grid[0] * cfg.grid[1])))
    counts = np.zeros(e_pn, np.int64)
    dropped2 = 0
    for a, e in enumerate(np.asarray(qidx).reshape(-1)):
        counts[e] += 1
        if counts[e] > cap2:
            dropped2 += 1
    want = dropped2 / (t * k)
    assert frac == pytest.approx(want, abs=1e-6)


@pytest.mark.parametrize("router", ["switch", "smile"])
def test_layer_sort_kernel_path(router, rng_key):
    """sort backend through the fused Pallas kernels (interpret on CPU)."""
    cfg = MoEConfig(num_experts=16, top_k=2, top_g=2, d_ff_expert=64,
                    capacity_factor=2.0, router=router, grid=(4, 4),
                    renorm_gates=True, dispatch_backend="sort")
    params = M.init_moe_params(rng_key, cfg, 32, PLAN, glu=True)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
    y_ref, _ = M.moe_layer(params, x, cfg, PLAN, act="silu", use_kernel=False)
    y_ker, _ = M.moe_layer(params, x, cfg, PLAN, act="silu", use_kernel=True)
    a = np.asarray(y_ref, np.float32)
    b = np.asarray(y_ker, np.float32)
    rel = np.abs(a - b).max() / (np.abs(a).max() + 1e-9)
    assert rel < 3e-2, rel


def test_unknown_backend_raises():
    x = jnp.ones((4, 8))
    gids = jnp.zeros((4,), jnp.int32)
    with pytest.raises(ValueError, match="unknown dispatch backend"):
        D.dispatch(x, gids, jnp.ones((4,)), 2, 2, backend="magic")

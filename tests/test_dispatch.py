"""Dispatch subsystem: the sort backend must match the dense oracle.

Covers the primitive level (positions / keep masks / buffers / flags, bit
for bit, including overflow-drop arrival ordering), the fused Pallas
kernels vs their jnp oracles, and full switch/smile layers (both SMILE
levels) run end-to-end under each backend.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.common.config import MoEConfig
from repro.core import dispatch as D
from repro.core import moe as M
from repro.kernels import ops as kops
from repro.kernels import ref
from repro.kernels.moe_dispatch import (combine_gather_pallas,
                                        dispatch_gather_pallas)
from repro.sharding.plan import single_device_plan

PLAN = single_device_plan()


def _random_case(rng, t, k, groups, cap, d, invalid_frac=0.0):
    A = t * k
    x = jnp.asarray(rng.standard_normal((t, d)), jnp.float32)
    gids = jnp.asarray(rng.integers(0, groups, A), jnp.int32)
    gates = jnp.asarray(rng.uniform(0.0, 1.0, A), jnp.float32)
    valid = jnp.asarray(rng.uniform(size=A) >= invalid_frac)
    return x, gids, gates, valid


# ------------------------------------------------------- property equivalence
@settings(deadline=None, max_examples=25)
@given(t=st.integers(4, 64), k=st.integers(1, 3), groups=st.integers(1, 8),
       cap=st.integers(1, 16), seed=st.integers(0, 2**31 - 1))
def test_sort_equals_dense_property(t, k, groups, cap, seed):
    """keep masks and kept positions bit-for-bit; buffers bit-for-bit;
    combined outputs allclose — including capacity overflow and invalid
    assignments."""
    rng = np.random.default_rng(seed)
    x, gids, gates, valid = _random_case(rng, t, k, groups, cap, d=8,
                                         invalid_frac=0.25)
    buf_d, st_d = D.dispatch(x, gids, gates, groups, cap, k=k, valid=valid,
                             backend="dense")
    buf_s, st_s = D.dispatch(x, gids, gates, groups, cap, k=k, valid=valid,
                             backend="sort")
    np.testing.assert_array_equal(np.asarray(st_d.keep), np.asarray(st_s.keep))
    kept = np.asarray(st_d.keep)
    np.testing.assert_array_equal(np.asarray(st_d.pos)[kept],
                                  np.asarray(st_s.pos)[kept])
    np.testing.assert_array_equal(np.asarray(buf_d), np.asarray(buf_s))
    y_d = D.combine(buf_d, st_d)
    y_s = D.combine(buf_s, st_s)
    np.testing.assert_allclose(np.asarray(y_d), np.asarray(y_s),
                               rtol=1e-6, atol=1e-6)
    vals = jnp.asarray(rng.uniform(1.0, 2.0, t * k), jnp.float32)
    np.testing.assert_array_equal(np.asarray(D.dispatch_flags(vals, st_d)),
                                  np.asarray(D.dispatch_flags(vals, st_s)))


def test_overflow_drops_in_arrival_order():
    """Paper semantics: within a group the first `cap` assignments survive,
    later arrivals are dropped — on both backends."""
    t, k, groups, cap, d = 12, 1, 2, 3, 4
    x = jnp.arange(t * d, dtype=jnp.float32).reshape(t, d)
    gids = jnp.asarray([0, 0, 1, 0, 0, 1, 0, 1, 1, 1, 0, 1], jnp.int32)
    gates = jnp.ones((t,), jnp.float32)
    for backend in D.BACKENDS:
        buf, state = D.dispatch(x, gids, gates, groups, cap, k=1,
                                backend=backend)
        keep = np.asarray(state.keep)
        for g in range(groups):
            idx = np.where(np.asarray(gids) == g)[0]
            assert keep[idx[:cap]].all(), backend
            assert not keep[idx[cap:]].any(), backend
        # surviving slots hold the first `cap` arrivals of each group, in order
        np.testing.assert_array_equal(np.asarray(buf)[0, :, 0],
                                      np.asarray(x)[[0, 1, 3], 0])
        np.testing.assert_array_equal(np.asarray(buf)[1, :, 0],
                                      np.asarray(x)[[2, 5, 7], 0])
        # dropped tokens contribute zero rows on combine
        y = D.combine(buf, state)
        dropped = ~keep
        assert (np.asarray(y)[dropped] == 0).all(), backend


def test_sort_backend_no_dense_onehot():
    """The sort path never materializes an (A, num_groups) intermediate."""
    t, groups, cap = 32, 8, 8
    gids = jnp.asarray(np.random.default_rng(0).integers(0, groups, t))
    jaxpr = jax.make_jaxpr(
        lambda g: D.sort_positions(g, jnp.ones((t,), bool), groups, cap))(gids)
    for eqn in jaxpr.jaxpr.eqns:
        for v in eqn.outvars:
            assert getattr(v.aval, "shape", ()) != (t, groups)


# --------------------------------------------------------------- the kernels
@pytest.mark.parametrize("T,d,R", [(32, 128, 64), (40, 64, 48), (8, 256, 96)])
def test_dispatch_gather_kernel_matches_ref(T, d, R):
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((T, d)), jnp.float32)
    src = jnp.asarray(rng.integers(-1, T, R), jnp.int32)
    got = dispatch_gather_pallas(x, src, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.dispatch_gather_ref(x, src)))


@pytest.mark.parametrize("t,k,d,R", [(16, 1, 128, 64), (24, 3, 64, 48)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_combine_gather_kernel_matches_ref(t, k, d, R, dtype):
    rng = np.random.default_rng(2)
    rows = jnp.asarray(rng.standard_normal((R, d)), jnp.float32).astype(dtype)
    src = jnp.asarray(rng.integers(-1, R, (t, k)), jnp.int32)
    scale = jnp.asarray(rng.uniform(0, 1, (t, k)), jnp.float32)
    got = combine_gather_pallas(rows, src, scale, interpret=True)
    want = ref.combine_gather_ref(rows, src, scale)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_ops_wrappers_tiny_shape_fallback():
    """ops.* must route tiny/misaligned shapes to the oracle, not Pallas."""
    x = jnp.ones((4, 7), jnp.float32)            # d % 8 != 0
    src = jnp.asarray([0, -1, 2, 3], jnp.int32)
    np.testing.assert_array_equal(np.asarray(kops.dispatch_gather(x, src)),
                                  np.asarray(ref.dispatch_gather_ref(x, src)))
    rows = jnp.ones((4, 7), jnp.float32)
    src2 = jnp.asarray([[0], [-1], [2], [3]], jnp.int32)
    sc = jnp.full((4, 1), 0.5, jnp.float32)
    np.testing.assert_allclose(
        np.asarray(kops.combine_gather(rows, src2, sc)),
        np.asarray(ref.combine_gather_ref(rows, src2, sc)))


# ------------------------------------------------------- full-layer coverage
@pytest.mark.parametrize("router", ["switch", "smile"])
@pytest.mark.parametrize("grid,E,k,g,cf", [
    ((4, 4), 16, 1, 1, 8.0),     # ample capacity, top-1 (the paper)
    ((4, 4), 8, 2, 1, 8.0),      # replication r=2
    ((4, 4), 32, 8, 4, 8.0),     # h=2, bi-level top-(4x2): both levels busy
    ((4, 4), 16, 2, 2, 0.5),     # overflow: drops on BOTH smile levels
])
def test_layer_backend_equivalence(router, grid, E, k, g, cf, rng_key):
    cfg = MoEConfig(num_experts=E, top_k=k, top_g=g, d_ff_expert=64,
                    capacity_factor=cf, router=router, grid=grid,
                    renorm_gates=(k > 1), dispatch_backend="dense")
    params = M.init_moe_params(rng_key, cfg, 32, PLAN, glu=True)
    x = jax.random.normal(jax.random.PRNGKey(1), (96, 32))
    y_d, s_d = M.moe_layer(params, x, cfg, PLAN, act="silu")
    cfg_s = dataclasses.replace(cfg, dispatch_backend="sort")
    y_s, s_s = M.moe_layer(params, x, cfg_s, PLAN, act="silu")
    np.testing.assert_allclose(np.asarray(y_d), np.asarray(y_s),
                               rtol=1e-5, atol=1e-6)
    assert float(s_d.drop_frac) == pytest.approx(float(s_s.drop_frac),
                                                 abs=1e-9)
    assert float(s_d.lb_loss) == pytest.approx(float(s_s.lb_loss), rel=1e-6)
    if cf < 1.0:
        assert float(s_s.drop_frac) > 0.0       # overflow actually exercised


@pytest.mark.parametrize("router", ["switch", "smile"])
def test_layer_sort_kernel_path(router, rng_key):
    """sort backend through the fused Pallas kernels (interpret on CPU)."""
    cfg = MoEConfig(num_experts=16, top_k=2, top_g=2, d_ff_expert=64,
                    capacity_factor=2.0, router=router, grid=(4, 4),
                    renorm_gates=True, dispatch_backend="sort")
    params = M.init_moe_params(rng_key, cfg, 32, PLAN, glu=True)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
    y_ref, _ = M.moe_layer(params, x, cfg, PLAN, act="silu", use_kernel=False)
    y_ker, _ = M.moe_layer(params, x, cfg, PLAN, act="silu", use_kernel=True)
    a = np.asarray(y_ref, np.float32)
    b = np.asarray(y_ker, np.float32)
    rel = np.abs(a - b).max() / (np.abs(a).max() + 1e-9)
    assert rel < 3e-2, rel


def test_unknown_backend_raises():
    x = jnp.ones((4, 8))
    gids = jnp.zeros((4,), jnp.int32)
    with pytest.raises(ValueError, match="unknown dispatch backend"):
        D.dispatch(x, gids, jnp.ones((4,)), 2, 2, backend="magic")
